"""Beyond-paper: the paper's ANN index applied to the two-tower assigned
architecture's retrieval_cand shape — tree-ANN vs exact dense scoring.

Quality metric: recall@10 of the ANN top-10 against the exact top-10;
cost metric: distance pairs computed vs the dense N_cand count."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit


def run(layout: str = "point_major", probes_sweep=(1, 3)):
    out = []
    from repro.core.index_build import build_index
    from repro.core.search import batch_search
    from repro.core.tree import build_tree
    from repro.distributed.meshutil import local_mesh
    from repro.models import recsys
    from repro.models.module import init_params

    from repro.data.batches import twotower_batch
    from repro.train import AdamWConfig, make_train_step
    from repro.train.step import init_train_state

    mesh = local_mesh()
    cfg = recsys.TwoTowerConfig(
        name="tt-ann", vocab_per_field=5000, field_dim=16,
        tower_mlp=(64, 32), embed_dim=32,
    )
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    # train briefly: untrained towers give near-uniform points on the
    # sphere, which no partitioning index (the paper's included) can help
    state = init_train_state(params)
    step = jax.jit(make_train_step(
        lambda p, b: recsys.twotower_loss(p, cfg, b), AdamWConfig(lr=3e-3)
    ))
    for i in range(60):
        b = jax.tree.map(jnp.asarray, twotower_batch(256, 4, 4, 5000, seed=i))
        params, state, _ = step(params, state, b)
    n_cand = 60_000
    rng = np.random.default_rng(1)
    cand_ids = jnp.asarray(rng.integers(0, 5000, (n_cand, 4), dtype=np.int32))
    cand_ids = cand_ids.at[:, 0].set(
        (jnp.asarray(rng.integers(0, 5000, n_cand, dtype=np.int32)) * 7919 + 13)
        % 5000
    )
    user_ids = jnp.asarray(rng.integers(0, 5000, (16, 4), dtype=np.int32))

    cand_emb = jax.jit(lambda p, i: recsys.tower(p, cfg, "item", i))(
        params, cand_ids
    )
    user_emb = jax.jit(lambda p, i: recsys.tower(p, cfg, "user", i))(
        params, user_ids
    )

    # exact dense scoring (the retrieval_cand baseline cell)
    def dense(u):
        return jax.lax.top_k(cand_emb @ u, 10)

    t_dense = timeit(lambda: jax.vmap(dense)(user_emb), warmup=1, iters=3)
    exact_idx = np.array(jax.vmap(dense)(user_emb)[1])
    out.append(row("ann_dense_exact", t_dense, f"pairs={16 * n_cand}"))

    # paper's index over the candidate embeddings (max-IP via L2 on
    # normalised vectors: both towers L2-normalise, so argmax dot ==
    # argmin L2); Lloyd-refined tree (beyond-paper quality knob)
    tree = build_tree(cand_emb, (8, 8), key=jax.random.PRNGKey(2),
                      refine_iters=2)
    index = build_index(cand_emb, tree, mesh, wire_dtype=jnp.float32)
    # multi-probe recall/cost sweep: every extra probed leaf buys recall at
    # a near-linear pairs cost (docs/engine.md)
    for probes in probes_sweep:
        res = batch_search(index, tree, user_emb, k=10, mesh=mesh,
                           q_cap=4096, layout=layout, probes=probes)
        t_ann = timeit(
            lambda p=probes: batch_search(index, tree, user_emb, k=10,
                                          mesh=mesh, q_cap=4096,
                                          layout=layout, probes=p),
            warmup=1, iters=3,
        )
        ann_idx = np.array(res.ids)
        recall = np.mean([
            len(set(ann_idx[i][ann_idx[i] >= 0]) & set(exact_idx[i])) / 10
            for i in range(16)
        ])
        name = "ann_tree_index" if probes == 1 else f"ann_tree_index_T{probes}"
        out.append(
            row(
                name, t_ann,
                f"recall@10={recall:.3f} pairs={float(res.pairs):.3g} "
                f"({float(res.pairs) / (16 * n_cand):.4f} of dense) "
                f"layout={layout} probes={probes}",
            )
        )
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--layout", choices=("point_major", "query_routed", "auto"),
        default="point_major",
    )
    ap.add_argument("--probes", type=int, nargs="+", default=[1, 3])
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for r in run(layout=args.layout, probes_sweep=tuple(args.probes)):
        print(r)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
