"""Paper Table 7 + Figs 6/8: most profitable block size.

HDFS block size -> ``block_rows`` (points per search wave) and ``q_cap``
(lookup slab budget). Bigger blocks amortise the slab re-read; smaller
blocks tighten the leaf span each tile must cover (less wasted masking) —
the paper's exact trade-off, three decks down the memory hierarchy."""

from __future__ import annotations

from benchmarks.common import Corpus, row, timeit


def run():
    out = []
    from repro.core.search import batch_search

    c = Corpus()
    for q_n, tag in ((2048, "copydays"), (8192, "12k")):
        q, _ = c.queries(q_n)
        for block_rows in (256, 512, 1024, 2048):
            t = timeit(
                lambda br=block_rows: batch_search(
                    c.index, c.tree, q, k=10, mesh=c.mesh,
                    block_rows=br, q_cap=1024,
                ),
                warmup=1, iters=3,
            )
            res = batch_search(c.index, c.tree, q, k=10, mesh=c.mesh,
                               block_rows=block_rows, q_cap=1024)
            out.append(
                row(
                    f"t7_{tag}_block{block_rows}", t,
                    f"pairs={float(res.pairs):.3g} "
                    f"overflow={int(res.q_cap_overflow)}",
                )
            )
    return out
