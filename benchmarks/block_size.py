"""Paper Table 7 + Figs 6/8: most profitable block size.

HDFS block size -> ``block_rows`` (points per search wave) and ``q_cap``
(lookup slab budget). Bigger blocks amortise the slab re-read; smaller
blocks tighten the leaf span each tile must cover (less wasted masking) —
the paper's exact trade-off, three decks down the memory hierarchy.

Beyond the paper, the same sweep drives the fused fast path's autotuner:
:func:`tune` times ``impl="fused"`` at each block size and persists the
winner per ``(layout, dim, dtype)`` via
``CalibrationStore.record_tile_config`` — into an index's manifest
calibration blob when ``index=`` is given — which ``plan()`` then
consults when budgeting a fused candidate (docs/kernels.md). ``run()``
writes the whole study to ``benchmarks/out/block_size.json``.
"""

from __future__ import annotations

import os

from benchmarks.common import (
    Corpus,
    bench_header,
    row,
    timeit,
    write_artifact,
)

BLOCK_SIZES = (256, 512, 1024, 2048)


def _sweep(c, q, *, impl, block_sizes=BLOCK_SIZES, k=10, q_cap=1024):
    """Time one eager batch_search per block size at a pinned slab."""
    from repro.core.search import batch_search

    entries = []
    for br in block_sizes:
        t = timeit(
            lambda br=br: batch_search(
                c.index, c.tree, q, k=k, mesh=c.mesh, layout="point_major",
                impl=impl, block_rows=br, q_cap=q_cap,
            ),
            warmup=1, iters=3,
        )
        res = batch_search(c.index, c.tree, q, k=k, mesh=c.mesh,
                           layout="point_major", impl=impl,
                           block_rows=br, q_cap=q_cap)
        entries.append({
            "block_rows": br, "impl": impl, "ms": t * 1e3,
            "pairs": float(res.pairs),
            "overflow": int(res.q_cap_overflow),
        })
    return entries


def tune(store=None, *, index=None, corpus=None, q_n=2048, k=10,
         block_sizes=BLOCK_SIZES, layout="point_major"):
    """Sweep fused block sizes and persist the winning tile config.

    With ``index`` (a lifecycle ``repro.index.Index``), each block size
    times ``index.search(impl="fused")`` over queries drawn from the
    index's own rows, and the winner lands in ``index.calibration`` +
    ``commit()`` — the manifest calibration blob a serving process
    reloads. Otherwise the benchmark :class:`Corpus` is swept and the
    winner lands in ``store`` (default: the process-wide calibration
    store), keyed ``(layout, dim, dtype)``. Returns ``(entries,
    winner)``.
    """
    from repro.core.engine import default_calibration

    if index is not None:
        import numpy as np

        from benchmarks.serving import _index_queries

        q_np = np.asarray(_index_queries(index, q_n))
        target = index.calibration
        entries = []
        for br in block_sizes:
            t = timeit(
                lambda br=br: index.search(
                    q_np, k=k, layout=layout, impl="fused", block_rows=br,
                ),
                warmup=1, iters=3,
            )
            entries.append({"block_rows": br, "impl": "fused", "ms": t * 1e3})
        dim = int(index.dim)
        rows = sum(int(v.rows) for v in index.segment_views())
    else:
        c = corpus or Corpus()
        q, _ = c.queries(q_n)
        target = store if store is not None else default_calibration()
        entries = _sweep(c, q, impl="fused", block_sizes=block_sizes)
        dim, rows = int(c.dim), int(c.index.rows)
    best = min(entries, key=lambda e: e["ms"])
    target.record_tile_config(layout, dim, "float32",
                              best["block_rows"], best["ms"])
    if index is not None:
        index.commit()
    winner = {
        "layout": layout, "dim": dim, "dtype": "float32", "rows": rows,
        "block_rows": best["block_rows"], "ms": best["ms"],
    }
    return entries, winner


def run():
    out = []
    c = Corpus()
    payload = {"sweeps": []}
    for q_n, tag in ((2048, "copydays"), (8192, "12k")):
        q, _ = c.queries(q_n)
        for impl in ("xla", "fused"):
            entries = _sweep(c, q, impl=impl)
            payload["sweeps"].append({
                "queries": q_n, "tag": tag, "impl": impl, "entries": entries,
            })
            prefix = f"t7_{tag}_" + ("" if impl == "xla" else "fused_")
            for e in entries:
                out.append(row(
                    f"{prefix}block{e['block_rows']}", e["ms"] / 1e3,
                    f"pairs={e['pairs']:.3g} overflow={e['overflow']}",
                ))
    entries, winner = tune(corpus=c)
    payload["tuned"] = {"entries": entries, "winner": winner}
    payload["header"] = bench_header(tuned_impl="fused")
    out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
    path = write_artifact(os.path.join(out_dir, "block_size.json"), payload)
    out.append(row(
        "block_size_json", 0.0,
        f"wrote={path} winner_block_rows={winner['block_rows']}",
    ))
    return out
