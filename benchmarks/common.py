"""Shared benchmark utilities: timing, corpus setup, CSV rows, and the
JSON artifact header (git rev + shard plan) that makes ``benchmarks/out``
trajectories comparable across PRs."""

from __future__ import annotations

import json
import os
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np


def git_rev() -> str:
    """Short git revision of the repo this benchmark ran from (or
    ``"unknown"`` outside a checkout)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        return out.stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def bench_header(shard_plan=None, **extra) -> dict:
    """Header stamped on every ``benchmarks/out`` JSON artifact.

    Records the git rev, the shard plan under which the numbers were
    taken (``None`` = unsharded), and the observability state (``obs``:
    tracer enabled/sample/span counts — so a trajectory point taken with
    tracing on is distinguishable), keeping ms/image trajectories
    comparable across PRs and shard topologies.
    """
    from repro.obs import get_tracer

    h = {"git_rev": git_rev(), "shard_plan": shard_plan,
         "obs": get_tracer().describe()}
    h.update(extra)
    return h


def layout_bytes(index) -> dict:
    """Per-layout resident bytes/row for artifact headers: the dense
    layouts hold f32 rows; ``scan_codes`` holds PQ codes (plus the shared
    codebook, amortised across the whole index). ``compression_ratio`` is
    dense/codes — 1.0 when the index carries no codes artifact."""
    raw = 4 * int(index.dim)
    per = {"point_major": raw, "query_routed": raw}
    cs = index.codes_stats() if hasattr(index, "codes_stats") else None
    if cs:
        per["scan_codes"] = cs["bytes_per_row"]
        return {"bytes_per_row": per,
                "compression_ratio": cs["compression_ratio"],
                "codebook_bytes": cs["codebook_bytes"]}
    return {"bytes_per_row": per, "compression_ratio": 1.0}


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall-clock seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


class Corpus:
    """Small SIFT-like corpus + tree + index shared across benchmarks."""

    _cache = {}

    def __new__(cls, rows=120_000, dim=64, fanouts=(32, 32), seed=0):
        key = (rows, dim, fanouts, seed)
        if key in cls._cache:
            return cls._cache[key]
        self = super().__new__(cls)
        from repro.core.index_build import build_index
        from repro.core.tree import build_tree
        from repro.data import synth
        from repro.distributed.meshutil import local_mesh

        self.mesh = local_mesh()
        self.dim = dim
        self.vecs_np, self.components = synth.sample_descriptors(
            rows, dim, seed=seed, n_centers=512
        )
        self.vecs = jnp.asarray(self.vecs_np)
        self.tree = build_tree(self.vecs, fanouts, key=jax.random.PRNGKey(1))
        self.index = build_index(self.vecs, self.tree, self.mesh)
        cls._cache[key] = self
        return self

    def queries(self, n, noise=4.0, seed=2):
        rng = np.random.default_rng(seed)
        rows = rng.choice(len(self.vecs_np), n, replace=False)
        q = self.vecs_np[rows] + rng.standard_normal((n, self.dim)).astype(
            np.float32
        ) * noise
        return jnp.asarray(np.clip(q, 0, 255)), rows


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def write_artifact(path: str, payload: dict) -> str:
    """Write one ``benchmarks/out`` JSON artifact (dirs created,
    indent=1 — the one place the on-disk format lives)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def fit_payload(calibration, committed_version: int) -> dict:
    """The shared skeleton of a ``--calibrate`` JSON artifact: header
    (stamped ``cost_model="fitted"``), the fit form quoted from its
    single source (``engine.FIT_FORM``), the fitted per-layout
    coefficients, and the store/commit provenance."""
    from repro.core.engine import FIT_FORM, FittedModel

    fitted = FittedModel(calibration)
    return {
        "header": bench_header(cost_model="fitted"),
        "fit_form": FIT_FORM,
        "coefficients": fitted.coefficients_json(),
        "n_records": len(calibration),
        "n_measurements": calibration.n_measurements(),
        "committed_version": committed_version,
    }
