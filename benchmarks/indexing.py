"""Paper Tables 3/4 + Fig 1: indexing time, default vs tuned pipeline.

Hadoop tuning (map slots, output compression, sort buffers) maps onto our
pipeline knobs: wire dtype (map-output compression), wave size (chunk
size / JVM reuse), routing capacity factor (spill headroom). 'Default'
mimics the paper's untuned run; 'tuned' applies every lesson.

Beyond the one-shot tables, this module also owns the *incremental* side
of the lifecycle API (``python -m benchmarks.indexing --incremental``):
per-segment ``Index.append``+``commit`` throughput (rows/s) recorded to
JSON, plus the lifecycle smoke (``--smoke``) gating every PR: create →
append ×2 → search → compact → search must return identical neighbours."""

from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import jax.numpy as jnp

from benchmarks.common import (
    Corpus,
    bench_header,
    fit_payload,
    row,
    timeit,
    write_artifact,
)


def run():
    out = []
    from repro.core.index_build import build_index

    c = Corpus()
    variants = {
        # analog of Table 4's default column
        "default": dict(wire_dtype=jnp.float32, capacity_factor=4.0,
                        wave_rows=256),
        # tuned: compressed wire, right-sized capacity, bigger waves
        "tuned": dict(wire_dtype=jnp.bfloat16, capacity_factor=2.0,
                      wave_rows=2048),
    }
    base = None
    for name, kw in variants.items():
        t = timeit(
            lambda kw=kw: build_index(c.vecs, c.tree, c.mesh, **kw),
            warmup=1, iters=3,
        )
        base = base or t
        out.append(
            row(
                f"t3_indexing_{name}", t,
                f"speedup_vs_default={base / t:.2f}x (paper: 202->174.7 min)",
            )
        )
    # per-knob ablation (Table 4 row-wise)
    for knob, kw in {
        "wire_bf16_only": dict(wire_dtype=jnp.bfloat16, capacity_factor=4.0,
                               wave_rows=256),
        "wave_2048_only": dict(wire_dtype=jnp.float32, capacity_factor=4.0,
                               wave_rows=2048),
        "capacity_2_only": dict(wire_dtype=jnp.float32, capacity_factor=2.0,
                                wave_rows=256),
    }.items():
        t = timeit(lambda kw=kw: build_index(c.vecs, c.tree, c.mesh, **kw),
                   warmup=1, iters=3)
        out.append(row(f"t4_{knob}", t, f"vs_default={base / t:.2f}x"))
    return out


def run_incremental(
    *,
    segments: int = 4,
    rows_per_segment: int = 30_000,
    dim: int = 64,
    fanouts: tuple = (32, 32),
    json_path: str | None = None,
    seed: int = 0,
) -> dict:
    """Incremental-append throughput: rows/s per committed segment.

    The paper's collection grows between runs; this measures the cost of
    growing ours — each round is one ``Index.append`` + ``commit`` into a
    durable directory, timed end-to-end (build, segment checkpoint write,
    manifest bump), plus a search over the accumulated segments.
    """
    import numpy as np

    from repro.data.store import VirtualStore
    from repro.index import Index
    from repro.core.tree import build_tree
    from repro.distributed.meshutil import local_mesh
    import jax

    from repro.core.engine import resolve_model

    mesh = local_mesh()
    store = VirtualStore(
        segments * rows_per_segment, dim, block_rows=rows_per_segment,
        seed=seed,
    )
    tree = build_tree(
        jnp.asarray(store.sample_for_tree(min(65_536, store.n_rows))),
        tuple(fanouts), key=jax.random.PRNGKey(seed),
    )
    payload = {"segments": [],
               "rows_per_segment": rows_per_segment,
               "dim": dim, "n_segments": segments}
    with tempfile.TemporaryDirectory() as d:
        idx = Index.create(tree, d, mesh=mesh)
        for b in range(segments):
            blk = store.read_block(b)
            t0 = time.perf_counter()
            name = idx.append(blk.vecs, ids=blk.ids)
            idx.commit()
            dt = time.perf_counter() - t0
            payload["segments"].append({
                "name": name,
                "rows": int(blk.vecs.shape[0]),
                "seconds": dt,
                "rows_per_s": blk.vecs.shape[0] / dt,
                "total_rows": idx.rows,
            })
        q = store.read_rows(
            np.arange(0, store.n_rows, max(1, store.n_rows // 256))
        )
        t0 = time.perf_counter()
        res = idx.search(q, k=10)
        jax.block_until_ready(res.ids)
        payload["search_s_over_all_segments"] = time.perf_counter() - t0
        payload["header"] = bench_header(
            cost_model=resolve_model("auto", idx.calibration).describe()
        )
    if json_path:
        write_artifact(json_path, payload)
        print(f"# incremental indexing JSON -> {json_path}", file=sys.stderr)
    return payload


def run_calibrate(
    *,
    steps: int = 3,
    rows_per_step: int = 20_000,
    dim: int = 32,
    fanouts: tuple = (16, 16),
    batch_rows: int = 256,
    rounds: int = 2,
    desc_per_image: int = 24,
    json_path: str | None = None,
    seed: int = 0,
) -> dict:
    """Calibrate the *rows* axis of the fitted cost model across index
    growth.

    The serving sweep (``benchmarks.serving --calibrate``) varies batch
    size at fixed corpus; this varies corpus size: each step appends a
    progressively larger segment (``rows_per_step * step``), commits, and
    measures ms/image through a pinned-layout warmed session at the grown
    shape — so the fit learns how cost scales with ``rows_scanned``. The
    observations and the manifest travel together (``commit``), and the
    fitted coefficients land in ``indexing_calibration.json``.
    """
    import numpy as np
    import jax

    from repro.core.tree import build_tree
    from repro.data.store import VirtualStore
    from repro.distributed.meshutil import local_mesh
    from repro.index import Index
    from repro.serving import SearchSession

    mesh = local_mesh()
    total = rows_per_step * steps * (steps + 1) // 2
    store = VirtualStore(total, dim, block_rows=rows_per_step, seed=seed)
    tree = build_tree(
        jnp.asarray(store.sample_for_tree(min(65_536, store.n_rows))),
        tuple(fanouts), key=jax.random.PRNGKey(seed),
    )
    rng = np.random.default_rng(seed + 1)
    q = store.read_rows(
        np.arange(0, rows_per_step, max(1, rows_per_step // batch_rows))
    )[:batch_rows]
    q = q + rng.standard_normal(q.shape).astype(np.float32)
    payload = {"steps": [], "rows_per_step": rows_per_step, "dim": dim}
    with tempfile.TemporaryDirectory() as d:
        idx = Index.create(tree, d, mesh=mesh)
        block = 0
        for step in range(1, steps + 1):
            vecs = np.concatenate(
                [store.read_block(block + i).vecs for i in range(step)]
            )
            block += step
            idx.append(vecs)
            idx.commit()
            entry = {"rows": int(idx.rows), "segments": idx.n_segments}
            for layout in ("point_major", "query_routed"):
                s = SearchSession(idx, k=10, layout=layout,
                                  buckets=(batch_rows,),
                                  cost_model="heuristic")
                s.warmup()
                for _ in range(rounds):
                    s.search(q, n_images=max(1, batch_rows // desc_per_image))
                entry[f"ms_per_image_{layout}"] = s.metrics.ms_per_image
            payload["steps"].append(entry)
        version = idx.commit()
        payload.update(fit_payload(idx.calibration, version))
    if json_path:
        write_artifact(json_path, payload)
        print(f"# indexing calibration JSON -> {json_path}", file=sys.stderr)
    return payload


def lifecycle_smoke() -> int:
    """Per-PR gate: create → append ×2 → search → compact → search must be
    exact — identical neighbour ids *and* distances before and after
    compaction, and identical to a one-shot build of the same rows."""
    import jax
    import numpy as np

    from repro.core.index_build import build_index
    from repro.core.search import batch_search
    from repro.core.tree import build_tree
    from repro.data import synth
    from repro.distributed.meshutil import local_mesh
    from repro.index import Index

    mesh = local_mesh()
    vecs, _ = synth.sample_descriptors(12_000, 32, seed=0, n_centers=128)
    tree = build_tree(jnp.asarray(vecs), (16, 16), key=jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    q = vecs[:128] + rng.standard_normal((128, 32)).astype(np.float32)

    with tempfile.TemporaryDirectory() as d:
        idx = Index.create(tree, d, mesh=mesh)
        idx.append(vecs[:7_000])
        idx.append(vecs[7_000:])
        idx.commit()
        assert idx.n_segments == 2 and idx.rows == 12_000, idx.stats()
        a = idx.search(q, k=5, layout="point_major", q_cap=1024)
        assert int(a.q_cap_overflow) == 0
        one = build_index(jnp.asarray(vecs), tree, mesh,
                          wire_dtype=jnp.float32)
        ref = batch_search(one, tree, jnp.asarray(q), k=5, mesh=mesh,
                           layout="point_major", q_cap=1024)
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(ref.ids))
        idx.compact()
        assert idx.n_segments == 1, idx.stats()
        b = idx.search(q, k=5, layout="point_major", q_cap=1024)
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(np.asarray(a.dists),
                                      np.asarray(b.dists))
        reopened = Index.open(d, mesh=mesh)
        c = reopened.search(q, k=5, layout="point_major", q_cap=1024)
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(c.ids))
    print(
        "# lifecycle smoke: append x2 == one-shot == compacted == reopened "
        "(128 queries, k=5)", file=sys.stderr,
    )
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the index-lifecycle smoke gate")
    ap.add_argument("--incremental", action="store_true",
                    help="incremental-append throughput mode")
    ap.add_argument("--calibrate", action="store_true",
                    help="grow an index step by step, measure ms/image at "
                         "each size, and commit + fit the cost model -> "
                         "indexing_calibration.json")
    ap.add_argument("--segments", type=int, default=4)
    ap.add_argument("--rows-per-segment", type=int, default=30_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--json", default=None,
                    help="JSON output path (incremental mode; default "
                    "benchmarks/out/indexing_incremental.json)")
    args = ap.parse_args(argv)
    if args.smoke:
        return lifecycle_smoke()
    if args.calibrate:
        out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
        payload = run_calibrate(
            steps=args.segments,
            rows_per_step=args.rows_per_segment,
            dim=args.dim,
            json_path=args.json or os.path.join(
                out_dir, "indexing_calibration.json"
            ),
        )
        print("name,us_per_call,derived")
        for s in payload["steps"]:
            print(row(
                f"calibrate_rows_{s['rows']}",
                s["ms_per_image_point_major"] / 1e3,
                f"qr_ms_per_image={s['ms_per_image_query_routed']:.2f}",
            ))
        return 0
    if args.incremental:
        out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
        payload = run_incremental(
            segments=args.segments, rows_per_segment=args.rows_per_segment,
            dim=args.dim,
            json_path=args.json or os.path.join(
                out_dir, "indexing_incremental.json"
            ),
        )
        for s in payload["segments"]:
            print(row(f"incremental_{s['name']}", s["seconds"],
                      f"rows_per_s={s['rows_per_s']:.0f}"))
        return 0
    print("name,us_per_call,derived")
    for r in run():
        print(r)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
