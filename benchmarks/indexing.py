"""Paper Tables 3/4 + Fig 1: indexing time, default vs tuned pipeline.

Hadoop tuning (map slots, output compression, sort buffers) maps onto our
pipeline knobs: wire dtype (map-output compression), wave size (chunk
size / JVM reuse), routing capacity factor (spill headroom). 'Default'
mimics the paper's untuned run; 'tuned' applies every lesson."""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import Corpus, row, timeit


def run():
    out = []
    from repro.core.index_build import build_index

    c = Corpus()
    variants = {
        # analog of Table 4's default column
        "default": dict(wire_dtype=jnp.float32, capacity_factor=4.0,
                        wave_rows=256),
        # tuned: compressed wire, right-sized capacity, bigger waves
        "tuned": dict(wire_dtype=jnp.bfloat16, capacity_factor=2.0,
                      wave_rows=2048),
    }
    base = None
    for name, kw in variants.items():
        t = timeit(
            lambda kw=kw: build_index(c.vecs, c.tree, c.mesh, **kw),
            warmup=1, iters=3,
        )
        base = base or t
        out.append(
            row(
                f"t3_indexing_{name}", t,
                f"speedup_vs_default={base / t:.2f}x (paper: 202->174.7 min)",
            )
        )
    # per-knob ablation (Table 4 row-wise)
    for knob, kw in {
        "wire_bf16_only": dict(wire_dtype=jnp.bfloat16, capacity_factor=4.0,
                               wave_rows=256),
        "wave_2048_only": dict(wire_dtype=jnp.float32, capacity_factor=4.0,
                               wave_rows=2048),
        "capacity_2_only": dict(wire_dtype=jnp.float32, capacity_factor=2.0,
                                wave_rows=256),
    }.items():
        t = timeit(lambda kw=kw: build_index(c.vecs, c.tree, c.mesh, **kw),
                   warmup=1, iters=3)
        out.append(row(f"t4_{knob}", t, f"vs_default={base / t:.2f}x"))
    return out
