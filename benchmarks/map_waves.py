"""Paper Table 5 + Figs 2/3/6: map-wave statistics, stragglers, failures,
and reduce-side balance."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row


def run():
    out = []
    from repro.core.index_build import build_index
    from repro.core.tree import build_tree, tree_assign
    from repro.data.store import VirtualStore
    from repro.distributed.failure import FailureInjector
    from repro.distributed.meshutil import local_mesh
    from repro.distributed.wavescheduler import WaveScheduler

    mesh = local_mesh()
    store = VirtualStore(160_000, 64, block_rows=16_000, seed=0, n_centers=512)
    tree = build_tree(
        jnp.asarray(store.sample_for_tree(32_768)), (32, 32),
        key=jnp.asarray([0, 1], jnp.uint32),
    )

    def wave_fn(b):
        blk = store.read_block(b)
        idx = build_index(
            jnp.asarray(blk.vecs), tree, mesh,
            ids=jnp.asarray(blk.ids.astype(np.int32)),
        )
        return int(idx.overflow)

    injector = FailureInjector(fail_at=[(2, 0), (6, 0)])
    sched = WaveScheduler(wave_fn, failure_injector=injector, max_retries=2)
    res = sched.run(range(store.n_blocks))
    ok = [r.duration_s for r in res.records if r.ok]
    failed = [r for r in res.records if not r.ok]
    out.append(row("t5_total_map_waves", sum(ok),
                   f"n={len(res.records)} (incl. {len(failed)} failed attempts)"))
    out.append(row("t5_avg_wave", float(np.mean(ok)),
                   f"min={min(ok):.3f}s max={max(ok):.3f}s"))
    out.append(row("t5_failed_reexecuted", sum(r.duration_s for r in failed),
                   f"failures={len(failed)} retried_ok=True"))
    out.append(row("fig2_stragglers", 0.0,
                   f"waves_over_2x_median={len(res.stragglers)}"))

    # Fig 3 analog: reduce-side balance = rows per shard after routing
    vecs = jnp.asarray(store.read_block(0).vecs)
    leaves = np.array(tree_assign(tree, vecs))
    counts = np.bincount(leaves % 8, minlength=8)  # 8 virtual reducers
    out.append(
        row(
            "fig3_reduce_balance", 0.0,
            f"max/mean={counts.max() / counts.mean():.3f} "
            f"(1.0 = perfectly balanced reducers)",
        )
    )
    return out
