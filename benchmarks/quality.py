"""Paper Fig 4: Copydays search quality vs distractor-set size.

Per-variant recall@1 of the original image, at two distractor scales —
the paper's claim: quality barely degrades 20M -> 100M (82.68% -> 82.16%)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row


def run():
    out = []
    from repro.core.index_build import build_index
    from repro.core.search import batch_search
    from repro.core.tree import build_tree
    from repro.data import synth
    from repro.data.copydays import VARIANTS, make_copydays, vote_images
    from repro.distributed.meshutil import local_mesh

    mesh = local_mesh()
    dim, n_images, dpi = 48, 600, 24
    vecs_np, img_ids = synth.sample_images(n_images, dpi, dim, seed=0)
    rng = np.random.default_rng(1)
    originals = rng.choice(n_images, 64, replace=False)
    rows = np.isin(img_ids, originals)
    cd = make_copydays(vecs_np[rows], img_ids[rows], seed=2)

    for scale, tag in ((1, "20M_analog"), (4, "100M_analog")):
        extra, _ = synth.sample_descriptors(
            (scale - 1) * len(vecs_np), dim, seed=7 + scale, n_centers=512
        )
        corpus = np.concatenate([vecs_np, extra]) if scale > 1 else vecs_np
        # distractor descriptors belong to their own (wrong) images
        extra_img = n_images + np.arange(len(extra)) // dpi
        db_img_ids = np.concatenate([img_ids, extra_img.astype(np.int32)])
        vecs = jnp.asarray(corpus)
        tree = build_tree(vecs, (24, 24), key=jax.random.PRNGKey(3))
        index = build_index(vecs, tree, mesh)
        res = batch_search(
            index, tree, jnp.asarray(cd.query_vecs), k=10, mesh=mesh,
            q_cap=2048,
        )
        per_variant, avg = vote_images(
            np.array(res.ids), db_img_ids, cd.query_img, cd.query_variant,
            len(VARIANTS),
        )
        for (name, _, _), r in zip(VARIANTS, per_variant):
            out.append(row(f"fig4_{tag}_{name}", 0.0, f"recall@1={r:.3f}"))
        out.append(row(f"fig4_{tag}_average", 0.0,
                       f"recall@1={avg:.3f} (paper ~0.82)"))
    return out
