"""Paper Fig 4: Copydays search quality vs distractor-set size.

Per-variant recall@1 of the original image, at two distractor scales —
the paper's claim: quality barely degrades 20M -> 100M (82.68% -> 82.16%).

Beyond-paper: :func:`codes_sweep` maps the compressed-codes tier's
quality/footprint frontier — recall@10 of the ADC scan + exact rerank vs
the scan-exact baseline, swept over rerank depth x code bits — into
``benchmarks/out/quality_codes.json`` (docs/compressed_codes.md)."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Corpus, bench_header, layout_bytes, row, \
    write_artifact


def run():
    out = []
    from repro.core.index_build import build_index
    from repro.core.search import batch_search
    from repro.core.tree import build_tree
    from repro.data import synth
    from repro.data.copydays import VARIANTS, make_copydays, vote_images
    from repro.distributed.meshutil import local_mesh

    mesh = local_mesh()
    dim, n_images, dpi = 48, 600, 24
    vecs_np, img_ids = synth.sample_images(n_images, dpi, dim, seed=0)
    rng = np.random.default_rng(1)
    originals = rng.choice(n_images, 64, replace=False)
    rows = np.isin(img_ids, originals)
    cd = make_copydays(vecs_np[rows], img_ids[rows], seed=2)

    for scale, tag in ((1, "20M_analog"), (4, "100M_analog")):
        extra, _ = synth.sample_descriptors(
            (scale - 1) * len(vecs_np), dim, seed=7 + scale, n_centers=512
        )
        corpus = np.concatenate([vecs_np, extra]) if scale > 1 else vecs_np
        # distractor descriptors belong to their own (wrong) images
        extra_img = n_images + np.arange(len(extra)) // dpi
        db_img_ids = np.concatenate([img_ids, extra_img.astype(np.int32)])
        vecs = jnp.asarray(corpus)
        tree = build_tree(vecs, (24, 24), key=jax.random.PRNGKey(3))
        index = build_index(vecs, tree, mesh)
        res = batch_search(
            index, tree, jnp.asarray(cd.query_vecs), k=10, mesh=mesh,
            q_cap=2048,
        )
        per_variant, avg = vote_images(
            np.array(res.ids), db_img_ids, cd.query_img, cd.query_variant,
            len(VARIANTS),
        )
        for (name, _, _), r in zip(VARIANTS, per_variant):
            out.append(row(f"fig4_{tag}_{name}", 0.0, f"recall@1={r:.3f}"))
        out.append(row(f"fig4_{tag}_average", 0.0,
                       f"recall@1={avg:.3f} (paper ~0.82)"))
    out.extend(codes_sweep())
    return out


def codes_sweep(
    *,
    code_bits=(4, 8),
    rerank_depths=(10, 40, 80, 128),
    k: int = 10,
    probes: int = 8,
    n_queries: int = 256,
    json_path: str | None = None,
):
    """Recall@k of the codes tier vs rerank depth x code bits.

    One index per bits setting (PQ retrained at m=8 subvectors), one
    ``scan_codes`` search per rerank depth, all scored against the
    scan-exact baseline over the same index at the same probe width —
    recall(codes vs exact) isolates the quantisation + candidate-depth
    loss from tree-routing loss. The JSON artifact carries the full
    frontier plus each setting's resident bytes/row, so the
    quality-per-byte tradeoff is one plot away."""
    from repro.index import Index

    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    q, _ = c.queries(n_queries)
    q = np.asarray(q)
    out, entries = [], []
    idx = None
    for bits in code_bits:
        idx = Index.create(c.tree, None, mesh=c.mesh)
        idx.append(c.vecs_np)
        idx.enable_codes(m=8, bits=int(bits))
        idx.commit()
        ref = np.asarray(
            idx.search(q, k=k, probes=probes, layout="point_major").ids
        )
        cs = idx.codes_stats()
        for depth in rerank_depths:
            res = idx.search(q, k=k, probes=probes, layout="scan_codes",
                             rerank=int(depth))
            ids = np.asarray(res.ids)
            recall = float(np.mean([
                len(set(ids[i][ids[i] >= 0])
                    & set(ref[i][ref[i] >= 0])) / k
                for i in range(len(q))
            ]))
            entries.append({
                "code_bits": int(bits), "code_m": cs["code_m"],
                "rerank": int(depth), "recall_at_k": recall, "k": k,
                "probes": probes,
                "bytes_per_row": cs["bytes_per_row"],
                "compression_ratio": cs["compression_ratio"],
            })
            out.append(row(
                f"quality_codes_b{bits}_r{depth}", 0.0,
                f"recall@{k}={recall:.3f} "
                f"bytes_per_row={cs['bytes_per_row']}",
            ))
    out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
    path = write_artifact(
        json_path or os.path.join(out_dir, "quality_codes.json"),
        {
            "header": bench_header(layout_bytes=layout_bytes(idx)),
            "baseline": "scan-exact (point_major) at the same probes",
            "sweep": entries,
        },
    )
    out.append(row("quality_codes_json", 0.0, f"wrote={path}"))
    return out
