# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure (see DESIGN.md §8).

  workflow_steps  — Table 2 (workflow step times)
  indexing        — Tables 3/4 + Fig 1 (default vs tuned indexing)
  map_waves       — Table 5 + Figs 2/3 (wave stats, failures, balance)
  block_size      — Table 7 + Figs 6/8 (block-size study)
  scalability     — Fig 5 + Table 6 (shard scaling, modelled 10->100)
  quality         — Fig 4 (Copydays recall vs distractors)
  throughput      — Exp #5 (ms/image vs batch size)
  ann_retrieval   — beyond-paper: tree-ANN on the two-tower arch
  serving         — beyond-paper: online serving (latency percentiles,
                    micro-batching, hot-leaf cache) + plan observations JSON

Usage: PYTHONPATH=src python -m benchmarks.run [module ...]
"""

from __future__ import annotations

import sys
import time

MODULES = [
    "workflow_steps",
    "indexing",
    "map_waves",
    "block_size",
    "scalability",
    "quality",
    "throughput",
    "ann_retrieval",
    "serving",
]


def smoke() -> int:
    """Tiny end-to-end serve runs on both layouts with multi-probe, the
    serving-session gate (2 warmed buckets, ~100 zipf requests, zero
    steady-state recompiles), the index-lifecycle gate (create →
    append ×2 → search → compact → search, identical results), the
    cost-model calibration round-trip gate, the sharded bit-identity
    gate, the SLO scheduling gate (fifo == edf results, EDF interactive
    p95 < batch p95), the compressed-codes gate (train → commit →
    reopen → auto plans scan_codes → ADC + rerank recall floor at ≥8x
    fewer resident bytes), the fused-kernel gate (fused == xla on a
    served trace, zero recompiles, ms/image within 1.5x), and the
    observability gate (traced ==
    untraced bit-identity, valid Chrome trace + registry dump +
    tracereport) —
    the per-PR gate wired into scripts/smoke.sh. Fails loudly,
    returns rc."""
    from benchmarks import indexing as indexing_bench
    from benchmarks import serving as serving_bench
    from repro.launch import serve

    base = [
        "--rows", "20000", "--dim", "32", "--images", "400",
        "--fanout", "16", "16", "--batches", "1", "--batch-images", "32",
        "--probes", "2",
    ]
    for layout in ("point_major", "query_routed"):
        print(f"# smoke: serve --layout {layout} --probes 2", file=sys.stderr)
        rc = serve.main(base + ["--layout", layout])
        if rc != 0:
            return rc
    print("# smoke: index lifecycle (append x2 / compact exactness)",
          file=sys.stderr)
    rc = indexing_bench.lifecycle_smoke()
    if rc != 0:
        return rc
    print("# smoke: serving session (2 buckets, zipf trace)", file=sys.stderr)
    rc = serving_bench.smoke()
    if rc != 0:
        return rc
    print("# smoke: calibration round-trip (record -> commit -> reopen -> "
          "fitted plan)", file=sys.stderr)
    rc = serving_bench.calibration_smoke()
    if rc != 0:
        return rc
    print("# smoke: sharded scatter-gather (bit-identity at shards 1/2/3)",
          file=sys.stderr)
    rc = serving_bench.sharded_smoke()
    if rc != 0:
        return rc
    print("# smoke: SLO scheduling (fifo == edf results, EDF interactive "
          "p95 < batch p95)", file=sys.stderr)
    rc = serving_bench.slo_smoke()
    if rc != 0:
        return rc
    print("# smoke: compressed codes (train -> commit -> reopen -> auto "
          "plans scan_codes -> ADC + rerank recall floor)", file=sys.stderr)
    rc = serving_bench.codes_smoke()
    if rc != 0:
        return rc
    print("# smoke: fused kernel (fused == xla on a served trace, "
          "0 recompiles, ms/image within 1.5x)", file=sys.stderr)
    rc = serving_bench.kernel_smoke()
    if rc != 0:
        return rc
    print("# smoke: dynamicity (serve while a writer appends + "
          "incrementally compacts: 0 drops, 0 recompiles, bounded p95, "
          "final == fresh open)", file=sys.stderr)
    rc = serving_bench.dynamicity_smoke()
    if rc != 0:
        return rc
    print("# smoke: observability (traced == untraced bit-identity, "
          "Chrome trace, registry, tracereport)", file=sys.stderr)
    return serving_bench.obs_smoke()


def main() -> None:
    import importlib

    if "--smoke" in sys.argv[1:]:
        raise SystemExit(smoke())
    names = sys.argv[1:] or MODULES
    print("name,us_per_call,derived")
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.perf_counter()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"{name}_FAILED,0,{e!r}")
            continue
        for r in rows:
            print(r)
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
