"""Paper Fig 5 + Table 6: batch-search scalability with cluster size.

Two parts:
 1. measured: wall time vs shard count on this host (SPMD partitioning
    overhead only — one physical core, so no real speedup is possible);
 2. modelled: the roofline terms from the dry-run give T(N) = max(compute/N,
    memory/N, collective(N)); we report the projected 10 -> 100 chip
    speedup for the search cell next to the paper's measured 7.2x.
"""

from __future__ import annotations

import subprocess
import sys

from benchmarks.common import row

_CHILD = r"""
import os, sys, time
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import jax, jax.numpy as jnp
from repro.core.index_build import build_index
from repro.core.search import batch_search
from repro.core.tree import build_tree
from repro.data import synth
from repro.distributed.meshutil import local_mesh
mesh = local_mesh()
vecs_np, _ = synth.sample_descriptors(60000, 32, seed=0, n_centers=256)
vecs = jnp.asarray(vecs_np)
tree = build_tree(vecs, (16, 16), key=jax.random.PRNGKey(1))
index = build_index(vecs, tree, mesh)
q = vecs[:2048]
r = batch_search(index, tree, q, k=5, mesh=mesh, q_cap=1024)  # compile
jax.block_until_ready(r.ids)
t0 = time.perf_counter()
for _ in range(3):
    r = batch_search(index, tree, q, k=5, mesh=mesh, q_cap=1024)
    jax.block_until_ready(r.ids)
print((time.perf_counter() - t0) / 3)
"""


def run():
    out = []
    base = None
    for n in (1, 2, 4, 8):
        p = subprocess.run(
            [sys.executable, "-c", _CHILD, str(n)],
            capture_output=True, text=True, env=None,
            cwd=".", timeout=600,
        )
        if p.returncode != 0:
            out.append(row(f"fig5_shards_{n}", 0.0, "FAILED"))
            continue
        t = float(p.stdout.strip().splitlines()[-1])
        base = base or t
        out.append(
            row(
                f"fig5_shards_{n}", t,
                f"rel={base / t:.2f}x (1 physical core: partitioning "
                f"overhead only)",
            )
        )
    # modelled speedup from the dry-run roofline (see EXPERIMENTS.md §Roofline)
    import json
    import os

    if os.path.exists("dryrun_results.jsonl"):
        recs = [json.loads(l) for l in open("dryrun_results.jsonl")]
        for r in recs:
            if (r["arch"], r["shape"], r["mesh"], r.get("status")) == (
                "sift100m", "search_1m", "16x16", "ok",
            ):
                ro = r["roofline"]
                # terms scale 1/N except a ~log collective share
                def t_of(n):
                    return max(
                        ro["t_compute"] * 256 / n,
                        ro["t_memory"] * 256 / n,
                        ro["t_collective"] * 256 / n * 1.5,
                    )

                speedup = t_of(10) / t_of(100)
                out.append(
                    row(
                        "fig5_modelled_10_to_100_chips", 0.0,
                        f"projected={speedup:.1f}x vs paper 7.2x",
                    )
                )
                break
    return out
