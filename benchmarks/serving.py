"""Serving benchmark: the online analog of paper Exp #5.

Exp #5 reports batch throughput (ms/image) at two batch sizes; a service
additionally owns the *latency distribution* that micro-batching buys that
throughput with. This module replays uniform and Zipf traces through a
warmed :class:`~repro.serving.SearchSession` + ``MicroBatcher`` and emits

  * CSV rows (the harness contract): per-trace p50/p95 latency, engine
    ms/image, cache hit rate, steady-state recompiles;
  * a JSON file (``benchmarks/out/serving.json`` or ``$REPRO_BENCH_OUT``)
    with the full metrics, per-bucket plans, and the per-plan *measured*
    ms/image observations (the session index's calibration store) — the
    data the ``plan()`` cost model is calibrated against;
  * ``--calibrate``: sweep batch-size x layout shapes, record measured
    ms/image into an index's calibration store, commit the fit, and emit
    the fitted coefficients (``serving_calibration.json``) — see
    docs/cost_model.md.
"""

from __future__ import annotations

import json
import math
import os

from benchmarks.common import (
    Corpus,
    bench_header,
    fit_payload,
    layout_bytes,
    row,
    write_artifact,
)


def _session(c, *, buckets, cache_leaves=0, cache_admit=2, probes=1,
             cost_model="auto"):
    from repro.serving import SearchSession

    s = SearchSession(
        c.index, c.tree, c.mesh, k=10, layout="auto", probes=probes,
        buckets=buckets, cache_leaves=cache_leaves,
        cache_admit_after=cache_admit, cost_model=cost_model,
    )
    s.warmup()
    return s


def _replay(session, c, *, skew, n_requests, desc_per_image, rate, seed=3):
    from repro.serving import MicroBatcher, TraceLoadGenerator

    n_images = len(c.vecs_np) // desc_per_image
    gen = TraceLoadGenerator(c.vecs_np, desc_per_image, seed=seed)
    reqs = gen.from_trace(n_requests, n_images, skew=skew, rate=rate)
    MicroBatcher(session, max_wait_ms=5.0, max_queue=4096).run(reqs)
    return session.metrics


def _traced_shard_replay(c, out_dir, *, trace_out=None, trace_sample=1.0,
                         shards=2, n_requests=200, desc_per_image=24):
    """The traced scatter-gather leg of :func:`run`: one Zipf replay over
    a ``shards``-segment index with a real tracer installed, exporting
    the trace artifacts next to the benchmark JSONs — the Chrome timeline
    (``serving_trace.json``, per-request queue-wait vs compute bars plus
    one process lane per shard), the structured event log
    (``serving_events.jsonl``), and the unified registry snapshot
    (``serving_metrics.json``). ``scripts/tracereport.py`` digests either
    trace file into a top-N-slowest breakdown."""
    import numpy as np

    from repro.index import Index
    from repro.obs import (
        Tracer,
        export_trace,
        get_registry,
        tracing,
        write_jsonl,
    )
    from repro.serving import (
        MicroBatcher,
        ShardedSearchSession,
        TraceLoadGenerator,
    )

    idx = Index.create(c.tree, None, mesh=c.mesh)
    for chunk in np.array_split(c.vecs_np, shards):
        idx.append(chunk)
    idx.commit()
    session = ShardedSearchSession(
        idx, mesh=c.mesh, shards=shards, k=10, buckets=(1024, 4096),
        cache_leaves=256, cache_admit_after=1,
    )
    session.warmup()
    n_images = len(c.vecs_np) // desc_per_image
    gen = TraceLoadGenerator(c.vecs_np, desc_per_image, seed=3)
    reqs = gen.from_trace(n_requests, n_images, skew="zipf", rate=100.0)
    tracer = Tracer(sample=trace_sample, seed=3)
    with tracing(tracer):
        MicroBatcher(session, max_wait_ms=5.0, max_queue=4096).run(reqs)
    paths = {
        "trace": export_trace(
            tracer, trace_out or os.path.join(out_dir, "serving_trace.json")
        ),
        "events": write_jsonl(
            tracer, os.path.join(out_dir, "serving_events.jsonl")
        ),
        "metrics": get_registry().dump(
            os.path.join(out_dir, "serving_metrics.json")
        ),
    }
    return tracer, session, paths


def run(*, trace_out=None, trace_sample=1.0):
    from repro.core.engine import CalibrationStore

    out_rows = []
    payload = {}
    c = Corpus()
    dpi = 24
    session = None
    # each session wraps the shared corpus index in its own ephemeral
    # facade; fold their calibration stores for the artifact
    calibration = CalibrationStore()
    for skew, cache_leaves in (("uniform", 0), ("zipf", 1024)):
        session = _session(
            c, buckets=(1024, 4096), cache_leaves=cache_leaves,
            cache_admit=1,
        )
        m = _replay(session, c, skew=skew, n_requests=200,
                    desc_per_image=dpi, rate=100.0)
        lat = m.latency.summary()
        name = f"serving_{skew}_200req"
        out_rows.append(row(
            name, lat["p50_ms"] / 1e3,
            f"p95_ms={lat['p95_ms']:.1f} ms_per_image={m.ms_per_image:.2f} "
            f"cache_hit={session.cache.hit_rate:.2f} "
            f"recompiles={session.steady_state_recompiles()}",
        ))
        calibration.merge(session.index.calibration)
        payload[skew] = {
            "metrics": m.to_dict(),
            "cache": session.cache.stats(),
            "plans": session.plan_summary(),
        }
    out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
    os.makedirs(out_dir, exist_ok=True)
    # the traced scatter-gather leg: same engine, tracing on — its trace/
    # events/registry artifacts land next to serving.json
    tracer, traced_session, trace_paths = _traced_shard_replay(
        c, out_dir, trace_out=trace_out, trace_sample=trace_sample,
    )
    tm = traced_session.metrics
    calibration.merge(traced_session.index.calibration)
    payload["sharded_traced"] = {
        "metrics": tm.to_dict(),
        "obs": tracer.describe(),
        "shards": traced_session.n_shards,
        "artifacts": trace_paths,
    }
    out_rows.append(row(
        "serving_traced_2shard", tm.latency.percentile(50) / 1e3,
        f"p95_ms={tm.latency.percentile(95):.1f} "
        f"spans={tracer.describe()['spans']} "
        f"trace={trace_paths['trace']}",
    ))
    payload["header"] = bench_header(
        cost_model=session.active_cost_model(),
        layout_bytes=layout_bytes(session.index),
    )
    payload["plan_observations"] = calibration.snapshot()
    path = write_artifact(os.path.join(out_dir, "serving.json"), payload)
    out_rows.append(row("serving_json", 0.0, f"wrote={path}"))
    return out_rows


def _calibrated_index(c, *, batch_sizes=(256, 1024), rounds=2,
                      desc_per_image=24):
    """An ephemeral lifecycle Index over the benchmark corpus with a
    usable fitted calibration: measurements are recorded by sessions
    pinned to ``cost_model="heuristic"`` (they must not be steered by the
    model they feed), two batch shapes per layout — enough for the
    per-layout fit. The SLO replay's admission control and ladder/slab
    tuning all key off this fit."""
    import numpy as np

    from repro.index import Index
    from repro.serving import SearchSession

    idx = Index.create(c.tree, None, mesh=c.mesh)
    idx.append(c.vecs_np)
    idx.commit()
    q, _ = c.queries(max(batch_sizes))
    q = np.asarray(q)
    for layout in ("point_major", "query_routed"):
        for b in batch_sizes:
            s = SearchSession(idx, k=10, layout=layout, buckets=(int(b),),
                              cost_model="heuristic")
            s.warmup()
            for _ in range(rounds):
                s.search(q[:int(b)],
                         n_images=max(1, int(b) // desc_per_image))
    idx.commit()
    return idx


def _identical_results(by_rid_a: dict, by_rid_b: dict) -> tuple[int, int]:
    """(compared, mismatches) over the rids completed in both replays —
    the scheduling-never-changes-results gate."""
    import numpy as np

    shared = set(by_rid_a) & set(by_rid_b)
    mismatches = 0
    for rid in shared:
        a, b = by_rid_a[rid], by_rid_b[rid]
        if not (np.array_equal(a.ids, b.ids)
                and np.array_equal(a.dists, b.dists)):
            mismatches += 1
    return len(shared), mismatches


def slo_run(
    *,
    n_requests: int = 400,
    rate: float = 2000.0,
    desc_per_image: int = 24,
    corpus: Corpus | None = None,
    json_path: str | None = None,
) -> list[str]:
    """Deadline-aware vs FIFO scheduling under one multi-tenant trace.

    The same bursty multi-tenant trace (:func:`default_tenant_mix` —
    steady interactive/standard classes plus heavily bursty batch
    traffic) is replayed through a FIFO and an EDF micro-batcher over the
    same calibrated index at the same offered load. The JSON artifact
    (``serving_slo.json``) carries, per scheduler, the per-class latency
    distributions and SLO attainment, the queue-wait vs compute
    breakdown, queue-depth percentiles, and the shed/downgrade counters —
    plus the cross-scheduler comparison (interactive p95 speedup) and the
    result-divergence gate (must be zero: scheduling changes *when* a
    request runs, never *what* it returns).
    """
    from repro.serving import (
        MicroBatcher,
        SearchSession,
        TraceLoadGenerator,
        default_tenant_mix,
    )

    c = corpus or Corpus()
    idx = _calibrated_index(c, desc_per_image=desc_per_image)
    n_images = len(c.vecs_np) // desc_per_image
    gen = TraceLoadGenerator(c.vecs_np, desc_per_image, seed=3)
    # the queue-owned regime: offered load outruns the engine, so the
    # pending set is deep and dispatch *order* decides each class's tail;
    # a minority interactive class is the one EDF protects
    classes = default_tenant_mix(n_requests, rate=rate,
                                 interactive_frac=0.2, standard_frac=0.3)
    reqs = gen.multi_tenant(classes, n_images, seed=7)
    out_rows, sched_payload, by_rid, p95s = [], {}, {}, {}
    session = None
    for sched in ("fifo", "edf"):
        # buckets sized so the trace spans many dispatches — one giant
        # bucket would put every class in the same dispatch and leave the
        # scheduler nothing to order
        session = SearchSession(idx, mesh=c.mesh, k=10, layout="auto",
                                buckets=(128, 512), cost_model="auto")
        session.warmup()
        batcher = MicroBatcher(session, max_wait_ms=5.0, max_queue=4096,
                               scheduler=sched)
        comps = batcher.run(reqs)
        by_rid[sched] = {cc.rid: cc for cc in comps if cc.ids is not None}
        m = session.metrics
        pc = {
            name: cm.latency.percentile(95)
            for name, cm in m.per_class.items()
        }
        p95s[sched] = pc
        offered = len(reqs)
        sched_payload[sched] = {
            "metrics": m.to_dict(),
            "queue": m.queue_summary(),
            "shed_rate": m.shed / offered,
            "policy": {
                "shed_depth": batcher.policy.shed_depth,
                "on_overload": batcher.policy.on_overload,
                "deadlines_ms": dict(batcher.policy.deadlines_ms),
                "max_wait_ms": dict(batcher.policy.max_wait_ms),
            },
        }
        attain = {
            name: cm.slo_attainment for name, cm in m.per_class.items()
        }
        out_rows.append(row(
            f"serving_slo_{sched}",
            pc.get("interactive", float("nan")) / 1e3,
            f"int_p95={pc.get('interactive', float('nan')):.1f} "
            f"std_p95={pc.get('standard', float('nan')):.1f} "
            f"batch_p95={pc.get('batch', float('nan')):.1f} "
            f"attain_int={attain.get('interactive', 1.0):.2f} "
            f"shed={m.shed} wait_p95={m.wait.percentile(95):.1f} "
            f"compute_p95={m.compute.percentile(95):.1f}",
        ))
    compared, mismatches = _identical_results(by_rid["fifo"], by_rid["edf"])
    assert mismatches == 0, (
        f"{mismatches}/{compared} requests diverged between fifo and edf"
    )
    speedup = p95s["fifo"]["interactive"] / max(1e-9,
                                                p95s["edf"]["interactive"])
    out_rows.append(row(
        "serving_slo_speedup", 0.0,
        f"interactive_p95_fifo={p95s['fifo']['interactive']:.1f} "
        f"interactive_p95_edf={p95s['edf']['interactive']:.1f} "
        f"speedup={speedup:.2f}x divergence=0/{compared}",
    ))
    payload = {
        "header": bench_header(cost_model=session.active_cost_model()),
        "trace": {
            "n_requests": len(reqs),
            "rate": rate,
            "desc_per_image": desc_per_image,
            "classes": [
                {"priority": tc.priority, "n_requests": tc.n_requests,
                 "rate": tc.rate, "skew": tc.skew,
                 "burst_factor": tc.burst_factor}
                for tc in classes
            ],
        },
        "schedulers": sched_payload,
        "comparison": {
            "interactive_p95_fifo_ms": p95s["fifo"]["interactive"],
            "interactive_p95_edf_ms": p95s["edf"]["interactive"],
            "interactive_p95_speedup": speedup,
            "divergence": {"compared": compared, "mismatches": mismatches},
        },
    }
    out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
    path = write_artifact(
        json_path or os.path.join(out_dir, "serving_slo.json"), payload
    )
    out_rows.append(row("serving_slo_json", 0.0, f"wrote={path}"))
    return out_rows


def slo_smoke() -> int:
    """SLO scheduling gate: one small multi-tenant trace replayed under
    FIFO and EDF over the same corpus. Asserts (a) zero result divergence
    (bit-identical ids + distances per request — scheduling never changes
    *what* a request returns) and (b) under EDF the interactive class's
    p95 beats the batch class's p95 (the deadline-aware ordering is
    actually doing something)."""
    from repro.serving import MicroBatcher, TraceLoadGenerator, \
        default_tenant_mix

    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    dpi = 20
    n_images = len(c.vecs_np) // dpi
    gen = TraceLoadGenerator(c.vecs_np, dpi, seed=3)
    # the offered load must outrun the engine (the queue, not the kernel,
    # owns the tail — the regime this PR schedules): at 2000 req/s the
    # whole trace arrives inside a couple of dispatches' wall time, so
    # the pending set is deep and ordering it is what matters
    reqs = gen.multi_tenant(
        default_tenant_mix(150, rate=2000.0), n_images, seed=7
    )
    by_rid, metrics = {}, {}
    for sched in ("fifo", "edf"):
        session = _session(c, buckets=(256, 1024))
        comps = MicroBatcher(session, max_wait_ms=5.0, max_queue=4096,
                             scheduler=sched).run(reqs)
        assert session.metrics.requests == len(reqs), (
            f"{sched}: served {session.metrics.requests}/{len(reqs)}"
        )
        by_rid[sched] = {cc.rid: cc for cc in comps if cc.ids is not None}
        metrics[sched] = session.metrics
    compared, mismatches = _identical_results(by_rid["fifo"], by_rid["edf"])
    assert compared == len(reqs) and mismatches == 0, (
        f"fifo vs edf divergence: {mismatches}/{compared} "
        f"(of {len(reqs)} requests)"
    )
    m = metrics["edf"]
    int_p95 = m.per_class["interactive"].latency.percentile(95)
    bat_p95 = m.per_class["batch"].latency.percentile(95)
    assert int_p95 < bat_p95, (
        f"EDF interactive p95 {int_p95:.1f} ms not under batch p95 "
        f"{bat_p95:.1f} ms"
    )
    print(
        f"# slo smoke: fifo == edf on {compared} requests (0 diverged); "
        f"EDF interactive p95 {int_p95:.1f} ms < batch p95 {bat_p95:.1f} ms; "
        f"wait p95 {m.wait.percentile(95):.1f} ms, "
        f"compute p95 {m.compute.percentile(95):.1f} ms"
    )
    return 0


def shard_sweep(
    shard_counts=(1, 2, 4),
    *,
    segments: int = 4,
    strategy: str = "balanced",
    n_queries: int = 2048,
    batch_rows: int = 1024,
    desc_per_image: int = 24,
    corpus: Corpus | None = None,
    json_path: str | None = None,
    check_identity: bool = True,
) -> list[str]:
    """Scatter-gather scaling: engine ms/image vs. shard count.

    The same corpus is appended as ``segments`` segments of one Index,
    then served through a :class:`~repro.serving.ShardedSearchSession` at
    each shard count — one JSON entry (and one CSV row) per count, all
    stamped with the shard plan and git rev so trajectories are
    comparable across PRs. Every dispatch feeds the per-plan ms/image
    observations (the ``plan()`` cost-model calibration data), and the
    sweep asserts each count's results are bit-identical to the first
    (the scatter-gather exactness gate, on by default).
    """
    import numpy as np

    from repro.index import Index
    from repro.serving import ShardedSearchSession

    c = corpus or Corpus()
    idx = Index.create(c.tree, None, mesh=c.mesh)
    # segment sizes on a round boundary: build_index pads each segment to
    # ~2x its rows, and a prime-ish padded count leaves plan() no usable
    # block_rows divisor (loud ValueError) — same corpus either way
    n = len(c.vecs_np)
    step = max(1000, n // segments // 1000 * 1000)
    bounds = [min(i * step, n) for i in range(1, segments)] + [n]
    for lo, hi in zip([0] + bounds[:-1], bounds):
        if hi > lo:
            idx.append(c.vecs_np[lo:hi])
    idx.commit()
    q, _ = c.queries(n_queries)
    q = np.asarray(q)
    out_rows, entries, ref = [], [], None
    session = None
    for n in shard_counts:
        session = ShardedSearchSession(
            idx, shards=n, shard_strategy=strategy, k=10, layout="auto",
            buckets=(batch_rows,),
        )
        session.warmup()
        got_i, got_d = [], []
        for s in range(0, len(q), batch_rows):
            chunk = q[s: s + batch_rows]
            ids, dists = session.search(
                chunk, n_images=max(1, len(chunk) // desc_per_image)
            )
            got_i.append(ids)
            got_d.append(dists)
        if check_identity:
            if ref is None:
                ref = (np.concatenate(got_i), np.concatenate(got_d))
            else:
                np.testing.assert_array_equal(np.concatenate(got_i), ref[0])
                np.testing.assert_array_equal(np.concatenate(got_d), ref[1])
        m = session.metrics
        recomp = session.steady_state_recompiles()
        assert recomp == 0, f"shards={n}: {recomp} steady-state recompiles"
        entries.append({
            "shards": n,
            "plan": session.shard_plan.to_json(),
            "ms_per_image": m.ms_per_image,
            "engine_ms": m.engine_ms,
            "engine_batches": m.engine_batches,
            "query_rows": m.query_rows,
        })
        out_rows.append(row(
            f"serving_shards_{n}", m.engine_ms / 1e3 / m.engine_batches,
            f"ms_per_image={m.ms_per_image:.2f} "
            f"plan={session.shard_plan.describe().replace(' ', '_')} "
            f"identical={'checked' if check_identity else 'unchecked'}",
        ))
    out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
    path = json_path or os.path.join(out_dir, "serving_shards.json")
    payload = {
        "header": bench_header(
            shard_plan={"strategy": strategy, "counts": list(shard_counts),
                        "segments": segments},
            cost_model=session.active_cost_model(),
        ),
        "sweep": entries,
        "plan_observations": idx.calibration.snapshot(),
    }
    write_artifact(path, payload)
    out_rows.append(row("serving_shards_json", 0.0, f"wrote={path}"))
    return out_rows


def _index_queries(idx, n: int, *, noise: float = 4.0, seed: int = 0):
    """``n`` perturbed live descriptor rows from ``idx`` — dimension-true
    query vectors for calibrating an arbitrary durable index."""
    import numpy as np

    if not idx.segments:
        raise ValueError(f"index at {idx.directory} has no live rows")
    ids = np.concatenate([s.host_ids() for s in idx.segments])
    ids = ids[ids >= 0]
    ids = np.setdiff1d(ids, idx.tombstones)
    if ids.size == 0:
        raise ValueError(f"index at {idx.directory} has no live rows")
    rng = np.random.default_rng(seed)
    take = rng.choice(ids, size=n, replace=ids.size < n)
    q = idx.read_rows(take)
    return q + rng.standard_normal(q.shape).astype(np.float32) * noise


def calibrate(
    *,
    index_dir: str | None = None,
    batch_sizes=(256, 1024),
    layouts=("point_major", "query_routed"),
    rounds: int = 3,
    desc_per_image: int = 24,
    corpus: Corpus | None = None,
    json_path: str | None = None,
    rows: int | None = None,
):
    """Sweep (batch size x layout) shapes, record measured ms/image into
    an index's calibration store, commit, and fit the cost model.

    Each sweep cell runs a warmed single-bucket session pinned to one
    layout with ``cost_model="heuristic"`` (measurements must not be
    steered by the model they will feed). The recorded observations land
    in the index's manifest via ``commit`` (for a durable ``index_dir``),
    and the fitted per-layout coefficients (``ms ≈ a·(rows_scanned/tile)
    + b·probes·leaves + c·batch + d``) are written to
    ``serving_calibration.json`` — after which ``plan(model="auto")``
    over this index prefers the fit (docs/cost_model.md).
    """
    import numpy as np

    from repro.index import Index
    from repro.serving import SearchSession

    if index_dir:
        # calibrate the durable index in place: queries must come from
        # *its* corpus (its dim), not the synthetic benchmark Corpus
        idx = Index.open(index_dir)
        q_base = _index_queries(idx, max(batch_sizes))
    else:
        c = corpus or (Corpus(rows=rows) if rows else Corpus())
        idx = Index.create(c.tree, None, mesh=c.mesh)
        idx.append(c.vecs_np)
        idx.commit()
        q_base, _ = c.queries(max(batch_sizes))
        q_base = np.asarray(q_base)
    out_rows = []
    for layout in layouts:
        for b in batch_sizes:
            session = SearchSession(
                idx, k=10, layout=layout, buckets=(int(b),),
                cost_model="heuristic",
            )
            session.warmup()
            q = q_base[:int(b)]
            for _ in range(rounds):
                session.search(
                    q, n_images=max(1, int(b) // desc_per_image)
                )
            m = session.metrics
            out_rows.append(row(
                f"calibrate_{layout}_b{b}",
                m.engine_ms / 1e3 / max(1, m.engine_batches),
                f"ms_per_image={m.ms_per_image:.3f}",
            ))
    # ephemeral indexes commit too: committed_version must name a
    # manifest state that actually contains these observations
    version = idx.commit()
    payload = dict(
        fit_payload(idx.calibration, version),
        observations=idx.calibration.snapshot(),
    )
    out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
    path = write_artifact(
        json_path or os.path.join(out_dir, "serving_calibration.json"),
        payload,
    )
    out_rows.append(row(
        "serving_calibration_json", 0.0,
        f"wrote={path} layouts_fitted={len(payload['coefficients'])}",
    ))
    return out_rows


def calibration_smoke() -> int:
    """Calibration round-trip gate: record during serving → ``commit``
    persists it to the manifest → ``Index.open`` reloads it →
    ``plan(model="auto")`` over the reopened store is decided by the
    calibrated models (fitted/observed), not the heuristic."""
    import tempfile

    import numpy as np

    from repro.core.engine import PlanShapes, plan as make_plan, resolve_model
    from repro.index import Index
    from repro.serving import SearchSession

    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    with tempfile.TemporaryDirectory() as d:
        idx = Index.create(c.tree, d, mesh=c.mesh)
        idx.append(c.vecs_np)
        idx.commit()
        q, _ = c.queries(512)
        q = np.asarray(q)
        # two batch shapes per layout: enough distinct measurements for
        # the per-layout fit to become usable
        for layout in ("point_major", "query_routed"):
            for b in (256, 512):
                s = SearchSession(idx, k=10, layout=layout, buckets=(b,),
                                  cost_model="heuristic")
                s.warmup()
                for _ in range(2):
                    s.search(q[:b], n_images=max(1, b // 24))
        assert idx.calibration.dirty, "serving dispatches did not record"
        n_recorded = len(idx.calibration)
        assert n_recorded >= 4, idx.calibration.snapshot()
        idx.commit()
        assert not idx.calibration.dirty
        reopened = Index.open(d, mesh=c.mesh)
    assert len(reopened.calibration) == n_recorded, (
        f"reopened {len(reopened.calibration)} != recorded {n_recorded}"
    )
    # decide at a batch size the sweep never measured: only the fit can
    # price it — plan(model="auto") must be decided by the fitted model
    rows_ = reopened.segments[0].rows
    shapes = dict(rows=rows_, n_leaves=c.tree.n_leaves, n_queries=384,
                  n_shards=1, k=10)
    candidates = tuple(
        make_plan(layout=lay, **shapes)
        for lay in ("point_major", "query_routed")
    )
    pick, kind = resolve_model("auto", reopened.calibration).decide(
        candidates,
        PlanShapes(rows=rows_, n_queries=384, n_shards=1,
                   n_leaves=c.tree.n_leaves),
    )
    assert kind == "fitted", (
        f"plan(model='auto') fell back to {kind!r} despite "
        f"{len(reopened.calibration)} reloaded calibration records"
    )
    auto = make_plan(model="auto", calibration=reopened.calibration, **shapes)
    assert auto.layout == pick.layout
    print(
        f"# calibration smoke: record → commit → reopen round-trips "
        f"{len(reopened.calibration)} plan signatures; plan(model='auto') "
        f"decided by the {kind} model → {auto.layout}"
    )
    return 0


def smoke() -> int:
    """Tiny serving gate: small corpus, 2 buckets, ~100 requests; asserts
    p95 is finite and the compile count stays at the warmed-bucket count."""
    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    session = _session(c, buckets=(256, 1024), cache_leaves=256,
                       cache_admit=1, probes=2)
    warmed = session.recompiles()
    assert warmed == 2, f"expected 2 warmed bucket programs, got {warmed}"
    m = _replay(session, c, skew="zipf", n_requests=100, desc_per_image=20,
                rate=200.0)
    p95 = m.latency.percentile(95)
    assert math.isfinite(p95), f"p95 latency not finite: {p95}"
    assert session.recompiles() == warmed, (
        f"steady-state recompile: {session.recompiles()} != {warmed}"
    )
    assert m.requests == 100, f"served {m.requests}/100"
    print(
        f"# serving smoke: p50 {m.latency.percentile(50):.1f} ms, "
        f"p95 {p95:.1f} ms, ms/image {m.ms_per_image:.2f}, "
        f"cache hit {session.cache.hit_rate:.2f}, recompiles 0",
    )
    return 0


def sharded_smoke() -> int:
    """Scatter-gather gate. Asserts (a) a `ShardedSearchSession` returns
    ids+dists bit-identical to the unsharded `SearchSession` over the
    same index, (b) a small shard sweep (counts 1/2/3 over a 3-segment
    index) is per-count bit-identical and recompile-free (assertions
    inside :func:`shard_sweep`), and (c) the sweep's JSON artifact
    carries one row per shard count plus the git-rev/shard-plan header."""
    import tempfile

    import numpy as np

    from repro.index import Index
    from repro.serving import SearchSession, ShardedSearchSession

    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    idx = Index.create(c.tree, None, mesh=c.mesh)
    idx.append(c.vecs_np[:12_000])
    idx.append(c.vecs_np[12_000:])
    idx.commit()
    q, _ = c.queries(256)
    q = np.asarray(q)
    ref = SearchSession(idx, k=10, probes=2, buckets=(256,))
    ref.warmup()
    for shards in (2, 3):
        s = ShardedSearchSession(idx, shards=shards, k=10, probes=2,
                                 buckets=(256,))
        s.warmup()
        for n in (1, 100, 256):
            ids, dists = s.search(q[:n])
            ref_ids, ref_dists = ref.search(q[:n])
            np.testing.assert_array_equal(ids, ref_ids)
            np.testing.assert_array_equal(dists, ref_dists)
        assert s.steady_state_recompiles() == 0

    counts = (1, 2, 3)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "serving_shards.json")
        shard_sweep(
            counts, segments=3, n_queries=512, batch_rows=256,
            corpus=c, json_path=path,
        )
        with open(path) as f:
            payload = json.load(f)
    assert [e["shards"] for e in payload["sweep"]] == list(counts), payload
    assert payload["header"]["git_rev"], payload["header"]
    assert payload["header"]["shard_plan"]["strategy"] == "balanced"
    ms = ", ".join(
        f"x{e['shards']}={e['ms_per_image']:.2f}" for e in payload["sweep"]
    )
    print("# sharded smoke: session == sharded session (shards 2/3, "
          f"256 queries, k=10); sweep bit-identical at 1/2/3; ms/image {ms}")
    return 0


def obs_smoke() -> int:
    """Observability gate. Asserts (a) a traced 2-shard replay returns
    ids + distances bit-identical to the untraced replay of the same
    trace (tracing must never perturb results), (b) the trace is
    non-empty and carries the full span taxonomy with both shard lanes,
    (c) the Chrome export round-trips as valid JSON with monotone
    timestamps, (d) the registry dump is non-empty, and (e)
    ``scripts/tracereport.py`` digests the trace into a top-N report."""
    import subprocess
    import sys
    import tempfile

    import numpy as np

    from repro.index import Index
    from repro.obs import Tracer, get_registry, tracing, write_chrome_trace
    from repro.serving import (
        MicroBatcher,
        ShardedSearchSession,
        TraceLoadGenerator,
    )

    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    idx = Index.create(c.tree, None, mesh=c.mesh)
    idx.append(c.vecs_np[:12_000])
    idx.append(c.vecs_np[12_000:])
    idx.commit()
    dpi = 20
    n_images = len(c.vecs_np) // dpi
    gen = TraceLoadGenerator(c.vecs_np, dpi, seed=3)
    reqs = gen.from_trace(80, n_images, skew="zipf", rate=200.0)

    def replay(tracer):
        # cache OFF: the virtual clock advances by measured wall compute,
        # so cache admission timing can differ between replays, and a
        # cache-served answer is a CPU recompute under a rounding contract
        # — not the engine's bits. Engine-only replays are deterministic.
        s = ShardedSearchSession(idx, mesh=c.mesh, shards=2, k=10,
                                 buckets=(256, 1024), cache_leaves=0)
        s.warmup()
        with tracing(tracer):
            comps = MicroBatcher(s, max_wait_ms=5.0).run(reqs)
        return {cc.rid: cc for cc in comps if cc.ids is not None}, s

    base, _ = replay(None)
    tracer = Tracer(sample=1.0, seed=0)
    # keep the traced session alive through the registry dump below — its
    # ServingMetrics source is weakly held and would be pruned once GC'd
    traced, session = replay(tracer)
    assert set(base) == set(traced), "traced replay completed different rids"
    for rid, cc in traced.items():
        np.testing.assert_array_equal(cc.ids, base[rid].ids)
        np.testing.assert_array_equal(cc.dists, base[rid].dists)
    assert session.metrics.requests == len(reqs)
    d = tracer.describe()
    assert d["spans"] > 0, d
    names = {s.name for s in tracer.spans}
    for want in ("request", "queue.wait", "compute", "engine.dispatch",
                 "shard.scan", "gather.merge"):
        assert want in names, f"missing {want} spans (have {sorted(names)})"
    shards_seen = {
        s.attrs["shard"] for s in tracer.spans if s.name == "shard.scan"
    }
    assert shards_seen == {0, 1}, shards_seen
    with tempfile.TemporaryDirectory() as td:
        path = write_chrome_trace(tracer, os.path.join(td, "trace.json"))
        with open(path) as f:
            doc = json.load(f)
        evs = [e for e in doc["traceEvents"] if e["ph"] != "M"]
        assert evs, "empty Chrome trace"
        ts = [e["ts"] for e in evs]
        assert ts == sorted(ts), "Chrome trace timestamps not monotone"
        with open(get_registry().dump(os.path.join(td, "m.json"))) as f:
            snap = json.load(f)
        assert snap["metrics"], "empty registry dump"
        assert any(k.startswith("serving_metrics") for k in snap["sources"])
        script = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "scripts", "tracereport.py",
        )
        rep = subprocess.run(
            [sys.executable, script, path, "--top", "3"],
            capture_output=True, text=True, timeout=120,
        )
        assert rep.returncode == 0, rep.stderr
        assert "slowest" in rep.stdout, rep.stdout
    print(
        f"# obs smoke: traced == untraced on {len(base)} requests "
        f"(2 shards); {d['spans']} spans / {d['events']} events; Chrome "
        f"export valid + monotone; registry {len(snap['metrics'])} series; "
        f"tracereport OK"
    )
    return 0


def codes_smoke() -> int:
    """Compressed-codes gate: train → encode → commit → ``Index.open``
    round-trips the codebook → ``plan(model="auto")`` picks the
    ``scan_codes`` tier at the serving shape → the ADC scan + exact
    rerank session meets the recall floor against a scan-exact reference
    at the same probe width — all at a ≥8x resident-bytes reduction
    (docs/compressed_codes.md)."""
    import tempfile

    import numpy as np

    from repro.index import Index
    from repro.serving import SearchSession

    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    k, probes = 10, 8
    with tempfile.TemporaryDirectory() as d:
        idx = Index.create(c.tree, d, mesh=c.mesh)
        idx.append(c.vecs_np[:12_000])
        idx.append(c.vecs_np[12_000:])
        idx.enable_codes(m=8, bits=8)
        idx.commit()
        reopened = Index.open(d, mesh=c.mesh)
        cs = reopened.codes_stats()
        assert cs is not None, "codes artifact did not survive the commit"
        assert cs["compression_ratio"] >= 8.0, cs
        q, _ = c.queries(256)
        q = np.asarray(q)
        # scan-exact reference over the same index at the same probes —
        # the recall floor is codes-vs-exact, not codes-vs-ground-truth
        ref_ids = np.asarray(
            reopened.search(q, k=k, probes=probes,
                            layout="point_major").ids
        )
        session = SearchSession(reopened, mesh=c.mesh, k=k, probes=probes,
                                buckets=(256,))
        assert session.serving_layout == "scan_codes", (
            f"plan(auto) served {session.serving_layout} at a shape the "
            "codes tier should win"
        )
        session.warmup()
        ids, dists = session.search(q)
        assert session.steady_state_recompiles() == 0
        # the warmed session and the index facade run the same tier —
        # one ADC scan + exact rerank — and must agree bit for bit
        res = reopened.search(q, k=k, probes=probes, layout="scan_codes")
        np.testing.assert_array_equal(ids, np.asarray(res.ids))
        np.testing.assert_array_equal(dists, np.asarray(res.dists))
        recall = float(np.mean([
            len(set(ids[i][ids[i] >= 0]) & set(ref_ids[i][ref_ids[i] >= 0]))
            / k
            for i in range(len(q))
        ]))
        assert recall >= 0.9, (
            f"recall@{k}(scan_codes vs scan-exact) {recall:.3f} < 0.9"
        )
        rr = session.plan_summary()[0]["rerank"]
    print(
        f"# codes smoke: {cs['compression_ratio']:.0f}x resident bytes "
        f"({cs['bytes_per_row']}B/row vs {cs['raw_bytes_per_row']}B), "
        f"plan(auto) -> scan_codes, rerank={rr}, "
        f"recall@{k} {recall:.3f} vs scan-exact, session == facade, "
        f"recompiles 0"
    )
    return 0


def kernel_smoke() -> int:
    """Fused fast-path gate (docs/kernels.md): the same served trace
    through an ``impl="xla"`` and an ``impl="fused"`` session over one
    index. Asserts (a) every request's ids + distances are bit-identical
    between the two impls (the fused executor contract), (b) zero
    steady-state recompiles after warmup on both, and (c) fused ms/image
    within 1.5x of xla — off-TPU the fused path is the pipelined wave
    sweep, so it must not regress throughput while buying the kernel its
    on-TPU dispatch. Writes ``serving_kernel.json`` with each leg's
    ms/image stamped under its active impl in the header."""
    import numpy as np  # noqa: F401 (via _identical_results)

    from repro.index import Index
    from repro.serving import MicroBatcher, SearchSession, TraceLoadGenerator

    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    idx = Index.create(c.tree, None, mesh=c.mesh)
    idx.append(c.vecs_np[:12_000])
    idx.append(c.vecs_np[12_000:])
    idx.commit()
    dpi = 20
    n_images = len(c.vecs_np) // dpi
    gen = TraceLoadGenerator(c.vecs_np, dpi, seed=3)
    reqs = gen.from_trace(100, n_images, skew="zipf", rate=200.0)
    by_impl, legs = {}, {}
    for impl in ("xla", "fused"):
        # cache OFF: a cache-served answer is a CPU recompute under a
        # rounding contract, not the executor's bits — and this gate is
        # exactly about the executor's bits
        s = SearchSession(idx, mesh=c.mesh, k=10, layout="point_major",
                          probes=2, impl=impl, buckets=(256, 1024),
                          cache_leaves=0, cost_model="heuristic")
        s.warmup()
        comps = MicroBatcher(s, max_wait_ms=5.0, max_queue=4096).run(reqs)
        m = s.metrics
        assert m.requests == len(reqs), (
            f"{impl}: served {m.requests}/{len(reqs)}"
        )
        recomp = s.steady_state_recompiles()
        assert recomp == 0, f"{impl}: {recomp} steady-state recompiles"
        assert all(p["impl"] == impl for p in s.plan_summary())
        by_impl[impl] = {cc.rid: cc for cc in comps if cc.ids is not None}
        legs[impl] = {
            "header": bench_header(impl=impl),
            "ms_per_image": m.ms_per_image,
            "plans": s.plan_summary(),
        }
    compared, mismatches = _identical_results(by_impl["xla"],
                                              by_impl["fused"])
    assert compared == len(reqs) and mismatches == 0, (
        f"fused vs xla divergence: {mismatches}/{compared} "
        f"(of {len(reqs)} requests)"
    )
    ratio = legs["fused"]["ms_per_image"] / max(
        1e-9, legs["xla"]["ms_per_image"]
    )
    assert ratio <= 1.5, (
        f"fused ms/image {legs['fused']['ms_per_image']:.2f} is {ratio:.2f}x "
        f"xla's {legs['xla']['ms_per_image']:.2f} (bound 1.5x)"
    )
    out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
    write_artifact(os.path.join(out_dir, "serving_kernel.json"), {
        "header": bench_header(impl="fused"),
        "legs": legs,
        "divergence": {"compared": compared, "mismatches": mismatches},
        "ms_per_image_ratio": ratio,
    })
    print(
        f"# kernel smoke: fused == xla on {compared} requests (0 diverged); "
        f"ms/image fused {legs['fused']['ms_per_image']:.2f} vs "
        f"xla {legs['xla']['ms_per_image']:.2f} ({ratio:.2f}x, bound 1.5x); "
        f"recompiles 0"
    )
    return 0


def dynamicity_smoke() -> int:
    """Read-during-write gate (docs/dynamicity.md): replay a multi-tenant
    trace against a pinned-version session while a background thread
    appends + incrementally compacts the same durable index. Asserts no
    request is dropped, zero steady-state recompiles across every adopted
    version, p95 within 2x of a frozen-index baseline, and the final
    refreshed results bit-identical to a fresh ``Index.open``."""
    import tempfile
    import threading

    import numpy as np

    from repro.index import Index
    from repro.serving import MicroBatcher, SearchSession, TraceLoadGenerator
    from repro.serving.trace import default_tenant_mix

    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    base, chunk, desc, n_req = 16_000, 500, 20, 150
    kw = dict(mesh=c.mesh, k=10, layout="point_major", probes=2,
              buckets=(256, 1024), cost_model="heuristic")
    with tempfile.TemporaryDirectory() as d:
        idx = Index.create(c.tree, d, mesh=c.mesh)
        idx.append(c.vecs_np[: base // 2])
        idx.append(c.vecs_np[base // 2: base])
        idx.commit()

        gen = TraceLoadGenerator(c.vecs_np[:base], desc, seed=3)
        reqs = gen.multi_tenant(
            default_tenant_mix(n_req, rate=250.0), base // desc)

        # frozen baseline: the same trace against the index as committed
        # above, with no writer running
        frozen = SearchSession(idx, **kw)
        frozen.warmup()
        MicroBatcher(frozen, max_wait_ms=5.0, max_queue=4096,
                     scheduler="fifo").run(reqs)
        base_p95 = frozen.metrics.latency.percentile(95)

        session = SearchSession(idx, **kw)
        session.warmup()
        v0 = session.pinned_version
        # one commit lands before the replay starts, so at least one
        # adoption happens regardless of writer-thread scheduling
        idx.append(c.vecs_np[base: base + chunk])
        idx.commit()

        stop = threading.Event()

        def writer() -> None:
            nxt = base + chunk
            while not stop.is_set() and nxt + chunk <= len(c.vecs_np):
                idx.append(c.vecs_np[nxt: nxt + chunk])
                idx.commit()
                idx.compact(incremental=True)
                nxt += chunk

        t = threading.Thread(target=writer)
        t.start()
        try:
            done = MicroBatcher(session, max_wait_ms=5.0, max_queue=4096,
                                scheduler="fifo", refresh_every=5).run(reqs)
        finally:
            stop.set()
            t.join()

        dropped = [x for x in done if x.source in ("rejected", "shed")]
        assert not dropped, f"{len(dropped)} requests dropped mid-refresh"
        assert len(done) == n_req
        assert session.steady_state_recompiles() == 0, (
            "adopting a new index version recompiled on the request path"
        )
        adopted = session.pinned_version - v0
        assert adopted > 0, "no newer version was ever adopted"
        # 2x the frozen baseline, plus absolute headroom for scheduler
        # noise: compute is wall-clock on a shared CPU, and the writer
        # thread competes for it by design
        p95 = session.metrics.latency.percentile(95)
        assert p95 <= 2.0 * base_p95 + 150.0, (
            f"p95 {p95:.1f}ms vs frozen baseline {base_p95:.1f}ms"
        )
        # final identity: adopt the last committed version and compare
        # against a cold open of the same directory
        session.maybe_refresh()
        q, _ = c.queries(256)
        q = np.asarray(q)
        ids, dists = session.search(q)
        res = Index.open(d, mesh=c.mesh).search(
            q, k=10, probes=2, layout="point_major", cost_model="heuristic")
        np.testing.assert_array_equal(ids, np.asarray(res.ids))
        np.testing.assert_array_equal(dists, np.asarray(res.dists))
    print(
        f"# dynamicity smoke: {n_req} requests served across "
        f"{adopted} adopted versions (v{v0} -> v{session.pinned_version}), "
        f"0 dropped, recompiles 0, p95 {p95:.1f}ms "
        f"(frozen {base_p95:.1f}ms), refreshed session == fresh open"
    )
    return 0


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the serving-session smoke gate")
    ap.add_argument("--obs-smoke", action="store_true",
                    help="run the observability gate (traced == untraced "
                         "bit-identity, valid Chrome trace, registry dump, "
                         "tracereport)")
    ap.add_argument("--sharded-smoke", action="store_true",
                    help="run the scatter-gather bit-identity gate")
    ap.add_argument("--calibration-smoke", action="store_true",
                    help="run the calibration round-trip gate "
                         "(record -> commit -> reopen -> fitted plan)")
    ap.add_argument("--slo-smoke", action="store_true",
                    help="run the SLO scheduling gate (fifo == edf "
                         "results, EDF interactive p95 < batch p95)")
    ap.add_argument("--codes-smoke", action="store_true",
                    help="run the compressed-codes gate (train -> commit "
                         "-> reopen -> auto plans scan_codes -> ADC + "
                         "rerank recall floor at >=8x fewer bytes)")
    ap.add_argument("--kernel-smoke", action="store_true",
                    help="run the fused fast-path gate (fused == xla on a "
                         "served trace, 0 recompiles, ms/image within "
                         "1.5x) -> benchmarks/out/serving_kernel.json")
    ap.add_argument("--dynamicity-smoke", action="store_true",
                    help="run the read-during-write gate (serve a trace "
                         "while a writer thread appends + incrementally "
                         "compacts: 0 drops, 0 recompiles, bounded p95, "
                         "final results == fresh open)")
    ap.add_argument("--slo", action="store_true",
                    help="replay the multi-tenant trace under fifo and "
                         "edf, report per-class SLO attainment and the "
                         "queue-wait vs compute breakdown -> "
                         "benchmarks/out/serving_slo.json")
    ap.add_argument("--requests", type=int, default=400,
                    help="trace length for --slo")
    ap.add_argument("--rate", type=float, default=250.0,
                    help="offered load (req/s) for --slo")
    ap.add_argument("--shard-sweep", action="store_true",
                    help="ms/image vs shard count -> "
                         "benchmarks/out/serving_shards.json")
    ap.add_argument("--calibrate", action="store_true",
                    help="sweep batch x layout shapes, commit the measured "
                         "ms/image into the index manifest, and fit the "
                         "cost model -> serving_calibration.json")
    ap.add_argument("--index-dir", default=None,
                    help="calibrate an existing durable index instead of "
                         "an ephemeral benchmark corpus (--calibrate)")
    ap.add_argument("--batch-sizes", type=int, nargs="+",
                    default=(256, 1024),
                    help="bucket sizes the calibration sweep measures")
    ap.add_argument("--shards", type=int, nargs="+", default=(1, 2, 4),
                    help="shard counts to sweep")
    ap.add_argument("--segments", type=int, default=4,
                    help="segments the sweep corpus is appended as")
    ap.add_argument("--strategy", choices=("round_robin", "balanced"),
                    default="balanced")
    ap.add_argument("--json", default=None, help="JSON output path")
    ap.add_argument("--trace-out", default=None,
                    help="write the traced leg's Chrome trace here "
                         "(default: benchmarks/out/serving_trace.json; "
                         ".jsonl = structured event log)")
    ap.add_argument("--trace-sample", type=float, default=1.0,
                    help="fraction of requests traced in the traced leg "
                         "(deterministic per-request hash)")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke()
    if args.obs_smoke:
        return obs_smoke()
    if args.sharded_smoke:
        return sharded_smoke()
    if args.calibration_smoke:
        return calibration_smoke()
    if args.slo_smoke:
        return slo_smoke()
    if args.codes_smoke:
        return codes_smoke()
    if args.kernel_smoke:
        return kernel_smoke()
    if args.dynamicity_smoke:
        return dynamicity_smoke()
    print("name,us_per_call,derived")
    if args.slo:
        rows = slo_run(n_requests=args.requests, rate=args.rate,
                       json_path=args.json)
    elif args.shard_sweep:
        rows = shard_sweep(tuple(args.shards), segments=args.segments,
                           strategy=args.strategy, json_path=args.json)
    elif args.calibrate:
        rows = calibrate(index_dir=args.index_dir,
                         batch_sizes=tuple(args.batch_sizes),
                         json_path=args.json)
    else:
        rows = run(trace_out=args.trace_out, trace_sample=args.trace_sample)
    for r in rows:
        print(r)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
