"""Serving benchmark: the online analog of paper Exp #5.

Exp #5 reports batch throughput (ms/image) at two batch sizes; a service
additionally owns the *latency distribution* that micro-batching buys that
throughput with. This module replays uniform and Zipf traces through a
warmed :class:`~repro.serving.SearchSession` + ``MicroBatcher`` and emits

  * CSV rows (the harness contract): per-trace p50/p95 latency, engine
    ms/image, cache hit rate, steady-state recompiles;
  * a JSON file (``benchmarks/out/serving.json`` or ``$REPRO_BENCH_OUT``)
    with the full metrics, per-bucket plans, and the per-plan *measured*
    ms/image observations (``engine.observations()``) — the data a later
    PR calibrates the ``plan()`` cost model against (ROADMAP open item).
"""

from __future__ import annotations

import json
import math
import os

from benchmarks.common import Corpus, row


def _session(c, *, buckets, cache_leaves=0, cache_admit=2, probes=1):
    from repro.serving import SearchSession

    s = SearchSession(
        c.index, c.tree, c.mesh, k=10, layout="auto", probes=probes,
        buckets=buckets, cache_leaves=cache_leaves,
        cache_admit_after=cache_admit,
    )
    s.warmup()
    return s


def _replay(session, c, *, skew, n_requests, desc_per_image, rate, seed=3):
    from repro.serving import MicroBatcher, TraceLoadGenerator

    n_images = len(c.vecs_np) // desc_per_image
    gen = TraceLoadGenerator(c.vecs_np, desc_per_image, seed=seed)
    reqs = gen.from_trace(n_requests, n_images, skew=skew, rate=rate)
    MicroBatcher(session, max_wait_ms=5.0, max_queue=4096).run(reqs)
    return session.metrics


def run():
    from repro.core.engine import observations, reset_observations

    out_rows = []
    payload = {}
    c = Corpus()
    dpi = 24
    reset_observations()
    for skew, cache_leaves in (("uniform", 0), ("zipf", 1024)):
        session = _session(
            c, buckets=(1024, 4096), cache_leaves=cache_leaves,
            cache_admit=1,
        )
        m = _replay(session, c, skew=skew, n_requests=200,
                    desc_per_image=dpi, rate=100.0)
        lat = m.latency.summary()
        name = f"serving_{skew}_200req"
        out_rows.append(row(
            name, lat["p50_ms"] / 1e3,
            f"p95_ms={lat['p95_ms']:.1f} ms_per_image={m.ms_per_image:.2f} "
            f"cache_hit={session.cache.hit_rate:.2f} "
            f"recompiles={session.steady_state_recompiles()}",
        ))
        payload[skew] = {
            "metrics": m.to_dict(),
            "cache": session.cache.stats(),
            "plans": session.plan_summary(),
        }
    payload["plan_observations"] = observations()
    out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "serving.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    out_rows.append(row("serving_json", 0.0, f"wrote={path}"))
    return out_rows


def smoke() -> int:
    """Tiny serving gate: small corpus, 2 buckets, ~100 requests; asserts
    p95 is finite and the compile count stays at the warmed-bucket count."""
    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    session = _session(c, buckets=(256, 1024), cache_leaves=256,
                       cache_admit=1, probes=2)
    warmed = session.recompiles()
    assert warmed == 2, f"expected 2 warmed bucket programs, got {warmed}"
    m = _replay(session, c, skew="zipf", n_requests=100, desc_per_image=20,
                rate=200.0)
    p95 = m.latency.percentile(95)
    assert math.isfinite(p95), f"p95 latency not finite: {p95}"
    assert session.recompiles() == warmed, (
        f"steady-state recompile: {session.recompiles()} != {warmed}"
    )
    assert m.requests == 100, f"served {m.requests}/100"
    print(
        f"# serving smoke: p50 {m.latency.percentile(50):.1f} ms, "
        f"p95 {p95:.1f} ms, ms/image {m.ms_per_image:.2f}, "
        f"cache hit {session.cache.hit_rate:.2f}, recompiles 0",
    )
    return 0
