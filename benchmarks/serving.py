"""Serving benchmark: the online analog of paper Exp #5.

Exp #5 reports batch throughput (ms/image) at two batch sizes; a service
additionally owns the *latency distribution* that micro-batching buys that
throughput with. This module replays uniform and Zipf traces through a
warmed :class:`~repro.serving.SearchSession` + ``MicroBatcher`` and emits

  * CSV rows (the harness contract): per-trace p50/p95 latency, engine
    ms/image, cache hit rate, steady-state recompiles;
  * a JSON file (``benchmarks/out/serving.json`` or ``$REPRO_BENCH_OUT``)
    with the full metrics, per-bucket plans, and the per-plan *measured*
    ms/image observations (``engine.observations()``) — the data a later
    PR calibrates the ``plan()`` cost model against (ROADMAP open item).
"""

from __future__ import annotations

import json
import math
import os

from benchmarks.common import Corpus, bench_header, row


def _session(c, *, buckets, cache_leaves=0, cache_admit=2, probes=1):
    from repro.serving import SearchSession

    s = SearchSession(
        c.index, c.tree, c.mesh, k=10, layout="auto", probes=probes,
        buckets=buckets, cache_leaves=cache_leaves,
        cache_admit_after=cache_admit,
    )
    s.warmup()
    return s


def _replay(session, c, *, skew, n_requests, desc_per_image, rate, seed=3):
    from repro.serving import MicroBatcher, TraceLoadGenerator

    n_images = len(c.vecs_np) // desc_per_image
    gen = TraceLoadGenerator(c.vecs_np, desc_per_image, seed=seed)
    reqs = gen.from_trace(n_requests, n_images, skew=skew, rate=rate)
    MicroBatcher(session, max_wait_ms=5.0, max_queue=4096).run(reqs)
    return session.metrics


def run():
    from repro.core.engine import observations, reset_observations

    out_rows = []
    payload = {}
    c = Corpus()
    dpi = 24
    reset_observations()
    for skew, cache_leaves in (("uniform", 0), ("zipf", 1024)):
        session = _session(
            c, buckets=(1024, 4096), cache_leaves=cache_leaves,
            cache_admit=1,
        )
        m = _replay(session, c, skew=skew, n_requests=200,
                    desc_per_image=dpi, rate=100.0)
        lat = m.latency.summary()
        name = f"serving_{skew}_200req"
        out_rows.append(row(
            name, lat["p50_ms"] / 1e3,
            f"p95_ms={lat['p95_ms']:.1f} ms_per_image={m.ms_per_image:.2f} "
            f"cache_hit={session.cache.hit_rate:.2f} "
            f"recompiles={session.steady_state_recompiles()}",
        ))
        payload[skew] = {
            "metrics": m.to_dict(),
            "cache": session.cache.stats(),
            "plans": session.plan_summary(),
        }
    payload["header"] = bench_header()
    payload["plan_observations"] = observations()
    out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "serving.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    out_rows.append(row("serving_json", 0.0, f"wrote={path}"))
    return out_rows


def shard_sweep(
    shard_counts=(1, 2, 4),
    *,
    segments: int = 4,
    strategy: str = "balanced",
    n_queries: int = 2048,
    batch_rows: int = 1024,
    desc_per_image: int = 24,
    corpus: Corpus | None = None,
    json_path: str | None = None,
    check_identity: bool = True,
) -> list[str]:
    """Scatter-gather scaling: engine ms/image vs. shard count.

    The same corpus is appended as ``segments`` segments of one Index,
    then served through a :class:`~repro.serving.ShardedSearchSession` at
    each shard count — one JSON entry (and one CSV row) per count, all
    stamped with the shard plan and git rev so trajectories are
    comparable across PRs. Every dispatch feeds the per-plan ms/image
    observations (the ``plan()`` cost-model calibration data), and the
    sweep asserts each count's results are bit-identical to the first
    (the scatter-gather exactness gate, on by default).
    """
    import numpy as np

    from repro.core.engine import observations
    from repro.index import Index
    from repro.serving import ShardedSearchSession

    c = corpus or Corpus()
    idx = Index.create(c.tree, None, mesh=c.mesh)
    # segment sizes on a round boundary: build_index pads each segment to
    # ~2x its rows, and a prime-ish padded count leaves plan() no usable
    # block_rows divisor (loud ValueError) — same corpus either way
    n = len(c.vecs_np)
    step = max(1000, n // segments // 1000 * 1000)
    bounds = [min(i * step, n) for i in range(1, segments)] + [n]
    for lo, hi in zip([0] + bounds[:-1], bounds):
        if hi > lo:
            idx.append(c.vecs_np[lo:hi])
    idx.commit()
    q, _ = c.queries(n_queries)
    q = np.asarray(q)
    out_rows, entries, ref = [], [], None
    for n in shard_counts:
        session = ShardedSearchSession(
            idx, shards=n, shard_strategy=strategy, k=10, layout="auto",
            buckets=(batch_rows,),
        )
        session.warmup()
        got_i, got_d = [], []
        for s in range(0, len(q), batch_rows):
            chunk = q[s: s + batch_rows]
            ids, dists = session.search(
                chunk, n_images=max(1, len(chunk) // desc_per_image)
            )
            got_i.append(ids)
            got_d.append(dists)
        if check_identity:
            if ref is None:
                ref = (np.concatenate(got_i), np.concatenate(got_d))
            else:
                np.testing.assert_array_equal(np.concatenate(got_i), ref[0])
                np.testing.assert_array_equal(np.concatenate(got_d), ref[1])
        m = session.metrics
        recomp = session.steady_state_recompiles()
        assert recomp == 0, f"shards={n}: {recomp} steady-state recompiles"
        entries.append({
            "shards": n,
            "plan": session.shard_plan.to_json(),
            "ms_per_image": m.ms_per_image,
            "engine_ms": m.engine_ms,
            "engine_batches": m.engine_batches,
            "query_rows": m.query_rows,
        })
        out_rows.append(row(
            f"serving_shards_{n}", m.engine_ms / 1e3 / m.engine_batches,
            f"ms_per_image={m.ms_per_image:.2f} "
            f"plan={session.shard_plan.describe().replace(' ', '_')} "
            f"identical={'checked' if check_identity else 'unchecked'}",
        ))
    out_dir = os.environ.get("REPRO_BENCH_OUT", "benchmarks/out")
    path = json_path or os.path.join(out_dir, "serving_shards.json")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {
        "header": bench_header(
            shard_plan={"strategy": strategy, "counts": list(shard_counts),
                        "segments": segments},
        ),
        "sweep": entries,
        "plan_observations": observations(),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    out_rows.append(row("serving_shards_json", 0.0, f"wrote={path}"))
    return out_rows


def smoke() -> int:
    """Tiny serving gate: small corpus, 2 buckets, ~100 requests; asserts
    p95 is finite and the compile count stays at the warmed-bucket count."""
    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    session = _session(c, buckets=(256, 1024), cache_leaves=256,
                       cache_admit=1, probes=2)
    warmed = session.recompiles()
    assert warmed == 2, f"expected 2 warmed bucket programs, got {warmed}"
    m = _replay(session, c, skew="zipf", n_requests=100, desc_per_image=20,
                rate=200.0)
    p95 = m.latency.percentile(95)
    assert math.isfinite(p95), f"p95 latency not finite: {p95}"
    assert session.recompiles() == warmed, (
        f"steady-state recompile: {session.recompiles()} != {warmed}"
    )
    assert m.requests == 100, f"served {m.requests}/100"
    print(
        f"# serving smoke: p50 {m.latency.percentile(50):.1f} ms, "
        f"p95 {p95:.1f} ms, ms/image {m.ms_per_image:.2f}, "
        f"cache hit {session.cache.hit_rate:.2f}, recompiles 0",
    )
    return 0


def sharded_smoke() -> int:
    """Scatter-gather gate. Asserts (a) a `ShardedSearchSession` returns
    ids+dists bit-identical to the unsharded `SearchSession` over the
    same index, (b) a small shard sweep (counts 1/2/3 over a 3-segment
    index) is per-count bit-identical and recompile-free (assertions
    inside :func:`shard_sweep`), and (c) the sweep's JSON artifact
    carries one row per shard count plus the git-rev/shard-plan header."""
    import tempfile

    import numpy as np

    from repro.index import Index
    from repro.serving import SearchSession, ShardedSearchSession

    c = Corpus(rows=20_000, dim=32, fanouts=(16, 16))
    idx = Index.create(c.tree, None, mesh=c.mesh)
    idx.append(c.vecs_np[:12_000])
    idx.append(c.vecs_np[12_000:])
    idx.commit()
    q, _ = c.queries(256)
    q = np.asarray(q)
    ref = SearchSession(idx, k=10, probes=2, buckets=(256,))
    ref.warmup()
    for shards in (2, 3):
        s = ShardedSearchSession(idx, shards=shards, k=10, probes=2,
                                 buckets=(256,))
        s.warmup()
        for n in (1, 100, 256):
            ids, dists = s.search(q[:n])
            ref_ids, ref_dists = ref.search(q[:n])
            np.testing.assert_array_equal(ids, ref_ids)
            np.testing.assert_array_equal(dists, ref_dists)
        assert s.steady_state_recompiles() == 0

    counts = (1, 2, 3)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "serving_shards.json")
        shard_sweep(
            counts, segments=3, n_queries=512, batch_rows=256,
            corpus=c, json_path=path,
        )
        with open(path) as f:
            payload = json.load(f)
    assert [e["shards"] for e in payload["sweep"]] == list(counts), payload
    assert payload["header"]["git_rev"], payload["header"]
    assert payload["header"]["shard_plan"]["strategy"] == "balanced"
    ms = ", ".join(
        f"x{e['shards']}={e['ms_per_image']:.2f}" for e in payload["sweep"]
    )
    print("# sharded smoke: session == sharded session (shards 2/3, "
          f"256 queries, k=10); sweep bit-identical at 1/2/3; ms/image {ms}")
    return 0


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the serving-session smoke gate")
    ap.add_argument("--sharded-smoke", action="store_true",
                    help="run the scatter-gather bit-identity gate")
    ap.add_argument("--shard-sweep", action="store_true",
                    help="ms/image vs shard count -> "
                         "benchmarks/out/serving_shards.json")
    ap.add_argument("--shards", type=int, nargs="+", default=(1, 2, 4),
                    help="shard counts to sweep")
    ap.add_argument("--segments", type=int, default=4,
                    help="segments the sweep corpus is appended as")
    ap.add_argument("--strategy", choices=("round_robin", "balanced"),
                    default="balanced")
    ap.add_argument("--json", default=None, help="JSON output path")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke()
    if args.sharded_smoke:
        return sharded_smoke()
    print("name,us_per_call,derived")
    if args.shard_sweep:
        rows = shard_sweep(tuple(args.shards), segments=args.segments,
                           strategy=args.strategy, json_path=args.json)
    else:
        rows = run()
    for r in rows:
        print(r)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
