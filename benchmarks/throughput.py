"""Paper Exp #5: batch-search throughput (ms per image) vs batch size.

The paper: 12k-image batches sustain ~210 ms/image over 100M images, >2x
better than small Copydays batches (~460 ms/image) — big batches amortise
the broadcast lookup table. Same protocol here, scaled."""

from __future__ import annotations

from benchmarks.common import Corpus, row, timeit


def run():
    out = []
    from repro.core.search import batch_search

    c = Corpus()
    desc_per_image = 24
    for n_images, tag in ((64, "copydays_batch"), (512, "12k_batch")):
        q, _ = c.queries(n_images * desc_per_image)
        t = timeit(
            lambda q=q: batch_search(c.index, c.tree, q, k=10, mesh=c.mesh,
                                     q_cap=1024),
            warmup=1, iters=3,
        )
        out.append(
            row(
                f"exp5_{tag}_{n_images}img", t,
                f"ms_per_image={t / n_images * 1e3:.2f} "
                f"(paper: 460 small / 210 large)",
            )
        )
    return out
