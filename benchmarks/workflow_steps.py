"""Paper Table 2: time per workflow step (deploy/transfer/index/lookup/
search/retrieve), scaled to the container."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Corpus, row, timeit


def run():
    out = []
    from repro.core.index_build import build_index
    from repro.core.lookup import build_lookup
    from repro.core.search import batch_search
    from repro.core.tree import build_tree
    from repro.data import synth
    from repro.distributed.meshutil import local_mesh

    mesh = local_mesh()
    rows, dim = 120_000, 64
    t0 = time.perf_counter()
    vecs_np, _ = synth.sample_descriptors(rows, dim, seed=0, n_centers=512)
    out.append(row("t2_generate_corpus", time.perf_counter() - t0,
                   f"rows={rows}"))

    t0 = time.perf_counter()
    vecs = jax.device_put(jnp.asarray(vecs_np))
    jax.block_until_ready(vecs)
    out.append(row("t2_transfer_to_devices", time.perf_counter() - t0,
                   "HDFS-upload analog"))

    t0 = time.perf_counter()
    tree = build_tree(vecs, (32, 32), key=jax.random.PRNGKey(1))
    jax.block_until_ready(tree.levels[-1])
    out.append(row("t2_tree_creation", time.perf_counter() - t0,
                   f"leaves={tree.n_leaves}"))

    t0 = time.perf_counter()
    index = build_index(vecs, tree, mesh)
    jax.block_until_ready(index.vecs)
    out.append(row("t2_index_creation", time.perf_counter() - t0,
                   f"overflow={int(index.overflow)}"))

    c = Corpus()
    q, _ = c.queries(4096)
    t0 = time.perf_counter()
    lk = jax.jit(build_lookup)(c.tree, q)
    jax.block_until_ready(lk.vecs)
    out.append(row("t2_lookup_table_creation", time.perf_counter() - t0,
                   f"queries={q.shape[0]}"))

    t0 = time.perf_counter()
    res = batch_search(c.index, c.tree, q, k=10, mesh=c.mesh)
    jax.block_until_ready(res.ids)
    out.append(row("t2_searching", time.perf_counter() - t0,
                   f"pairs={float(res.pairs):.3g}"))

    t0 = time.perf_counter()
    _ = jax.device_get((res.ids, res.dists))
    out.append(row("t2_retrieve_results", time.perf_counter() - t0, ""))
    return out
