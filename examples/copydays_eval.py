"""Search-quality evaluation: the paper's Fig 4 (Copydays) protocol.

Distorted query variants (crop / jpeg-noise / strong) are drowned in a
distractor collection; we report per-variant recall@1 of the original
image via k-NN voting — compare with the paper's ~82% average.

Run:  PYTHONPATH=src python examples/copydays_eval.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import batch_search, build_index, build_tree
from repro.data import synth
from repro.data.copydays import VARIANTS, make_copydays, vote_images
from repro.distributed.meshutil import local_mesh


def main():
    mesh = local_mesh()
    dim, n_images, dpi = 48, 800, 24
    print(f"corpus: {n_images} images x {dpi} descriptors (d={dim})")
    vecs_np, img_ids = synth.sample_images(n_images, dpi, dim, seed=0)

    rng = np.random.default_rng(1)
    originals = rng.choice(n_images, 100, replace=False)
    rows = np.isin(img_ids, originals)
    cd = make_copydays(vecs_np[rows], img_ids[rows], seed=2)
    print(f"queries: {len(cd.query_vecs)} descriptors from "
          f"{cd.n_originals} originals x {len(VARIANTS)} variants")

    vecs = jnp.asarray(vecs_np)
    tree = build_tree(vecs, (24, 24), key=jax.random.PRNGKey(3))
    index = build_index(vecs, tree, mesh)
    res = batch_search(index, tree, jnp.asarray(cd.query_vecs), k=10,
                       mesh=mesh, q_cap=2048)
    assert int(res.q_cap_overflow) == 0

    per_variant, avg = vote_images(
        np.array(res.ids), img_ids, cd.query_img, cd.query_variant,
        len(VARIANTS),
    )
    print()
    print(f"{'variant':<10} {'kept':>5} {'noise':>6} {'recall@1':>9}")
    for (name, keep, noise), r in zip(VARIANTS, per_variant):
        print(f"{name:<10} {keep:>5.0%} {noise:>6.1f} {r:>9.1%}")
    print(f"{'AVERAGE':<10} {'':>5} {'':>6} {avg:>9.1%}   (paper: ~82%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
