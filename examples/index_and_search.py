"""End-to-end driver: streaming index job + batched search serving.

This is the paper's full production pipeline (Table 2): stream a descriptor
store through the wave-scheduled index job (with an injected failure to
show retry), then serve query batches and report ms/image throughput — the
paper's 210 ms/image headline protocol.

Run:  PYTHONPATH=src python examples/index_and_search.py
"""

import sys

from repro.launch import index as index_job
from repro.launch import serve


def main():
    print("=" * 70)
    print("PHASE 1 — streaming index job (with injected failures + retry)")
    print("=" * 70)
    rc = index_job.main(
        [
            "--rows", "120000",
            "--dim", "48",
            "--block-rows", "30000",
            "--fanout", "24", "24",
            "--inject-failures",
        ]
    )
    assert rc == 0

    print()
    print("=" * 70)
    print("PHASE 2 — batched search serving (throughput protocol, Exp #5)")
    print("=" * 70)
    rc = serve.main(
        [
            "--rows", "120000",
            "--dim", "48",
            "--images", "2000",
            "--fanout", "24", "24",
            "--batches", "2",
            "--batch-images", "128",
        ]
    )
    assert rc == 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
