"""Quickstart: build a vocabulary-tree index and search it — the paper's
whole workflow in ~30 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import batch_search, build_index, build_tree
from repro.data import synth
from repro.distributed.meshutil import local_mesh

mesh = local_mesh()  # on a pod this is make_production_mesh()

# 1. a synthetic SIFT-like collection (50k descriptors, 64-d)
vecs_np, _ = synth.sample_descriptors(50_000, 64, seed=0, n_centers=256)
vecs = jnp.asarray(vecs_np)

# 2. the index tree: wide-fanout hierarchical quantization (paper §2.3)
tree = build_tree(vecs, fanouts=(16, 16), key=jax.random.PRNGKey(0))
print(f"index tree: {tree.n_leaves} leaves, {tree.nbytes / 1e6:.2f} MB")

# 3. distributed index creation: assign -> shuffle -> cluster-sort
index = build_index(vecs, tree, mesh)
print(f"index: {int(index.n_valid.sum())} descriptors, "
      f"routing overflow {int(index.overflow)}")

# 4. batch search: 100 noisy queries, k=5 approximate nearest neighbors.
#    layout="auto" lets the engine plan() heuristic pick the scan layout;
#    probes=3 visits each query's 3 nearest leaves (multi-probe recall
#    lever — see docs/engine.md for the recall/cost tradeoff)
queries = vecs[:100] + 2.0 * jax.random.normal(jax.random.PRNGKey(1), (100, 64))
for probes in (1, 3):
    result = batch_search(index, tree, queries, k=5, mesh=mesh,
                          layout="auto", probes=probes)
    top1 = np.array(result.ids[:, 0])
    print(f"probes={probes}: top-1 self-retrieval "
          f"{(top1 == np.arange(100)).mean():.0%}, "
          f"distance pairs {float(result.pairs):.3g} "
          f"(brute force would be {50_000 * 100:.3g})")
