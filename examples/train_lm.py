"""Train an LM from the arch zoo (reduced config) with checkpoint/resume.

Demonstrates the training substrate: AdamW, warmup-cosine, microbatch
accumulation, bf16 gradient compression with error feedback, and
mid-run checkpoint + resume producing a continuous loss curve.

Run:  PYTHONPATH=src python examples/train_lm.py
"""

import shutil

from repro.launch import train


def main():
    ckpt = "/tmp/repro_example_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)
    print("=== phase 1: steps 0..30 (bf16-compressed grads, 2 microbatches)")
    train.main(
        [
            "--arch", "internlm2-1.8b",
            "--steps", "30",
            "--batch", "8",
            "--seq", "64",
            "--microbatches", "2",
            "--compress", "bf16",
            "--ckpt-dir", ckpt,
            "--checkpoint-every", "10",
        ]
    )
    print("=== phase 2: simulated restart — resume from step 30, run to 60")
    train.main(
        [
            "--arch", "internlm2-1.8b",
            "--steps", "60",
            "--batch", "8",
            "--seq", "64",
            "--microbatches", "2",
            "--compress", "bf16",
            "--ckpt-dir", ckpt,
            "--checkpoint-every", "10",
            "--resume",
        ]
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
