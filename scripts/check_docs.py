#!/usr/bin/env python
"""Docs CI gate: cross-links must resolve, bash snippets must be real.

Run from the repo root (scripts/smoke.sh does):

    python scripts/check_docs.py

Checks every ``docs/*.md`` plus ``README.md`` for

  * markdown links ``[text](path)`` whose non-URL target does not exist
    (resolved against the file's directory, then the repo root);
  * path-like inline references (``docs/engine.md``, ``scripts/smoke.sh``,
    ``src/repro/...py``) that do not exist from the repo root;
  * fenced shell snippets: every ``python -m <module>`` must resolve to a
    real module (repo modules via ``src``/repo root, external ones via
    ``importlib``), every ``--flag`` passed to a repo module must appear
    literally in that module's source (argparse flags are declared as
    string literals), and every repo-path token must exist.

Exits 1 when any check fails (0 = docs are sound).
"""

from __future__ import annotations

import importlib.util
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PATH_REF = re.compile(
    r"\b((?:docs|scripts|benchmarks|examples|tests|src)/"
    r"[A-Za-z0-9_./-]*[A-Za-z0-9_-]\.(?:md|py|sh))\b"
)
FENCE = re.compile(r"^```")


def doc_files() -> list[str]:
    out = [
        os.path.join(REPO, "docs", n)
        for n in sorted(os.listdir(os.path.join(REPO, "docs")))
        if n.endswith(".md")
    ]
    readme = os.path.join(REPO, "README.md")
    if os.path.exists(readme):
        out.append(readme)
    return out


def module_source(mod: str) -> str | None:
    """Path of a ``python -m``-able module if it lives in this repo."""
    rel = mod.replace(".", os.sep)
    for cand in (
        os.path.join(REPO, "src", rel + ".py"),
        os.path.join(REPO, "src", rel, "__main__.py"),
        os.path.join(REPO, "src", rel, "__init__.py"),
        os.path.join(REPO, rel + ".py"),
        os.path.join(REPO, rel, "__main__.py"),
        os.path.join(REPO, rel, "__init__.py"),
    ):
        if os.path.exists(cand):
            return cand
    return None


def iter_fenced_lines(text: str):
    """Logical lines inside fenced blocks, backslash-continuations joined."""
    in_fence = False
    pending = ""
    for raw in text.splitlines():
        if FENCE.match(raw.strip()):
            in_fence = not in_fence
            pending = ""
            continue
        if not in_fence:
            continue
        line = raw.rstrip()
        if line.endswith("\\"):
            pending += line[:-1] + " "
            continue
        yield (pending + line).strip()
        pending = ""


def check_snippet_line(line: str, where: str, errors: list[str]) -> None:
    line = line.split("#", 1)[0].strip()  # trailing comments
    if not line:
        return
    tokens = line.split()
    # path-like tokens must exist (relative, known extension, not a URL)
    for t in tokens:
        t = t.strip("\"'`,;")
        if (
            "/" in t
            and not t.startswith(("/", "http:", "https:", "$"))
            and t.split("/", 1)[0]
            in ("docs", "scripts", "benchmarks", "examples", "tests", "src")
            and re.search(r"\.(?:py|sh|md)$", t)
            and not os.path.exists(os.path.join(REPO, t))
        ):
            errors.append(f"{where}: snippet references missing file {t!r}")
    # python -m <module> [--flags]
    if "-m" not in tokens:
        return
    mod = tokens[tokens.index("-m") + 1] if tokens.index("-m") + 1 < len(
        tokens
    ) else None
    if not mod or not re.fullmatch(r"[A-Za-z_][A-Za-z0-9_.]*", mod):
        return
    src = module_source(mod)
    if src is None:
        sys.path.insert(0, os.path.join(REPO, "src"))
        sys.path.insert(0, REPO)
        try:
            found = importlib.util.find_spec(mod) is not None
        except (ImportError, ValueError):
            found = False
        finally:
            sys.path = sys.path[2:]
        if not found:
            errors.append(f"{where}: snippet runs unknown module {mod!r}")
        return
    source = open(src).read()
    for t in tokens[tokens.index("-m") + 2:]:
        if not t.startswith("--"):
            continue
        flag = t.split("=", 1)[0].strip("\"'`,;")
        if flag == "--":
            continue
        # match the argparse declaration's *quoted* literal: a bare
        # substring test would let prefix typos ("--shard" for
        # "--shards") ride through on longer flags that contain them
        if f'"{flag}"' not in source and f"'{flag}'" not in source:
            errors.append(
                f"{where}: snippet passes {flag!r} which {mod} "
                f"({os.path.relpath(src, REPO)}) does not define"
            )


def check_file(path: str, errors: list[str]) -> None:
    rel = os.path.relpath(path, REPO)
    text = open(path).read()
    for m in MD_LINK.finditer(text):
        target = m.group(1).split("#", 1)[0]
        if not target or target.startswith(("http:", "https:", "mailto:")):
            continue
        if not (
            os.path.exists(os.path.join(os.path.dirname(path), target))
            or os.path.exists(os.path.join(REPO, target))
        ):
            errors.append(f"{rel}: broken link -> {target!r}")
    for m in PATH_REF.finditer(text):
        if not os.path.exists(os.path.join(REPO, m.group(1))):
            errors.append(f"{rel}: stale path reference {m.group(1)!r}")
    for line in iter_fenced_lines(text):
        check_snippet_line(line, rel, errors)


def main() -> int:
    errors: list[str] = []
    files = doc_files()
    for path in files:
        check_file(path, errors)
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    print(
        f"check_docs: {len(files)} files, "
        f"{len(errors)} problem{'s' if len(errors) != 1 else ''}"
    )
    # never the raw count: 256 failures would wrap to exit status 0
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
