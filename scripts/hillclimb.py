"""Perf-loop driver: roofline breakdowns and fused-scan block tuning.

Roofline mode (per-source breakdown of a dry-run cell, on 512 faked hosts):

  PYTHONPATH=src python scripts/hillclimb.py --arch phi3.5-moe-42b-a6.6b \
      --shape train_4k [--multi-pod] [--key wire|hbm|flops] [--variant NAME]

Tune mode (sweep fused block sizes on real devices, persist the winner
into the calibration blob — an index manifest's when --index-dir is
given, else the process default store; see docs/kernels.md):

  PYTHONPATH=src python scripts/hillclimb.py --tune-fused \
      [--index-dir DIR] [--queries 2048] [--block-sizes 256 512 1024 2048] \
      [--out tune.jsonl]

Variants are registered in repro.configs.variants and apply a named
beyond-baseline change to the cell (e.g. routed_moe, flash_attn).
"""

import argparse
import sys


def tune_fused(args) -> int:
    import json
    import os
    import time

    # `python scripts/hillclimb.py` puts scripts/ on sys.path, not the
    # repo root where the benchmarks package lives.
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.block_size import tune
    from repro.launch import roofline as rl

    index = None
    if args.index_dir:
        from repro.index import Index

        index = Index.open(args.index_dir)
    entries, winner = tune(
        index=index,
        q_n=args.queries,
        block_sizes=tuple(args.block_sizes),
    )
    for e in entries:
        print(f"  block_rows={e['block_rows']:<6d} ms={e['ms']:.2f}")
    where = (f"manifest calibration blob at {args.index_dir}"
             if args.index_dir else "process default calibration store")
    print(
        f"winner: block_rows={winner['block_rows']} ({winner['ms']:.2f} ms) "
        f"recorded for ({winner['layout']}, dim={winner['dim']}, "
        f"{winner['dtype']}) in the {where}"
    )
    est = rl.fused_scan_estimate(
        rows=winner["rows"], dim=winner["dim"], q_rows=args.queries,
        k=10, block_rows=winner["block_rows"],
    )
    print(
        f"roofline estimate: fused_intensity={est['fused_intensity']:.1f} "
        f"reference_intensity={est['reference_intensity']:.1f} "
        f"flop/byte over {est['n_waves']} waves"
    )
    if args.out:
        rec = dict(
            mode="tune_fused", status="ok", ts=time.time(),
            index_dir=args.index_dir, entries=entries, winner=winner,
            roofline_estimate=est,
        )
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--key", default=None, choices=[None, "hbm", "wire", "flops"])
    ap.add_argument("--variant", default=None)
    ap.add_argument("--top", type=int, default=14)
    ap.add_argument("--tune-fused", action="store_true",
                    help="sweep fused block sizes instead of a roofline run")
    ap.add_argument("--index-dir", default=None,
                    help="tune against this on-disk index; winner lands in "
                         "its manifest calibration blob")
    ap.add_argument("--queries", type=int, default=2048)
    ap.add_argument("--block-sizes", type=int, nargs="+",
                    default=[256, 512, 1024, 2048])
    ap.add_argument("--out", default=None, help="append JSONL record")
    args = ap.parse_args(argv)

    if args.tune_fused:
        # Real devices: the 512-host fake below is for dry-run lowering
        # only and would wreck a timed sweep.
        return tune_fused(args)
    if not args.arch or not args.shape:
        ap.error("--arch and --shape are required (unless --tune-fused)")

    import os

    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
    )

    import json
    import time

    import jax

    from repro.configs import REGISTRY
    from repro.launch import hlo_cost
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    if args.variant:
        from repro.configs import variants

        cell = variants.apply(args.variant, args.arch, args.shape)
    else:
        cell = REGISTRY[args.arch].cell(args.shape)
    t0 = time.time()
    lowered = cell.lower(mesh)
    compiled = lowered.compile()
    print(f"compiled in {time.time() - t0:.1f}s")
    cost = hlo_cost.analyze_text(compiled.as_text())
    t_c = cost.flops / rl.PEAK_FLOPS_BF16
    t_m = cost.hbm_bytes / rl.HBM_BW
    t_x = cost.wire_bytes / rl.ICI_LINK_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])
    print(
        f"roofline: compute={t_c:.4f}s memory={t_m:.4f}s collective={t_x:.4f}s"
        f"  dominant={dom[0]}"
    )
    mem = rl.memory_stats(compiled)
    print("memory_analysis:", json.dumps(mem))
    key = args.key or {"compute": "flops", "memory": "hbm", "collective": "wire"}[dom[0]]
    print(f"top sources by {key}:")
    for name, f, h, w in cost.top_sources(args.top, key=key):
        print(f"  {name[:110]:<110s} flops={f:.3e} hbm={h:.3e} wire={w:.3e}")
    if args.out:
        rec = dict(
            arch=args.arch, shape=args.shape,
            mesh="2x16x16" if args.multi_pod else "16x16",
            variant=args.variant or "baseline",
            status="ok",
            kind=cell.kind, model_flops=cell.model_flops,
            n_devices=len(jax.devices()),
            memory=mem,
            roofline=dict(
                flops=cost.flops, hbm_bytes=cost.hbm_bytes,
                wire_bytes=cost.wire_bytes, t_compute=t_c, t_memory=t_m,
                t_collective=t_x, dominant=dom[0],
                collectives=dict(cost.wire_by_op, total=cost.wire_bytes),
            ),
            model_flops_per_device=cell.model_flops / len(jax.devices()),
        )
        if cost.flops:
            rec["useful_flops_ratio"] = rec["model_flops_per_device"] / cost.flops
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
