"""Render EXPERIMENTS.md tables from dryrun_results.jsonl."""

from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_e(x):
    return f"{x:.2e}" if x else "-"


def main(path="dryrun_results.jsonl", mesh_filter=None):
    recs = [json.loads(l) for l in open(path)]
    # keep the latest record per (arch, shape, mesh)
    latest = {}
    for r in recs:
        latest[(r["arch"], r["shape"], r["mesh"])] = r
    rows = sorted(latest.values(), key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    if mesh_filter:
        rows = [r for r in rows if r["mesh"] == mesh_filter]

    print(
        "| arch | shape | mesh | status | FLOPs/dev | HBM B/dev | wire B/dev |"
        " t_comp | t_mem | t_coll | dominant | useful/HLO | arg+tmp mem |"
    )
    print("|" + "---|" * 13)
    for r in rows:
        if r["status"] != "ok":
            reason = r.get("skip_reason", r.get("error", ""))[:60]
            print(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']}"
                f" | - | - | - | - | - | - | - | - | {reason} |"
            )
            continue
        ro = r["roofline"]
        mem = r.get("memory", {})
        memtot = None
        if "argument_bytes" in mem:
            memtot = mem["argument_bytes"] + mem.get("temp_bytes", 0)
        ratio = r.get("useful_flops_ratio")
        print(
            "| {arch} | {shape} | {mesh} | ok | {fl} | {hb} | {wb} | "
            "{tc:.4f} | {tm:.4f} | {tx:.4f} | {dom} | {ur} | {mt} |".format(
                arch=r["arch"],
                shape=r["shape"],
                mesh=r["mesh"],
                fl=fmt_e(ro["flops"]),
                hb=fmt_e(ro["hbm_bytes"]),
                wb=fmt_e(ro["wire_bytes"]),
                tc=ro["t_compute"],
                tm=ro["t_memory"],
                tx=ro["t_collective"],
                dom=ro["dominant"],
                ur=f"{ratio:.3f}" if ratio else "-",
                mt=fmt_bytes(memtot),
            )
        )


if __name__ == "__main__":
    main(*sys.argv[1:])
