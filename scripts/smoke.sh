#!/usr/bin/env bash
# Per-PR smoke gate: the tier-1 suite plus a tiny end-to-end serve run on
# BOTH search layouts with multi-probe (--probes 2), so every future PR
# exercises the full engine serve path, not just unit tests.
#
# Usage: scripts/smoke.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 test suite =="
python -m pytest -x -q

echo "== serve smoke (both layouts, --probes 2) =="
python -m benchmarks.run --smoke

echo "smoke OK"
