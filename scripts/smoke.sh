#!/usr/bin/env bash
# Per-PR smoke gate: the tier-1 suite plus a tiny end-to-end serve run on
# BOTH search layouts with multi-probe (--probes 2), so every future PR
# exercises the full engine serve path, not just unit tests.
#
# Usage: scripts/smoke.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== docs gate (cross-links + snippet files/flags) =="
python scripts/check_docs.py

echo "== tier-1 test suite =="
python -m pytest -x -q

# includes the index-lifecycle gate (create -> append x2 -> search ->
# compact -> search, exactness asserted; standalone: benchmarks.indexing
# --smoke), the cost-model calibration round-trip gate (record -> commit ->
# reopen -> plan(model="auto") uses the fit; standalone: benchmarks.serving
# --calibration-smoke), the sharded scatter-gather gate (shards 1/2/3
# bit-identical to unsharded; standalone: benchmarks.serving --sharded-smoke)
# and the SLO scheduling gate (same trace under fifo and edf returns
# bit-identical results, EDF interactive p95 < batch p95; standalone:
# benchmarks.serving --slo-smoke), the compressed-codes gate (train ->
# commit -> reopen -> plan(auto) picks scan_codes -> ADC scan + exact
# rerank meets the recall floor at >=8x fewer resident bytes; standalone:
# benchmarks.serving --codes-smoke), the fused-kernel gate (the same
# served trace through impl="xla" and impl="fused" sessions returns
# bit-identical ids+dists, zero steady-state recompiles, fused ms/image
# within 1.5x of xla; standalone: benchmarks.serving --kernel-smoke),
# the dynamicity gate (serve a trace
# while a writer thread appends + incrementally compacts: 0 dropped
# requests, 0 steady-state recompiles, p95 within 2x of a frozen baseline,
# final results bit-identical to a fresh open; standalone:
# benchmarks.serving --dynamicity-smoke), and the observability gate
# (traced == untraced bit-identity at 2 shards, valid Chrome trace,
# registry dump, tracereport; standalone: benchmarks.serving --obs-smoke)
echo "== serve smoke (both layouts, --probes 2) + lifecycle + session + calibration + shard + SLO + codes + dynamicity + obs gates =="
python -m benchmarks.run --smoke

echo "== serving CLI smoke (zipf trace, hot-leaf cache, recompile gate) =="
python -m repro.launch.serve --rows 20000 --dim 32 --images 400 \
    --fanout 16 16 --trace zipf --requests 100 --buckets 512,1024 \
    --probes 2 --cache-leaves 256 --cache-admit 1 --rate 300 --no-recall \
    --cost-model auto

echo "== SLO serving CLI smoke (multi-tenant trace, p95 target, EDF) =="
python -m repro.launch.serve --rows 20000 --dim 32 --images 400 \
    --fanout 16 16 --trace multi --requests 120 --target-p95-ms 150 \
    --rate 400 --no-recall

echo "== sharded serving CLI smoke (scatter-gather, 2 shards, traced) =="
OBS_TMP="$(mktemp -d)"
trap 'rm -rf "$OBS_TMP"' EXIT
python -m repro.launch.serve --rows 20000 --dim 32 --images 400 \
    --fanout 16 16 --trace zipf --requests 100 --buckets 512 \
    --shards 2 --shard-plan balanced --cache-leaves 256 --cache-admit 1 \
    --rate 300 --no-recall \
    --trace-out "$OBS_TMP/serve_trace.json" \
    --metrics-out "$OBS_TMP/serve_metrics.json"

echo "== trace report (top-3 slowest from the traced CLI run) =="
python scripts/tracereport.py "$OBS_TMP/serve_trace.json" --top 3

echo "smoke OK"
