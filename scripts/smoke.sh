#!/usr/bin/env bash
# Per-PR smoke gate: the tier-1 suite plus a tiny end-to-end serve run on
# BOTH search layouts with multi-probe (--probes 2), so every future PR
# exercises the full engine serve path, not just unit tests.
#
# Usage: scripts/smoke.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 test suite =="
python -m pytest -x -q

# includes the index-lifecycle gate (create -> append x2 -> search ->
# compact -> search, exactness asserted); standalone: benchmarks.indexing --smoke
echo "== serve smoke (both layouts, --probes 2) + lifecycle + session gates =="
python -m benchmarks.run --smoke

echo "== serving CLI smoke (zipf trace, hot-leaf cache, recompile gate) =="
python -m repro.launch.serve --rows 20000 --dim 32 --images 400 \
    --fanout 16 16 --trace zipf --requests 100 --buckets 512,1024 \
    --probes 2 --cache-leaves 256 --cache-admit 1 --rate 300 --no-recall

echo "smoke OK"
