#!/usr/bin/env python3
"""Top-N-slowest breakdown of a recorded serving trace.

Reads either trace artifact the observability exporters produce
(docs/observability.md) — the Chrome ``trace_event`` JSON
(``--trace-out serving_trace.json``) or the structured JSONL event log
(``--trace-out serving_events.jsonl``) — and prints, with no repo or
third-party imports (stdlib only, no PYTHONPATH needed):

  * the top-N slowest requests, each split into queue-wait vs compute
    (vs cache lookup), with priority class and source;
  * per-priority-class totals (count, mean/max latency, mean wait share);
  * per-shard scan accounting (count, total/mean ms) and the gather-merge
    total — where the scatter-gather wall time actually went.

Usage:
  python scripts/tracereport.py benchmarks/out/serving_trace.json
  python scripts/tracereport.py benchmarks/out/serving_events.jsonl --top 10
"""

from __future__ import annotations

import argparse
import json
import sys


def _load_spans(path: str) -> list[dict]:
    """Normalise either artifact into span dicts: ``name``, ``trace_id``,
    ``dur_ms``, ``attrs`` (Chrome events: X-phase only; JSONL: header
    line skipped, ``kind == "span"`` only)."""
    spans = []
    if path.endswith(".jsonl"):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                if "header" in rec or rec.get("kind") != "span":
                    continue
                spans.append({
                    "name": rec["name"],
                    "trace_id": rec.get("trace_id"),
                    "dur_ms": float(rec.get("dur_ms") or 0.0),
                    "attrs": rec.get("attrs") or {},
                })
        return spans
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    for ev in events:
        if ev.get("ph") != "X":
            continue
        args = ev.get("args") or {}
        spans.append({
            "name": ev["name"],
            "trace_id": args.get("trace_id"),
            "dur_ms": float(ev.get("dur", 0.0)) / 1e3,
            "attrs": args,
        })
    return spans


def report(spans: list[dict], top: int = 5) -> str:
    requests, waits, computes, lookups = [], {}, {}, {}
    per_shard: dict[int, list[float]] = {}
    merge_ms, merge_n = 0.0, 0
    for s in spans:
        rid = s["trace_id"]
        if s["name"] == "request":
            requests.append(s)
        elif s["name"] == "queue.wait" and rid is not None:
            waits[rid] = s["dur_ms"]
        elif s["name"] == "compute" and rid is not None:
            computes[rid] = s["dur_ms"]
        elif s["name"] == "cache.lookup" and rid is not None:
            lookups[rid] = s["dur_ms"]
        elif s["name"] == "shard.scan":
            per_shard.setdefault(
                int(s["attrs"].get("shard", -1)), []
            ).append(s["dur_ms"])
        elif s["name"] == "gather.merge":
            merge_ms += s["dur_ms"]
            merge_n += 1
    lines = [f"== trace report: {len(spans)} spans, "
             f"{len(requests)} traced requests =="]
    if not requests:
        lines.append("(no request spans — was the replay traced with "
                     "sample > 0?)")
        return "\n".join(lines)

    requests.sort(key=lambda s: -s["dur_ms"])
    lines.append(f"-- top {min(top, len(requests))} slowest requests "
                 "(wait vs compute) --")
    for s in requests[:top]:
        rid = s["trace_id"]
        total = s["dur_ms"]
        wait = waits.get(rid, 0.0)
        comp = computes.get(rid, 0.0)
        share = wait / total if total else 0.0
        lines.append(
            f"rid={rid:<6} class={s['attrs'].get('priority', '?'):<12} "
            f"total={total:8.2f} ms  wait={wait:8.2f} ms ({share:4.0%})  "
            f"compute={comp:8.2f} ms  "
            f"source={s['attrs'].get('source', '?')}"
        )

    by_class: dict[str, list[dict]] = {}
    for s in requests:
        by_class.setdefault(s["attrs"].get("priority", "?"), []).append(s)
    lines.append("-- per class --")
    for name in sorted(by_class):
        rs = by_class[name]
        tot = [s["dur_ms"] for s in rs]
        ws = [waits.get(s["trace_id"], 0.0) for s in rs]
        wait_share = sum(ws) / sum(tot) if sum(tot) else 0.0
        lines.append(
            f"{name:<12} n={len(rs):<5} mean={sum(tot) / len(tot):8.2f} ms  "
            f"max={max(tot):8.2f} ms  wait-share={wait_share:4.0%}"
        )

    if per_shard:
        lines.append("-- per shard --")
        for shard in sorted(per_shard):
            ds = per_shard[shard]
            lines.append(
                f"shard {shard}: scans={len(ds):<5} "
                f"total={sum(ds):9.1f} ms  mean={sum(ds) / len(ds):7.2f} ms  "
                f"max={max(ds):7.2f} ms"
            )
        if merge_n:
            lines.append(
                f"gather.merge: n={merge_n} total={merge_ms:.1f} ms  "
                f"mean={merge_ms / merge_n:.2f} ms"
            )
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="top-N-slowest breakdown of a serving trace artifact"
    )
    ap.add_argument("trace", help="serving_trace.json (Chrome) or "
                                  "serving_events.jsonl (structured log)")
    ap.add_argument("--top", type=int, default=5,
                    help="how many slowest requests to show")
    args = ap.parse_args(argv)
    spans = _load_spans(args.trace)
    if not spans:
        print(f"error: no spans in {args.trace}", file=sys.stderr)
        return 1
    print(report(spans, top=args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
