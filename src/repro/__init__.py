"""repro: scalable high-dimensional indexing & search (Shestakov & Moise 2015),
re-architected for TPU pods in JAX.

The paper's MapReduce workflow (distributed vocabulary-tree index creation +
distributed batch k-NN search) is rebuilt as an SPMD dataflow:

  * HDFS blocks        -> sharded global arrays (``data`` mesh axis)
  * map waves          -> microbatched tiles per device shard
  * shuffle by cluster -> capacity-padded counting sort + ``all_to_all``
  * reduce             -> cluster-sorted index shards / log-tree k-NN merge

Public API re-exports live here; see DESIGN.md for the system inventory.
"""

from repro.core.tree import VocabTree, build_tree, tree_assign  # noqa: F401
from repro.core.index_build import build_index, DistributedIndex  # noqa: F401
from repro.core.search import batch_search, SearchResult  # noqa: F401
from repro.core.lookup import build_lookup, LookupTable  # noqa: F401
from repro.core.engine import SearchPlan, plan  # noqa: F401

__version__ = "1.0.0"
