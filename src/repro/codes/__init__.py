"""Compressed-codes tier: PQ encoder + exact rerank
(docs/compressed_codes.md)."""

from repro.codes.pq import CODES_FORMAT, ProductQuantizer  # noqa: F401
from repro.codes.rerank import rerank_exact  # noqa: F401
