"""Product quantization: the compressed-codes encoder (docs/compressed_codes.md).

A :class:`ProductQuantizer` splits the descriptor dimension into ``m``
subspaces and learns a ``2**bits``-centroid k-means codebook per subspace
from a *deterministic seeded sample* of the corpus. Encoding maps every
row to ``m`` uint8 codes (``m`` bytes/row vs ``4 * dim`` full-precision);
searching scans the codes with asymmetric distances (the query stays
full-precision, each code byte indexes a per-query lookup table) and
reranks the surviving candidates exactly from the raw rows.

Everything here is plain numpy on purpose: training/encoding are
index-build-time host work (like segment construction), and the byte
output must be reproducible — same seed + sample → byte-identical
codebooks and codes, which the manifest round-trip tests pin down.
"""

from __future__ import annotations

import numpy as np

CODES_FORMAT = 1

#: assignment/encoding chunk: bounds the (chunk, C) distance matrix
_CHUNK = 8192


def _sq_dists(x: np.ndarray, cents: np.ndarray) -> np.ndarray:
    """(n, C) squared L2 distances, f32; ||x||^2 dropped (argmin-safe)."""
    return (
        (cents * cents).sum(1)[None, :] - 2.0 * (x @ cents.T)
    ).astype(np.float32)


def _kmeans(x: np.ndarray, n_centers: int, iters: int,
            rng: np.random.Generator) -> np.ndarray:
    """Deterministic Lloyd k-means: seeded row init, fixed iterations,
    empty clusters reseeded to the worst-served points."""
    n = x.shape[0]
    cents = x[np.sort(rng.choice(n, n_centers, replace=n < n_centers))].copy()
    for _ in range(max(1, iters)):
        assign = np.empty(n, np.int64)
        mind = np.empty(n, np.float32)
        for s in range(0, n, _CHUNK):
            d = _sq_dists(x[s:s + _CHUNK], cents)
            assign[s:s + _CHUNK] = d.argmin(1)
            mind[s:s + _CHUNK] = d.min(1)
        sums = np.zeros_like(cents, dtype=np.float64)
        np.add.at(sums, assign, x.astype(np.float64))
        counts = np.bincount(assign, minlength=n_centers)
        empty = np.flatnonzero(counts == 0)
        if empty.size:
            # farthest-from-centroid points re-seed dead centers (ordered
            # by distance then index: fully deterministic)
            order = np.argsort(-mind, kind="stable")[: empty.size]
            for c, row in zip(empty, order):
                cents[c] = x[row]
                counts[c] = 1
                sums[c] = x[row].astype(np.float64)
        live = counts > 0
        cents[live] = (sums[live] / counts[live, None]).astype(np.float32)
    return cents.astype(np.float32)


class ProductQuantizer:
    """Per-subspace k-means codebooks + uint8 code encode/decode.

    Args:
      codebooks: ``(m, 2**bits, dim // m)`` float32 centroid table.
      meta: provenance (seed/sample/iters) carried through serialization.
    """

    def __init__(self, codebooks: np.ndarray, meta: dict | None = None):
        cb = np.asarray(codebooks, np.float32)
        if cb.ndim != 3:
            raise ValueError(f"codebooks must be (m, C, dsub), got {cb.shape}")
        self.codebooks = cb
        self.m = cb.shape[0]
        self.n_centers = cb.shape[1]
        self.bits = int(self.n_centers - 1).bit_length()
        if 1 << self.bits != self.n_centers or self.bits > 8:
            raise ValueError(
                f"n_centers {self.n_centers} must be a power of 2, <= 256"
            )
        self.dsub = cb.shape[2]
        self.dim = self.m * self.dsub
        self.meta = dict(meta or {})

    # -- training -----------------------------------------------------------
    @classmethod
    def train(cls, vecs, *, m: int = 4, bits: int = 8, seed: int = 0,
              sample: int = 65_536, iters: int = 16) -> "ProductQuantizer":
        """Fit per-subspace codebooks on a deterministic seeded sample.

        Args:
          vecs: ``(n, dim)`` training rows (the corpus or a slice of it).
          m: subvectors (bytes per encoded row); must divide ``dim``.
          bits: code width per subvector (``2**bits`` centroids, <= 8).
          seed: sample + init seed — same (seed, sample, vecs) trains
            byte-identical codebooks.
          sample: max training rows (seeded choice without replacement).
          iters: Lloyd iterations (fixed count — no data-dependent stop,
            so training is reproducible).
        """
        x = np.asarray(vecs, np.float32)
        n, dim = x.shape
        if dim % m:
            raise ValueError(f"{m=} must divide {dim=}")
        if not 1 <= bits <= 8:
            raise ValueError(f"{bits=} must be in [1, 8]")
        rng = np.random.default_rng(seed)
        take = min(int(sample), n)
        rows = np.sort(rng.choice(n, take, replace=False))
        xs = x[rows]
        dsub = dim // m
        cb = np.empty((m, 1 << bits, dsub), np.float32)
        for j in range(m):
            cb[j] = _kmeans(
                xs[:, j * dsub:(j + 1) * dsub], 1 << bits, iters,
                np.random.default_rng([seed, j]),
            )
        return cls(cb, meta={"seed": int(seed), "sample": int(take),
                             "iters": int(iters), "trained_rows": int(n)})

    # -- encode / decode ----------------------------------------------------
    def encode(self, vecs) -> np.ndarray:
        """``(n, dim)`` rows -> ``(n, m)`` uint8 codes (nearest centroid
        per subspace; ties break to the lowest code, deterministically)."""
        x = np.asarray(vecs, np.float32)
        if x.shape[-1] != self.dim:
            raise ValueError(f"dim mismatch: {x.shape[-1]} != {self.dim}")
        n = x.shape[0]
        codes = np.empty((n, self.m), np.uint8)
        for j in range(self.m):
            sub = x[:, j * self.dsub:(j + 1) * self.dsub]
            for s in range(0, n, _CHUNK):
                codes[s:s + _CHUNK, j] = _sq_dists(
                    sub[s:s + _CHUNK], self.codebooks[j]
                ).argmin(1).astype(np.uint8)
        return codes

    def decode(self, codes) -> np.ndarray:
        """``(n, m)`` codes -> ``(n, dim)`` reconstructed f32 rows."""
        c = np.asarray(codes)
        if c.shape[-1] != self.m:
            raise ValueError(f"code width {c.shape[-1]} != m={self.m}")
        out = np.empty((c.shape[0], self.dim), np.float32)
        for j in range(self.m):
            out[:, j * self.dsub:(j + 1) * self.dsub] = (
                self.codebooks[j][c[:, j].astype(np.int64)]
            )
        return out

    def lut(self, queries) -> np.ndarray:
        """``(q, dim)`` queries -> ``(q, m, C)`` squared-distance tables:
        ``lut[q, j, c] = ||q_j - codebook[j, c]||^2`` (the asymmetric
        distance is ``sum_j lut[q, j, codes[p, j]]``)."""
        q = np.asarray(queries, np.float32)
        sub = q.reshape(q.shape[0], self.m, self.dsub)
        diff = sub[:, :, None, :] - self.codebooks[None]
        return (diff * diff).sum(-1).astype(np.float32)

    # -- footprint ----------------------------------------------------------
    @property
    def bytes_per_row(self) -> int:
        """Resident bytes per encoded row (uint8 codes)."""
        return self.m

    @property
    def codebook_bytes(self) -> int:
        return int(self.codebooks.nbytes)

    def compression_ratio(self) -> float:
        """Full-precision bytes/row over code bytes/row (f32 baseline)."""
        return 4.0 * self.dim / self.m

    # -- serialization ------------------------------------------------------
    def to_json(self) -> dict:
        """Versioned manifest payload. Float32 values survive the JSON
        round-trip exactly (f32 -> f64 is exact, repr(f64) round-trips),
        so ``from_json(to_json())`` is byte-identical."""
        return {
            "format": CODES_FORMAT,
            "m": int(self.m),
            "bits": int(self.bits),
            "dsub": int(self.dsub),
            "meta": dict(self.meta),
            "codebooks": [
                [[float(v) for v in cent] for cent in book]
                for book in self.codebooks
            ],
        }

    @classmethod
    def from_json(cls, d: dict) -> "ProductQuantizer":
        cb = np.asarray(d["codebooks"], np.float32)
        pq = cls(cb, meta=d.get("meta"))
        if pq.m != int(d["m"]) or pq.bits != int(d["bits"]):
            raise ValueError(
                f"codebook shape {cb.shape} disagrees with m={d['m']}/"
                f"bits={d['bits']}"
            )
        return pq

    def __repr__(self) -> str:
        return (
            f"ProductQuantizer(m={self.m}, bits={self.bits}, dim={self.dim},"
            f" bytes/row={self.bytes_per_row})"
        )
