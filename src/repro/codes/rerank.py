"""Exact rerank over codes-scan survivors (docs/compressed_codes.md).

The ADC scan returns approximate per-query candidate ids; this stage
fetches the survivors' raw rows (one batched ``read_rows`` call) and
re-scores them with exact squared L2, so the final (ids, dists) ordering
is exact over the candidate set. The computation is canonical and pure
numpy — ascending (distance, id), f32 accumulation — which is what the
bit-identity tests (and the sharded serving merge) rely on: the same
candidate set always reranks to the same bytes.
"""

from __future__ import annotations

import numpy as np

from repro.core.sentinels import INVALID_ID


def rerank_exact(read_rows, queries, cand_ids, k: int):
    """Exact-L2 rerank of per-query candidate ids.

    Args:
      read_rows: ``ids (n,) -> rows (n, dim)`` raw-row fetch, called once
        with the sorted union of all surviving ids (``Index.read_rows`` /
        ``DescriptorStore.read_rows``).
      queries: ``(Q, dim)`` original full-precision queries.
      cand_ids: ``(Q, R)`` candidate ids from the codes scan,
        ``INVALID_ID`` (-1) where a slot is empty. Per-row duplicates are
        dropped (keeps the rerank well-defined under any upstream merge).
      k: neighbours to keep per query.

    Returns:
      ``(ids (Q, k) int32, dists (Q, k) float32)`` — exact squared L2,
      ascending, ties broken by ascending id; ``-1``/``inf`` padding where
      fewer than ``k`` valid candidates survived.
    """
    q = np.asarray(queries, np.float32)
    cand = np.asarray(cand_ids, np.int64)
    if cand.ndim != 2:
        raise ValueError(f"cand_ids must be (Q, R), got {cand.shape}")
    n_q, _ = cand.shape
    # canonical per-row order: ascending id (so distance ties break by id),
    # duplicates masked out
    cand = np.sort(cand, axis=1)
    dup = np.zeros_like(cand, dtype=bool)
    dup[:, 1:] = cand[:, 1:] == cand[:, :-1]
    valid = (cand >= 0) & ~dup
    uniq = np.unique(cand[valid])
    if uniq.size:
        vecs = np.asarray(read_rows(uniq), np.float32)
        pos = np.searchsorted(uniq, np.where(valid, cand, uniq[0]))
        d = ((vecs[pos] - q[:, None, :]) ** 2).sum(-1, dtype=np.float32)
        d = np.where(valid, d, np.float32(np.inf))
    else:
        d = np.full(cand.shape, np.inf, np.float32)
    order = np.argsort(d, axis=1, kind="stable")[:, :k]
    out_d = np.take_along_axis(d, order, axis=1)
    out_i = np.take_along_axis(cand, order, axis=1)
    out_i = np.where(np.isfinite(out_d), out_i, INVALID_ID).astype(np.int32)
    out_d = out_d.astype(np.float32)
    if out_d.shape[1] < k:
        pad = k - out_d.shape[1]
        out_d = np.pad(out_d, ((0, 0), (0, pad)),
                       constant_values=np.float32(np.inf))
        out_i = np.pad(out_i, ((0, 0), (0, pad)),
                       constant_values=np.int32(INVALID_ID))
    return out_i, out_d
