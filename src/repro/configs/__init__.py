"""Architecture registry: ``--arch <id>`` resolution for launchers/tests.

One module per assigned architecture (+ the paper's own ``sift100m``); each
exposes ``ARCH: ArchDef``. Import order defines the canonical cell order of
the roofline table.
"""

from repro.configs.base import ArchDef, Cell, get_arch, register  # noqa: F401

from repro.configs import (  # noqa: F401  (import side effect: registration)
    llama32_3b,
    gemma3_4b,
    internlm2_18b,
    moonshot_v1_16b,
    phi35_moe,
    gin_tu,
    dlrm_rm2,
    din,
    dien,
    two_tower,
    sift100m,
)
from repro.configs.base import REGISTRY  # noqa: F401  (after registration)

ASSIGNED = [
    "llama3.2-3b",
    "gemma3-4b",
    "internlm2-1.8b",
    "moonshot-v1-16b-a3b",
    "phi3.5-moe-42b-a6.6b",
    "gin-tu",
    "dlrm-rm2",
    "din",
    "dien",
    "two-tower-retrieval",
]
