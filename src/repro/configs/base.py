"""Cell/ArchDef machinery shared by every architecture config.

A *cell* = (architecture x input shape): everything the dry-run needs to
``jit(fn, in_shardings=...).lower(*abstract_args).compile()`` on a given
mesh, plus the MODEL_FLOPS bookkeeping the roofline analysis divides by.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.partitioning import DEFAULT_RULES, partition_spec

REGISTRY: Dict[str, "ArchDef"] = {}


def register(arch: "ArchDef") -> "ArchDef":
    REGISTRY[arch.name] = arch
    return arch


def get_arch(name: str) -> "ArchDef":
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def sharding_for(mesh: Mesh, spec_or_axes, shape=None) -> NamedSharding:
    """NamedSharding from either a PartitionSpec or logical axes (+shape)."""
    if isinstance(spec_or_axes, P):
        return NamedSharding(mesh, spec_or_axes)
    return NamedSharding(
        mesh, partition_spec(shape, spec_or_axes, mesh, DEFAULT_RULES)
    )


def logical_shardings(abstract_tree, axes_tree, mesh: Mesh):
    """Map matching pytrees of ShapeDtypeStructs + logical-axes tuples."""
    return jax.tree.map(
        lambda a, ax: sharding_for(mesh, tuple(ax), a.shape),
        abstract_tree,
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


@dataclasses.dataclass
class Cell:
    """One (arch x shape) dry-run unit."""

    arch: str
    shape: str
    kind: str  # train | prefill | decode | serve
    make_fn: Callable[[Mesh], Callable]  # returns the function to jit
    make_args: Callable[[Mesh], tuple]  # returns (args tuple of SDS-pytrees,
    #                                              in_shardings tuple)
    model_flops: float  # useful FLOPs per step (6ND train / 2ND inference)
    donate: tuple = ()
    skip: Optional[str] = None  # reason if this cell is a documented skip
    static_argnums: tuple = ()

    def lower(self, mesh: Mesh):
        fn = self.make_fn(mesh)
        args, shardings = self.make_args(mesh)
        jitted = jax.jit(
            fn, in_shardings=shardings, donate_argnums=self.donate
        )
        return jitted.lower(*args)


@dataclasses.dataclass
class ArchDef:
    name: str
    family: str  # lm | gnn | recsys | index
    config: object
    cells: Dict[str, Callable[[], Cell]]  # shape name -> cell factory
    smoke: Callable[[], dict]  # tiny CPU end-to-end step; returns metrics
    notes: str = ""

    def cell(self, shape: str) -> Cell:
        if shape not in self.cells:
            raise KeyError(
                f"arch {self.name} has no shape {shape!r}; has {sorted(self.cells)}"
            )
        return self.cells[shape]()

    def all_cells(self):
        return [self.cells[s]() for s in self.cells]
