"""dien [recsys] embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80
interaction=augru [arXiv:1809.03672; unverified]. DIN + GRU interest
extraction + AUGRU interest evolution (two lax.scan passes)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchDef, register, sds
from repro.configs.din import make_din_smoke
from repro.configs.recsys_common import mlp_flops, standard_recsys_cells
from repro.models import recsys

CONFIG = recsys.DINConfig(
    name="dien",
    embed_dim=18,
    seq_len=100,
    vocab=10_000_000,
    attn_mlp=(80, 40),
    mlp=(200, 80),
    gru_dim=108,
)


def batch_abs(b: int):
    return {
        "hist": sds((b, CONFIG.seq_len), jnp.int32),
        "target": sds((b,), jnp.int32),
        "label": sds((b,), jnp.float32),
    }


def serve_batch_abs(b: int):
    a = batch_abs(b)
    del a["label"]
    return a


def dien_flops_per_sample(cfg: recsys.DINConfig) -> float:
    D, T, H = cfg.embed_dim, cfg.seq_len, cfg.gru_dim
    gru = 2.0 * T * (3 * (D * H + H * H))
    augru = 2.0 * T * (3 * (H * H + H * H))
    att = T * mlp_flops((H + D, *cfg.attn_mlp, 1))
    fin = mlp_flops((H + D, *cfg.mlp, 1))
    return gru + augru + att + fin


def _forward_serve(params, cfg, b):
    return recsys.din_forward(params, cfg, b)


ARCH = register(
    ArchDef(
        name="dien",
        family="recsys",
        config=CONFIG,
        cells=standard_recsys_cells(
            "dien", CONFIG, recsys.din_loss, _forward_serve, batch_abs,
            dien_flops_per_sample(CONFIG), serve_batch_abs_fn=serve_batch_abs,
        ),
        smoke=make_din_smoke(16),
    )
)
