"""din [recsys] embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
interaction=target-attn [arXiv:1706.06978; paper]. Item table 10M x 18,
row-sharded over the model axis."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchDef, register, sds
from repro.configs.recsys_common import mlp_flops, standard_recsys_cells
from repro.models import recsys
from repro.models.module import init_params
from repro.train import AdamWConfig, make_train_step
from repro.train.step import init_train_state

CONFIG = recsys.DINConfig(
    name="din",
    embed_dim=18,
    seq_len=100,
    vocab=10_000_000,
    attn_mlp=(80, 40),
    mlp=(200, 80),
)


def batch_abs(b: int):
    return {
        "hist": sds((b, CONFIG.seq_len), jnp.int32),
        "target": sds((b,), jnp.int32),
        "label": sds((b,), jnp.float32),
    }


def serve_batch_abs(b: int):
    a = batch_abs(b)
    del a["label"]
    return a


def din_flops_per_sample(cfg: recsys.DINConfig) -> float:
    D, T = cfg.embed_dim, cfg.seq_len
    att = T * mlp_flops((4 * D, *cfg.attn_mlp, 1))
    pool = 2.0 * T * D
    fin = mlp_flops((3 * D, *cfg.mlp, 1))
    return att + pool + fin


def _forward_serve(params, cfg, b):
    return recsys.din_forward(params, cfg, b)


def make_din_smoke(gru_dim: int = 0):
    def smoke() -> dict:
        from repro.data.batches import din_batch

        cfg = recsys.DINConfig(
            name="din-smoke", vocab=2000, seq_len=20, gru_dim=gru_dim,
            attn_mlp=(16, 8), mlp=(24, 12),
        )
        params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
        opt = init_train_state(params)
        step = jax.jit(
            make_train_step(lambda p, b: recsys.din_loss(p, cfg, b), AdamWConfig())
        )
        b = jax.tree.map(jnp.asarray, din_batch(64, 20, 2000, seed=1))
        params, opt, m = step(params, opt, b)
        assert np.isfinite(float(m["loss"]))
        s = jax.jit(lambda p, bb: recsys.din_forward(p, cfg, bb))(
            params, {k: v for k, v in b.items() if k != "label"}
        )
        assert s.shape == (64,) and not bool(jnp.isnan(s).any())
        return {"loss": float(m["loss"]), "params": cfg.param_count()}

    return smoke


ARCH = register(
    ArchDef(
        name="din",
        family="recsys",
        config=CONFIG,
        cells=standard_recsys_cells(
            "din", CONFIG, recsys.din_loss, _forward_serve, batch_abs,
            din_flops_per_sample(CONFIG), serve_batch_abs_fn=serve_batch_abs,
        ),
        smoke=make_din_smoke(0),
    )
)
