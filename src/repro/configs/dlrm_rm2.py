"""dlrm-rm2 [recsys] n_dense=13 n_sparse=26 embed_dim=64
bot_mlp=13-512-256-64 top_mlp=512-512-256-1 interaction=dot
[arXiv:1906.00091; paper]. Tables: 26 x 1M rows x 64, row-sharded over the
model axis (the routed-lookup substrate, DESIGN.md §5)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchDef, register, sds
from repro.configs.recsys_common import mlp_flops, standard_recsys_cells
from repro.models import recsys
from repro.models.module import init_params
from repro.train import AdamWConfig, make_train_step
from repro.train.step import init_train_state

CONFIG = recsys.DLRMConfig(
    name="dlrm-rm2",
    n_dense=13,
    n_sparse=26,
    embed_dim=64,
    vocab_per_field=1_000_000,
    bot_mlp=(512, 256, 64),
    top_mlp=(512, 512, 256, 1),
)


def batch_abs(b: int):
    return {
        "dense": sds((b, CONFIG.n_dense), jnp.float32),
        "sparse": sds((b, CONFIG.n_sparse), jnp.int32),
        "label": sds((b,), jnp.float32),
    }


def serve_batch_abs(b: int):
    a = batch_abs(b)
    del a["label"]
    return a


_n_pairs = (CONFIG.n_sparse + 1) * CONFIG.n_sparse // 2
FLOPS_PER_SAMPLE = (
    mlp_flops((CONFIG.n_dense, *CONFIG.bot_mlp))
    + 2.0 * (CONFIG.n_sparse + 1) ** 2 * CONFIG.embed_dim  # dot interaction
    + mlp_flops((CONFIG.bot_mlp[-1] + _n_pairs, *CONFIG.top_mlp))
)


def _forward_serve(params, cfg, b):
    return recsys.dlrm_forward(params, cfg, b)


def dlrm_smoke() -> dict:
    from repro.data.batches import dlrm_batch

    cfg = recsys.DLRMConfig(name="dlrm-smoke", vocab_per_field=1000,
                            embed_dim=16, bot_mlp=(32, 16),
                            top_mlp=(32, 16, 1))
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    opt = init_train_state(params)
    step = jax.jit(
        make_train_step(lambda p, b: recsys.dlrm_loss(p, cfg, b), AdamWConfig())
    )
    b = jax.tree.map(jnp.asarray, dlrm_batch(64, 13, 26, 1000, seed=1))
    params, opt, m = step(params, opt, b)
    assert np.isfinite(float(m["loss"]))
    scores = jax.jit(lambda p, bb: recsys.dlrm_forward(p, cfg, bb))(
        params, {k: v for k, v in b.items() if k != "label"}
    )
    assert scores.shape == (64,) and not bool(jnp.isnan(scores).any())
    return {"loss": float(m["loss"]), "params": cfg.param_count()}


ARCH = register(
    ArchDef(
        name="dlrm-rm2",
        family="recsys",
        config=CONFIG,
        cells=standard_recsys_cells(
            "dlrm-rm2", CONFIG, recsys.dlrm_loss, _forward_serve, batch_abs,
            FLOPS_PER_SAMPLE, serve_batch_abs_fn=serve_batch_abs,
        ),
        smoke=dlrm_smoke,
    )
)
