"""gemma3-4b [dense] 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-4b-pt; unverified].

The hybrid 5 local (window 1024) : 1 global pattern makes this the one
assigned LM arch that runs long_500k (sub-quadratic family per shape spec).
"""

from repro.configs.base import ArchDef, register
from repro.configs.lm_common import lm_cells, lm_smoke
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma3-4b",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    window=1024,
    global_every=6,  # 5 local : 1 global
    rope_theta=1_000_000.0,
    scale_embed=True,
    qk_norm=True,
)

SMOKE_CONFIG = TransformerConfig(
    name="gemma3-4b-smoke",
    n_layers=6,  # one full 5:1 local:global period
    d_model=32,
    n_heads=4,
    n_kv_heads=2,
    head_dim=8,
    d_ff=64,
    vocab_size=256,
    window=4,
    global_every=6,
    scale_embed=True,
    qk_norm=True,
    dtype="float32",
)

ARCH = register(
    ArchDef(
        name="gemma3-4b",
        family="lm",
        config=CONFIG,
        cells=lm_cells("gemma3-4b", CONFIG, long_ok=True),
        smoke=lambda: lm_smoke(SMOKE_CONFIG),
    )
)
