"""gin-tu [gnn] n_layers=5 d_hidden=64 aggregator=sum eps=learnable
[arXiv:1810.00826; paper].

Four shape regimes (each its own padded static shape; edges shard over the
data axes, node features over model):
  full_graph_sm — Cora-scale full batch (2708 nodes / 10556 edges / 1433 f)
  minibatch_lg  — Reddit-scale sampled training (fanout 15-10, batch 1024)
  ogb_products  — 2.45M nodes / 61.9M edges full batch (d_feat 100)
  molecule      — 128 graphs x 30 nodes x 64 edges (disjoint union)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchDef, Cell, register, sds, sharding_for
from repro.distributed.meshutil import round_up
from repro.distributed.partitioning import shard_specs
from repro.distributed.shardutil import abstract_opt_state
from repro.models import gnn
from repro.models.module import abstract_params, init_params, shard_ctx
from repro.train import AdamWConfig, make_train_step
from repro.train.step import init_train_state


def _mlp_flops_gin(cfg: gnn.GINConfig, n_nodes: int, n_edges: int) -> float:
    h = cfg.d_hidden
    per_layer = 2.0 * n_nodes * (h * h * 2)
    l0 = 2.0 * n_nodes * (cfg.d_in * h + h * h)
    agg = cfg.n_layers * n_edges * h  # segment-sum adds
    out = 2.0 * n_nodes * h * cfg.n_classes
    return l0 + (cfg.n_layers - 1) * per_layer + agg + out


#: (shape name, d_in, n_classes, nodes, edges) — padded to mesh-safe sizes
SHAPES = {
    "full_graph_sm": dict(d_in=1433, n_classes=7, nodes=2708, edges=10556),
    "minibatch_lg": dict(d_in=602, n_classes=41, nodes=169984, edges=168960),
    "ogb_products": dict(d_in=100, n_classes=47, nodes=2449029, edges=61859140),
    "molecule": dict(d_in=16, n_classes=2, nodes=30 * 128, edges=64 * 128),
}


def _padded(spec):
    return dict(
        spec,
        nodes=round_up(spec["nodes"], 256),
        edges=round_up(spec["edges"], 1024),
    )


def make_gin_cell(shape_name: str) -> Cell:
    spec = _padded(SHAPES[shape_name])
    cfg = gnn.GINConfig(
        name="gin-tu",
        n_layers=5,
        d_hidden=64,
        d_in=spec["d_in"],
        n_classes=spec["n_classes"],
    )

    def make_fn(mesh):
        step = make_train_step(lambda p, b: gnn.loss_fn(p, cfg, b), AdamWConfig())

        def fn(params, opt_state, batch):
            with shard_ctx(mesh):
                return step(params, opt_state, batch)

        return fn

    def make_args(mesh):
        specs = cfg.param_specs()
        p_abs = abstract_params(specs)
        p_sh = shard_specs(specs, mesh)
        o_abs, o_sh = abstract_opt_state(p_abs, p_sh, mesh)
        N, E = spec["nodes"], spec["edges"]
        b_abs = {
            "feats": sds((N, spec["d_in"]), jnp.float32),
            "edges": sds((2, E), jnp.int32),
            "edge_w": sds((E,), jnp.float32),
            "labels": sds((N,), jnp.int32),
        }
        b_sh = {
            "feats": sharding_for(mesh, ("nodes", None), (N, spec["d_in"])),
            "edges": sharding_for(mesh, (None, "edges"), (2, E)),
            "edge_w": sharding_for(mesh, ("edges",), (E,)),
            "labels": sharding_for(mesh, ("nodes",), (N,)),
        }
        return (p_abs, o_abs, b_abs), (p_sh, o_sh, b_sh)

    return Cell(
        arch="gin-tu",
        shape=shape_name,
        kind="train",
        make_fn=make_fn,
        make_args=make_args,
        model_flops=3.0 * _mlp_flops_gin(cfg, spec["nodes"], spec["edges"]),
        donate=(0, 1),
    )


def gin_smoke() -> dict:
    """Reduced GIN + a real neighbor-sampled minibatch on CPU."""
    import numpy as np

    from repro.data import graph as gd

    cfg = gnn.GINConfig(name="gin-smoke", n_layers=3, d_in=12, d_hidden=16,
                        n_classes=4)
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    opt = init_train_state(params)
    step = jax.jit(
        make_train_step(lambda p, b: gnn.loss_fn(p, cfg, b), AdamWConfig())
    )
    g = gd.random_graph(300, 6.0, seed=1)
    feats = np.random.default_rng(2).standard_normal((300, 12)).astype(np.float32)
    labels = np.random.default_rng(3).integers(0, 4, 300).astype(np.int32)
    # full-batch step
    edges = gd.to_edge_list(g)
    batch = gd.pad_graph_batch(feats, edges, labels, n_nodes_pad=384,
                               n_edges_pad=round_up(edges.shape[1], 256))
    batch = jax.tree.map(jnp.asarray, batch)
    params2, opt2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    # sampled minibatch step (the minibatch_lg path, reduced)
    seeds = np.arange(32)
    sub, sedges, n_seed = gd.neighbor_sample(g, seeds, (5, 3), seed=4)
    sl = np.full(len(sub), -1, np.int32)
    sl[:n_seed] = labels[sub[:n_seed]]
    sb = gd.pad_graph_batch(feats[sub], sedges, sl, n_nodes_pad=640,
                            n_edges_pad=640)
    sb = jax.tree.map(jnp.asarray, sb)
    params3, _, m2 = step(params2, opt2, sb)
    assert np.isfinite(float(m2["loss"]))
    return {"loss": float(m["loss"]), "mb_loss": float(m2["loss"]),
            "params": cfg.param_count()}


ARCH = register(
    ArchDef(
        name="gin-tu",
        family="gnn",
        config=gnn.GINConfig(name="gin-tu", n_layers=5, d_hidden=64),
        cells={s: (lambda s=s: make_gin_cell(s)) for s in SHAPES},
        smoke=gin_smoke,
    )
)
