"""internlm2-1.8b [dense] 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 [arXiv:2403.17297; hf]. long_500k: documented skip."""

from repro.configs.base import ArchDef, register
from repro.configs.lm_common import lm_cells, lm_smoke
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="internlm2-1.8b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92544,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = TransformerConfig(
    name="internlm2-1.8b-smoke",
    n_layers=2,
    d_model=32,
    n_heads=4,
    n_kv_heads=2,
    head_dim=8,
    d_ff=64,
    vocab_size=256,
    dtype="float32",
)

ARCH = register(
    ArchDef(
        name="internlm2-1.8b",
        family="lm",
        config=CONFIG,
        cells=lm_cells("internlm2-1.8b", CONFIG, long_ok=False),
        smoke=lambda: lm_smoke(SMOKE_CONFIG),
    )
)
