"""llama3.2-3b [dense] 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256 [hf:meta-llama/Llama-3.2-3B; unverified].

Sharding note: 24 query heads do not divide model=16 — the head axis
replicates and the fused qkv projection axis (24*128=3072) shards instead
(divisibility fallback, DESIGN.md §6). long_500k is a documented skip
(pure full attention)."""

from repro.configs.base import ArchDef, register
from repro.configs.lm_common import lm_cells, lm_smoke
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="llama3.2-3b",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
)

SMOKE_CONFIG = TransformerConfig(
    name="llama3.2-3b-smoke",
    n_layers=2,
    d_model=48,
    n_heads=6,  # keep heads % kv != heads (GQA) and heads not divisible by 16
    n_kv_heads=2,
    head_dim=8,
    d_ff=96,
    vocab_size=256,
    dtype="float32",
)

ARCH = register(
    ArchDef(
        name="llama3.2-3b",
        family="lm",
        config=CONFIG,
        cells=lm_cells("llama3.2-3b", CONFIG, long_ok=False),
        smoke=lambda: lm_smoke(SMOKE_CONFIG),
    )
)
