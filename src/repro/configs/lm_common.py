"""Shared cell/smoke machinery for the LM-family architectures.

LM shapes (assigned): train_4k (4096 x 256, train_step), prefill_32k
(32768 x 32, prefill), decode_32k (one token, 32768-cache, batch 128),
long_500k (one token, 524288-cache, batch 1 — hybrid/sub-quadratic archs
only; pure full-attention archs record a documented skip).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import Cell, sds, sharding_for
from repro.distributed.partitioning import DEFAULT_RULES
from repro.distributed.shardutil import abstract_opt_state, tree_shardings
from repro.models import transformer as tfm
from repro.models.module import abstract_params, init_params, shard_ctx
from repro.train import AdamWConfig, make_train_step
from repro.train.step import init_train_state

TRAIN_4K = dict(seq=4096, batch=256)
PREFILL_32K = dict(seq=32768, batch=32)
DECODE_32K = dict(seq=32768, batch=128)
LONG_500K = dict(seq=524288, batch=1)


def _attn_eff_context(cfg: tfm.TransformerConfig, seq: int, *, decode: bool):
    """Per-layer average attended context length (window-aware)."""
    wins = []
    for i in range(cfg.n_layers):
        is_global = cfg.window <= 0 or (
            cfg.global_every > 0 and (i + 1) % cfg.global_every == 0
        )
        w = seq if is_global else min(cfg.window, seq)
        if not decode and w == seq:
            w = seq / 2  # causal averaging over query positions
        wins.append(w)
    return wins


def lm_model_flops(cfg: tfm.TransformerConfig, batch: int, seq: int, mode: str):
    """Useful-FLOPs bookkeeping: 6ND (train) / 2ND (inference) + lm-head +
    window-aware attention term. N excludes the embedding table (its only
    compute is the tied lm-head matmul, counted separately)."""
    V, D = cfg.vocab_size, cfg.d_model
    n_active = cfg.active_param_count() - V * D
    if mode == "decode":
        toks = batch
        ctx = _attn_eff_context(cfg, seq, decode=True)
        attn = sum(4.0 * toks * w * cfg.q_dim for w in ctx)
        return 2.0 * toks * (n_active + D * V) + attn
    toks = batch * seq
    ctx = _attn_eff_context(cfg, seq, decode=False)
    attn = sum(4.0 * toks * w * cfg.q_dim for w in ctx)
    fwd = 2.0 * toks * (n_active + D * V) + attn
    return 3.0 * fwd if mode == "train" else fwd


def _params_abstract_and_shardings(cfg, mesh):
    from repro.distributed.partitioning import shard_specs

    specs = cfg.param_specs()
    return abstract_params(specs), shard_specs(specs, mesh)


def _batch_sds(batch, seq):
    return {
        "tokens": sds((batch, seq), jnp.int32),
        "labels": sds((batch, seq), jnp.int32),
    }


def _batch_shardings(batch, seq, mesh):
    sh = sharding_for(mesh, ("batch", None), (batch, seq))
    return {"tokens": sh, "labels": sh}


def make_train_cell(name: str, cfg: tfm.TransformerConfig, *, seq: int,
                    batch: int, shape_name: str = "train_4k") -> Cell:
    def make_fn(mesh):
        step = make_train_step(
            lambda p, b: tfm.loss_fn(p, cfg, b), AdamWConfig(weight_decay=0.1)
        )

        def fn(params, opt_state, batch_):
            with shard_ctx(mesh):
                return step(params, opt_state, batch_)

        return fn

    def make_args(mesh):
        p_abs, p_sh = _params_abstract_and_shardings(cfg, mesh)
        o_abs, o_sh = abstract_opt_state(p_abs, p_sh, mesh)
        b_abs = _batch_sds(batch, seq)
        b_sh = _batch_shardings(batch, seq, mesh)
        return (p_abs, o_abs, b_abs), (p_sh, o_sh, b_sh)

    return Cell(
        arch=name,
        shape=shape_name,
        kind="train",
        make_fn=make_fn,
        make_args=make_args,
        model_flops=lm_model_flops(cfg, batch, seq, "train"),
        donate=(0, 1),
    )


def make_prefill_cell(name: str, cfg: tfm.TransformerConfig, *, seq: int,
                      batch: int, shape_name: str = "prefill_32k") -> Cell:
    def make_fn(mesh):
        def fn(params, tokens):
            with shard_ctx(mesh):
                return tfm.prefill(params, cfg, tokens, seq)

        return fn

    def make_args(mesh):
        p_abs, p_sh = _params_abstract_and_shardings(cfg, mesh)
        t_abs = sds((batch, seq), jnp.int32)
        t_sh = sharding_for(mesh, ("batch", None), (batch, seq))
        return (p_abs, t_abs), (p_sh, t_sh)

    return Cell(
        arch=name,
        shape=shape_name,
        kind="prefill",
        make_fn=make_fn,
        make_args=make_args,
        model_flops=lm_model_flops(cfg, batch, seq, "prefill"),
    )


def make_decode_cell(name: str, cfg: tfm.TransformerConfig, *, seq: int,
                     batch: int, shape_name: str, skip: str | None = None) -> Cell:
    def make_fn(mesh):
        def fn(params, tokens, cache, pos):
            with shard_ctx(mesh):
                return tfm.decode_step(params, cfg, tokens, cache, pos)

        return fn

    def make_args(mesh):
        p_abs, p_sh = _params_abstract_and_shardings(cfg, mesh)
        t_abs = sds((batch, 1), jnp.int32)
        t_sh = sharding_for(mesh, ("batch", None), (batch, 1))
        c_abs = tfm.cache_specs(cfg, batch, seq)
        c_sh = jax.tree.map(
            lambda a: sharding_for(mesh, tfm.CACHE_AXES, a.shape), c_abs
        )
        pos_abs = sds((), jnp.int32)
        pos_sh = sharding_for(mesh, (), ())
        return (p_abs, t_abs, c_abs, pos_abs), (p_sh, t_sh, c_sh, pos_sh)

    return Cell(
        arch=name,
        shape=shape_name,
        kind="decode",
        make_fn=make_fn,
        make_args=make_args,
        model_flops=lm_model_flops(cfg, batch, seq, "decode"),
        donate=(2,),
        skip=skip,
    )


def lm_cells(name: str, cfg: tfm.TransformerConfig, *, long_ok: bool):
    skip = (
        None
        if long_ok
        else "pure full-attention arch: 512k-context decode skipped per shape "
        "spec (sub-quadratic/hybrid archs only); see DESIGN.md §5"
    )
    return {
        "train_4k": lambda: make_train_cell(name, cfg, **TRAIN_4K),
        "prefill_32k": lambda: make_prefill_cell(name, cfg, **PREFILL_32K),
        "decode_32k": lambda: make_decode_cell(
            name, cfg, shape_name="decode_32k", **DECODE_32K
        ),
        "long_500k": lambda: make_decode_cell(
            name, cfg, shape_name="long_500k", skip=skip, **LONG_500K
        ),
    }


def lm_smoke(cfg: tfm.TransformerConfig, *, batch=2, seq=16) -> dict:
    """Reduced-config end-to-end: one train step + prefill + decode on CPU."""
    import numpy as np

    from repro.data.batches import lm_batch

    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    opt = init_train_state(params)
    step = make_train_step(lambda p, b: tfm.loss_fn(p, cfg, b), AdamWConfig())
    b = jax.tree.map(jnp.asarray, lm_batch(batch, seq, cfg.vocab_size, seed=1))
    params, opt, metrics = jax.jit(step)(params, opt, b)
    assert np.isfinite(float(metrics["loss"])), "train loss is not finite"
    logits, cache = jax.jit(lambda p, t: tfm.prefill(p, cfg, t, seq + 4))(
        params, b["tokens"]
    )
    assert logits.shape == (batch, seq, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), "prefill logits NaN"
    nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    dl, _ = jax.jit(
        lambda p, t, c: tfm.decode_step(p, cfg, t, c, jnp.int32(seq))
    )(params, nxt, cache)
    assert dl.shape == (batch, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(dl).any()), "decode logits NaN"
    return {"loss": float(metrics["loss"]), "params": cfg.param_count()}
