"""moonshot-v1-16b-a3b [moe] 48L d_model=2048 16H (GQA kv=16) d_ff=1408
(per expert), vocab=163840, MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf].

64 experts / model=16 -> 4 experts per chip (expert parallelism). The MoE
dispatch is the paper's lookup-table routing applied to experts
(repro.core.dispatch). long_500k: documented skip (full attention)."""

from repro.configs.base import ArchDef, register
from repro.configs.lm_common import lm_cells, lm_smoke
from repro.models.transformer import MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, capacity_factor=1.25),
    rope_theta=500_000.0,
)

SMOKE_CONFIG = TransformerConfig(
    name="moonshot-smoke",
    n_layers=2,
    d_model=32,
    n_heads=4,
    n_kv_heads=4,
    head_dim=8,
    d_ff=48,
    vocab_size=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=48, capacity_factor=2.0),
    dtype="float32",
)

ARCH = register(
    ArchDef(
        name="moonshot-v1-16b-a3b",
        family="lm",
        config=CONFIG,
        cells=lm_cells("moonshot-v1-16b-a3b", CONFIG, long_ok=False),
        smoke=lambda: lm_smoke(SMOKE_CONFIG),
    )
)
