"""phi3.5-moe-42b-a6.6b [moe] 32L d_model=4096 32H (GQA kv=8) d_ff=6400
(per expert), vocab=32064, MoE 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct; hf].

16 experts / model=16 -> exactly one expert per chip. long_500k:
documented skip (full attention)."""

from repro.configs.base import ArchDef, register
from repro.configs.lm_common import lm_cells, lm_smoke
from repro.models.transformer import MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=6400, capacity_factor=1.25),
    rope_theta=10_000.0,
)

SMOKE_CONFIG = TransformerConfig(
    name="phi35-moe-smoke",
    n_layers=2,
    d_model=32,
    n_heads=4,
    n_kv_heads=2,
    head_dim=8,
    d_ff=64,
    vocab_size=256,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, capacity_factor=2.0),
    dtype="float32",
)

ARCH = register(
    ArchDef(
        name="phi3.5-moe-42b-a6.6b",
        family="lm",
        config=CONFIG,
        cells=lm_cells("phi3.5-moe-42b-a6.6b", CONFIG, long_ok=False),
        smoke=lambda: lm_smoke(SMOKE_CONFIG),
    )
)
