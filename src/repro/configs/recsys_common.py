"""Shared cell machinery for the recsys architectures.

Shapes (assigned): train_batch (B=65536, train), serve_p99 (B=512, online
inference), serve_bulk (B=262144, offline scoring), retrieval_cand (one
query scored against 1M candidates).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import Cell, sds, sharding_for
from repro.distributed.partitioning import shard_specs
from repro.distributed.shardutil import abstract_opt_state
from repro.models.module import abstract_params, shard_ctx
from repro.train import AdamWConfig, make_train_step

TRAIN_B = 65536
P99_B = 512
BULK_B = 262144
CAND_N = 1_000_000


def mlp_flops(dims) -> float:
    return 2.0 * sum(a * b for a, b in zip(dims[:-1], dims[1:]))


def batch_tree_shardings(batch_abs, mesh):
    """Shard every leaf's leading dim over the batch axes."""
    return jax.tree.map(
        lambda a: sharding_for(mesh, ("batch",) + (None,) * (len(a.shape) - 1),
                               a.shape),
        batch_abs,
    )


def make_recsys_train_cell(
    arch: str,
    cfg,
    loss_fn: Callable,
    batch_abs_fn: Callable[[int], dict],
    flops_per_sample: float,
    *,
    batch: int = TRAIN_B,
    shape_name: str = "train_batch",
) -> Cell:
    def make_fn(mesh):
        step = make_train_step(lambda p, b: loss_fn(p, cfg, b), AdamWConfig())

        def fn(params, opt_state, b):
            with shard_ctx(mesh):
                return step(params, opt_state, b)

        return fn

    def make_args(mesh):
        specs = cfg.param_specs()
        p_abs = abstract_params(specs)
        p_sh = shard_specs(specs, mesh)
        o_abs, o_sh = abstract_opt_state(p_abs, p_sh, mesh)
        b_abs = batch_abs_fn(batch)
        b_sh = batch_tree_shardings(b_abs, mesh)
        return (p_abs, o_abs, b_abs), (p_sh, o_sh, b_sh)

    return Cell(
        arch=arch,
        shape=shape_name,
        kind="train",
        make_fn=make_fn,
        make_args=make_args,
        model_flops=3.0 * flops_per_sample * batch,
        donate=(0, 1),
    )


def make_recsys_serve_cell(
    arch: str,
    cfg,
    forward: Callable,
    batch_abs_fn: Callable[[int], dict],
    flops_per_sample: float,
    *,
    batch: int,
    shape_name: str,
) -> Cell:
    def make_fn(mesh):
        def fn(params, b):
            with shard_ctx(mesh):
                return forward(params, cfg, b)

        return fn

    def make_args(mesh):
        specs = cfg.param_specs()
        p_abs = abstract_params(specs)
        p_sh = shard_specs(specs, mesh)
        b_abs = batch_abs_fn(batch)
        b_sh = batch_tree_shardings(b_abs, mesh)
        return (p_abs, b_abs), (p_sh, b_sh)

    return Cell(
        arch=arch,
        shape=shape_name,
        kind="serve",
        make_fn=make_fn,
        make_args=make_args,
        model_flops=flops_per_sample * batch,
    )


def standard_recsys_cells(arch, cfg, loss_fn, forward, batch_abs_fn,
                          flops_per_sample, *, serve_batch_abs_fn=None,
                          retrieval_batch_abs_fn=None, retrieval_forward=None):
    """train_batch / serve_p99 / serve_bulk / retrieval_cand cell dict."""
    s_abs = serve_batch_abs_fn or batch_abs_fn
    r_abs = retrieval_batch_abs_fn or s_abs
    r_fwd = retrieval_forward or forward
    return {
        "train_batch": lambda: make_recsys_train_cell(
            arch, cfg, loss_fn, batch_abs_fn, flops_per_sample
        ),
        "serve_p99": lambda: make_recsys_serve_cell(
            arch, cfg, forward, s_abs, flops_per_sample,
            batch=P99_B, shape_name="serve_p99",
        ),
        "serve_bulk": lambda: make_recsys_serve_cell(
            arch, cfg, forward, s_abs, flops_per_sample,
            batch=BULK_B, shape_name="serve_bulk",
        ),
        "retrieval_cand": lambda: make_recsys_serve_cell(
            arch, cfg, r_fwd, r_abs, flops_per_sample,
            batch=CAND_N, shape_name="retrieval_cand",
        ),
    }
