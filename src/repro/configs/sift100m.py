"""sift100m — the paper's own architecture: vocabulary-tree index build +
batch search over SIFT descriptors (d=128), TPU-scaled.

The paper streams 4TB (30B descriptors) from HDFS; here each *step*
processes one resident window of 2^28 descriptors (64 GB bf16 global,
~128 MB/chip on the 512-chip mesh) — the 30B corpus is ~112 such waves
driven by launch/index.py + the WaveScheduler. Tree: fanout 256 x 256 =
65536 leaves (MXU-aligned wide fanout, DESIGN.md §2), ~17 MB replicated —
the paper's 1.8 GB broadcast index tree, three orders smaller relative to
device memory.

Shapes:
  index_wave   — one index-creation wave (map + shuffle + reduce), 2^28 rows
  search_1m    — 2^20-descriptor query batch (the "12k image" batch analog)
  search_32k   — 2^15-descriptor batch (the Copydays batch analog)
  tree_build   — sampling + hierarchy construction on a 2^22-row sample
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchDef, Cell, register, sds, sharding_for
from repro.core import index_build as ib
from repro.core import search as srch
from repro.core.lookup import LookupTable
from repro.core.tree import VocabTree, build_tree
from repro.distributed.meshutil import batch_axes, data_axis_size

DIM = 128
FANOUTS = (256, 256)
N_LEAVES = 65536
INDEX_ROWS = 2**28
WAVE_ROWS = 1024
CAPACITY_FACTOR = 2.0
K = 20


def tree_abstract():
    return VocabTree(
        levels=(
            sds((FANOUTS[0], DIM), jnp.float32),
            sds((FANOUTS[0], FANOUTS[1], DIM), jnp.float32),
        )
    )


def tree_shardings(mesh):
    rep = sharding_for(mesh, P())
    return VocabTree(levels=(rep, rep))


def all_axes(mesh):
    return tuple(mesh.axis_names)


def n_shards_for(mesh, axes=None):
    import math

    axes = axes or batch_axes(mesh)
    return math.prod(mesh.shape[a] for a in axes)


def index_abstract(mesh, rows: int, axes=None):
    n_shards = n_shards_for(mesh, axes)
    rows_per_shard = rows // n_shards
    capacity = ib.routing_capacity(rows_per_shard, n_shards, CAPACITY_FACTOR)
    r = n_shards * capacity  # received rows per shard
    lps = N_LEAVES // n_shards
    return ib.DistributedIndex(
        vecs=sds((n_shards * r, DIM), jnp.bfloat16),
        ids=sds((n_shards * r,), jnp.int32),
        leaves=sds((n_shards * r,), jnp.int32),
        offsets=sds((n_shards, lps + 1), jnp.int32),
        n_valid=sds((n_shards,), jnp.int32),
        overflow=sds((), jnp.int32),
        n_leaves=N_LEAVES,
    )


def index_shardings(mesh, axes=None):
    axes = axes or batch_axes(mesh)
    rows = sharding_for(mesh, P(axes, None))
    flat = sharding_for(mesh, P(axes))
    rep = sharding_for(mesh, P())
    return ib.DistributedIndex(
        vecs=rows, ids=flat, leaves=flat, offsets=flat, n_valid=flat,
        overflow=rep, n_leaves=N_LEAVES,
    )


def lookup_abstract(q_total: int):
    return LookupTable(
        vecs=sds((q_total, DIM), jnp.float32),
        qids=sds((q_total,), jnp.int32),
        leaves=sds((q_total,), jnp.int32),
        offsets=sds((N_LEAVES + 1,), jnp.int32),
    )


def lookup_shardings(mesh):
    rep = sharding_for(mesh, P())
    return LookupTable(vecs=rep, qids=rep, leaves=rep, offsets=rep)


def make_index_cell() -> Cell:
    def make_fn(mesh):
        n_shards = data_axis_size(mesh)
        return ib.build_index_fn(
            mesh,
            n_leaves=N_LEAVES,
            rows_per_shard=INDEX_ROWS // n_shards,
            wave_rows=WAVE_ROWS,
            capacity_factor=CAPACITY_FACTOR,
        )

    def make_args(mesh):
        axes = batch_axes(mesh)
        vecs = sds((INDEX_ROWS, DIM), jnp.bfloat16)
        ids = sds((INDEX_ROWS,), jnp.int32)
        return (
            (vecs, ids, tree_abstract()),
            (
                sharding_for(mesh, P(axes, None)),
                sharding_for(mesh, P(axes)),
                tree_shardings(mesh),
            ),
        )

    # useful work: every row 2d-GEMM'd against f0 + f1 centroids
    flops = INDEX_ROWS * 2.0 * DIM * (FANOUTS[0] + FANOUTS[1])
    return Cell(
        arch="sift100m",
        shape="index_wave",
        kind="train",
        make_fn=make_fn,
        make_args=make_args,
        model_flops=flops,
    )


def make_search_cell(shape_name: str, q_total: int, q_cap: int,
                     block_rows: int = 4096) -> Cell:
    def make_fn(mesh):
        n_shards = data_axis_size(mesh)
        idx_abs = index_abstract(mesh, INDEX_ROWS)
        shard_rows = idx_abs.vecs.shape[0] // n_shards
        return srch.batch_search_fn(
            mesh,
            n_leaves=N_LEAVES,
            shard_rows=shard_rows,
            q_total=q_total,
            block_rows=block_rows,
            q_cap=q_cap,
            k=K,
        )

    def make_args(mesh):
        return (
            (index_abstract(mesh, INDEX_ROWS), lookup_abstract(q_total)),
            (index_shardings(mesh), lookup_shardings(mesh)),
        )

    # useful work: expected same-leaf collision pairs x 2d (uniform estimate)
    pairs = INDEX_ROWS * (q_total / N_LEAVES)
    flops = pairs * 2.0 * DIM + q_total * 2.0 * DIM * sum(FANOUTS)
    return Cell(
        arch="sift100m",
        shape=shape_name,
        kind="serve",
        make_fn=make_fn,
        make_args=make_args,
        model_flops=flops,
    )


def make_tree_cell() -> Cell:
    sample_rows = 2**22

    def make_fn(mesh):
        def fn(vecs, key):
            return build_tree(vecs, FANOUTS, key=key, refine_iters=0)

        return fn

    def make_args(mesh):
        return (
            (sds((sample_rows, DIM), jnp.float32), sds((2,), jnp.uint32)),
            (sharding_for(mesh, P(batch_axes(mesh), None)),
             sharding_for(mesh, P())),
        )

    flops = sample_rows * 2.0 * DIM * (FANOUTS[0] + FANOUTS[1])
    return Cell(
        arch="sift100m",
        shape="tree_build",
        kind="train",
        make_fn=make_fn,
        make_args=make_args,
        model_flops=flops,
    )


def sift_smoke() -> dict:
    """Reduced end-to-end: build tree + index + search, check exactness."""
    from repro.core.index_build import build_index
    from repro.core.search import batch_search
    from repro.core.tree import tree_assign
    from repro.data import synth
    from repro.distributed.meshutil import local_mesh

    mesh = local_mesh()
    vecs_np, _ = synth.sample_descriptors(2048, 32, seed=0, n_centers=40)
    vecs = jnp.asarray(vecs_np)
    tree = build_tree(vecs, (8, 8), key=jax.random.PRNGKey(1))
    index = build_index(vecs, tree, mesh, wire_dtype=jnp.float32)
    assert int(index.overflow) == 0
    queries = vecs[:64] + 0.5
    res = batch_search(index, tree, queries, k=5, mesh=mesh, q_cap=64)
    assert int(res.q_cap_overflow) == 0
    top1 = np.array(res.ids[:, 0])
    # oracle: brute-force within-leaf
    leaves = np.array(tree_assign(tree, vecs))
    qleaves = np.array(tree_assign(tree, queries))
    V = np.array(vecs, np.float32)
    correct = 0
    for i in range(64):
        cand = np.flatnonzero(leaves == qleaves[i])
        d2 = ((V[cand] - np.array(queries[i])) ** 2).sum(1)
        if cand[np.argmin(d2)] == top1[i]:
            correct += 1
    assert correct >= 62, f"in-leaf nearest mismatch: {correct}/64"
    return {"top1_exact": correct / 64.0, "leaves": tree.n_leaves}


ARCH = register(
    ArchDef(
        name="sift100m",
        family="index",
        config=dict(dim=DIM, fanouts=FANOUTS, n_leaves=N_LEAVES,
                    index_rows_per_wave=INDEX_ROWS, k=K),
        cells={
            "index_wave": make_index_cell,
            "search_1m": lambda: make_search_cell("search_1m", 2**20, 4096),
            "search_32k": lambda: make_search_cell("search_32k", 2**15, 1024),
            "tree_build": make_tree_cell,
        },
        smoke=sift_smoke,
    )
)
