"""Hillclimb variants for the paper's own architecture (sift100m)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs import sift100m as s
from repro.configs.base import Cell
from repro.core import search as srch
from repro.distributed.meshutil import data_axis_size


def make_routed_search_cell(shape_name: str, q_total: int, *, q_tile: int,
                            p_cap: int, flat_mesh: bool = False) -> Cell:
    def make_fn(mesh):
        axes = s.all_axes(mesh) if flat_mesh else None
        n_shards = s.n_shards_for(mesh, axes)
        idx_abs = s.index_abstract(mesh, s.INDEX_ROWS, axes)
        shard_rows = idx_abs.vecs.shape[0] // n_shards
        return srch.routed_search_fn(
            mesh,
            n_leaves=s.N_LEAVES,
            shard_rows=shard_rows,
            q_total=q_total,
            q_tile=q_tile,
            p_cap=p_cap,
            k=s.K,
            axes=axes,
        )

    def make_args(mesh):
        axes = s.all_axes(mesh) if flat_mesh else None
        return (
            (s.index_abstract(mesh, s.INDEX_ROWS, axes),
             s.lookup_abstract(q_total)),
            (s.index_shardings(mesh, axes), s.lookup_shardings(mesh)),
        )

    pairs = s.INDEX_ROWS * (q_total / s.N_LEAVES)
    flops = pairs * 2.0 * s.DIM + q_total * 2.0 * s.DIM * sum(s.FANOUTS)
    return Cell(
        arch="sift100m",
        shape=shape_name,
        kind="serve",
        make_fn=make_fn,
        make_args=make_args,
        model_flops=flops,
    )


def make_flat_index_cell() -> Cell:
    """index_wave over ALL mesh axes (the paper's cluster is flat; leaving
    the model axis idle replicates the whole job 16x per pod)."""
    import dataclasses

    from jax.sharding import PartitionSpec as P

    import jax.numpy as jnp

    from repro.configs.base import sds, sharding_for
    from repro.core import index_build as ib

    def make_fn(mesh):
        axes = s.all_axes(mesh)
        n_shards = s.n_shards_for(mesh, axes)
        return ib.build_index_fn(
            mesh,
            n_leaves=s.N_LEAVES,
            rows_per_shard=s.INDEX_ROWS // n_shards,
            wave_rows=s.WAVE_ROWS,
            capacity_factor=s.CAPACITY_FACTOR,
            axes=axes,
        )

    def make_args(mesh):
        axes = s.all_axes(mesh)
        vecs = sds((s.INDEX_ROWS, s.DIM), jnp.bfloat16)
        ids = sds((s.INDEX_ROWS,), jnp.int32)
        return (
            (vecs, ids, s.tree_abstract()),
            (
                sharding_for(mesh, P(axes, None)),
                sharding_for(mesh, P(axes)),
                s.tree_shardings(mesh),
            ),
        )

    base = s.make_index_cell()
    return dataclasses.replace(base, make_fn=make_fn, make_args=make_args)


def apply(name: str, arch: str, shape: str) -> Cell:
    if arch != "sift100m":
        raise KeyError(f"unknown variant {name} for {arch}")
    if name == "query_routed":
        q_total = {"search_1m": 2**20, "search_32k": 2**15}[shape]
        return make_routed_search_cell(shape, q_total, q_tile=512, p_cap=8192)
    if name == "query_routed_flat":
        q_total = {"search_1m": 2**20, "search_32k": 2**15}[shape]
        return make_routed_search_cell(shape, q_total, q_tile=512, p_cap=8192,
                                       flat_mesh=True)
    if name == "flat_mesh":
        return make_flat_index_cell()
    raise KeyError(f"unknown variant {name}")
