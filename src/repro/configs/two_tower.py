"""two-tower-retrieval [recsys] embed_dim=256 tower_mlp=1024-512-256
interaction=dot, sampled-softmax retrieval [RecSys'19 (YouTube); unverified].

This is the arch where the paper's technique applies *directly*:
``retrieval_cand`` scores one user against 1M candidates — exactly the
batch k-NN problem. Both paths exist: dense exact scoring (this cell) and
the vocabulary-tree ANN route (benchmarks/ann_retrieval.py compares them).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchDef, register, sds
from repro.configs.recsys_common import (
    CAND_N,
    make_recsys_serve_cell,
    make_recsys_train_cell,
    mlp_flops,
)
from repro.models import recsys
from repro.models.module import init_params
from repro.train import AdamWConfig, make_train_step
from repro.train.step import init_train_state

CONFIG = recsys.TwoTowerConfig(
    name="two-tower-retrieval",
    embed_dim=256,
    field_dim=64,
    n_user_fields=4,
    n_item_fields=4,
    vocab_per_field=1_000_000,
    tower_mlp=(1024, 512, 256),
)

_TOWER_FLOPS = mlp_flops(
    (CONFIG.n_user_fields * CONFIG.field_dim, *CONFIG.tower_mlp)
)


def train_batch_abs(b: int):
    return {
        "user_ids": sds((b, CONFIG.n_user_fields), jnp.int32),
        "item_ids": sds((b, CONFIG.n_item_fields), jnp.int32),
    }


def pair_batch_abs(b: int):
    return train_batch_abs(b)


def retrieval_batch_abs(n_cand: int):
    return {
        "user_ids": sds((1, CONFIG.n_user_fields), jnp.int32),
        "cand_ids": sds((n_cand, CONFIG.n_item_fields), jnp.int32),
    }


def pair_score(params, cfg, b):
    """Online serving: score (user, item) pairs row-wise."""
    u = recsys.tower(params, cfg, "user", b["user_ids"])
    it = recsys.tower(params, cfg, "item", b["item_ids"])
    return jnp.sum(u * it, axis=-1).astype(jnp.float32)


def _cells():
    # train flops include the BxB in-batch softmax logits matmul
    def train_flops(b):
        return 3.0 * (2 * _TOWER_FLOPS + 2.0 * b * CONFIG.embed_dim)

    cells = {
        "train_batch": lambda: make_recsys_train_cell(
            "two-tower-retrieval", CONFIG, recsys.twotower_loss,
            train_batch_abs, train_flops(65536),
        ),
        "serve_p99": lambda: make_recsys_serve_cell(
            "two-tower-retrieval", CONFIG, pair_score, pair_batch_abs,
            2 * _TOWER_FLOPS + 2 * CONFIG.embed_dim, batch=512,
            shape_name="serve_p99",
        ),
        "serve_bulk": lambda: make_recsys_serve_cell(
            "two-tower-retrieval", CONFIG, pair_score, pair_batch_abs,
            2 * _TOWER_FLOPS + 2 * CONFIG.embed_dim, batch=262144,
            shape_name="serve_bulk",
        ),
        "retrieval_cand": lambda: make_recsys_serve_cell(
            "two-tower-retrieval", CONFIG, recsys.twotower_score,
            retrieval_batch_abs,
            _TOWER_FLOPS + 2 * CONFIG.embed_dim,  # item tower + dot per cand
            batch=CAND_N, shape_name="retrieval_cand",
        ),
    }
    return cells


def twotower_smoke() -> dict:
    from repro.data.batches import twotower_batch

    cfg = recsys.TwoTowerConfig(
        name="tt-smoke", vocab_per_field=1000, field_dim=16,
        tower_mlp=(64, 32), embed_dim=32,
    )
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(0))
    opt = init_train_state(params)
    step = jax.jit(
        make_train_step(lambda p, b: recsys.twotower_loss(p, cfg, b), AdamWConfig())
    )
    b = jax.tree.map(jnp.asarray, twotower_batch(64, 4, 4, 1000, seed=1))
    params, opt, m = step(params, opt, b)
    assert np.isfinite(float(m["loss"]))
    sc = jax.jit(lambda p, bb: recsys.twotower_score(p, cfg, bb))(
        params,
        {
            "user_ids": b["user_ids"][:1],
            "cand_ids": jnp.asarray(
                np.random.default_rng(2).integers(0, 1000, (256, 4), dtype=np.int32)
            ),
        },
    )
    assert sc.shape == (256,) and not bool(jnp.isnan(sc).any())
    return {"loss": float(m["loss"]), "params": cfg.param_count()}


ARCH = register(
    ArchDef(
        name="two-tower-retrieval",
        family="recsys",
        config=CONFIG,
        cells=_cells(),
        smoke=twotower_smoke,
    )
)
