"""Named beyond-baseline variants for the §Perf hillclimb.

``apply(name, arch, shape)`` returns a Cell identical to the baseline
except for one change, so before/after rooflines isolate that change.
"""

from __future__ import annotations

import dataclasses

from repro.configs import lm_common
from repro.configs.base import Cell


def _lm_config(arch: str):
    import importlib

    mod = {
        "llama3.2-3b": "repro.configs.llama32_3b",
        "gemma3-4b": "repro.configs.gemma3_4b",
        "internlm2-1.8b": "repro.configs.internlm2_18b",
        "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b",
        "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    }[arch]
    return importlib.import_module(mod).CONFIG


def _lm_cell_with(cfg, arch: str, shape: str) -> Cell:
    shapes = {
        "train_4k": lambda: lm_common.make_train_cell(arch, cfg, **lm_common.TRAIN_4K),
        "prefill_32k": lambda: lm_common.make_prefill_cell(
            arch, cfg, **lm_common.PREFILL_32K
        ),
        "decode_32k": lambda: lm_common.make_decode_cell(
            arch, cfg, shape_name="decode_32k", **lm_common.DECODE_32K
        ),
        "long_500k": lambda: lm_common.make_decode_cell(
            arch, cfg, shape_name="long_500k", **lm_common.LONG_500K
        ),
    }
    return shapes[shape]()


def routed_moe(arch: str, shape: str) -> Cell:
    """Hillclimb #1: MoE dispatch via shard_map all_to_all routing."""
    cfg = dataclasses.replace(_lm_config(arch), moe_impl="routed")
    return _lm_cell_with(cfg, arch, shape)


def head_pad(arch: str, shape: str) -> Cell:
    """Hillclimb #3 (llama3.2): pad 24 query heads -> 32 so the head axis
    divides model=16 and attention shards without replicate-then-partition
    resharding. +33% attention-einsum compute and ~3% params; a production
    deployment zero-initialises and freezes the 8 pad heads (wo rows = 0),
    which is bit-identical to the 24-head model."""
    cfg = _lm_config(arch)
    target = ((cfg.n_heads + 15) // 16) * 16
    cfg = dataclasses.replace(cfg, n_heads=target)
    return _lm_cell_with(cfg, arch, shape)


def head_pad_chunked(arch: str, shape: str) -> Cell:
    """Hillclimb #3 iteration 2: head padding + chunked (flash-dataflow)
    attention — bounds the materialised score tile to (Sq, chunk)."""
    cfg = _lm_config(arch)
    target = ((cfg.n_heads + 15) // 16) * 16
    cfg = dataclasses.replace(cfg, n_heads=target, attn_impl="chunked",
                              attn_chunk=1024)
    return _lm_cell_with(cfg, arch, shape)


def remat_full(arch: str, shape: str) -> Cell:
    """Memory knob: full remat (nothing saved) for train cells."""
    cfg = dataclasses.replace(_lm_config(arch), remat="full")
    return _lm_cell_with(cfg, arch, shape)


def microbatch8(arch: str, shape: str) -> Cell:
    """Memory knob: 8-way gradient accumulation."""
    cfg = _lm_config(arch)
    base = lm_common.make_train_cell(arch, cfg, **lm_common.TRAIN_4K)

    import jax

    from repro.models import transformer as tfm
    from repro.models.module import shard_ctx
    from repro.train import AdamWConfig, make_train_step

    def make_fn(mesh):
        step = make_train_step(
            lambda p, b: tfm.loss_fn(p, cfg, b),
            AdamWConfig(weight_decay=0.1),
            microbatches=8,
        )

        def fn(params, opt_state, batch_):
            with shard_ctx(mesh):
                return step(params, opt_state, batch_)

        return fn

    return dataclasses.replace(base, make_fn=make_fn)


VARIANTS = {
    "routed_moe": routed_moe,
    "head_pad": head_pad,
    "head_pad_chunked": head_pad_chunked,
    "remat_full": remat_full,
    "microbatch8": microbatch8,
}


def apply(name: str, arch: str, shape: str) -> Cell:
    if name not in VARIANTS:
        # search/index variants register lazily (sift100m module)
        from repro.configs import sift_variants

        return sift_variants.apply(name, arch, shape)
    return VARIANTS[name](arch, shape)
