# The paper's primary contribution: distributed vocabulary-tree indexing and
# batch k-NN search, as an SPMD dataflow (assign -> route/all_to_all -> sort;
# lookup-join -> distance GEMM -> top-k merge). See DESIGN.md §2-4.
from repro.core.tree import VocabTree, build_tree, tree_assign  # noqa: F401
from repro.core.lookup import LookupTable, build_lookup  # noqa: F401
from repro.core.index_build import DistributedIndex, build_index  # noqa: F401
from repro.core.search import SearchResult, batch_search  # noqa: F401
from repro.core.engine import SearchPlan, make_executor, plan  # noqa: F401
