"""Capacity-padded dispatch/combine: one substrate, three clients.

The paper's lookup table "reorders query descriptors by their closest
representative" so per-cluster work becomes dense. That is the same
primitive as MoE token dispatch (group tokens by expert) and recsys
embedding-bag grouping (group ids by table shard). This module implements it
once, sort-based (no O(n*E*c) one-hot einsum), and the MoE layers, the index
pipeline, and the embedding sharding all call it.

``assign`` maps each of n rows to a bucket in [0, n_buckets); each bucket
accepts up to ``capacity`` rows; the rest are dropped-and-counted (MoE calls
this token dropping; the paper calls it a failed task).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.route import counting_layout


class Dispatch(NamedTuple):
    gather_idx: jax.Array  # (n_buckets, capacity) row index into x (0 if empty)
    slot_valid: jax.Array  # (n_buckets, capacity) bool
    slot_of_row: jax.Array  # (n,) flat slot per row, -1 if dropped
    fits: jax.Array  # (n,) bool
    overflow: jax.Array  # () int32 dropped rows


def make_dispatch(assign: jax.Array, n_buckets: int, capacity: int) -> Dispatch:
    n = assign.shape[0]
    layout = counting_layout(assign.astype(jnp.int32), n_buckets, capacity)
    flat = n_buckets * capacity
    slot = jnp.where(layout.fits, layout.slot_of_row, flat)
    gather_flat = jnp.zeros((flat + 1,), jnp.int32).at[slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop"
    )[:flat]
    valid_flat = jnp.zeros((flat + 1,), jnp.bool_).at[slot].set(
        True, mode="drop"
    )[:flat]
    return Dispatch(
        gather_idx=gather_flat.reshape(n_buckets, capacity),
        slot_valid=valid_flat.reshape(n_buckets, capacity),
        slot_of_row=layout.slot_of_row,
        fits=layout.fits,
        overflow=layout.overflow,
    )


def dispatch_rows(d: Dispatch, x: jax.Array) -> jax.Array:
    """(n, ...) -> (n_buckets, capacity, ...), empty slots zeroed."""
    out = x[d.gather_idx]
    mask_shape = d.slot_valid.shape + (1,) * (x.ndim - 1)
    return out * d.slot_valid.reshape(mask_shape).astype(out.dtype)


def combine_rows(d: Dispatch, y: jax.Array, fill=0) -> jax.Array:
    """(n_buckets, capacity, ...) -> (n, ...); dropped rows get ``fill``."""
    nb, cap = d.gather_idx.shape
    flat = y.reshape((nb * cap,) + y.shape[2:])
    n = d.slot_of_row.shape[0]
    safe_slot = jnp.clip(d.slot_of_row, 0, nb * cap - 1)
    out = flat[safe_slot]
    mask_shape = (n,) + (1,) * (y.ndim - 2)
    keep = d.fits.reshape(mask_shape)
    return jnp.where(keep, out, jnp.asarray(fill, dtype=out.dtype))
