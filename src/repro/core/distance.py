"""L2-distance algebra used everywhere (index build, search, k-means refine).

All entry points use the expansion  ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2
so the inner loop is a GEMM (MXU work on TPU). The ``x`` norm term is dropped
where only an argmin/top-k over ``c`` is needed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sq_norms(x: jax.Array) -> jax.Array:
    """Row squared norms, accumulated in fp32."""
    xf = x.astype(jnp.float32)
    return jnp.sum(xf * xf, axis=-1)


def sq_dists(x: jax.Array, c: jax.Array, c_norms: jax.Array | None = None) -> jax.Array:
    """Full (n, m) squared distances between rows of x (n,d) and c (m,d)."""
    if c_norms is None:
        c_norms = sq_norms(c)
    dots = jnp.einsum(
        "nd,md->nm", x, c, preferred_element_type=jnp.float32
    )
    return sq_norms(x)[:, None] - 2.0 * dots + c_norms[None, :]


def nearest(x: jax.Array, c: jax.Array, c_norms: jax.Array | None = None):
    """(argmin, min_sqdist) of each row of x over centroid rows c.

    The ||x||^2 term is omitted from the argmin and added back to the
    returned distance, saving one reduction.
    """
    if c_norms is None:
        c_norms = sq_norms(c)
    dots = jnp.einsum("nd,md->nm", x, c, preferred_element_type=jnp.float32)
    partial = c_norms[None, :] - 2.0 * dots  # (n, m)
    idx = jnp.argmin(partial, axis=1)
    best = jnp.min(partial, axis=1) + sq_norms(x)
    return idx.astype(jnp.int32), best


def topk_neighbors(x: jax.Array, c: jax.Array, k: int,
                   c_norms: jax.Array | None = None):
    """(indices, sq_dists) of the k nearest rows of c for each row of x."""
    d2 = sq_dists(x, c, c_norms)
    neg, idx = jax.lax.top_k(-d2, k)
    return idx.astype(jnp.int32), -neg
