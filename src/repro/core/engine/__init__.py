"""Unified search-engine subsystem.

One declarative :class:`SearchPlan` describes *how* a batch of queries is
executed against a :class:`~repro.core.index_build.DistributedIndex`:
layout (point-major wave scan vs query-routed), tile sizes, slab budgets,
``k``, multi-probe width, kernel impl and wire dtype. ``plan()`` auto-picks
layout and budgets from the index/mesh/query shapes by consulting a
pluggable cost model (:mod:`repro.core.engine.costmodel`: fitted >
observed > heuristic); ``make_executor()`` builds the jittable
``(index, lookup) -> SearchResult`` pipeline for a plan.

Both executors are thin orchestrations over the shared tile-scan core in
:mod:`repro.core.engine.tilescan` — slab slicing, the fused distance+top-k
candidate fold, and pairs/overflow accounting are written once.
"""

from repro.core.engine.costmodel import (  # noqa: F401
    FIT_FORM,
    MODEL_KINDS,
    CalibrationStore,
    CostModel,
    FittedModel,
    HeuristicModel,
    ModelChain,
    ObservedModel,
    PlanShapes,
    default_calibration,
    fitted_component,
    observations,
    plan_signature,
    record_observation,
    reset_default_calibration,
    reset_observations,
    resolve_model,
    scale_slab_budget,
    shard_slab_scales,
)
from repro.core.engine.plan import (  # noqa: F401
    LAYOUTS,
    SearchPlan,
    bucket_ladder,
    default_rerank,
    largest_divisor_leq,
    plan,
    snap_to_bucket,
)
from repro.core.engine.executors import (  # noqa: F401
    SearchResult,
    make_executor,
    pad_lookup,
)
