"""Pluggable cost models: what ``plan()`` consults to pick layouts/budgets.

The paper's headline result — a stable ~210 ms/image at 100M-image scale —
comes from tuning index/search parameters to the *measured* behaviour of
the cluster, not from a fixed heuristic. This module is that calibration
loop as a subsystem: a :class:`CostModel` interface with three
implementations, plus the durable :class:`CalibrationStore` they share.

  * :class:`HeuristicModel` — the shape rules (distance pairs + carry
    traffic) that used to live inline in ``plan()``. Always decides.
  * :class:`ObservedModel` — exact-signature measured ms/image: decides
    only when *every* candidate plan has been measured under its exact
    plan signature.
  * :class:`FittedModel` — least-squares fits, per layout, the parametric
    cost ``ms ≈ a·(rows_scanned/tile) + b·probes·leaves + c·batch + d``
    from all recorded observations, so measurements at one shape inform
    nearby unmeasured shapes. Slope coefficients are clamped ≥ 0, making
    predictions monotone in ``rows_scanned``.

``resolve_model("auto", store)`` builds the default fallback chain
**fitted > observed > heuristic**: the most calibrated model that can
rank the candidates decides. A model only ever picks layouts and budgets
— it never alters search results (bit-identity is the invariant every
consumer's tests assert under every model setting).

Calibration data is *index-scoped*: each :class:`repro.index.Index`
carries a :class:`CalibrationStore` persisted in its manifest
(``calibration`` field, versioned like ``shard_plan``), recorded into by
the serving session after warmup and reloaded on ``Index.open``. The
module-level default store exists for the eager/legacy paths
(``engine.observations()`` et al.) and is reset around every test.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

LAYOUTS = ("point_major", "query_routed", "scan_codes")

#: the full-precision scan layouts — what calibration readiness is gated
#: on (every index can run these; "scan_codes" needs a codes artifact and
#: only enters the candidate set when one exists)
DENSE_LAYOUTS = ("point_major", "query_routed")

#: every field of a plan that shapes its cost (and its signature key)
SIGNATURE_FIELDS = (
    "layout", "k", "probes", "impl", "block_rows", "q_cap", "q_tile", "p_cap",
)

MODEL_KINDS = ("auto", "heuristic", "observed", "fitted")

#: format 2 added record timestamps (decay windowing) and the autotuned
#: tile-config blob; ``from_json`` still accepts format-1 payloads
#: (legacy records load as fresh — better to trust an undated measurement
#: than to discard the only calibration an old manifest has)
CALIBRATION_FORMAT = 2

#: the FittedModel's parametric form - the single source the benchmark
#: artifacts quote (keep in lockstep with FittedModel.features)
FIT_FORM = "ms ~ a*(rows_scanned/tile) + b*probes*leaves + c*batch + d"

#: exponential-decay half-life for calibration records: a measurement
#: ``age`` seconds old carries weight ``0.5 ** (age / half_life)`` in the
#: fitted model, so ms/image measured on a previous impl/hardware stops
#: steering ``plan(model="auto")`` as fresh measurements accumulate
CALIBRATION_HALF_LIFE_S = 7 * 24 * 3600.0

#: records older than this many half-lives are dropped outright (from
#: fits, exact-signature consults, and tuned tile configs) — their weight
#: would be < 0.4% anyway, and a lone stale record must not decide alone
CALIBRATION_MAX_AGE_HALF_LIVES = 8.0


def _age_weight(ts: float, now: float) -> float:
    """Exponential-window weight of a record last touched at ``ts``."""
    age = max(0.0, now - ts)
    return 0.5 ** (age / CALIBRATION_HALF_LIFE_S)


def _is_stale(ts: float, now: float) -> bool:
    return (now - ts) > CALIBRATION_MAX_AGE_HALF_LIVES * CALIBRATION_HALF_LIFE_S


def plan_signature(plan) -> tuple:
    """The cost-relevant identity of a resolved plan (hashable)."""
    return tuple(getattr(plan, f) for f in SIGNATURE_FIELDS)


def signature_key(sig: tuple) -> str:
    """Stable string form of a plan signature (JSON dict key)."""
    layout, k, probes, impl, block_rows, q_cap, q_tile, p_cap = sig
    return (
        f"{layout}/k={k}/probes={probes}/impl={impl}/"
        f"block_rows={block_rows}/q_cap={q_cap}/"
        f"q_tile={q_tile}/p_cap={p_cap}"
    )


@dataclasses.dataclass(frozen=True)
class PlanShapes:
    """The index/query shapes a plan decision (or measurement) was taken
    at — the features the fitted model generalizes over.

    Args:
      rows: padded index rows the plan scans (summed over shards).
      n_queries: query rows per batch, pre-probe-expansion.
      n_shards: device row-shards the scan splits over.
      n_leaves: vocabulary-tree leaf count.
      dim: descriptor dimension (0 = unknown, legacy records) — what the
        compressed-codes pricing compares code bytes/row against.
    """

    rows: int
    n_queries: int
    n_shards: int = 1
    n_leaves: int = 1
    dim: int = 0

    def to_json(self) -> dict:
        return {
            "rows": int(self.rows),
            "n_queries": int(self.n_queries),
            "n_shards": int(self.n_shards),
            "n_leaves": int(self.n_leaves),
            "dim": int(self.dim),
        }

    @classmethod
    def from_json(cls, d: dict) -> "PlanShapes":
        return cls(
            rows=int(d["rows"]),
            n_queries=int(d["n_queries"]),
            n_shards=int(d.get("n_shards", 1)),
            n_leaves=int(d.get("n_leaves", 1)),
            dim=int(d.get("dim", 0)),
        )


class CalibrationStore:
    """Measured ms/image per plan signature — the durable calibration data.

    One store per :class:`repro.index.Index` (persisted in the manifest);
    a module-level default serves the eager/legacy paths. Records fold
    into per-signature running stats; when the recorder supplies
    :class:`PlanShapes`, the observation also feeds the fitted model.
    The ``dirty`` flag tells ``Index.commit`` a manifest bump is due.
    """

    def __init__(self):
        # keyed by (signature, shapes-or-None): a plan signature embeds
        # the index/query shapes only when its budgets were derived from
        # them — pinned or snap-coincident budgets produce the same
        # signature at different corpus sizes, and those measurements
        # must stay distinct for the fit
        self._records: dict[tuple, dict] = {}
        # autotuned fused-kernel tile configs keyed (layout, dim, dtype):
        # the winning block size per shape class (benchmarks/block_size.py)
        self._tile_configs: dict[tuple, dict] = {}
        self._dirty = False
        self._seq = 0  # bumps on every mutation; also the fit-cache key
        self._fit_cache: dict[int, tuple[int, dict]] = {}
        # a serving session records between dispatches while a writer
        # thread's commit serializes the store into the manifest
        # (docs/dynamicity.md): guard every dict mutation/iteration
        self._mu = threading.RLock()

    @staticmethod
    def _key(plan, shapes: PlanShapes | None) -> tuple:
        return (
            plan_signature(plan),
            dataclasses.astuple(shapes) if shapes is not None else None,
        )

    # -- recording ----------------------------------------------------------
    def record(self, plan, ms_per_image: float,
               shapes: PlanShapes | None = None, *,
               ts: float | None = None) -> None:
        """Fold one measured ms/image into ``plan``'s running stats.

        Args:
          plan: the resolved ``SearchPlan`` that executed.
          ms_per_image: measured engine milliseconds per image.
          shapes: the shapes the measurement was taken at; required for
            the observation to participate in the fitted model.
          ts: measurement wall-clock (``time.time()``); defaults to now.
            The record's timestamp drives the exponential decay window —
            stale measurements stop steering ``plan(model="auto")``
            (tests back-date records through this).
        """
        ms = float(ms_per_image)
        ts = time.time() if ts is None else float(ts)
        with self._mu:
            o = self._records.setdefault(
                self._key(plan, shapes),
                {"count": 0, "total_ms": 0.0, "min_ms": ms, "max_ms": ms,
                 "last_ms": ms, "ts": ts,
                 "shapes": shapes.to_json() if shapes is not None else None},
            )
            o["count"] += 1
            o["total_ms"] += ms
            o["min_ms"] = min(o["min_ms"], ms)
            o["max_ms"] = max(o["max_ms"], ms)
            o["last_ms"] = ms
            o["ts"] = max(float(o.get("ts", ts)), ts)
            self._seq += 1
            o["seq"] = self._seq
            self._dirty = True
        from repro.obs import get_registry

        get_registry().counter("calibration.records").inc()

    def record_tile_config(self, layout: str, dim: int, dtype: str,
                           block_rows: int, ms: float, *,
                           ts: float | None = None) -> None:
        """Persist the autotuned fused-scan block size for a shape class.

        Keyed ``(layout, dim, dtype)`` — the axes the winning tile
        actually varies over. ``plan()`` consults this when budgeting a
        fused candidate (unless the caller pinned ``block_rows``); the
        sweep in ``benchmarks/block_size.py`` writes it.
        """
        ts = time.time() if ts is None else float(ts)
        with self._mu:
            self._tile_configs[(str(layout), int(dim), str(dtype))] = {
                "block_rows": int(block_rows), "ms": float(ms), "ts": ts,
            }
            self._seq += 1
            self._dirty = True

    def tile_config(self, layout: str, dim: int, dtype: str) -> dict | None:
        """The tuned ``{"block_rows", "ms", "ts"}`` for a shape class, or
        ``None`` when never tuned (or tuned too long ago — stale tiles
        age out on the same window as measurements)."""
        with self._mu:
            cfg = self._tile_configs.get((str(layout), int(dim), str(dtype)))
            if cfg is None or _is_stale(cfg["ts"], time.time()):
                return None
            return dict(cfg)

    def tile_configs(self) -> dict[tuple, dict]:
        """All tuned tile configs (stale included — reporting view)."""
        with self._mu:
            return {k: dict(v) for k, v in self._tile_configs.items()}

    def merge(self, other: "CalibrationStore") -> None:
        """Fold another store's records into this one (stats summed,
        timestamps and tile configs newest-wins)."""
        with self._mu, other._mu:
            now = time.time()
            for key, o in other._records.items():
                mine = self._records.get(key)
                if mine is None:
                    self._seq += 1
                    self._records[key] = dict(o, seq=self._seq)
                else:
                    mine["count"] += o["count"]
                    mine["total_ms"] += o["total_ms"]
                    mine["min_ms"] = min(mine["min_ms"], o["min_ms"])
                    mine["max_ms"] = max(mine["max_ms"], o["max_ms"])
                    mine["last_ms"] = o["last_ms"]
                    mine["ts"] = max(float(mine.get("ts", now)),
                                     float(o.get("ts", now)))
                    self._seq += 1
                    mine["seq"] = self._seq
            for key, cfg in other._tile_configs.items():
                mine = self._tile_configs.get(key)
                if mine is None or cfg["ts"] >= mine["ts"]:
                    self._tile_configs[key] = dict(cfg)
                    self._seq += 1
            if len(other) or other._tile_configs:
                self._dirty = True

    def clear(self) -> None:
        with self._mu:
            if self._records or self._tile_configs:
                self._dirty = True
            self._records.clear()
            self._tile_configs.clear()
            self._seq += 1  # invalidate cached fits

    # -- consultation -------------------------------------------------------
    def lookup(self, plan) -> dict | None:
        """Aggregated running stats recorded under ``plan``'s exact
        signature (folded across the shapes it was measured at)."""
        sig = plan_signature(plan)
        with self._mu:
            return self._aggregate(
                [o for (s, _), o in self._records.items() if s == sig]
            )

    @staticmethod
    def _aggregate(entries) -> dict | None:
        if not entries:
            return None
        latest = max(entries, key=lambda o: o.get("seq", 0))
        return {
            "count": sum(o["count"] for o in entries),
            "total_ms": sum(o["total_ms"] for o in entries),
            "min_ms": min(o["min_ms"] for o in entries),
            "max_ms": max(o["max_ms"] for o in entries),
            "last_ms": latest["last_ms"],
        }

    def mean_ms(self, plan,
                shapes: PlanShapes | None = None) -> float | None:
        """Mean measured ms/image for ``plan``.

        With ``shapes``, only a measurement taken at exactly those shapes
        (or a legacy shape-less record) counts — a pinned budget can
        produce the same plan signature at very different corpus sizes,
        and those measurements must not rank layouts for each other
        (generalizing across shapes is the *fitted* model's job). Without
        ``shapes``, aggregates across everything recorded under the
        signature (the legacy consult/reporting behaviour).
        """
        if shapes is not None:
            o = self._records.get(self._key(plan, shapes))
            if o is None:
                o = self._records.get(self._key(plan, None))
            if o is None or _is_stale(o.get("ts", time.time()), time.time()):
                return None
            return o["total_ms"] / max(1, o["count"])
        o = self.lookup(plan)
        if o is None:
            return None
        return o["total_ms"] / max(1, o["count"])

    def fit_rows(self) -> list[tuple[tuple, dict, PlanShapes]]:
        """Observations usable by the fit: ``(signature, stats, shapes)``
        for every record that carries shapes and is inside the decay
        window (stale records are dropped; fresher ones are further
        down-weighted by age inside :class:`FittedModel`)."""
        out = []
        now = time.time()
        with self._mu:
            for (sig, _), o in self._records.items():
                if not o.get("shapes"):
                    continue
                if _is_stale(o.get("ts", now), now):
                    continue
                out.append((sig, dict(o), PlanShapes.from_json(o["shapes"])))
        return out

    def __len__(self) -> int:
        return len(self._records)

    def n_measurements(self) -> int:
        """Total recorded measurements (``len(self)`` counts distinct
        (signature, shapes) records; each folds many measurements)."""
        with self._mu:
            return sum(o["count"] for o in self._records.values())

    def layouts(self) -> set:
        """The layouts with at least one recorded measurement."""
        with self._mu:
            return {sig[0] for (sig, _) in self._records}

    # -- persistence --------------------------------------------------------
    @property
    def dirty(self) -> bool:
        """True when records changed since the last :meth:`mark_clean`."""
        return self._dirty

    def mark_clean(self) -> None:
        self._dirty = False

    def snapshot(self) -> dict[str, dict]:
        """JSON-ready view: signature key -> aggregated stats with a
        derived ``mean_ms`` (and the shapes measured under, when any)."""
        by_sig: dict[tuple, list[dict]] = {}
        with self._mu:
            for (sig, _), o in self._records.items():
                by_sig.setdefault(sig, []).append(dict(o))
        out = {}
        for sig, entries in by_sig.items():
            agg = self._aggregate(entries)
            agg["mean_ms"] = agg["total_ms"] / max(1, agg["count"])
            measured_at = [o["shapes"] for o in entries if o.get("shapes")]
            if measured_at:
                agg["shapes"] = measured_at
            out[signature_key(sig)] = agg
        return out

    def to_json(self) -> dict:
        """Versioned manifest payload (``calibration`` field)."""
        with self._mu:
            return {
                "format": CALIBRATION_FORMAT,
                "records": [
                    {"signature": list(sig),
                     "stats": {k: v for k, v in o.items()
                               if k not in ("shapes", "seq")},
                     "shapes": o.get("shapes")}
                    for (sig, _), o in self._records.items()
                ],
                "tile_configs": [
                    {"layout": layout, "dim": dim, "dtype": dtype,
                     **cfg}
                    for (layout, dim, dtype), cfg
                    in self._tile_configs.items()
                ],
            }

    @classmethod
    def from_json(cls, d: dict | None) -> "CalibrationStore":
        store = cls()
        now = time.time()
        for rec in (d or {}).get("records", []):
            sig = tuple(rec["signature"])
            o = dict(rec["stats"])
            # format-1 records carry no timestamp: load them as fresh —
            # an undated measurement beats no calibration, and it ages
            # out on the normal window from here
            o["ts"] = float(o.get("ts", now))
            o["shapes"] = rec.get("shapes")
            shapes_key = (
                dataclasses.astuple(PlanShapes.from_json(o["shapes"]))
                if o["shapes"] else None
            )
            store._seq += 1
            o["seq"] = store._seq
            store._records[(sig, shapes_key)] = o
        for cfg in (d or {}).get("tile_configs", []):
            key = (str(cfg["layout"]), int(cfg["dim"]), str(cfg["dtype"]))
            store._tile_configs[key] = {
                "block_rows": int(cfg["block_rows"]),
                "ms": float(cfg.get("ms", 0.0)),
                "ts": float(cfg.get("ts", now)),
            }
            store._seq += 1
        return store


# ---------------------------------------------------------------------------
# module-level default store: the eager/legacy paths (batch_search without
# an Index, direct record_observation calls) and their JSON snapshots.
# Index-scoped planning uses Index.calibration instead.
# ---------------------------------------------------------------------------

_DEFAULT_STORE = CalibrationStore()


def default_calibration() -> CalibrationStore:
    """The process-wide fallback store (index-less callers)."""
    return _DEFAULT_STORE


def reset_default_calibration() -> None:
    """Empty the default store (the autouse test fixture calls this so one
    test's recordings can never flip another test's plan)."""
    _DEFAULT_STORE.clear()
    _DEFAULT_STORE.mark_clean()


# ---------------------------------------------------------------------------
# the models
# ---------------------------------------------------------------------------


class CostModel:
    """Interface: predict a plan's cost at given shapes, rank candidates.

    ``predict_ms`` returns a comparable cost figure (milliseconds for the
    calibrated models, relative scan units for the heuristic) or ``None``
    when this model cannot price the plan. ``choose`` picks the cheapest
    candidate, or returns ``None`` when any candidate is unpriceable —
    the chain then falls through to the next model.
    """

    kind = "base"

    def predict_ms(self, plan, shapes: PlanShapes) -> float | None:
        raise NotImplementedError

    def ready(self) -> bool:
        """True when this model has enough data to ever decide."""
        return True

    def choose(self, candidates, shapes: PlanShapes):
        """The cheapest of ``candidates`` under this model, or ``None``.

        Ties keep the candidates' given order (callers list the
        paper-faithful baseline first).
        """
        preds = [self.predict_ms(p, shapes) for p in candidates]
        if any(v is None for v in preds):
            return None
        best = min(range(len(preds)), key=lambda i: (preds[i], i))
        return candidates[best]

    def describe(self) -> str:
        return self.kind


#: the heuristic's flat launch/merge cost of the fused fast path (tile
#: padding, the in-kernel sorted merge, pipeline fill) — what a small
#: scan can't amortise. A fused candidate drops the per-wave carry
#: traffic (its running table never leaves VMEM) and pays this instead,
#: so the heuristic flips fused-vs-xla with scan size in both directions.
FUSED_OVERHEAD = 32768.0


class HeuristicModel(CostModel):
    """Today's shape rules, now one implementation among peers: first-order
    per-shard scan cost (distance pairs + carry traffic). Unitless — it
    only has to *rank* the layouts, never predict wall-clock."""

    kind = "heuristic"

    def predict_ms(self, plan, shapes: PlanShapes) -> float:
        from repro.distributed.meshutil import round_up

        shard_rows = max(1, shapes.rows // max(1, shapes.n_shards))
        q_rows = max(1, shapes.n_queries * plan.probes)
        if plan.layout == "scan_codes":
            # codes-scan pairs are m/(4*dim) the cost of full-precision
            # pairs (uint8 codes vs f32 rows); the LUT build
            # (q_rows * C * dim mults) and the exact rerank over
            # ``rerank`` survivors are what a small corpus can't amortise
            # — so scan-exact wins small shapes and codes wins large ones
            dim = shapes.dim or 64
            rerank = plan.rerank or plan.k
            ratio = (plan.code_m or dim) / (4.0 * dim)
            n_waves = shard_rows // plan.block_rows
            tile_pairs = shard_rows * plan.q_cap * ratio
            if plan.impl == "fused":
                # in-kernel selection: the running table stays in VMEM —
                # one (q, rerank) emit instead of a per-wave carry fold
                carry = q_rows * rerank + FUSED_OVERHEAD
            else:
                carry = n_waves * q_rows * rerank  # running table per wave
            # LUT build + exact rerank are per *query*, not per probe-
            # expanded scan row: the LUT is leaf-independent and the
            # rerank runs once over the post-merge candidate list
            nq = max(1, shapes.n_queries)
            lut = nq * float(1 << (plan.code_bits or 8)) * dim
            fetch = nq * rerank * 2.0  # row fetch + exact re-score
            return float(tile_pairs + carry + lut + fetch)
        if plan.layout == "point_major":
            n_waves = shard_rows // plan.block_rows
            tile_pairs = shard_rows * plan.q_cap
            if plan.impl == "fused":
                carry = q_rows * plan.k + FUSED_OVERHEAD
            else:
                carry = n_waves * q_rows * plan.k  # running table per wave
            return float(tile_pairs + carry)
        q_cap_shard = round_up(
            max(plan.q_tile,
                int(q_rows / shapes.n_shards * plan.query_capacity_factor)),
            plan.q_tile,
        )
        n_qwaves = q_cap_shard // plan.q_tile
        shuffle = q_rows / shapes.n_shards * 2.0  # all_to_all send+recv rows
        return float(n_qwaves * plan.q_tile * plan.p_cap + shuffle)


class ObservedModel(CostModel):
    """Exact-signature measured ms/image (``plan(model="observed")``, and
    the middle rung of the default chain): decides only when every
    candidate has been measured under its exact resolved signature — and, for
    shape-carrying records, at the exact shapes being planned (see
    :meth:`CalibrationStore.mean_ms`)."""

    kind = "observed"

    def __init__(self, store: CalibrationStore):
        self.store = store

    def ready(self) -> bool:
        """Both dense layouts measured — the minimum for this model to
        ever rank an auto candidate pair (``describe()`` relies on this;
        per-candidate signatures are still checked at decision time)."""
        return set(DENSE_LAYOUTS) <= self.store.layouts()

    def predict_ms(self, plan, shapes: PlanShapes) -> float | None:
        return self.store.mean_ms(plan, shapes)


class FittedModel(CostModel):
    """Per-(layout, impl) least-squares fit of the parametric cost

        ``ms ≈ a·(rows_scanned/tile) + b·probes·leaves + c·batch + d``

    over every shape-carrying observation in the store, so measurements
    at one shape inform nearby unmeasured shapes. ``tile`` is the plan's
    wave tile (``block_rows`` point-major, ``q_tile`` query-routed);
    slope coefficients ``a, b, c`` are clamped ≥ 0 via an active-set
    refit, which makes predictions monotone in ``rows_scanned``.
    Observations are weighted by the exponential decay window
    (``0.5 ** (age / CALIBRATION_HALF_LIFE_S)``) so measurements from a
    retired impl or old hardware fade instead of steering forever. A
    curve is usable once its (layout, impl) has ``min_observations``
    distinct measured signatures; :meth:`choose` requires every
    candidate's curve usable, else the chain falls back to the observed
    model.
    """

    kind = "fitted"

    #: distinct measured signatures a curve needs before its fit is used
    DEFAULT_MIN_OBSERVATIONS = 2

    def __init__(self, store: CalibrationStore,
                 min_observations: int = DEFAULT_MIN_OBSERVATIONS):
        self.store = store
        self.min_observations = int(min_observations)
        # keyed (layout, impl)
        self.coefficients: dict[tuple, tuple[float, float, float, float]] = {}
        self._fit()

    @staticmethod
    def features(layout: str, tile: int, probes: int, shapes: PlanShapes):
        return (
            shapes.rows / max(1, tile),          # rows_scanned / tile
            float(probes * shapes.n_leaves),     # probes · leaves
            float(shapes.n_queries),             # batch
            1.0,
        )

    @staticmethod
    def _plan_tile(layout: str, block_rows, q_tile) -> int:
        tile = q_tile if layout == "query_routed" else block_rows
        return int(tile) if tile else 1

    def _fit(self) -> None:
        # plan() builds a FittedModel per call (Index.search: per segment)
        # — reuse the store's cached coefficients until a record changes.
        # (Age weights drift with wall clock between cache hits, but the
        # half-life is days; the drift within a process run is noise.)
        cached = self.store._fit_cache.get(self.min_observations)
        if cached is not None and cached[0] == self.store._seq:
            self.coefficients = dict(cached[1])
            return
        now = time.time()
        by_curve: dict[tuple, list[tuple[tuple, float, float]]] = {}
        for sig, o, shapes in self.store.fit_rows():
            layout, k, probes, impl, block_rows, q_cap, q_tile, p_cap = sig
            tile = self._plan_tile(layout, block_rows, q_tile)
            x = self.features(layout, tile, probes, shapes)
            y = o["total_ms"] / max(1, o["count"])
            w = _age_weight(float(o.get("ts", now)), now)
            by_curve.setdefault((layout, impl), []).append((x, y, w))
        for curve, rows in by_curve.items():
            if len(rows) < self.min_observations:
                continue
            # weighted least squares via sqrt(w) row scaling
            sw = np.sqrt(np.array([w for _, _, w in rows], np.float64))
            X = np.array([x for x, _, _ in rows], np.float64) * sw[:, None]
            y = np.array([v for _, v, _ in rows], np.float64) * sw
            self.coefficients[curve] = tuple(_nonneg_slope_lstsq(X, y))
        self.store._fit_cache[self.min_observations] = (
            self.store._seq, dict(self.coefficients)
        )

    def ready(self, layout: str | None = None) -> bool:
        if layout is not None:
            return any(curve[0] == layout for curve in self.coefficients)
        return bool(self.coefficients)

    def predict_ms(self, plan, shapes: PlanShapes) -> float | None:
        coef = self.coefficients.get((plan.layout, plan.impl))
        if coef is None:
            return None
        tile = self._plan_tile(plan.layout, plan.block_rows, plan.q_tile)
        x = self.features(plan.layout, tile, plan.probes, shapes)
        return float(np.dot(coef, x))

    def coefficients_json(self) -> dict:
        """``"layout/impl" -> {a, b, c, d}`` (the benchmark artifact
        payload)."""
        return {
            f"{layout}/{impl}": dict(zip("abcd", (float(v) for v in coef)))
            for (layout, impl), coef in self.coefficients.items()
        }


def _nonneg_slope_lstsq(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Least squares with the slope columns (all but the last, intercept)
    clamped ≥ 0: solve, drop negative slopes, re-solve — the tiny
    active-set loop that keeps fitted costs monotone in their features."""
    n_cols = X.shape[1]
    active = list(range(n_cols))
    while active:
        coef_active, *_ = np.linalg.lstsq(X[:, active], y, rcond=None)
        full = np.zeros(n_cols)
        full[active] = coef_active
        bad = [j for j in active if j < n_cols - 1 and full[j] < 0]
        if not bad:
            return full
        active = [j for j in active if j not in bad]
    return np.zeros(n_cols)


class ModelChain(CostModel):
    """Fallback composition: the first member that can rank the candidates
    decides (fitted > observed > heuristic for ``"auto"``)."""

    def __init__(self, models, kind: str):
        self.models = tuple(models)
        self.kind = kind

    def decide(self, candidates, shapes: PlanShapes):
        """``(pick, kind)`` — which plan won and which member decided."""
        for m in self.models:
            pick = m.choose(candidates, shapes)
            if pick is not None:
                return pick, m.kind
        raise ValueError("no model in the chain could rank the candidates")

    def choose(self, candidates, shapes: PlanShapes):
        return self.decide(candidates, shapes)[0]

    def predict_ms(self, plan, shapes: PlanShapes) -> float | None:
        for m in self.models:
            v = m.predict_ms(plan, shapes)
            if v is not None:
                return v
        return None

    def describe(self) -> str:
        """Best-effort provenance label (e.g. ``"auto(fitted)"``): the
        most calibrated member with enough data to *ever* rank an auto
        candidate pair. Whether it decided a particular plan depends on
        that plan's signature/shapes — :meth:`decide` returns the exact
        per-decision answer."""
        for m in self.models:
            # a fitted model that cannot price every dense layout cannot
            # rank an auto candidate pair — don't claim it decides
            if isinstance(m, FittedModel):
                if not all(m.ready(layout) for layout in DENSE_LAYOUTS):
                    continue
            elif not m.ready():
                continue
            return f"{self.kind}({m.kind})" if m.kind != self.kind \
                else self.kind
        return f"{self.kind}({self.models[-1].kind})"


def resolve_model(model="auto",
                  calibration: CalibrationStore | None = None) -> CostModel:
    """A ready-to-consult :class:`CostModel` for a spec + store.

    Args:
      model: one of :data:`MODEL_KINDS`, or an already-built
        :class:`CostModel` (returned unchanged).
      calibration: the store the calibrated models read; ``None`` means
        the module default (index-less callers).

    Returns:
      ``"heuristic"`` → shape rules only; ``"observed"`` → exact
      signatures, heuristic fallback; ``"fitted"``/``"auto"`` → the full
      fitted > observed > heuristic chain (``auto`` is the default alias
      consumers advertise).

    Raises:
      ValueError: an unknown model spec.
    """
    if isinstance(model, CostModel):
        return model
    store = calibration if calibration is not None else default_calibration()
    heuristic = HeuristicModel()
    if model == "heuristic":
        return ModelChain([heuristic], "heuristic")
    if model == "observed":
        return ModelChain([ObservedModel(store), heuristic], "observed")
    if model in ("fitted", "auto"):
        return ModelChain(
            [FittedModel(store), ObservedModel(store), heuristic], model
        )
    raise ValueError(f"unknown cost model {model!r}; want one of {MODEL_KINDS}")


def fitted_component(model, calibration: CalibrationStore | None):
    """The :class:`FittedModel` a spec implies, or ``None`` — what the
    sharded layers consult for per-shard budget scaling (scales stay
    uniform until a fit is actually available)."""
    if isinstance(model, FittedModel):
        return model if model.ready() else None
    if isinstance(model, ModelChain):
        for m in model.models:
            if isinstance(m, FittedModel):
                return m if m.ready() else None
        return None
    if model in ("fitted", "auto"):
        store = (calibration if calibration is not None
                 else default_calibration())
        fitted = FittedModel(store)
        return fitted if fitted.ready() else None
    return None


# ---------------------------------------------------------------------------
# per-shard budget scaling (the sharded scatter-gather consumers)
# ---------------------------------------------------------------------------


def shard_slab_scales(fitted, plans, shapes_per_shard,
                      *, max_scale: float = 2.0) -> list[float]:
    """Per-shard slab-headroom multipliers from fitted per-shard costs.

    Replaces the uniform budget split: a shard the fit predicts to be
    more expensive than the mean earns proportionally more slab headroom
    (up to ``max_scale``); cheaper shards keep the derived default.
    Scales are ≥ 1 by construction — budgets only ever *grow*, so in the
    zero-overflow regime (the one every bit-identity test pins down)
    results are untouched; when a slab *would* overflow, the grown slab
    can only recover candidates the uniform split truncated — strictly
    closer to the true k-NN, with the remaining overflow still counted.
    All-ones when ``fitted`` is ``None`` or cannot price every shard
    (the uniform fallback).
    """
    n = len(plans)
    if fitted is None or n < 2:
        return [1.0] * n
    preds = [fitted.predict_ms(p, s) for p, s in zip(plans, shapes_per_shard)]
    if any(v is None for v in preds):
        return [1.0] * n
    mean = sum(preds) / n
    if mean <= 0:
        return [1.0] * n
    return [min(float(max_scale), max(1.0, v / mean)) for v in preds]


def scale_slab_budget(plan, scale: float, *, n_queries: int,
                      shard_rows: int):
    """``plan`` with its slab budget (``q_cap`` point-major, ``p_cap``
    query-routed) grown by ``scale`` (≥ 1; snapped to 8 rows).

    Growth is capped at what a slab can actually hold — the
    probe-expanded query rows for point-major, the shard's point rows
    for query-routed — so scaling never pads dead rows into the wave
    scans. ``scale <= 1`` returns the plan unchanged: shrinking a slab
    could introduce overflow truncation and is never done here; growth
    is identity-preserving while no slab overflows and can only
    *reduce* truncation otherwise.
    """
    from repro.distributed.meshutil import round_up

    if scale <= 1.0:
        return plan
    if plan.layout != "query_routed":  # point_major and scan_codes slab q_cap
        grown = min(
            round_up(int(plan.q_cap * scale), 8),
            max(plan.q_cap, n_queries * plan.probes),
        )
        return dataclasses.replace(plan, q_cap=grown)
    grown = min(
        round_up(int(plan.p_cap * scale), 8),
        max(plan.p_cap, shard_rows),
    )
    return dataclasses.replace(plan, p_cap=grown)


# ---------------------------------------------------------------------------
# legacy module-level observation API (shims over the default store)
# ---------------------------------------------------------------------------


def record_observation(plan, ms_per_image: float,
                       shapes: PlanShapes | None = None) -> None:
    """Fold one measured ms/image into the *default* store (index-less
    callers; index-scoped recording goes through ``Index.calibration``)."""
    _DEFAULT_STORE.record(plan, ms_per_image, shapes)


def observations() -> dict[str, dict]:
    """JSON-ready snapshot of the default store (legacy API)."""
    return _DEFAULT_STORE.snapshot()


def reset_observations() -> None:
    """Clear the default store (legacy alias of
    :func:`reset_default_calibration`)."""
    reset_default_calibration()
