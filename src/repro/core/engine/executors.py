"""The two search executors, built on the shared tile-scan core.

Point-major (paper §2.4): every shard sweeps its cluster-sorted index rows
in waves of ``block_rows`` against the replicated lookup table; the slab of
queries colliding with a tile is contiguous (both sides leaf-sorted), and a
running ``(rows, k)`` best table is folded per wave, then merged across
shards with one log-shaped top-k.

Query-routed (beyond-paper): the lookup rows are shuffled to the shard
owning their leaf (the same capacity-padded counting sort + all_to_all as
index creation), after which every query row is answered entirely locally —
one contiguous point slab per query tile, no running table, no cross-shard
merge.

Multi-probe: ``build_lookup(tree, queries, probes=T)`` expands each query
into ``T`` rows (one per probed leaf) whose ``qids`` are *flat slots*
``query_id * T + probe_rank``. Both executors treat rows independently; the
final ``merge_probe_groups`` folds each query's ``T`` disjoint candidate
rows into one ``k``-row (see tilescan.py for why no id-dedupe is needed).

Fused fast path (``plan.impl="fused"``, docs/kernels.md): the point-major
and codes scans dispatch to fused variants that never materialize a full
distance slab between scan and select. On TPU the whole shard goes
through one ``kernels/fusedscan`` launch with in-kernel k-selection; off
TPU the wave sweep is software-pipelined — the next wave's lookup/LUT
slab is fetched into the loop carry while the current wave scans, so the
gather and the GEMM have no data dependency and can overlap (double
buffering, structured for async device streams on hardware). Both
variants return ids+dists bit-identical to ``impl="xla"``.
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import route as route_lib
from repro.core.distance import sq_norms
from repro.core.engine import tilescan
from repro.core.engine.plan import SearchPlan
from repro.core.index_build import DistributedIndex
from repro.core.lookup import LookupTable
from repro.core.sentinels import INVALID_ID, LEAF_SENTINEL, PAD_QUERY_LEAF
from repro.distributed.compat import pcast_varying, shard_map
from repro.distributed.meshutil import batch_axes, round_up


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SearchResult:
    ids: jax.Array  # (Q, k) global descriptor ids, -1 where fewer than k
    dists: jax.Array  # (Q, k) true squared L2 distances (inf where id=-1)
    pairs: jax.Array  # () number of (point, query) distance pairs computed
    q_cap_overflow: jax.Array  # () slab-budget misses (0 == exact-in-cluster)

    def tree_flatten(self):
        return (self.ids, self.dists, self.pairs, self.q_cap_overflow), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


class _Carry(NamedTuple):
    best_d: jax.Array
    best_i: jax.Array
    pairs: jax.Array
    overflow: jax.Array


class _PipedCarry(NamedTuple):
    """Wave-loop carry for the pipelined fused executor: in addition to
    the running table it holds the *next* wave's prefetched lookup slab
    (``qv``/``qlf``/``slab_start``), so the slab gather issued at the end
    of wave ``i`` has no data dependency on wave ``i+1``'s scan and the
    two can overlap (double buffering)."""

    best_d: jax.Array
    best_i: jax.Array
    pairs: jax.Array
    overflow: jax.Array
    qv: jax.Array
    qlf: jax.Array
    slab_start: jax.Array


def _fused_wants_kernel() -> bool:
    """Whether ``impl="fused"`` should launch the Pallas fusedscan kernel.

    On TPU the whole-shard kernel is the point; elsewhere interpret-mode
    Pallas is an eval loop, so the fused executor runs the pipelined XLA
    wave sweep instead (bit-identical to ``impl="xla"``). Tests force the
    kernel off-TPU with ``REPRO_FUSED_FORCE_KERNEL=1``.
    """
    if os.environ.get("REPRO_FUSED_FORCE_KERNEL", "") == "1":
        return True
    return jax.default_backend() == "tpu"


def _leaf_pair_count(p_leaves, q_leaves, n_leaves: int):
    """Analytic (point, query) leaf-collision count for the whole-shard
    kernel path: the kernel scans every (tile, tile) cell but only
    leaf-matching pairs survive masking, so the histogram product equals
    the wave sweep's summed ``count_pairs`` whenever q_cap never
    overflowed (and is the honest pair count even when it would have)."""
    p_ok = ((p_leaves >= 0) & (p_leaves != LEAF_SENTINEL)).astype(jnp.float32)
    q_ok = ((q_leaves >= 0) & (q_leaves != LEAF_SENTINEL)).astype(jnp.float32)
    p_cnt = jnp.zeros((n_leaves,), jnp.float32).at[
        jnp.clip(p_leaves, 0, n_leaves - 1)
    ].add(p_ok)
    q_cnt = jnp.zeros((n_leaves,), jnp.float32).at[
        jnp.clip(q_leaves, 0, n_leaves - 1)
    ].add(q_ok)
    return jnp.sum(p_cnt * q_cnt)


def pad_lookup(lookup: LookupTable, q_total: int) -> LookupTable:
    """Pad the lookup table to ``q_total`` rows; padding never matches.

    Pad rows get fresh flat slot ids past the real ones so every scatter
    target stays a permutation of ``arange(q_total)``.
    """
    q = lookup.vecs.shape[0]
    if q_total < q:
        raise ValueError(f"{q_total=} < {q}")
    if q_total == q:
        return lookup
    pad = q_total - q
    return LookupTable(
        vecs=jnp.concatenate(
            [lookup.vecs, jnp.zeros((pad, lookup.vecs.shape[1]), lookup.vecs.dtype)]
        ),
        qids=jnp.concatenate([lookup.qids, jnp.arange(q, q_total, dtype=jnp.int32)]),
        leaves=jnp.concatenate(
            [lookup.leaves, jnp.full((pad,), PAD_QUERY_LEAF, jnp.int32)]
        ),
        offsets=lookup.offsets,
    )


def _shard_id(mesh: Mesh, axes) -> jax.Array:
    sid = jnp.int32(0)
    for a in axes:
        sid = sid * mesh.shape[a] + jax.lax.axis_index(a)
    return sid


def _merge_shard_tables(mesh, axes, plan, lookup, best_d, best_i, pairs,
                        overflow, *, q_total, n_shards, width, add_q_norms):
    """Merge per-shard ``(S, Q, width)`` k-NN tables into a SearchResult.

    (S, Q, w) sharded over S -> (Q, S*w) sharded over Q (all_to_all
    reshard), then a purely local per-row top-k. Never replicated: at pod
    scale the stacked table is tens of GB global. ``add_q_norms`` restores
    the deferred ``||q||^2`` term (dense scans only — ADC distances are
    already full squared estimates). Shared by the xla and fused
    executors so the merge is op-for-op identical across impls.
    """
    row_sh = NamedSharding(mesh, P(axes, None))
    all_d = jnp.transpose(best_d, (1, 0, 2)).reshape(q_total, n_shards * width)
    all_i = jnp.transpose(best_i, (1, 0, 2)).reshape(q_total, n_shards * width)
    all_d = jax.lax.with_sharding_constraint(all_d, row_sh)
    all_i = jax.lax.with_sharding_constraint(all_i, row_sh)
    neg, sel = jax.lax.top_k(-all_d, width)
    merged_d = -neg
    if add_q_norms:
        merged_d = merged_d + sq_norms(lookup.vecs)[:, None]
    merged_i = jnp.take_along_axis(all_i, sel, axis=1)
    merged_d = jnp.where(merged_i >= 0, merged_d, jnp.inf)
    # unsort to flat slot order, then merge probe groups
    out_d = jnp.full_like(merged_d, jnp.inf).at[lookup.qids].set(merged_d)
    out_i = jnp.full_like(merged_i, INVALID_ID).at[lookup.qids].set(merged_i)
    out_d, out_i = tilescan.merge_probe_groups(out_d, out_i, plan.probes)
    out_d = jax.lax.with_sharding_constraint(out_d, row_sh)
    out_i = jax.lax.with_sharding_constraint(out_i, row_sh)
    return SearchResult(ids=out_i, dists=out_d, pairs=pairs,
                        q_cap_overflow=overflow)


def _point_major_fn(mesh, plan: SearchPlan, *, n_leaves, shard_rows, q_total,
                    axes):
    block_rows, q_cap, k = plan.block_rows, plan.q_cap, plan.k
    n_shards = math.prod(mesh.shape[a] for a in axes)
    if shard_rows % block_rows != 0:
        raise ValueError(f"{shard_rows=} not divisible by {block_rows=}")
    if k > block_rows:
        raise ValueError(f"{k=} must be <= {block_rows=}")
    if q_cap > q_total:
        raise ValueError(f"{q_cap=} must be <= padded query count {q_total=}")
    n_waves = shard_rows // block_rows

    def shard_fn(vecs, leaves, ids, lk_vecs, lk_leaves, lk_offsets):
        vecs, leaves, ids = vecs[0], leaves[0], ids[0]

        def wave(i, c: _Carry) -> _Carry:
            start = i * block_rows
            pv = jax.lax.dynamic_slice(vecs, (start, 0), (block_rows, vecs.shape[1]))
            plf = jax.lax.dynamic_slice(leaves, (start,), (block_rows,))
            pid = jax.lax.dynamic_slice(ids, (start,), (block_rows,))
            # contiguous query slab for this tile's leaf span
            slab = tilescan.leaf_slab(
                lk_offsets, plf[0], n_entries=n_leaves, total_rows=q_total,
                cap=q_cap,
            )
            qv = jax.lax.dynamic_slice(
                lk_vecs, (slab.start, 0), (q_cap, lk_vecs.shape[1])
            )
            qlf = jax.lax.dynamic_slice(lk_leaves, (slab.start,), (q_cap,))
            cand_d, cand_i = tilescan.scan_tile(
                pv, plf, pid, qv, qlf, k=k, impl=plan.impl
            )
            # fold into the running per-query k-NN table
            cur_d = jax.lax.dynamic_slice(c.best_d, (slab.start, 0), (q_cap, k))
            cur_i = jax.lax.dynamic_slice(c.best_i, (slab.start, 0), (q_cap, k))
            new_d, new_i = tilescan.fold_topk(cur_d, cur_i, cand_d, cand_i)
            best_d = jax.lax.dynamic_update_slice(c.best_d, new_d, (slab.start, 0))
            best_i = jax.lax.dynamic_update_slice(c.best_i, new_i, (slab.start, 0))
            # bookkeeping: pairs computed + slab-budget misses
            pairs = c.pairs + tilescan.count_pairs(plf, qlf)
            overflow = c.overflow + tilescan.slab_overflow(
                lk_offsets, tilescan.last_valid_leaf(plf), slab,
                n_entries=n_leaves,
            )
            return _Carry(best_d, best_i, pairs, overflow)

        init = _Carry(
            best_d=jnp.full((q_total, k), jnp.inf, jnp.float32),
            best_i=jnp.full((q_total, k), INVALID_ID, jnp.int32),
            pairs=jnp.zeros((), jnp.float32),
            overflow=jnp.zeros((), jnp.int32),
        )
        # the carry varies across shards (each shard scans its own rows)
        init = jax.tree.map(lambda x: pcast_varying(x, axes), init)
        out = jax.lax.fori_loop(0, n_waves, wave, init)
        pairs = jax.lax.psum(out.pairs, axes)
        overflow = jax.lax.psum(out.overflow, axes)
        return out.best_d[None], out.best_i[None], pairs, overflow

    def pipeline(index: DistributedIndex, lookup: LookupTable) -> SearchResult:
        d = index.vecs.shape[-1]
        vecs = index.vecs.reshape(n_shards, shard_rows, d)
        leaves = index.leaves.reshape(n_shards, shard_rows)
        ids = index.ids.reshape(n_shards, shard_rows)
        row_spec = P(axes, None)
        flat_spec = P(axes)
        rep = P()
        best_d, best_i, pairs, overflow = shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(row_spec, flat_spec, flat_spec, rep, rep, rep),
            out_specs=(P(axes, None, None), P(axes, None, None), rep, rep),
        )(vecs, leaves, ids, lookup.vecs, lookup.leaves, lookup.offsets)
        return _merge_shard_tables(
            mesh, axes, plan, lookup, best_d, best_i, pairs, overflow,
            q_total=q_total, n_shards=n_shards, width=k, add_q_norms=True,
        )

    return pipeline


def _query_routed_fn(mesh, plan: SearchPlan, *, n_leaves, shard_rows, q_total,
                     axes):
    q_tile, p_cap, k = plan.q_tile, plan.p_cap, plan.k
    n_shards = math.prod(mesh.shape[a] for a in axes)
    if n_leaves % n_shards:
        raise ValueError(f"{n_leaves=} must divide over {n_shards} shards")
    lps = n_leaves // n_shards
    q_cap_shard = round_up(
        max(q_tile, int(q_total / n_shards * plan.query_capacity_factor)),
        q_tile,
    )
    n_qwaves = q_cap_shard // q_tile

    def shard_fn(vecs, leaves, ids, offsets, lk_vecs, lk_leaves, lk_qids):
        vecs, leaves, ids, offsets = vecs[0], leaves[0], ids[0], offsets[0]
        leaf_base = _shard_id(mesh, axes) * lps
        # ---- shuffle: route query rows to their leaf's owner shard --------
        routed = route_lib.route_by_leaf(
            lk_vecs,
            lk_qids,
            lk_leaves,
            axis_name=axes,
            n_shards=n_shards,
            leaves_per_shard=lps,
            capacity=q_cap_shard // n_shards,
            wire_dtype=plan.wire_dtype,
        )
        qv_all, qids_all, qlf_all, _, _ = route_lib.cluster_sort(
            routed, leaf_base=leaf_base, leaves_per_shard=lps
        )
        # pad/trim the local query set to the static budget
        pad = q_cap_shard - qv_all.shape[0]
        if pad > 0:
            qv_all = jnp.concatenate(
                [qv_all, jnp.zeros((pad, qv_all.shape[1]), qv_all.dtype)]
            )
            qids_all = jnp.concatenate(
                [qids_all, jnp.full((pad,), INVALID_ID, jnp.int32)]
            )
            qlf_all = jnp.concatenate(
                [qlf_all, jnp.full((pad,), LEAF_SENTINEL, jnp.int32)]
            )
        else:
            qv_all = qv_all[:q_cap_shard]
            qids_all = qids_all[:q_cap_shard]
            qlf_all = qlf_all[:q_cap_shard]

        def wave(w):
            qs = w * q_tile
            qv = jax.lax.dynamic_slice(qv_all, (qs, 0), (q_tile, qv_all.shape[1]))
            qlf = jax.lax.dynamic_slice(qlf_all, (qs,), (q_tile,))
            # contiguous local point slab covering this tile's leaf span
            slab = tilescan.leaf_slab(
                offsets, qlf[0] - leaf_base, n_entries=lps,
                total_rows=shard_rows, cap=p_cap,
            )
            pv = jax.lax.dynamic_slice(
                vecs, (slab.start, 0), (p_cap, vecs.shape[1])
            )
            plf = jax.lax.dynamic_slice(leaves, (slab.start,), (p_cap,))
            pid = jax.lax.dynamic_slice(ids, (slab.start,), (p_cap,))
            cand_d, cand_i = tilescan.scan_tile(
                pv, plf, pid, qv, qlf, k=k, impl=plan.impl
            )
            cand_d = cand_d + sq_norms(qv)[:, None]  # true squared distance
            ov = tilescan.slab_overflow(
                offsets, tilescan.last_valid_leaf(qlf, base=leaf_base), slab,
                n_entries=lps,
            )
            pairs = tilescan.count_pairs(plf, qlf)
            return cand_d, cand_i, ov, pairs

        cand_d, cand_i, ov, pairs = jax.lax.map(wave, jnp.arange(n_qwaves))
        overflow = jax.lax.psum(jnp.sum(ov), axes) + jax.lax.psum(
            routed.overflow, axes
        )
        pairs = jax.lax.psum(jnp.sum(pairs), axes)
        return (
            cand_d.reshape(1, q_cap_shard, k),
            cand_i.reshape(1, q_cap_shard, k),
            qids_all[None],
            pairs,
            overflow,
        )

    def pipeline(index: DistributedIndex, lookup: LookupTable) -> SearchResult:
        d = index.vecs.shape[-1]
        vecs = index.vecs.reshape(n_shards, shard_rows, d)
        leaves = index.leaves.reshape(n_shards, shard_rows)
        ids = index.ids.reshape(n_shards, shard_rows)
        row_spec = P(axes, None)
        flat_spec = P(axes)
        rep = P()
        cand_d, cand_i, qids, pairs, overflow = shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(row_spec, flat_spec, flat_spec, row_spec, rep, rep, rep),
            out_specs=(P(axes, None, None), P(axes, None, None), P(axes, None),
                       rep, rep),
        )(vecs, leaves, ids, index.offsets, lookup.vecs, lookup.leaves,
          lookup.qids)
        # one global scatter back to flat slot order (each lookup row was
        # answered by exactly one shard — no cross-shard merge needed),
        # then merge each query's probe rows
        flat_d = cand_d.reshape(-1, k)
        flat_i = cand_i.reshape(-1, k)
        flat_q = qids.reshape(-1)
        safe_q = jnp.where(flat_q >= 0, flat_q, q_total)
        out_d = jnp.full((q_total, k), jnp.inf, jnp.float32).at[safe_q].set(
            flat_d, mode="drop"
        )
        out_i = jnp.full((q_total, k), INVALID_ID, jnp.int32).at[safe_q].set(
            flat_i, mode="drop"
        )
        out_d, out_i = tilescan.merge_probe_groups(out_d, out_i, plan.probes)
        row_sh = NamedSharding(mesh, P(axes, None))
        out_d = jax.lax.with_sharding_constraint(out_d, row_sh)
        out_i = jax.lax.with_sharding_constraint(out_i, row_sh)
        return SearchResult(ids=out_i, dists=out_d, pairs=pairs,
                            q_cap_overflow=overflow)

    return pipeline


def _build_adc_lut(lookup_vecs, codebooks, *, q_total: int, m: int,
                   n_centers: int):
    """Per-lookup-row ADC tables, flattened to (Q, m * n_centers):
    ``lut[q, j, c] = ||q_j - codebook[j, c]||^2``."""
    dsub = codebooks.shape[-1]
    sub = lookup_vecs.astype(jnp.float32).reshape(q_total, m, dsub)
    cb = codebooks.astype(jnp.float32)
    cross = jnp.einsum(
        "qmd,mcd->qmc", sub, cb, preferred_element_type=jnp.float32
    )
    return (
        jnp.sum(sub * sub, axis=-1)[:, :, None]
        - 2.0 * cross
        + jnp.sum(cb * cb, axis=-1)[None]
    ).reshape(q_total, m * n_centers)


def _scan_codes_fn(mesh, plan: SearchPlan, *, n_leaves, shard_rows, q_total,
                   axes):
    """Compressed-tier scan (docs/compressed_codes.md): a point-major wave
    sweep over uint8 PQ code slabs under the asymmetric distance. Each
    wave folds the adcscan kernel's candidates into a running
    ``(q_total, rerank)`` table; the emitted ``SearchResult`` carries
    *approximate* ADC distances over ``plan.rerank`` survivors per query —
    callers fetch those rows and rerank exactly
    (:func:`repro.codes.rerank_exact`)."""
    from repro.kernels.adcscan import ops as adc_ops

    block_rows, q_cap = plan.block_rows, plan.q_cap
    r, m = plan.rerank, plan.code_m
    n_centers = 1 << plan.code_bits
    n_shards = math.prod(mesh.shape[a] for a in axes)
    if shard_rows % block_rows != 0:
        raise ValueError(f"{shard_rows=} not divisible by {block_rows=}")
    if r > block_rows:
        raise ValueError(f"rerank {r} must be <= {block_rows=}")
    if q_cap > q_total:
        raise ValueError(f"{q_cap=} must be <= padded query count {q_total=}")
    n_waves = shard_rows // block_rows
    from repro.core.sentinels import PAD_TILE_POINT_LEAF

    def shard_fn(codes, leaves, ids, lk_lut, lk_leaves, lk_offsets):
        codes, leaves, ids = codes[0], leaves[0], ids[0]

        def wave(i, c: _Carry) -> _Carry:
            start = i * block_rows
            pc = jax.lax.dynamic_slice(codes, (start, 0), (block_rows, m))
            plf = jax.lax.dynamic_slice(leaves, (start,), (block_rows,))
            pid = jax.lax.dynamic_slice(ids, (start,), (block_rows,))
            slab = tilescan.leaf_slab(
                lk_offsets, plf[0], n_entries=n_leaves, total_rows=q_total,
                cap=q_cap,
            )
            lut = jax.lax.dynamic_slice(
                lk_lut, (slab.start, 0), (q_cap, m * n_centers)
            ).reshape(q_cap, m, n_centers)
            qlf = jax.lax.dynamic_slice(lk_leaves, (slab.start,), (q_cap,))
            # tombstoned rows keep their leaf for slab location but must
            # never match: codes can't carry the 1e15 vec mask the dense
            # scan uses, so mask the *match* leaves by id validity
            plf_m = jnp.where(pid >= 0, plf, PAD_TILE_POINT_LEAF)
            cand_d, cand_sel = adc_ops.adc_topk(
                pc, plf_m, lut, qlf, k=r, impl=plan.impl
            )
            cand_i = jnp.where(
                cand_sel >= 0, pid[jnp.clip(cand_sel, 0)], INVALID_ID
            )
            cand_d = jnp.where(cand_i >= 0, cand_d, jnp.inf)
            cur_d = jax.lax.dynamic_slice(c.best_d, (slab.start, 0), (q_cap, r))
            cur_i = jax.lax.dynamic_slice(c.best_i, (slab.start, 0), (q_cap, r))
            new_d, new_i = tilescan.fold_topk(cur_d, cur_i, cand_d, cand_i)
            best_d = jax.lax.dynamic_update_slice(c.best_d, new_d, (slab.start, 0))
            best_i = jax.lax.dynamic_update_slice(c.best_i, new_i, (slab.start, 0))
            pairs = c.pairs + tilescan.count_pairs(plf_m, qlf)
            overflow = c.overflow + tilescan.slab_overflow(
                lk_offsets, tilescan.last_valid_leaf(plf), slab,
                n_entries=n_leaves,
            )
            return _Carry(best_d, best_i, pairs, overflow)

        init = _Carry(
            best_d=jnp.full((q_total, r), jnp.inf, jnp.float32),
            best_i=jnp.full((q_total, r), INVALID_ID, jnp.int32),
            pairs=jnp.zeros((), jnp.float32),
            overflow=jnp.zeros((), jnp.int32),
        )
        init = jax.tree.map(lambda x: pcast_varying(x, axes), init)
        out = jax.lax.fori_loop(0, n_waves, wave, init)
        pairs = jax.lax.psum(out.pairs, axes)
        overflow = jax.lax.psum(out.overflow, axes)
        return out.best_d[None], out.best_i[None], pairs, overflow

    def pipeline(index: DistributedIndex, lookup: LookupTable,
                 codes: jax.Array, codebooks: jax.Array) -> SearchResult:
        lut = _build_adc_lut(lookup.vecs, codebooks, q_total=q_total, m=m,
                             n_centers=n_centers)
        codes3 = codes.astype(jnp.int32).reshape(n_shards, shard_rows, m)
        leaves = index.leaves.reshape(n_shards, shard_rows)
        ids = index.ids.reshape(n_shards, shard_rows)
        row_spec = P(axes, None)
        flat_spec = P(axes)
        rep = P()
        best_d, best_i, pairs, overflow = shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(row_spec, flat_spec, flat_spec, rep, rep, rep),
            out_specs=(P(axes, None, None), P(axes, None, None), rep, rep),
        )(codes3, leaves, ids, lut, lookup.leaves, lookup.offsets)
        # ADC distances are *full* squared estimates (the LUT carries the
        # ||q_j - c||^2 terms), so unlike the dense scan no ||q||^2 add-back
        return _merge_shard_tables(
            mesh, axes, plan, lookup, best_d, best_i, pairs, overflow,
            q_total=q_total, n_shards=n_shards, width=r, add_q_norms=False,
        )

    return pipeline


def _kernel_tile_p(block_rows: int) -> int | None:
    """The autotuned ``plan.block_rows`` doubles as the fusedscan point
    tile when it is lane-aligned; otherwise fall back to the kernel's own
    default tiling (the ops layer pads the shard up regardless)."""
    return block_rows if block_rows % 128 == 0 else None


def _point_major_fused_fn(mesh, plan: SearchPlan, *, n_leaves, shard_rows,
                          q_total, axes):
    """Fused point-major executor (docs/kernels.md).

    TPU (or forced): the whole shard goes through one
    ``fusedscan.fused_topk`` launch — per-tile top-k kept in VMEM and
    merged across point tiles in-kernel, so no (rows, q) distance slab or
    per-wave candidate list ever lands in HBM between scan and select.

    Off-TPU: a software-pipelined wave sweep with the same per-tile math
    as ``impl="xla"`` — the next wave's query slab is prefetched into the
    loop carry while the current wave scans (double buffering), keeping
    results bit-identical to the reference executor.
    """
    block_rows, q_cap, k = plan.block_rows, plan.q_cap, plan.k
    n_shards = math.prod(mesh.shape[a] for a in axes)
    if shard_rows % block_rows != 0:
        raise ValueError(f"{shard_rows=} not divisible by {block_rows=}")
    if k > block_rows:
        raise ValueError(f"{k=} must be <= {block_rows=}")
    if q_cap > q_total:
        raise ValueError(f"{q_cap=} must be <= padded query count {q_total=}")
    n_waves = shard_rows // block_rows
    use_kernel = _fused_wants_kernel()

    def kernel_shard_fn(vecs, leaves, ids, lk_vecs, lk_leaves, lk_offsets):
        from repro.kernels.fusedscan import ops as fused_ops

        vecs, leaves, ids = vecs[0], leaves[0], ids[0]
        best_d, best_i = fused_ops.fused_topk(
            vecs, leaves, ids, lk_vecs, lk_leaves, k=k, impl="pallas",
            tile_p=_kernel_tile_p(block_rows),
        )
        pairs = jax.lax.psum(
            _leaf_pair_count(leaves, lk_leaves, n_leaves), axes
        )
        # whole-shard scan: every leaf-matching query row is visible to
        # every point tile — the q_cap slab budget cannot be exceeded
        overflow = jax.lax.psum(jnp.zeros((), jnp.int32), axes)
        return best_d[None], best_i[None], pairs, overflow

    def piped_shard_fn(vecs, leaves, ids, lk_vecs, lk_leaves, lk_offsets):
        vecs, leaves, ids = vecs[0], leaves[0], ids[0]

        def fetch(i):
            first = jax.lax.dynamic_slice(leaves, (i * block_rows,), (1,))[0]
            slab = tilescan.leaf_slab(
                lk_offsets, first, n_entries=n_leaves, total_rows=q_total,
                cap=q_cap,
            )
            qv = jax.lax.dynamic_slice(
                lk_vecs, (slab.start, 0), (q_cap, lk_vecs.shape[1])
            )
            qlf = jax.lax.dynamic_slice(lk_leaves, (slab.start,), (q_cap,))
            return qv, qlf, slab.start

        def wave(i, c: _PipedCarry) -> _PipedCarry:
            start = i * block_rows
            pv = jax.lax.dynamic_slice(vecs, (start, 0), (block_rows, vecs.shape[1]))
            plf = jax.lax.dynamic_slice(leaves, (start,), (block_rows,))
            pid = jax.lax.dynamic_slice(ids, (start,), (block_rows,))
            # scan the slab prefetched by the previous iteration
            cand_d, cand_i = tilescan.scan_tile(
                pv, plf, pid, c.qv, c.qlf, k=k, impl="xla"
            )
            cur_d = jax.lax.dynamic_slice(c.best_d, (c.slab_start, 0), (q_cap, k))
            cur_i = jax.lax.dynamic_slice(c.best_i, (c.slab_start, 0), (q_cap, k))
            new_d, new_i = tilescan.fold_topk(cur_d, cur_i, cand_d, cand_i)
            best_d = jax.lax.dynamic_update_slice(c.best_d, new_d, (c.slab_start, 0))
            best_i = jax.lax.dynamic_update_slice(c.best_i, new_i, (c.slab_start, 0))
            pairs = c.pairs + tilescan.count_pairs(plf, c.qlf)
            overflow = c.overflow + tilescan.slab_overflow(
                lk_offsets, tilescan.last_valid_leaf(plf),
                tilescan.Slab(start=c.slab_start, cap=q_cap),
                n_entries=n_leaves,
            )
            # prefetch wave i+1's slab (clamped on the last wave)
            qv, qlf, slab_start = fetch(jnp.minimum(i + 1, n_waves - 1))
            return _PipedCarry(best_d, best_i, pairs, overflow, qv, qlf,
                               slab_start)

        qv0, qlf0, start0 = fetch(0)
        init = _PipedCarry(
            best_d=jnp.full((q_total, k), jnp.inf, jnp.float32),
            best_i=jnp.full((q_total, k), INVALID_ID, jnp.int32),
            pairs=jnp.zeros((), jnp.float32),
            overflow=jnp.zeros((), jnp.int32),
            qv=qv0, qlf=qlf0, slab_start=start0,
        )
        init = jax.tree.map(lambda x: pcast_varying(x, axes), init)
        out = jax.lax.fori_loop(0, n_waves, wave, init)
        pairs = jax.lax.psum(out.pairs, axes)
        overflow = jax.lax.psum(out.overflow, axes)
        return out.best_d[None], out.best_i[None], pairs, overflow

    shard_fn = kernel_shard_fn if use_kernel else piped_shard_fn

    def pipeline(index: DistributedIndex, lookup: LookupTable) -> SearchResult:
        d = index.vecs.shape[-1]
        vecs = index.vecs.reshape(n_shards, shard_rows, d)
        leaves = index.leaves.reshape(n_shards, shard_rows)
        ids = index.ids.reshape(n_shards, shard_rows)
        row_spec = P(axes, None)
        flat_spec = P(axes)
        rep = P()
        best_d, best_i, pairs, overflow = shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(row_spec, flat_spec, flat_spec, rep, rep, rep),
            out_specs=(P(axes, None, None), P(axes, None, None), rep, rep),
        )(vecs, leaves, ids, lookup.vecs, lookup.leaves, lookup.offsets)
        return _merge_shard_tables(
            mesh, axes, plan, lookup, best_d, best_i, pairs, overflow,
            q_total=q_total, n_shards=n_shards, width=k, add_q_norms=True,
        )

    return pipeline


def _scan_codes_fused_fn(mesh, plan: SearchPlan, *, n_leaves, shard_rows,
                         q_total, axes):
    """Fused compressed-tier executor: same dispatch split as
    :func:`_point_major_fused_fn` but over PQ code slabs under the
    asymmetric distance — the kernel path is one whole-shard
    ``fusedscan.fused_adc_topk`` launch; the pipelined path prefetches
    the next wave's LUT slab into the loop carry."""
    from repro.core.sentinels import PAD_TILE_POINT_LEAF

    block_rows, q_cap = plan.block_rows, plan.q_cap
    r, m = plan.rerank, plan.code_m
    n_centers = 1 << plan.code_bits
    n_shards = math.prod(mesh.shape[a] for a in axes)
    if shard_rows % block_rows != 0:
        raise ValueError(f"{shard_rows=} not divisible by {block_rows=}")
    if r > block_rows:
        raise ValueError(f"rerank {r} must be <= {block_rows=}")
    if q_cap > q_total:
        raise ValueError(f"{q_cap=} must be <= padded query count {q_total=}")
    n_waves = shard_rows // block_rows
    use_kernel = _fused_wants_kernel()

    def kernel_shard_fn(codes, leaves, ids, lk_lut, lk_leaves, lk_offsets):
        from repro.kernels.fusedscan import ops as fused_ops

        codes, leaves, ids = codes[0], leaves[0], ids[0]
        # tombstoned rows must never match (see _scan_codes_fn)
        plf_m = jnp.where(ids >= 0, leaves, PAD_TILE_POINT_LEAF)
        best_d, best_i = fused_ops.fused_adc_topk(
            codes, plf_m, ids, lk_lut.reshape(q_total, m, n_centers),
            lk_leaves, k=r, impl="pallas",
            tile_p=_kernel_tile_p(block_rows),
        )
        pairs = jax.lax.psum(
            _leaf_pair_count(plf_m, lk_leaves, n_leaves), axes
        )
        overflow = jax.lax.psum(jnp.zeros((), jnp.int32), axes)
        return best_d[None], best_i[None], pairs, overflow

    def piped_shard_fn(codes, leaves, ids, lk_lut, lk_leaves, lk_offsets):
        codes, leaves, ids = codes[0], leaves[0], ids[0]

        def fetch(i):
            first = jax.lax.dynamic_slice(leaves, (i * block_rows,), (1,))[0]
            slab = tilescan.leaf_slab(
                lk_offsets, first, n_entries=n_leaves, total_rows=q_total,
                cap=q_cap,
            )
            lut = jax.lax.dynamic_slice(
                lk_lut, (slab.start, 0), (q_cap, m * n_centers)
            )
            qlf = jax.lax.dynamic_slice(lk_leaves, (slab.start,), (q_cap,))
            return lut, qlf, slab.start

        def wave(i, c: _PipedCarry) -> _PipedCarry:
            from repro.kernels.adcscan import ops as adc_ops

            start = i * block_rows
            pc = jax.lax.dynamic_slice(codes, (start, 0), (block_rows, m))
            plf = jax.lax.dynamic_slice(leaves, (start,), (block_rows,))
            pid = jax.lax.dynamic_slice(ids, (start,), (block_rows,))
            plf_m = jnp.where(pid >= 0, plf, PAD_TILE_POINT_LEAF)
            cand_d, cand_sel = adc_ops.adc_topk(
                pc, plf_m, c.qv.reshape(q_cap, m, n_centers), c.qlf, k=r,
                impl="xla",
            )
            cand_i = jnp.where(
                cand_sel >= 0, pid[jnp.clip(cand_sel, 0)], INVALID_ID
            )
            cand_d = jnp.where(cand_i >= 0, cand_d, jnp.inf)
            cur_d = jax.lax.dynamic_slice(c.best_d, (c.slab_start, 0), (q_cap, r))
            cur_i = jax.lax.dynamic_slice(c.best_i, (c.slab_start, 0), (q_cap, r))
            new_d, new_i = tilescan.fold_topk(cur_d, cur_i, cand_d, cand_i)
            best_d = jax.lax.dynamic_update_slice(c.best_d, new_d, (c.slab_start, 0))
            best_i = jax.lax.dynamic_update_slice(c.best_i, new_i, (c.slab_start, 0))
            pairs = c.pairs + tilescan.count_pairs(plf_m, c.qlf)
            overflow = c.overflow + tilescan.slab_overflow(
                lk_offsets, tilescan.last_valid_leaf(plf),
                tilescan.Slab(start=c.slab_start, cap=q_cap),
                n_entries=n_leaves,
            )
            lut, qlf, slab_start = fetch(jnp.minimum(i + 1, n_waves - 1))
            return _PipedCarry(best_d, best_i, pairs, overflow, lut, qlf,
                               slab_start)

        lut0, qlf0, start0 = fetch(0)
        init = _PipedCarry(
            best_d=jnp.full((q_total, r), jnp.inf, jnp.float32),
            best_i=jnp.full((q_total, r), INVALID_ID, jnp.int32),
            pairs=jnp.zeros((), jnp.float32),
            overflow=jnp.zeros((), jnp.int32),
            qv=lut0, qlf=qlf0, slab_start=start0,
        )
        init = jax.tree.map(lambda x: pcast_varying(x, axes), init)
        out = jax.lax.fori_loop(0, n_waves, wave, init)
        pairs = jax.lax.psum(out.pairs, axes)
        overflow = jax.lax.psum(out.overflow, axes)
        return out.best_d[None], out.best_i[None], pairs, overflow

    shard_fn = kernel_shard_fn if use_kernel else piped_shard_fn

    def pipeline(index: DistributedIndex, lookup: LookupTable,
                 codes: jax.Array, codebooks: jax.Array) -> SearchResult:
        lut = _build_adc_lut(lookup.vecs, codebooks, q_total=q_total, m=m,
                             n_centers=n_centers)
        codes3 = codes.astype(jnp.int32).reshape(n_shards, shard_rows, m)
        leaves = index.leaves.reshape(n_shards, shard_rows)
        ids = index.ids.reshape(n_shards, shard_rows)
        row_spec = P(axes, None)
        flat_spec = P(axes)
        rep = P()
        best_d, best_i, pairs, overflow = shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(row_spec, flat_spec, flat_spec, rep, rep, rep),
            out_specs=(P(axes, None, None), P(axes, None, None), rep, rep),
        )(codes3, leaves, ids, lut, lookup.leaves, lookup.offsets)
        return _merge_shard_tables(
            mesh, axes, plan, lookup, best_d, best_i, pairs, overflow,
            q_total=q_total, n_shards=n_shards, width=r, add_q_norms=False,
        )

    return pipeline


_LAYOUT_BUILDERS = {
    "point_major": _point_major_fn,
    "query_routed": _query_routed_fn,
    "scan_codes": _scan_codes_fn,
}

_FUSED_BUILDERS = {
    "point_major": _point_major_fused_fn,
    "scan_codes": _scan_codes_fused_fn,
}


def make_executor(
    mesh: Mesh,
    plan: SearchPlan,
    *,
    n_leaves: int,
    shard_rows: int,
    q_total: int,
    axes=None,
):
    """Build the jittable ``(index, lookup) -> SearchResult`` pipeline.

    ``q_total`` is the *padded lookup row* count (``n_queries * probes``
    rounded up); it must be a multiple of ``plan.probes`` so the final
    probe-group merge can reshape. Output tables have
    ``q_total // plan.probes`` rows (one per original query group).

    The ``scan_codes`` pipeline takes two extra arguments —
    ``(index, lookup, codes, codebooks)`` — and its result rows are
    ``plan.rerank`` *approximate* ADC candidates per query, which the
    caller reranks exactly (docs/compressed_codes.md).
    """
    plan = plan.resolved()
    axes = tuple(axes) if axes else batch_axes(mesh)
    if q_total % plan.probes:
        raise ValueError(f"{q_total=} must be a multiple of {plan.probes=}")
    if plan.impl == "fused":
        if plan.layout not in _FUSED_BUILDERS:
            raise ValueError(
                f"impl='fused' is not supported for layout {plan.layout!r}"
            )
        builder = _FUSED_BUILDERS[plan.layout]
    else:
        builder = _LAYOUT_BUILDERS[plan.layout]
    return builder(
        mesh, plan, n_leaves=n_leaves, shard_rows=shard_rows, q_total=q_total,
        axes=axes,
    )
