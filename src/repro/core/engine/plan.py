"""Declarative search plans + the auto-planning entry point.

A :class:`SearchPlan` is the single static description both executors are
built from. ``plan()`` resolves an ``"auto"`` layout and any unset budgets
from the index/mesh/query shapes; *which* candidate wins is delegated to
the pluggable cost-model subsystem (:mod:`repro.core.engine.costmodel`):

  * ``HeuristicModel`` — first-order shape rules (distance pairs + carry
    traffic) for the two scan layouts:

    - ``point_major`` — every shard sweeps its ``shard_rows`` index rows
      in waves of ``block_rows`` against a ``q_cap``-row query slab,
      carrying a full ``(rows, k)`` running-best table;
    - ``query_routed`` — queries are shuffled to the shard owning their
      leaf, then each ``q_tile`` query tile reads one ``p_cap`` point
      slab (no carry, one all_to_all).

  * ``ObservedModel`` — exact-signature measured ms/image;
  * ``FittedModel`` — a parametric fit over all observations, so
    measurements at one shape inform nearby unmeasured shapes.

``plan(model="auto")`` (the default) prefers **fitted > observed >
heuristic** — measured behaviour decides whenever calibration data
exists, and the shape rules only break the cold-start tie. The model
only picks layouts and budgets; results are bit-identical under every
model (the invariant the engine/serving/sharding tests assert).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.numpy as jnp

from repro.core.engine import costmodel as costmodel_lib
from repro.core.engine.costmodel import (
    LAYOUTS,
    CalibrationStore,
    PlanShapes,
)
from repro.distributed.meshutil import round_up


IMPLS = ("xla", "pallas", "fused", "auto")


def largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is ``<= cap`` — O(sqrt n), no linear
    countdown. Used to snap requested tile sizes onto the shard grid."""
    if n <= 0:
        raise ValueError(f"{n=} must be positive")
    cap = max(1, min(cap, n))
    best = 1
    for lo in range(1, int(math.isqrt(n)) + 1):
        if n % lo:
            continue
        hi = n // lo
        if lo <= cap and lo > best:
            best = lo
        if hi <= cap and hi > best:
            best = hi
    return best


def bucket_ladder(
    max_queries: int,
    *,
    n_buckets: int = 4,
    min_queries: int = 32,
) -> tuple[int, ...]:
    """Padded batch-size buckets for the serving layer, ascending.

    A geometric ladder from ``max_queries`` down (each rung ~half the one
    above), with every rung snapped to a *divisor* of ``max_queries`` via
    the shared :func:`largest_divisor_leq` helper — so a full bucket of
    small requests coalesces exactly into the next rung and the executor
    set stays tiny. Serving sessions compile one executor per rung at
    warmup; steady-state requests snap up to a rung and never recompile.
    """
    if max_queries < 1:
        raise ValueError(f"{max_queries=} must be positive")
    min_queries = max(1, min(min_queries, max_queries))
    rungs = {max_queries}
    target = max_queries // 2
    while len(rungs) < n_buckets and target >= min_queries:
        rung = largest_divisor_leq(max_queries, target)
        if rung >= min_queries:  # divisor-poor sizes: no sub-floor rungs
            rungs.add(rung)
        target //= 2
    return tuple(sorted(rungs))


def snap_to_bucket(n: int, buckets) -> int:
    """Smallest warmed bucket that fits ``n`` rows (largest bucket caps it:
    callers split bigger batches across dispatches)."""
    if n < 1:
        raise ValueError(f"{n=} must be positive")
    fitting = [b for b in buckets if b >= n]
    return min(fitting) if fitting else max(buckets)


@dataclasses.dataclass(frozen=True)
class SearchPlan:
    """Static description of one search execution (hashable, jit-safe).

    ``None`` budget fields mean "let ``plan()``/the wrapper pick"; the
    executors require them resolved.
    """

    layout: str  # "point_major" | "query_routed" | "scan_codes"
    k: int
    probes: int = 1  # multi-probe width T: leaves visited per query
    # executor implementation (docs/kernels.md):
    #   "xla"    — reference wave sweep (per-tile l2topk/adcscan, impl xla)
    #   "pallas" — reference wave sweep with the per-tile Pallas kernels
    #   "fused"  — fused fast path: whole-shard fusedscan kernel on TPU,
    #              pipelined double-buffered wave sweep elsewhere
    #   "auto"   — plan() prices "xla" vs "fused" via the cost model
    impl: str = "xla"
    wire_dtype: Any = jnp.float32  # routed-shuffle payload dtype
    # point-major budgets (scan_codes shares them: its code scan is a
    # point-major wave sweep over uint8 code slabs)
    block_rows: int | None = None  # index rows per wave tile
    q_cap: int | None = None  # query-slab rows per tile
    # query-routed budgets
    q_tile: int | None = None  # queries per wave tile
    p_cap: int | None = None  # point-slab rows per query tile
    query_capacity_factor: float = 4.0  # routing headroom for hot shards
    # scan_codes (compressed-tier) parameters — docs/compressed_codes.md
    rerank: int | None = None  # ADC survivors fetched for exact rerank
    code_m: int | None = None  # PQ subvectors (code bytes per row)
    code_bits: int | None = None  # bits per subvector (2**bits centroids)

    def __post_init__(self):
        if self.layout not in LAYOUTS:
            raise ValueError(f"unknown layout {self.layout!r}; want {LAYOUTS}")
        if self.impl not in IMPLS:
            raise ValueError(f"unknown impl {self.impl!r}; want {IMPLS}")
        if self.impl == "fused" and self.layout == "query_routed":
            raise ValueError(
                "impl='fused' is not supported for layout 'query_routed' "
                "(the fused scan is a point-major sweep; docs/kernels.md)"
            )
        if self.k < 1:
            raise ValueError(f"{self.k=} must be >= 1")
        if self.probes < 1:
            raise ValueError(f"{self.probes=} must be >= 1")
        if self.rerank is not None and self.rerank < self.k:
            raise ValueError(f"{self.rerank=} must be >= {self.k=}")

    def resolved(self) -> "SearchPlan":
        """Check the budgets this layout needs are set."""
        if self.layout == "query_routed":
            need = ("q_tile", "p_cap")
        elif self.layout == "scan_codes":
            need = ("block_rows", "q_cap", "rerank", "code_m", "code_bits")
        else:
            need = ("block_rows", "q_cap")
        for f in need:
            if getattr(self, f) is None:
                raise ValueError(f"plan field {f!r} unresolved for {self.layout}")
        return self

    def observe(
        self,
        ms_per_image: float,
        *,
        store: CalibrationStore | None = None,
        shapes: PlanShapes | None = None,
    ) -> None:
        """Record one measured ms/image for this plan.

        Args:
          ms_per_image: measured engine milliseconds per image.
          store: the :class:`CalibrationStore` to record into — an
            index-scoped store (``Index.calibration``) for durable,
            manifest-persisted calibration, or ``None`` for the
            module-level default (the frozen plan itself stays
            hashable/jit-safe either way).
          shapes: the shapes the measurement was taken at; required for
            the observation to feed the fitted model.
        """
        target = (store if store is not None
                  else costmodel_lib.default_calibration())
        target.record(self, ms_per_image, shapes)


def _point_major_budgets(
    p: SearchPlan, *, shard_rows: int, n_leaves: int, q_rows: int,
    n_shards: int
) -> SearchPlan:
    block_rows = p.block_rows or 1024
    block_rows = largest_divisor_leq(shard_rows, block_rows)
    q_cap = p.q_cap
    if q_cap is None:
        # slab must cover the probe-expanded queries of every leaf a block
        # tile spans: expected rows = q_rows * block_rows / global rows,
        # floored by the per-leaf mean; 4x headroom for skew (multi-probe
        # concentrates extra rows in popular leaves — paper Exp #5 RAM knob)
        expected = max(
            q_rows * block_rows // max(1, shard_rows * n_shards),
            q_rows // max(1, n_leaves),
        )
        q_cap = min(q_rows, max(256, round_up(4 * expected, 8)))
    return dataclasses.replace(p, block_rows=block_rows, q_cap=q_cap)


def default_rerank(k: int, rows: int) -> int:
    """Default exact-rerank depth for the codes layout: generous relative
    to ``k`` (8x, floored at 64) so recall survives the lossy ADC scan,
    capped at 128 (the in-kernel top-k stays VPU-cheap) and at the corpus
    itself."""
    return max(k, min(rows, max(8 * k, 64), 128))


def _scan_codes_budgets(
    p: SearchPlan, *, shard_rows: int, n_leaves: int, q_rows: int,
    n_shards: int
) -> SearchPlan:
    """The codes scan is a point-major sweep over uint8 code slabs — it
    reuses the point-major block/slab derivation, plus a rerank depth."""
    p = _point_major_budgets(
        p, shard_rows=shard_rows, n_leaves=n_leaves, q_rows=q_rows,
        n_shards=n_shards,
    )
    rerank = p.rerank or default_rerank(p.k, shard_rows * n_shards)
    # the running candidate table needs rerank <= block_rows (same bound
    # as k <= block_rows on the dense scan)
    rerank = max(p.k, min(rerank, p.block_rows))
    return dataclasses.replace(p, rerank=rerank)


def _query_routed_budgets(
    p: SearchPlan, *, shard_rows: int, n_leaves: int, q_rows: int,
    n_shards: int
) -> SearchPlan:
    q_tile = p.q_tile or 128
    p_cap = p.p_cap
    if p_cap is None:
        # each shard owns n_leaves/n_shards leaves, so rows per *owned*
        # leaf is shard_rows * n_shards / n_leaves (== global rows/leaf)
        avg_leaf = max(1, shard_rows * n_shards // max(1, n_leaves))
        # a q_tile of consecutive sorted queries covers ~q_tile/local_rows
        # of the shard's leaf range — when queries are sparse relative to
        # leaves the point span explodes (and the cost model then correctly
        # prefers point-major); 2x headroom for skew
        local_rows = max(q_tile, q_rows // max(1, n_shards))
        span = shard_rows * q_tile // local_rows
        p_cap = min(
            shard_rows, round_up(max(4096, 16 * avg_leaf, 2 * span), 8)
        )
    return dataclasses.replace(p, q_tile=q_tile, p_cap=p_cap)


def plan(
    *,
    rows: int,
    n_leaves: int,
    n_queries: int,
    n_shards: int,
    k: int,
    probes: int = 1,
    layout: str = "auto",
    impl: str = "xla",
    wire_dtype: Any = jnp.float32,
    block_rows: int | None = None,
    q_cap: int | None = None,
    q_tile: int | None = None,
    p_cap: int | None = None,
    query_capacity_factor: float = 4.0,
    dim: int = 0,
    rerank: int | None = None,
    code_m: int | None = None,
    code_bits: int | None = None,
    model: Any = "auto",
    calibration: CalibrationStore | None = None,
) -> SearchPlan:
    """Resolve a full :class:`SearchPlan` from shapes.

    Args:
      rows: padded index rows (``DistributedIndex.rows``) of the index
        (or segment view) the plan will scan.
      n_leaves: vocabulary-tree leaf count.
      n_queries: query rows per batch (pre-probe-expansion).
      n_shards: device row-shards (``meshutil.data_axis_size``).
      k: neighbours returned per query; ``probes``: multi-probe width.
      layout: ``"point_major"``, ``"query_routed"``, ``"scan_codes"``
        (requires a codes artifact — ``code_m``/``code_bits`` set), or
        ``"auto"``.
      impl: executor implementation — ``"xla"`` (reference),
        ``"pallas"`` (per-tile kernels), ``"fused"`` (the fused fast
        path, docs/kernels.md), or ``"auto"`` (the cost model prices
        ``"xla"`` vs ``"fused"`` per candidate layout; query-routed only
        ever runs ``"xla"``). Fused candidates pick up the autotuned
        block size persisted in the calibration store (see
        ``benchmarks/block_size.py``) unless ``block_rows`` is pinned.
      wire_dtype: routed-shuffle payload dtype.
      block_rows/q_cap/q_tile/p_cap: pin a budget instead of deriving it;
        ``query_capacity_factor``: routing headroom for hot shards.
      dim: descriptor dimension (0 = unknown) — feeds the codes pricing.
      rerank: exact-rerank depth for ``scan_codes`` (default: derived,
        see :func:`default_rerank`); ``code_m``/``code_bits``: the index's
        PQ geometry — when set, ``layout="auto"`` also prices the
        ``scan_codes`` candidate (docs/compressed_codes.md).
      model: which cost model ranks an ``"auto"`` layout — one of
        ``"auto"`` (fitted > observed > heuristic, the default),
        ``"heuristic"``, ``"observed"``, ``"fitted"``, or a prebuilt
        :class:`~repro.core.engine.costmodel.CostModel`.
      calibration: the :class:`CalibrationStore` the calibrated models
        read (an index's ``Index.calibration``); ``None`` uses the
        module-level default store.

    Returns:
      A fully resolved (budgeted) :class:`SearchPlan`.

    Raises:
      ValueError: ``probes > n_leaves``; an unknown ``layout`` or
        ``model``; or ``layout="query_routed"`` when ``n_leaves`` does
        not divide over the shards (leaf ownership is a contiguous range
        per shard).

    ``layout="auto"`` budgets *both* layouts and asks the cost model to
    keep the cheaper one; ``impl="auto"`` additionally expands each
    dense-scan layout into an ``"xla"`` and a ``"fused"`` candidate, so
    the model prices impl as one more planning axis. With no calibration
    data every model chain falls back to the heuristic shape rules, so a
    cold process plans exactly as it always has; once measurements exist
    (recorded by the serving session, persisted in the index manifest)
    they decide. Ties go to the paper-faithful point-major ``"xla"``
    baseline under every model.
    """
    if probes > n_leaves:
        raise ValueError(f"{probes=} must be <= {n_leaves=}")
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; want {IMPLS}")
    shard_rows = max(1, rows // max(1, n_shards))
    q_rows = max(1, n_queries * probes)  # probe-expanded lookup rows
    base = dict(
        k=k, probes=probes, wire_dtype=wire_dtype,
        block_rows=block_rows, q_cap=q_cap, q_tile=q_tile, p_cap=p_cap,
        query_capacity_factor=query_capacity_factor,
    )
    shapes = dict(shard_rows=shard_rows, n_leaves=n_leaves, q_rows=q_rows)
    store = (calibration if calibration is not None
             else costmodel_lib.default_calibration())

    def impls_for(lay: str) -> tuple[str, ...]:
        if impl != "auto":
            return (impl,)
        # only the point-major sweeps have a fused variant; the xla
        # reference comes first so ties keep the baseline
        return ("xla", "fused") if lay != "query_routed" else ("xla",)

    def variants(p: SearchPlan) -> list[SearchPlan]:
        """One resolved candidate per impl; fused candidates honor the
        autotuned tile config persisted in the calibration store."""
        out = []
        for i in impls_for(p.layout):
            v = dataclasses.replace(p, impl=i)
            if i == "fused" and block_rows is None:
                cfg = store.tile_config(
                    p.layout, dim, jnp.dtype(wire_dtype).name
                )
                if cfg:
                    v = dataclasses.replace(
                        v,
                        block_rows=largest_divisor_leq(
                            shard_rows, int(cfg["block_rows"])
                        ),
                    )
            out.append(v.resolved())
        return out

    has_codes = code_m is not None and code_bits is not None
    if layout == "scan_codes" and not has_codes:
        raise ValueError(
            "layout='scan_codes' needs code_m/code_bits (a PQ codes "
            "artifact on the index; docs/compressed_codes.md)"
        )
    candidates: list[SearchPlan] = []
    if has_codes:
        sc = _scan_codes_budgets(
            SearchPlan(layout="scan_codes", rerank=rerank, code_m=code_m,
                       code_bits=code_bits, **base),
            n_shards=n_shards, **shapes,
        )
        if layout == "scan_codes":
            candidates = variants(sc)
    pm = _point_major_budgets(
        SearchPlan(layout="point_major", **base), n_shards=n_shards, **shapes
    )
    if layout == "point_major":
        candidates = variants(pm)
    routable = n_leaves % n_shards == 0
    if layout == "query_routed":
        if not routable:
            raise ValueError(
                f"{n_leaves=} must divide over {n_shards} shards for "
                "layout='query_routed'"
            )
        candidates = variants(
            _query_routed_budgets(
                SearchPlan(layout="query_routed", **base),
                n_shards=n_shards, **shapes,
            )
        )
    elif layout == "auto":
        # candidates listed baseline-first: every model breaks ties toward
        # the paper-faithful point-major xla scan
        candidates = variants(pm)
        if routable and impl != "fused":
            candidates += variants(
                _query_routed_budgets(
                    SearchPlan(layout="query_routed", **base),
                    n_shards=n_shards, **shapes,
                )
            )
        if has_codes:
            candidates += variants(sc)
    if not candidates:
        raise ValueError(f"unknown layout {layout!r}")
    if len(candidates) == 1:
        return candidates[0]
    ctx = PlanShapes(
        rows=rows, n_queries=n_queries, n_shards=n_shards, n_leaves=n_leaves,
        dim=dim,
    )
    pick = costmodel_lib.resolve_model(model, calibration).choose(
        tuple(candidates), ctx
    )
    return pick
