"""Declarative search plans + the auto-planning heuristic.

A :class:`SearchPlan` is the single static description both executors are
built from. ``plan()`` resolves an ``"auto"`` layout and any unset budgets
from the index/mesh/query shapes using a first-order cost model of the two
scan layouts:

  * ``point_major`` — every shard sweeps its ``shard_rows`` index rows in
    waves of ``block_rows`` against a ``q_cap``-row query slab, carrying a
    full ``(rows, k)`` running-best table. Tile work per shard is
    ``shard_rows * q_cap`` distance pairs; the carry costs
    ``O(rows * k)`` HBM traffic per wave.
  * ``query_routed`` — queries are shuffled to the shard owning their leaf,
    then each ``q_tile`` query tile reads one ``p_cap`` point slab. Tile
    work per shard is ``n_qwaves * q_tile * p_cap`` pairs with no carry.

The model only has to rank the two layouts, not predict wall-clock.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.numpy as jnp

from repro.distributed.meshutil import round_up

LAYOUTS = ("point_major", "query_routed")


def largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is ``<= cap`` — O(sqrt n), no linear
    countdown. Used to snap requested tile sizes onto the shard grid."""
    if n <= 0:
        raise ValueError(f"{n=} must be positive")
    cap = max(1, min(cap, n))
    best = 1
    for lo in range(1, int(math.isqrt(n)) + 1):
        if n % lo:
            continue
        hi = n // lo
        if lo <= cap and lo > best:
            best = lo
        if hi <= cap and hi > best:
            best = hi
    return best


def bucket_ladder(
    max_queries: int,
    *,
    n_buckets: int = 4,
    min_queries: int = 32,
) -> tuple[int, ...]:
    """Padded batch-size buckets for the serving layer, ascending.

    A geometric ladder from ``max_queries`` down (each rung ~half the one
    above), with every rung snapped to a *divisor* of ``max_queries`` via
    the shared :func:`largest_divisor_leq` helper — so a full bucket of
    small requests coalesces exactly into the next rung and the executor
    set stays tiny. Serving sessions compile one executor per rung at
    warmup; steady-state requests snap up to a rung and never recompile.
    """
    if max_queries < 1:
        raise ValueError(f"{max_queries=} must be positive")
    min_queries = max(1, min(min_queries, max_queries))
    rungs = {max_queries}
    target = max_queries // 2
    while len(rungs) < n_buckets and target >= min_queries:
        rung = largest_divisor_leq(max_queries, target)
        if rung >= min_queries:  # divisor-poor sizes: no sub-floor rungs
            rungs.add(rung)
        target //= 2
    return tuple(sorted(rungs))


def snap_to_bucket(n: int, buckets) -> int:
    """Smallest warmed bucket that fits ``n`` rows (largest bucket caps it:
    callers split bigger batches across dispatches)."""
    if n < 1:
        raise ValueError(f"{n=} must be positive")
    fitting = [b for b in buckets if b >= n]
    return min(fitting) if fitting else max(buckets)


# ---------------------------------------------------------------------------
# Measured-cost observations (ROADMAP: calibrate plan() from real runs).
# Keyed by the plan's cost-relevant signature; the serving session and the
# benchmarks feed these via ``SearchPlan.observe(ms_per_image)`` and persist
# them in the benchmark JSON so a later PR can fit the cost model.
# ---------------------------------------------------------------------------

_OBSERVATIONS: dict[tuple, dict] = {}


def _plan_signature(p: "SearchPlan") -> tuple:
    return (
        p.layout, p.k, p.probes, p.impl, p.block_rows, p.q_cap, p.q_tile,
        p.p_cap,
    )


def record_observation(p: "SearchPlan", ms_per_image: float) -> None:
    """Fold one measured ms/image into the per-plan running stats."""
    ms = float(ms_per_image)
    o = _OBSERVATIONS.setdefault(
        _plan_signature(p),
        {"count": 0, "total_ms": 0.0, "min_ms": ms, "max_ms": ms,
         "last_ms": ms},
    )
    o["count"] += 1
    o["total_ms"] += ms
    o["min_ms"] = min(o["min_ms"], ms)
    o["max_ms"] = max(o["max_ms"], ms)
    o["last_ms"] = ms


def observations() -> dict[str, dict]:
    """JSON-ready snapshot: plan signature string -> running ms/image stats
    (with a derived ``mean_ms``)."""
    out = {}
    for sig, o in _OBSERVATIONS.items():
        layout, k, probes, impl, block_rows, q_cap, q_tile, p_cap = sig
        key = (
            f"{layout}/k={k}/probes={probes}/impl={impl}/"
            f"block_rows={block_rows}/q_cap={q_cap}/"
            f"q_tile={q_tile}/p_cap={p_cap}"
        )
        out[key] = dict(o, mean_ms=o["total_ms"] / max(1, o["count"]))
    return out


def reset_observations() -> None:
    _OBSERVATIONS.clear()


@dataclasses.dataclass(frozen=True)
class SearchPlan:
    """Static description of one search execution (hashable, jit-safe).

    ``None`` budget fields mean "let ``plan()``/the wrapper pick"; the
    executors require them resolved.
    """

    layout: str  # "point_major" | "query_routed"
    k: int
    probes: int = 1  # multi-probe width T: leaves visited per query
    impl: str = "xla"  # l2topk impl: "xla" | "pallas" | "auto"
    wire_dtype: Any = jnp.float32  # routed-shuffle payload dtype
    # point-major budgets
    block_rows: int | None = None  # index rows per wave tile
    q_cap: int | None = None  # query-slab rows per tile
    # query-routed budgets
    q_tile: int | None = None  # queries per wave tile
    p_cap: int | None = None  # point-slab rows per query tile
    query_capacity_factor: float = 4.0  # routing headroom for hot shards

    def __post_init__(self):
        if self.layout not in LAYOUTS:
            raise ValueError(f"unknown layout {self.layout!r}; want {LAYOUTS}")
        if self.k < 1:
            raise ValueError(f"{self.k=} must be >= 1")
        if self.probes < 1:
            raise ValueError(f"{self.probes=} must be >= 1")

    def resolved(self) -> "SearchPlan":
        """Check the budgets this layout needs are set."""
        need = (
            ("block_rows", "q_cap")
            if self.layout == "point_major"
            else ("q_tile", "p_cap")
        )
        for f in need:
            if getattr(self, f) is None:
                raise ValueError(f"plan field {f!r} unresolved for {self.layout}")
        return self

    def observe(self, ms_per_image: float) -> None:
        """Record one measured ms/image for this plan (module-level registry
        — the frozen plan itself stays hashable/jit-safe)."""
        record_observation(self, ms_per_image)


def _point_major_budgets(
    p: SearchPlan, *, shard_rows: int, n_leaves: int, q_rows: int,
    n_shards: int
) -> SearchPlan:
    block_rows = p.block_rows or 1024
    block_rows = largest_divisor_leq(shard_rows, block_rows)
    q_cap = p.q_cap
    if q_cap is None:
        # slab must cover the probe-expanded queries of every leaf a block
        # tile spans: expected rows = q_rows * block_rows / global rows,
        # floored by the per-leaf mean; 4x headroom for skew (multi-probe
        # concentrates extra rows in popular leaves — paper Exp #5 RAM knob)
        expected = max(
            q_rows * block_rows // max(1, shard_rows * n_shards),
            q_rows // max(1, n_leaves),
        )
        q_cap = min(q_rows, max(256, round_up(4 * expected, 8)))
    return dataclasses.replace(p, block_rows=block_rows, q_cap=q_cap)


def _query_routed_budgets(
    p: SearchPlan, *, shard_rows: int, n_leaves: int, q_rows: int,
    n_shards: int
) -> SearchPlan:
    q_tile = p.q_tile or 128
    p_cap = p.p_cap
    if p_cap is None:
        # each shard owns n_leaves/n_shards leaves, so rows per *owned*
        # leaf is shard_rows * n_shards / n_leaves (== global rows/leaf)
        avg_leaf = max(1, shard_rows * n_shards // max(1, n_leaves))
        # a q_tile of consecutive sorted queries covers ~q_tile/local_rows
        # of the shard's leaf range — when queries are sparse relative to
        # leaves the point span explodes (and the cost model then correctly
        # prefers point-major); 2x headroom for skew
        local_rows = max(q_tile, q_rows // max(1, n_shards))
        span = shard_rows * q_tile // local_rows
        p_cap = min(
            shard_rows, round_up(max(4096, 16 * avg_leaf, 2 * span), 8)
        )
    return dataclasses.replace(p, q_tile=q_tile, p_cap=p_cap)


def _scan_cost(p: SearchPlan, *, shard_rows: int, n_shards: int,
               q_rows: int, k: int) -> float:
    """First-order per-shard cost (distance pairs + carry traffic)."""
    if p.layout == "point_major":
        n_waves = shard_rows // p.block_rows
        tile_pairs = shard_rows * p.q_cap
        carry = n_waves * q_rows * k  # running-best table touched per wave
        return float(tile_pairs + carry)
    q_cap_shard = round_up(
        max(p.q_tile, int(q_rows / n_shards * p.query_capacity_factor)),
        p.q_tile,
    )
    n_qwaves = q_cap_shard // p.q_tile
    shuffle = q_rows / n_shards * 2.0  # all_to_all send+recv rows
    return float(n_qwaves * p.q_tile * p.p_cap + shuffle)


def plan(
    *,
    rows: int,
    n_leaves: int,
    n_queries: int,
    n_shards: int,
    k: int,
    probes: int = 1,
    layout: str = "auto",
    impl: str = "xla",
    wire_dtype: Any = jnp.float32,
    block_rows: int | None = None,
    q_cap: int | None = None,
    q_tile: int | None = None,
    p_cap: int | None = None,
    query_capacity_factor: float = 4.0,
    use_observations: bool = False,
) -> SearchPlan:
    """Resolve a full :class:`SearchPlan` from shapes.

    Args:
      rows: padded index rows (``DistributedIndex.rows``) of the index
        (or segment view) the plan will scan.
      n_leaves: vocabulary-tree leaf count.
      n_queries: query rows per batch (pre-probe-expansion).
      n_shards: device row-shards (``meshutil.data_axis_size``).
      k: neighbours returned per query; ``probes``: multi-probe width.
      layout: ``"point_major"``, ``"query_routed"``, or ``"auto"``.
      impl: l2topk kernel implementation (``"xla"``/``"pallas"``/``"auto"``).
      wire_dtype: routed-shuffle payload dtype.
      block_rows/q_cap/q_tile/p_cap: pin a budget instead of deriving it;
        ``query_capacity_factor``: routing headroom for hot shards.
      use_observations: prefer measured ms/image over the shape model
        (see below).

    Returns:
      A fully resolved (budgeted) :class:`SearchPlan`.

    Raises:
      ValueError: ``probes > n_leaves``; an unknown ``layout``; or
        ``layout="query_routed"`` when ``n_leaves`` does not divide over
        the shards (leaf ownership is a contiguous range per shard).

    ``layout="auto"`` budgets *both* layouts and keeps the one with the
    lower modelled scan cost.

    ``use_observations=True`` closes the cost-model loop (ROADMAP): when
    *both* candidate plans have measured ms/image under their exact plan
    signature (fed by ``SearchPlan.observe`` from the serving session and
    benchmarks), the measured means rank the layouts instead of the shape
    model. With fewer than two measured candidates the shape model decides
    — a single measurement cannot be compared against a modelled cost.

    Caveat: a plan signature keys on the *resolved budgets*, which embed
    the index/query shapes only when the budgets were derived by this
    function. Explicitly pinned budgets (e.g. a CLI ``--q-cap``) produce
    the same signature at any corpus size, so measurements can bleed
    across shapes; fitting a parametric model over shapes is the ROADMAP
    follow-on.
    """
    if probes > n_leaves:
        raise ValueError(f"{probes=} must be <= {n_leaves=}")
    shard_rows = max(1, rows // max(1, n_shards))
    q_rows = max(1, n_queries * probes)  # probe-expanded lookup rows
    base = dict(
        k=k, probes=probes, impl=impl, wire_dtype=wire_dtype,
        block_rows=block_rows, q_cap=q_cap, q_tile=q_tile, p_cap=p_cap,
        query_capacity_factor=query_capacity_factor,
    )
    shapes = dict(shard_rows=shard_rows, n_leaves=n_leaves, q_rows=q_rows)
    pm = _point_major_budgets(
        SearchPlan(layout="point_major", **base), n_shards=n_shards, **shapes
    )
    routable = n_leaves % n_shards == 0
    if layout == "point_major" or (layout == "auto" and not routable):
        return pm.resolved()
    qr = _query_routed_budgets(
        SearchPlan(layout="query_routed", **base), n_shards=n_shards, **shapes
    )
    if layout == "query_routed":
        if not routable:
            raise ValueError(
                f"{n_leaves=} must divide over {n_shards} shards for "
                "layout='query_routed'"
            )
        return qr.resolved()
    if layout != "auto":
        raise ValueError(f"unknown layout {layout!r}")
    if use_observations:
        measured = {
            p.layout: _OBSERVATIONS.get(_plan_signature(p)) for p in (pm, qr)
        }
        if all(measured.values()):
            mean = lambda o: o["total_ms"] / max(1, o["count"])  # noqa: E731
            # tie goes to the paper-faithful baseline, like the shape model
            pick = (
                pm
                if mean(measured["point_major"]) <= mean(measured["query_routed"])
                else qr
            )
            return pick.resolved()
    cost = {
        p.layout: _scan_cost(p, shard_rows=shard_rows, n_shards=n_shards,
                             q_rows=q_rows, k=k)
        for p in (pm, qr)
    }
    # tie goes to the paper-faithful baseline
    return (pm if cost["point_major"] <= cost["query_routed"] else qr).resolved()
