"""Shared tile-scan core for both search layouts.

Both executors reduce to the same inner shape: an *anchor* tile (sliced by
wave index) meets a *slab* (a contiguous run of the opposite, cluster-sorted
table, located through CSR offsets), one fused distance+top-k produces
per-query candidates, and pairs/overflow are accounted exactly. Point-major
anchors on index rows and slabs the lookup table; query-routed anchors on
query tiles and slabs the local point rows. The arithmetic is identical and
lives here once.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.sentinels import INVALID_ID, LEAF_SENTINEL
from repro.kernels.l2topk import ops as l2topk_ops


class Slab(NamedTuple):
    """A contiguous slab start for one tile, plus its budget."""

    start: jax.Array  # () int32 row offset into the sorted table
    cap: int  # static slab row budget


def leaf_slab(
    offsets: jax.Array, first_leaf: jax.Array, *, n_entries: int,
    total_rows: int, cap: int
) -> Slab:
    """Locate the slab covering ``first_leaf`` in a CSR-sorted table.

    ``offsets`` has ``n_entries + 1`` entries; the returned start is clamped
    so a full ``cap``-row dynamic_slice stays in bounds (padding rows at the
    tail never match any real leaf).
    """
    l0 = jnp.clip(first_leaf, 0, n_entries - 1)
    start = jnp.clip(offsets[l0], 0, max(0, total_rows - cap))
    return Slab(start=start, cap=cap)


def slab_overflow(
    offsets: jax.Array, last_leaf: jax.Array, slab: Slab, *, n_entries: int
) -> jax.Array:
    """Rows of the tile's leaf span that did not fit in the slab budget.

    ``last_leaf`` is the highest *valid local* leaf id of the anchor tile
    (``-1`` when the tile is all padding). Exact, never silently wrong: the
    pipelines report the psum of this and tests assert 0 on healthy runs.
    """
    need_end = jnp.where(
        last_leaf >= 0,
        offsets[jnp.clip(last_leaf, 0, n_entries - 1) + 1],
        slab.start,
    )
    return jnp.maximum(0, need_end - slab.start - slab.cap).astype(jnp.int32)


def last_valid_leaf(leaves: jax.Array, *, base=0) -> jax.Array:
    """Highest real leaf id in a tile, shifted by ``base``; -1 if none."""
    valid = leaves != LEAF_SENTINEL
    return jnp.max(jnp.where(valid, leaves - base, -1))


def scan_tile(
    pv: jax.Array,
    plf: jax.Array,
    pid: jax.Array,
    qv: jax.Array,
    qlf: jax.Array,
    *,
    k: int,
    impl: str,
) -> tuple[jax.Array, jax.Array]:
    """Fused distance + per-query top-k over one (points, queries) tile.

    Returns ``(cand_d, cand_i)`` of shape ``(Q, k)``: partial squared
    distances (no ``||q||^2`` term) with ``inf``/``INVALID_ID`` where fewer
    than ``k`` same-leaf points exist. ``cand_i`` holds *global* descriptor
    ids (mapped through ``pid``), not tile-row indices.
    """
    cand_d, cand_sel = l2topk_ops.l2_topk(pv, plf, qv, qlf, k=k, impl=impl)
    cand_i = jnp.where(cand_sel >= 0, pid[jnp.clip(cand_sel, 0)], INVALID_ID)
    cand_d = jnp.where(cand_i >= 0, cand_d, jnp.inf)
    return cand_d, cand_i


def count_pairs(plf: jax.Array, qlf: jax.Array) -> jax.Array:
    """Exact number of same-leaf (point, query) distance pairs in a tile.

    Sentinel/padding leaves on either side never match a real leaf (see
    ``repro.core.sentinels``), but two padded rows of the *same* kind would
    match each other — mask both sides explicitly.
    """
    p_ok = (plf >= 0) & (plf != LEAF_SENTINEL)
    q_ok = (qlf >= 0) & (qlf != LEAF_SENTINEL)
    match = (plf[:, None] == qlf[None, :]) & p_ok[:, None] & q_ok[None, :]
    return jnp.sum(match, dtype=jnp.float32)


def fold_topk(
    cur_d: jax.Array, cur_i: jax.Array, cand_d: jax.Array, cand_i: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Merge a candidate table into a running best-k table (row-wise)."""
    k = cur_d.shape[-1]
    all_d = jnp.concatenate([cur_d, cand_d], axis=-1)
    all_i = jnp.concatenate([cur_i, cand_i], axis=-1)
    neg, sel = jax.lax.top_k(-all_d, k)
    return -neg, jnp.take_along_axis(all_i, sel, axis=-1)


def merge_probe_groups(
    d: jax.Array, i: jax.Array, probes: int
) -> tuple[jax.Array, jax.Array]:
    """Dedupe/merge the ``probes`` candidate rows of each original query.

    ``d``/``i`` are ``(rows, k)`` tables indexed by flat lookup-row slot
    (``query_id * probes + probe_rank``). Each query's probe rows target
    *distinct* leaves and every point lives in exactly one leaf, so the id
    sets are disjoint and merging is a plain per-group top-k.
    """
    if probes == 1:
        return d, i
    rows, k = d.shape
    if rows % probes:
        raise ValueError(f"{rows=} not a multiple of {probes=}")
    gd = d.reshape(rows // probes, probes * k)
    gi = i.reshape(rows // probes, probes * k)
    neg, sel = jax.lax.top_k(-gd, k)
    return -neg, jnp.take_along_axis(gi, sel, axis=-1)
