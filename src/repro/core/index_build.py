"""Distributed index creation (paper §2.3), SPMD.

Map: every shard assigns its descriptor rows to tree leaves in *waves*
(microbatched tiles — the map-wave analog; wave size is the HDFS-chunk-size
analog, studied in benchmarks/block_size.py). Shuffle: rows are routed to
the shard owning their leaf range via capacity-padded counting sort +
``all_to_all``. Reduce: each shard sorts its received rows by leaf and
builds CSR offsets — the "index files which contain clustered
high-dimensional descriptors".

Everything is one jittable function of (vecs, ids, tree) so the multi-pod
dry-run lowers it directly.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import route as route_lib
from repro.distributed.compat import shard_map
from repro.core.tree import VocabTree, tree_assign
from repro.distributed.meshutil import batch_axes, data_axis_size, round_up


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DistributedIndex:
    """Cluster-sorted descriptor shards + per-shard CSR offsets."""

    vecs: jax.Array  # (S*R, d) rows sharded over data axes; leaf-sorted per shard
    ids: jax.Array  # (S*R,) global descriptor ids (-1 padding)
    leaves: jax.Array  # (S*R,) leaf ids (SENTINEL padding)
    offsets: jax.Array  # (S, leaves_per_shard+1) CSR per shard
    n_valid: jax.Array  # (S,) valid rows per shard
    overflow: jax.Array  # () rows dropped in routing (0 in healthy runs)
    n_leaves: int = dataclasses.field(metadata=dict(static=True), default=0)

    def tree_flatten(self):
        children = (self.vecs, self.ids, self.leaves, self.offsets,
                    self.n_valid, self.overflow)
        return children, self.n_leaves

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, n_leaves=aux)

    @property
    def rows(self) -> int:
        return self.vecs.shape[0]

    @property
    def leaves_per_shard(self) -> int:
        return self.offsets.shape[1] - 1


def routing_capacity(rows_per_shard: int, n_shards: int,
                     capacity_factor: float) -> int:
    """Send capacity per (source shard, destination shard) pair."""
    expected = rows_per_shard / n_shards
    return round_up(max(8, int(math.ceil(expected * capacity_factor))), 8)


def _assign_in_waves(tree: VocabTree, vecs: jax.Array, wave_rows: int) -> jax.Array:
    """Map phase: leaf assignment microbatched into waves (bounds the
    gather working set of deep tree levels, the VMEM analog of the paper's
    block-at-a-time map input)."""
    n = vecs.shape[0]
    if n % wave_rows != 0:
        raise ValueError(f"shard rows {n} not divisible by wave_rows {wave_rows}")
    waves = vecs.reshape(n // wave_rows, wave_rows, vecs.shape[1])
    leaves = jax.lax.map(lambda w: tree_assign(tree, w), waves)
    return leaves.reshape(n)


def build_index_fn(
    mesh: Mesh,
    *,
    n_leaves: int,
    rows_per_shard: int,
    wave_rows: int,
    capacity_factor: float = 2.0,
    wire_dtype=jnp.bfloat16,
    axes=None,
):
    """Return the jittable (vecs, ids, tree) -> DistributedIndex pipeline.

    ``axes``: mesh axes the descriptor rows shard over. The paper's cluster
    is flat — an index job has no model-parallel dimension — so production
    cells pass *every* mesh axis (leaving the model axis out replicates the
    whole job per model column: §Perf hillclimb, index_wave).
    """
    import math as _math

    axes = tuple(axes) if axes else batch_axes(mesh)
    n_shards = _math.prod(mesh.shape[a] for a in axes)
    if n_leaves % n_shards != 0:
        raise ValueError(f"n_leaves {n_leaves} must divide over {n_shards} shards")
    leaves_per_shard = n_leaves // n_shards
    capacity = routing_capacity(rows_per_shard, n_shards, capacity_factor)

    def shard_fn(vecs, ids, tree):
        # --- map: assignment in waves --------------------------------------
        leaves = _assign_in_waves(tree, vecs[0], wave_rows)
        # --- shuffle: route to owner shards --------------------------------
        routed = route_lib.route_by_leaf(
            vecs[0],
            ids[0],
            leaves,
            axis_name=axes,
            n_shards=n_shards,
            leaves_per_shard=leaves_per_shard,
            capacity=capacity,
            wire_dtype=wire_dtype,
        )
        # --- reduce: cluster sort + CSR ------------------------------------
        shard_id = jnp.int32(0)
        for a in axes:
            shard_id = shard_id * mesh.shape[a] + jax.lax.axis_index(a)
        leaf_base = shard_id * leaves_per_shard
        svecs, sids, sleaves, offsets, n_valid = route_lib.cluster_sort(
            routed, leaf_base=leaf_base, leaves_per_shard=leaves_per_shard
        )
        return (
            svecs[None],
            sids[None],
            sleaves[None],
            offsets[None],
            n_valid[None],
            routed.overflow,
        )

    row_spec = P(axes, None)
    flat_spec = P(axes)

    def pipeline(vecs, ids, tree):
        # keep a leading per-shard axis so shard row counts are explicit
        vecs = vecs.reshape(n_shards, rows_per_shard, vecs.shape[-1])
        ids = ids.reshape(n_shards, rows_per_shard)
        tree_specs = jax.tree.map(lambda _: P(), tree)
        out = shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(row_spec, flat_spec, tree_specs),
            out_specs=(row_spec, flat_spec, flat_spec, flat_spec, flat_spec, P()),
        )(vecs, ids, tree)
        svecs, sids, sleaves, offsets, n_valid, overflow = out
        return DistributedIndex(
            vecs=svecs.reshape(-1, svecs.shape[-1]),
            ids=sids.reshape(-1),
            leaves=sleaves.reshape(-1),
            offsets=offsets,
            n_valid=n_valid,
            overflow=overflow,
            n_leaves=n_leaves,
        )

    return pipeline


def build_index(
    vecs: jax.Array,
    tree: VocabTree,
    mesh: Mesh,
    *,
    ids: jax.Array | None = None,
    wave_rows: int | None = None,
    capacity_factor: float = 2.0,
    wire_dtype=jnp.bfloat16,
) -> DistributedIndex:
    """Eager convenience wrapper (pads rows to the shard grid, jits, runs)."""
    n, d = vecs.shape
    n_shards = data_axis_size(mesh)
    n_pad = round_up(n, n_shards)
    if ids is None:
        ids = jnp.arange(n, dtype=jnp.int32)
    if n_pad != n:
        vecs = jnp.concatenate([vecs, jnp.zeros((n_pad - n, d), vecs.dtype)])
        # padding rows get id -1 and will be routed but never matched
        ids = jnp.concatenate([ids, jnp.full((n_pad - n,), -1, jnp.int32)])
    rows_per_shard = n_pad // n_shards
    from repro.core.engine.plan import largest_divisor_leq

    # snap to the largest divisor of rows_per_shard <= requested
    wave_rows = largest_divisor_leq(rows_per_shard, wave_rows or 4096)
    fn = build_index_fn(
        mesh,
        n_leaves=tree.n_leaves,
        rows_per_shard=rows_per_shard,
        wave_rows=wave_rows,
        capacity_factor=capacity_factor,
        wire_dtype=wire_dtype,
    )
    sharded = NamedSharding(mesh, P(batch_axes(mesh), None))
    vecs = jax.device_put(vecs, sharded)
    ids = jax.device_put(ids, NamedSharding(mesh, P(batch_axes(mesh))))
    return jax.jit(fn)(vecs, ids, tree)
