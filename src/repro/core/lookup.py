"""Query lookup table (paper §2.4, step 1).

All query descriptors of a batch are assigned to their leaf cluster by
traversing the index tree, then reordered by leaf id; a CSR offset array per
leaf lets any index block find "which query descriptors have to be used in
distance calculations when a cluster identifier is given". The table is the
broadcast auxiliary data of the search phase — replicated across devices
(the paper ships it to every map task via HDFS).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.distance import sq_norms
from repro.core.sentinels import PAD_QUERY_LEAF
from repro.core.tree import VocabTree, tree_assign


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LookupTable:
    vecs: jax.Array  # (Q, d) query descriptors, sorted by leaf id
    qids: jax.Array  # (Q,) original query row ids (permutation)
    leaves: jax.Array  # (Q,) leaf id per sorted query
    offsets: jax.Array  # (n_leaves + 1,) CSR start offsets into vecs

    def tree_flatten(self):
        return (self.vecs, self.qids, self.leaves, self.offsets), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_queries(self) -> int:
        return self.vecs.shape[0]

    @property
    def n_leaves(self) -> int:
        return self.offsets.shape[0] - 1

    @property
    def nbytes(self) -> int:
        return sum(
            a.size * a.dtype.itemsize
            for a in (self.vecs, self.qids, self.leaves, self.offsets)
        )


def probe_leaves(tree: VocabTree, queries: jax.Array, probes: int) -> jax.Array:
    """(Q, probes) leaves per query: the hierarchical assignment first, then
    the next-nearest leaves (multi-probe soft assignment).

    Beam descent, not a dense scan over all ``n_leaves`` centroids: each
    level keeps the ``probes`` nearest nodes among the beam's children
    (O(Q * probes * fanout * d) per level — same shape as ``tree_assign``,
    beam-wide), so large-vocab trees (65k leaves) never materialise a
    (Q, n_leaves) distance matrix.

    Column 0 is exactly ``tree_assign``: the greedy chain is maintained
    *inside* the loop with the same arithmetic (one descent, not two), is
    force-kept in the beam, and is pinned to rank 0 — so ``probes=1``
    reproduces the hard assignment and widening ``probes`` only ever
    *adds* visited leaves (recall is monotone non-decreasing in T).
    """
    if probes == 1:
        return tree_assign(tree, queries).astype(jnp.int32)[:, None]
    qf = queries.astype(jnp.float32)
    n_q = qf.shape[0]
    roots = tree.levels[0].astype(jnp.float32)
    d2 = sq_norms(roots)[None, :] - 2.0 * jnp.einsum(
        "qd,md->qm", qf, roots, preferred_element_type=jnp.float32
    )  # (Q, f0) — same partial distance tree_assign's nearest() uses
    greedy = jnp.argmin(d2, axis=1).astype(jnp.int32)
    neg, nodes = jax.lax.top_k(-d2, min(probes, roots.shape[0]))
    has = (nodes == greedy[:, None]).any(axis=1)
    nodes = nodes.at[:, -1].set(jnp.where(has, nodes[:, -1], greedy))
    for lvl in tree.levels[1:]:
        f = lvl.shape[1]
        lf = lvl.astype(jnp.float32)
        cn = jnp.sum(lf * lf, axis=-1)  # (nodes, f) — loop-invariant
        gathered = lf[nodes]  # (Q, B, f, d)
        d2 = cn[nodes] - 2.0 * jnp.einsum(
            "qd,qbfd->qbf", qf, gathered, preferred_element_type=jnp.float32
        )
        cand = nodes[:, :, None] * f + jnp.arange(f, dtype=jnp.int32)
        neg, sel = jax.lax.top_k(-d2.reshape(n_q, -1), min(probes, cand[0].size))
        nodes = jnp.take_along_axis(cand.reshape(n_q, -1), sel, axis=1)
        # advance the greedy chain and force it into the beam (it can fall
        # out: beam score is centroid distance, which is not monotone down
        # the hierarchy) — replace the worst slot when missing
        g_children = lf[greedy]  # (Q, f, d)
        gd2 = cn[greedy] - 2.0 * jnp.einsum(
            "qd,qfd->qf", qf, g_children, preferred_element_type=jnp.float32
        )
        greedy = greedy * f + jnp.argmin(gd2, axis=1).astype(jnp.int32)
        has = (nodes == greedy[:, None]).any(axis=1)
        nodes = nodes.at[:, -1].set(jnp.where(has, nodes[:, -1], greedy))
    # pin the hard assignment (== greedy chain) to rank 0, keep the rest in
    # beam (ascending-distance) order
    is_primary = nodes == greedy[:, None]
    rank = jnp.where(is_primary, -1, jnp.arange(nodes.shape[1], dtype=jnp.int32))
    order = jnp.argsort(rank, axis=1, stable=True)
    return jnp.take_along_axis(nodes, order, axis=1).astype(jnp.int32)


def build_lookup(
    tree: VocabTree, queries: jax.Array, *, probes: int = 1
) -> LookupTable:
    """Assign queries to their ``probes`` nearest leaves and build the CSR
    table (jit-able; ``probes`` static).

    Args:
      tree: the vocabulary :class:`~repro.core.tree.VocabTree`.
      queries: ``(Q, d)`` query descriptors (any float dtype; routing
        arithmetic is f32).
      probes: leaves visited per query (multi-probe width T, static).

    Returns:
      A :class:`LookupTable` of ``Q * probes`` rows, leaf-sorted with CSR
      offsets. With multi-probe, each query expands into ``probes`` rows
      (same vector, one row per probed leaf); ``qids`` then hold *flat
      merge slots* ``query_id * probes + probe_rank`` — a permutation of
      ``arange(Q * probes)`` — which the engine executors scatter into
      and fold back to one k-row per query at merge time.

    Raises:
      ValueError: ``probes < 1`` or ``probes > tree.n_leaves``.
    """
    if probes < 1:
        raise ValueError(f"{probes=} must be >= 1")
    if probes > tree.n_leaves:
        raise ValueError(f"{probes=} must be <= n_leaves={tree.n_leaves}")
    # one implementation of the sort/CSR build: the fixed-shape serving
    # path with no masked rows and no tail padding IS the direct build
    leaves = probe_leaves(tree, queries, probes)
    return lookup_from_leaves(queries, leaves, n_leaves=tree.n_leaves)


def lookup_from_leaves(
    queries: jax.Array,
    leaves: jax.Array,
    *,
    n_leaves: int,
    n_valid: jax.Array | int | None = None,
    q_total: int | None = None,
) -> LookupTable:
    """Build a :class:`LookupTable` from precomputed ``(Q, probes)`` probe
    leaves, at a *fixed output shape* — the serving bucket path.

    ``n_valid`` (traced OK) marks how many leading query rows are real;
    rows ``>= n_valid`` get :data:`PAD_QUERY_LEAF`, so a padded bucket
    never routes garbage to a real leaf, never matches any point, and never
    changes a real query's slab — yet the jitted shapes are those of the
    full bucket, so varying request sizes within a bucket never recompile.
    ``q_total`` appends pad_lookup-style tail rows (fresh flat slots past
    the real ones) up to the executor's padded row count.

    Real rows keep exactly the ordering :func:`build_lookup` gives them
    (stable sort by leaf), so bucketed results are bit-identical to the
    direct path for the same plan budgets.
    """
    q, probes = leaves.shape
    q_rows = q * probes
    if q_total is None:
        q_total = q_rows
    if q_total < q_rows or q_total % probes:
        raise ValueError(
            f"{q_total=} must be >= {q_rows} and a multiple of {probes=}"
        )
    if n_valid is None:
        n_valid = q
    valid = jnp.arange(q, dtype=jnp.int32) < n_valid
    leaves = jnp.where(
        valid[:, None], leaves, jnp.int32(PAD_QUERY_LEAF)
    ).reshape(-1)
    vecs = jnp.repeat(queries, probes, axis=0) if probes > 1 else queries
    order = jnp.argsort(leaves, stable=True)
    sorted_leaves = leaves[order].astype(jnp.int32)
    # offsets over the q_rows sorted region only (tail pads appended after,
    # exactly like pad_lookup — they are outside every CSR span)
    offsets = jnp.searchsorted(
        sorted_leaves, jnp.arange(n_leaves + 1, dtype=jnp.int32)
    ).astype(jnp.int32)
    pad = q_total - q_rows
    svecs = vecs[order]
    qids = order.astype(jnp.int32)
    if pad:
        svecs = jnp.concatenate(
            [svecs, jnp.zeros((pad, svecs.shape[1]), svecs.dtype)]
        )
        qids = jnp.concatenate(
            [qids, jnp.arange(q_rows, q_total, dtype=jnp.int32)]
        )
        sorted_leaves = jnp.concatenate(
            [sorted_leaves, jnp.full((pad,), PAD_QUERY_LEAF, jnp.int32)]
        )
    return LookupTable(
        vecs=svecs, qids=qids, leaves=sorted_leaves, offsets=offsets
    )


def build_lookup_bucketed(
    tree: VocabTree,
    queries: jax.Array,
    n_valid: jax.Array | int,
    *,
    probes: int = 1,
    q_total: int | None = None,
) -> tuple[LookupTable, jax.Array]:
    """Bucket-shaped :func:`build_lookup`: queries are padded to a warmed
    bucket size and ``n_valid`` masks the tail. Returns the table plus the
    ``(Q, probes)`` probe-leaf matrix (the serving hot-leaf cache keys on
    it)."""
    leaves = probe_leaves(tree, queries, probes)
    lk = lookup_from_leaves(
        queries, leaves, n_leaves=tree.n_leaves, n_valid=n_valid,
        q_total=q_total,
    )
    return lk, leaves
