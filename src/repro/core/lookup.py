"""Query lookup table (paper §2.4, step 1).

All query descriptors of a batch are assigned to their leaf cluster by
traversing the index tree, then reordered by leaf id; a CSR offset array per
leaf lets any index block find "which query descriptors have to be used in
distance calculations when a cluster identifier is given". The table is the
broadcast auxiliary data of the search phase — replicated across devices
(the paper ships it to every map task via HDFS).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.tree import VocabTree, tree_assign


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LookupTable:
    vecs: jax.Array  # (Q, d) query descriptors, sorted by leaf id
    qids: jax.Array  # (Q,) original query row ids (permutation)
    leaves: jax.Array  # (Q,) leaf id per sorted query
    offsets: jax.Array  # (n_leaves + 1,) CSR start offsets into vecs

    def tree_flatten(self):
        return (self.vecs, self.qids, self.leaves, self.offsets), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_queries(self) -> int:
        return self.vecs.shape[0]

    @property
    def n_leaves(self) -> int:
        return self.offsets.shape[0] - 1

    @property
    def nbytes(self) -> int:
        return sum(
            a.size * a.dtype.itemsize
            for a in (self.vecs, self.qids, self.leaves, self.offsets)
        )


def build_lookup(tree: VocabTree, queries: jax.Array) -> LookupTable:
    """Assign queries to leaves and build the CSR table (jit-able)."""
    leaves = tree_assign(tree, queries)
    order = jnp.argsort(leaves, stable=True)
    sorted_leaves = leaves[order].astype(jnp.int32)
    offsets = jnp.searchsorted(
        sorted_leaves, jnp.arange(tree.n_leaves + 1, dtype=jnp.int32)
    ).astype(jnp.int32)
    return LookupTable(
        vecs=queries[order],
        qids=order.astype(jnp.int32),
        leaves=sorted_leaves,
        offsets=offsets,
    )
