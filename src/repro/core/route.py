"""Cluster routing: the paper's shuffle phase, TPU-native.

Hadoop's copy-merge-sort shuffle (map outputs keyed by cluster id, delivered
to the reducer owning that key) becomes, per device shard:

  1. destination = owner shard of the row's leaf  (contiguous leaf ranges)
  2. capacity-padded counting sort into per-destination send buffers
  3. ``lax.all_to_all`` over the data axis (the wire)
  4. local sort of received rows by leaf  (the reduce-side merge-sort)

Capacity padding replaces Hadoop's elastic spill-to-disk: a shard can send at
most ``capacity`` rows to any destination; rows beyond that are dropped and
*counted* (the analog of the paper's failed/re-executed task statistics,
Table 5). Pipelines size the capacity factor so the expected drop count is
zero, and tests assert it.

Wire compression: payload vectors can be cast to a narrower ``wire_dtype``
for the exchange — the analog of the paper's map-output compression, which
cut shuffle bytes by 30%; bf16 cuts ours by 50%.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.sentinels import LEAF_SENTINEL

# Historical alias — the named constants now live in repro.core.sentinels.
SENTINEL = LEAF_SENTINEL


class CountingLayout(NamedTuple):
    """Scatter layout of local rows into (n_dest, capacity) send slots."""

    slot_of_row: jax.Array  # (n,) flat slot id dest*capacity+pos, or -1
    fits: jax.Array  # (n,) bool — row made it into its destination bucket
    overflow: jax.Array  # () int32 — rows dropped (capacity exceeded)


def counting_layout(dest: jax.Array, n_dest: int, capacity: int) -> CountingLayout:
    """Stable counting sort of rows by destination with per-dest capacity."""
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sorted_dest = dest[order]
    # start offset of each destination's segment in the sorted order
    starts = jnp.searchsorted(sorted_dest, jnp.arange(n_dest, dtype=dest.dtype))
    # position of each row within its destination segment
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_dest].astype(jnp.int32)
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    in_range = (dest >= 0) & (dest < n_dest)
    fits = (pos < capacity) & in_range
    slot = jnp.where(fits, dest.astype(jnp.int32) * capacity + pos, -1)
    # only in-range rows count as dropped (negative dest = padding rows)
    overflow = jnp.sum(~fits & in_range).astype(jnp.int32)
    return CountingLayout(slot_of_row=slot, fits=fits, overflow=overflow)


def scatter_to_slots(
    layout: CountingLayout, x: jax.Array, n_dest: int, capacity: int, fill=0
) -> jax.Array:
    """Place rows into their (n_dest*capacity, ...) send slots."""
    out_shape = (n_dest * capacity,) + x.shape[1:]
    buf = jnp.full(out_shape, fill, dtype=x.dtype)
    # rows that don't fit get an out-of-bounds slot and are dropped
    slot = jnp.where(layout.fits, layout.slot_of_row, n_dest * capacity)
    return buf.at[slot].set(x, mode="drop")


class Routed(NamedTuple):
    """Per-shard received rows after the exchange (padded, mask via leaf)."""

    vecs: jax.Array  # (n_dest*capacity, d)
    ids: jax.Array  # (n_dest*capacity,) global row ids; -1 invalid
    leaves: jax.Array  # (n_dest*capacity,) leaf ids; SENTINEL invalid
    overflow: jax.Array  # () rows dropped on the send side (psum'd)


def route_by_leaf(
    vecs: jax.Array,
    ids: jax.Array,
    leaves: jax.Array,
    *,
    axis_name,
    n_shards: int,
    leaves_per_shard: int,
    capacity: int,
    wire_dtype=jnp.bfloat16,
) -> Routed:
    """Shuffle rows to the shard owning their leaf (call inside shard_map)."""
    dest = (leaves // leaves_per_shard).astype(jnp.int32)
    layout = counting_layout(dest, n_shards, capacity)

    send_vecs = scatter_to_slots(layout, vecs.astype(wire_dtype), n_shards, capacity)
    send_ids = scatter_to_slots(layout, ids.astype(jnp.int32), n_shards, capacity, fill=-1)
    send_leaves = scatter_to_slots(
        layout, leaves.astype(jnp.int32), n_shards, capacity, fill=SENTINEL
    )
    # mark empty slots invalid (fill of vecs/ids alone is ambiguous)
    slot_used = scatter_to_slots(
        layout, jnp.ones(leaves.shape, jnp.int8), n_shards, capacity
    )
    send_leaves = jnp.where(slot_used > 0, send_leaves, SENTINEL)
    send_ids = jnp.where(slot_used > 0, send_ids, -1)

    recv_vecs = jax.lax.all_to_all(send_vecs, axis_name, 0, 0, tiled=True)
    recv_ids = jax.lax.all_to_all(send_ids, axis_name, 0, 0, tiled=True)
    recv_leaves = jax.lax.all_to_all(send_leaves, axis_name, 0, 0, tiled=True)
    overflow = jax.lax.psum(layout.overflow, axis_name)
    return Routed(
        vecs=recv_vecs.astype(vecs.dtype),
        ids=recv_ids,
        leaves=recv_leaves,
        overflow=overflow,
    )


def cluster_sort(routed: Routed, *, leaf_base: jax.Array, leaves_per_shard: int):
    """Reduce-side merge: sort received rows by leaf, build CSR offsets.

    ``leaf_base`` is this shard's first owned leaf. Returns
    (vecs, ids, leaves, offsets, n_valid) where offsets has length
    ``leaves_per_shard + 1`` over *local* leaf ids.
    """
    order = jnp.argsort(routed.leaves, stable=True)
    vecs = routed.vecs[order]
    ids = routed.ids[order]
    leaves = routed.leaves[order]
    n_valid = jnp.sum(leaves != SENTINEL).astype(jnp.int32)
    local_leaf = jnp.where(
        leaves == SENTINEL, jnp.int32(leaves_per_shard), leaves - leaf_base
    ).astype(jnp.int32)
    offsets = jnp.searchsorted(
        local_leaf, jnp.arange(leaves_per_shard + 1, dtype=jnp.int32)
    ).astype(jnp.int32)
    return vecs, ids, leaves, offsets, n_valid
