"""Distributed batch search (paper §2.4), SPMD.

Map: every shard scans its cluster-sorted index rows in waves of
``block_rows`` (HDFS-block analog). Because both the index shard and the
lookup table are sorted by leaf id, the queries colliding with a tile are a
*contiguous slab* of the lookup table — the tile reads ``q_cap`` rows
starting at ``offsets[first_leaf_of_tile]``, computes one dense distance
GEMM, masks exact leaf equality, and folds the per-query best-k into a
running table (``l2topk`` kernel shape). Reduce: per-shard k-NN tables are
merged with one log-shaped top-k across the data axis.

The lookup table is the broadcast auxiliary data; ``q_cap`` is the RAM-
limited lookup-table budget the paper discusses in Exp #5 — overflow of the
slab is counted and reported, never silently wrong (tests assert 0).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.distance import sq_norms
from repro.core.index_build import DistributedIndex
from repro.core.lookup import LookupTable, build_lookup
from repro.core.route import SENTINEL
from repro.core.tree import VocabTree
from repro.distributed.meshutil import batch_axes, data_axis_size, round_up
from repro.kernels.l2topk import ops as l2topk_ops


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SearchResult:
    ids: jax.Array  # (Q, k) global descriptor ids, -1 where fewer than k
    dists: jax.Array  # (Q, k) true squared L2 distances (inf where id=-1)
    pairs: jax.Array  # () number of (point, query) distance pairs computed
    q_cap_overflow: jax.Array  # () slab-budget misses (0 == exact-in-cluster)

    def tree_flatten(self):
        return (self.ids, self.dists, self.pairs, self.q_cap_overflow), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


class _Carry(NamedTuple):
    best_d: jax.Array
    best_i: jax.Array
    pairs: jax.Array
    overflow: jax.Array


def batch_search_fn(
    mesh: Mesh,
    *,
    n_leaves: int,
    shard_rows: int,
    q_total: int,
    block_rows: int,
    q_cap: int,
    k: int,
    impl: str = "xla",
    axes=None,
):
    """Build the jittable (index, lookup) -> SearchResult pipeline."""
    import math as _math

    axes = tuple(axes) if axes else batch_axes(mesh)
    n_shards = _math.prod(mesh.shape[a] for a in axes)
    if shard_rows % block_rows != 0:
        raise ValueError(f"{shard_rows=} not divisible by {block_rows=}")
    if k > block_rows:
        raise ValueError(f"{k=} must be <= {block_rows=}")
    if q_cap > q_total:
        raise ValueError(f"{q_cap=} must be <= padded query count {q_total=}")
    n_waves = shard_rows // block_rows

    def shard_fn(vecs, leaves, ids, lk_vecs, lk_leaves, lk_offsets):
        vecs, leaves, ids = vecs[0], leaves[0], ids[0]

        def wave(i, c: _Carry) -> _Carry:
            start = i * block_rows
            pv = jax.lax.dynamic_slice(vecs, (start, 0), (block_rows, vecs.shape[1]))
            plf = jax.lax.dynamic_slice(leaves, (start,), (block_rows,))
            pid = jax.lax.dynamic_slice(ids, (start,), (block_rows,))
            # contiguous query slab for this tile's leaf span
            l0 = jnp.clip(plf[0], 0, n_leaves - 1)
            qstart = jnp.clip(lk_offsets[l0], 0, q_total - q_cap)
            qv = jax.lax.dynamic_slice(lk_vecs, (qstart, 0), (q_cap, lk_vecs.shape[1]))
            qlf = jax.lax.dynamic_slice(lk_leaves, (qstart,), (q_cap,))
            # fused distance + per-query top-k over the tile (kernel shape)
            cand_d, cand_sel = l2topk_ops.l2_topk(
                pv, plf, qv, qlf, k=k, impl=impl
            )  # (q_cap, k): partial sqdist (no ||q||^2) + tile-row index
            cand_i = jnp.where(cand_sel >= 0, pid[jnp.clip(cand_sel, 0)], -1)
            cand_d = jnp.where(cand_i >= 0, cand_d, jnp.inf)
            # fold into the running per-query k-NN table
            cur_d = jax.lax.dynamic_slice(c.best_d, (qstart, 0), (q_cap, k))
            cur_i = jax.lax.dynamic_slice(c.best_i, (qstart, 0), (q_cap, k))
            all_d = jnp.concatenate([cur_d, cand_d], axis=1)
            all_i = jnp.concatenate([cur_i, cand_i], axis=1)
            neg, sel = jax.lax.top_k(-all_d, k)
            new_i = jnp.take_along_axis(all_i, sel, axis=1)
            best_d = jax.lax.dynamic_update_slice(c.best_d, -neg, (qstart, 0))
            best_i = jax.lax.dynamic_update_slice(c.best_i, new_i, (qstart, 0))
            # bookkeeping: pairs computed + slab-budget misses
            valid = plf != SENTINEL
            match = (plf[:, None] == qlf[None, :]) & valid[:, None]
            pairs = c.pairs + jnp.sum(match, dtype=jnp.float32)
            last_leaf = jnp.max(jnp.where(valid, plf, -1))
            need_end = jnp.where(
                last_leaf >= 0, lk_offsets[jnp.clip(last_leaf, 0, n_leaves - 1) + 1], qstart
            )
            overflow = c.overflow + jnp.maximum(0, need_end - qstart - q_cap)
            return _Carry(best_d, best_i, pairs, overflow)

        init = _Carry(
            best_d=jnp.full((q_total, k), jnp.inf, jnp.float32),
            best_i=jnp.full((q_total, k), -1, jnp.int32),
            pairs=jnp.zeros((), jnp.float32),
            overflow=jnp.zeros((), jnp.int32),
        )
        # the carry varies across shards (each shard scans its own rows)
        init = jax.tree.map(lambda x: jax.lax.pcast(x, axes, to="varying"), init)
        out = jax.lax.fori_loop(0, n_waves, wave, init)
        pairs = jax.lax.psum(out.pairs, axes)
        overflow = jax.lax.psum(out.overflow, axes)
        return out.best_d[None], out.best_i[None], pairs, overflow

    def pipeline(index: DistributedIndex, lookup: LookupTable) -> SearchResult:
        d = index.vecs.shape[-1]
        vecs = index.vecs.reshape(n_shards, shard_rows, d)
        leaves = index.leaves.reshape(n_shards, shard_rows)
        ids = index.ids.reshape(n_shards, shard_rows)
        row_spec = P(axes, None)
        flat_spec = P(axes)
        rep = P()
        best_d, best_i, pairs, overflow = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(row_spec, flat_spec, flat_spec, rep, rep, rep),
            out_specs=(P(axes, None, None), P(axes, None, None), rep, rep),
        )(vecs, leaves, ids, lookup.vecs, lookup.leaves, lookup.offsets)
        # ---- reduce: merge per-shard k-NN tables --------------------------
        # (S, Q, k) sharded over S -> (Q, S*k) sharded over Q (all_to_all
        # reshard), then a purely local per-row top-k. Never replicated:
        # at pod scale the stacked table is tens of GB global.
        row_sh = NamedSharding(mesh, P(axes, None))
        all_d = jnp.transpose(best_d, (1, 0, 2)).reshape(q_total, n_shards * k)
        all_i = jnp.transpose(best_i, (1, 0, 2)).reshape(q_total, n_shards * k)
        all_d = jax.lax.with_sharding_constraint(all_d, row_sh)
        all_i = jax.lax.with_sharding_constraint(all_i, row_sh)
        neg, sel = jax.lax.top_k(-all_d, k)
        merged_d = -neg + sq_norms(lookup.vecs)[:, None]  # add back ||q||^2
        merged_i = jnp.take_along_axis(all_i, sel, axis=1)
        merged_d = jnp.where(merged_i >= 0, merged_d, jnp.inf)
        # ---- unsort to original query order -------------------------------
        out_d = jnp.zeros_like(merged_d).at[lookup.qids].set(merged_d)
        out_i = jnp.zeros_like(merged_i).at[lookup.qids].set(merged_i)
        out_d = jax.lax.with_sharding_constraint(out_d, row_sh)
        out_i = jax.lax.with_sharding_constraint(out_i, row_sh)
        return SearchResult(ids=out_i, dists=out_d, pairs=pairs,
                            q_cap_overflow=overflow)

    return pipeline


def routed_search_fn(
    mesh: Mesh,
    *,
    n_leaves: int,
    shard_rows: int,
    q_total: int,
    q_tile: int,
    p_cap: int,
    k: int,
    query_capacity_factor: float = 4.0,
    impl: str = "xla",
    wire_dtype=jnp.float32,
    axes=None,
):
    """Query-routed search (beyond-paper, EXPERIMENTS.md §Perf hillclimb #2).

    The baseline (``batch_search_fn``) is point-major: every shard scans its
    index rows against a replicated lookup table, carrying a full
    (q_total, k) running best table that is copied/updated every wave —
    the dominant HBM term at scale. Here the *queries* are routed to the
    shard owning their leaf (the same capacity-padded counting sort +
    all_to_all as index creation — paper's shuffle, reused), after which
    every query is answered entirely locally: one contiguous point slab per
    query tile (both sides are cluster-sorted), one distance GEMM, one
    top-k. No running table, no cross-shard k-NN merge.

    Budget knobs (both counted, never silently wrong):
      * query routing capacity (hot shards may overflow),
      * ``p_cap`` — points slab per query tile (leaf-span overflow).
    """
    import math as _math

    axes = tuple(axes) if axes else batch_axes(mesh)
    n_shards = _math.prod(mesh.shape[a] for a in axes)
    if n_leaves % n_shards:
        raise ValueError(f"{n_leaves=} must divide over {n_shards} shards")
    lps = n_leaves // n_shards
    q_cap_shard = round_up(
        max(q_tile, int(q_total / n_shards * query_capacity_factor)), q_tile
    )
    n_qwaves = q_cap_shard // q_tile
    from repro.core import route as route_lib
    from repro.core.route import SENTINEL

    def shard_fn(vecs, leaves, ids, offsets, lk_vecs, lk_leaves, lk_qids):
        vecs, leaves, ids, offsets = vecs[0], leaves[0], ids[0], offsets[0]
        shard_id = jnp.int32(0)
        for a in axes:
            shard_id = shard_id * mesh.shape[a] + jax.lax.axis_index(a)
        leaf_base = shard_id * lps
        # ---- shuffle: route queries to their leaf's owner shard ----------
        routed = route_lib.route_by_leaf(
            lk_vecs,
            lk_qids,
            lk_leaves,
            axis_name=axes,
            n_shards=n_shards,
            leaves_per_shard=lps,
            capacity=q_cap_shard // n_shards,
            wire_dtype=wire_dtype,
        )
        qv_all, qids_all, qlf_all, _, _ = route_lib.cluster_sort(
            routed, leaf_base=leaf_base, leaves_per_shard=lps
        )
        # pad/trim the local query set to the static budget
        pad = q_cap_shard - qv_all.shape[0]
        if pad > 0:
            qv_all = jnp.concatenate(
                [qv_all, jnp.zeros((pad, qv_all.shape[1]), qv_all.dtype)]
            )
            qids_all = jnp.concatenate([qids_all, jnp.full((pad,), -1, jnp.int32)])
            qlf_all = jnp.concatenate(
                [qlf_all, jnp.full((pad,), SENTINEL, jnp.int32)]
            )
        else:
            qv_all = qv_all[:q_cap_shard]
            qids_all = qids_all[:q_cap_shard]
            qlf_all = qlf_all[:q_cap_shard]

        def wave(w):
            qs = w * q_tile
            qv = jax.lax.dynamic_slice(qv_all, (qs, 0), (q_tile, qv_all.shape[1]))
            qlf = jax.lax.dynamic_slice(qlf_all, (qs,), (q_tile,))
            # contiguous local point slab covering this tile's leaf span
            l0 = jnp.clip(qlf[0] - leaf_base, 0, lps - 1)
            pstart = jnp.clip(offsets[l0], 0, shard_rows - p_cap)
            pv = jax.lax.dynamic_slice(vecs, (pstart, 0), (p_cap, vecs.shape[1]))
            plf = jax.lax.dynamic_slice(leaves, (pstart,), (p_cap,))
            pid = jax.lax.dynamic_slice(ids, (pstart,), (p_cap,))
            cand_d, cand_sel = l2topk_ops.l2_topk(
                pv, plf, qv, qlf, k=k, impl=impl
            )
            cand_i = jnp.where(cand_sel >= 0, pid[jnp.clip(cand_sel, 0)], -1)
            cand_d = jnp.where(cand_i >= 0, cand_d, jnp.inf)
            cand_d = cand_d + sq_norms(qv)[:, None]  # true squared distance
            # slab-budget accounting
            valid = qlf != SENTINEL
            last = jnp.max(jnp.where(valid, qlf, -1)) - leaf_base
            need_end = jnp.where(
                last >= 0, offsets[jnp.clip(last, 0, lps - 1) + 1], pstart
            )
            ov = jnp.maximum(0, need_end - pstart - p_cap)
            pairs = jnp.sum(
                (plf[:, None] == qlf[None, :]) & valid[None, :],
                dtype=jnp.float32,
            )
            return cand_d, cand_i, ov, pairs

        cand_d, cand_i, ov, pairs = jax.lax.map(wave, jnp.arange(n_qwaves))
        overflow = jax.lax.psum(jnp.sum(ov), axes) + jax.lax.psum(
            routed.overflow, axes
        )
        pairs = jax.lax.psum(jnp.sum(pairs), axes)
        return (
            cand_d.reshape(1, q_cap_shard, k),
            cand_i.reshape(1, q_cap_shard, k),
            qids_all[None],
            pairs,
            overflow,
        )

    def pipeline(index: DistributedIndex, lookup: LookupTable) -> SearchResult:
        d = index.vecs.shape[-1]
        vecs = index.vecs.reshape(n_shards, shard_rows, d)
        leaves = index.leaves.reshape(n_shards, shard_rows)
        ids = index.ids.reshape(n_shards, shard_rows)
        row_spec = P(axes, None)
        flat_spec = P(axes)
        rep = P()
        cand_d, cand_i, qids, pairs, overflow = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(row_spec, flat_spec, flat_spec, row_spec, rep, rep, rep),
            out_specs=(P(axes, None, None), P(axes, None, None), P(axes, None),
                       rep, rep),
        )(vecs, leaves, ids, index.offsets, lookup.vecs, lookup.leaves,
          lookup.qids)
        # one global scatter back to original query order (each query was
        # answered by exactly one shard — no merge needed)
        flat_d = cand_d.reshape(-1, k)
        flat_i = cand_i.reshape(-1, k)
        flat_q = qids.reshape(-1)
        safe_q = jnp.where(flat_q >= 0, flat_q, q_total)
        out_d = jnp.full((q_total, k), jnp.inf, jnp.float32).at[safe_q].set(
            flat_d, mode="drop"
        )
        out_i = jnp.full((q_total, k), -1, jnp.int32).at[safe_q].set(
            flat_i, mode="drop"
        )
        row_sh = NamedSharding(mesh, P(axes, None))
        out_d = jax.lax.with_sharding_constraint(out_d, row_sh)
        out_i = jax.lax.with_sharding_constraint(out_i, row_sh)
        return SearchResult(ids=out_i, dists=out_d, pairs=pairs,
                            q_cap_overflow=overflow)

    return pipeline


def pad_lookup(lookup: LookupTable, q_total: int) -> LookupTable:
    """Pad the lookup table to ``q_total`` rows; padding never matches."""
    q = lookup.vecs.shape[0]
    if q_total < q:
        raise ValueError(f"{q_total=} < {q}")
    if q_total == q:
        return lookup
    pad = q_total - q
    return LookupTable(
        vecs=jnp.concatenate(
            [lookup.vecs, jnp.zeros((pad, lookup.vecs.shape[1]), lookup.vecs.dtype)]
        ),
        qids=jnp.concatenate([lookup.qids, jnp.arange(q, q_total, dtype=jnp.int32)]),
        leaves=jnp.concatenate([lookup.leaves, jnp.full((pad,), -2, jnp.int32)]),
        offsets=lookup.offsets,
    )


def batch_search(
    index: DistributedIndex,
    tree: VocabTree,
    queries: jax.Array,
    k: int,
    mesh: Mesh,
    *,
    block_rows: int | None = None,
    q_cap: int | None = None,
    impl: str = "xla",
    layout: str = "point_major",
    p_cap: int | None = None,
    q_tile: int | None = None,
) -> SearchResult:
    """Eager convenience wrapper: build lookup, pad, jit, run, trim.

    layout="point_major": the paper-faithful baseline (scan index blocks
    against the broadcast lookup table). layout="query_routed": the
    beyond-paper pipeline (route queries to leaf owners; see
    routed_search_fn).
    """
    n_shards = data_axis_size(mesh)
    shard_rows = index.rows // n_shards
    q = queries.shape[0]
    lookup = jax.jit(build_lookup)(tree, queries)
    if layout == "query_routed":
        q_tile = q_tile or 128
        q_total = round_up(q, q_tile * n_shards)
        lookup = pad_lookup(lookup, q_total)
        if p_cap is None:
            avg_leaf = max(1, index.rows // max(1, index.n_leaves))
            # a q_tile may span many leaves on small shards: saturate to the
            # full shard if the budget would cover most of it anyway
            p_cap = min(shard_rows, round_up(max(4096, 16 * avg_leaf), 8))
        fn = routed_search_fn(
            mesh,
            n_leaves=index.n_leaves,
            shard_rows=shard_rows,
            q_total=q_total,
            q_tile=q_tile,
            p_cap=p_cap,
            k=k,
            impl=impl,
        )
        res = jax.jit(fn)(index, lookup)
        return SearchResult(
            ids=res.ids[:q], dists=res.dists[:q], pairs=res.pairs,
            q_cap_overflow=res.q_cap_overflow,
        )
    if block_rows is None:
        block_rows = 1024
    if shard_rows % block_rows != 0:
        # snap to the largest divisor of shard_rows <= requested
        block_rows = next(
            b for b in range(min(block_rows, shard_rows), 0, -1)
            if shard_rows % b == 0
        )
    if q_cap is None:
        q_cap = min(q, max(256, round_up(4 * q // max(1, tree.n_leaves), 8)))
    q_total = max(q, q_cap)
    lookup = pad_lookup(lookup, q_total)
    fn = batch_search_fn(
        mesh,
        n_leaves=index.n_leaves,
        shard_rows=shard_rows,
        q_total=q_total,
        block_rows=block_rows,
        q_cap=q_cap,
        k=k,
        impl=impl,
    )
    res = jax.jit(fn)(index, lookup)
    return SearchResult(
        ids=res.ids[:q],
        dists=res.dists[:q],
        pairs=res.pairs,
        q_cap_overflow=res.q_cap_overflow,
    )
