"""Distributed batch search (paper §2.4) — compatibility shim.

The actual machinery lives in :mod:`repro.core.engine`: a declarative
:class:`~repro.core.engine.SearchPlan`, a ``plan()`` heuristic, and two
executors (point-major and query-routed) rewritten on one shared tile-scan
core. This module keeps the historical entry points stable:

  * ``batch_search_fn`` / ``routed_search_fn`` — jittable pipeline builders
    with their original signatures (configs and hillclimb cells call these);
  * ``pad_lookup`` — lookup padding (now sentinel-named);
  * ``batch_search`` — the eager convenience wrapper, which gained
    ``layout="auto"`` (plan-heuristic pick) and multi-probe ``probes=T``;
  * ``search_with_lookup`` — one executor run over a *pre-built* lookup
    table. The segment-based :class:`repro.index.Index` shares a single
    lookup build across all its segments and calls this per segment.
"""

from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.engine import SearchPlan, make_executor, plan as make_plan
from repro.core.engine.executors import SearchResult, pad_lookup  # noqa: F401
from repro.core.index_build import DistributedIndex
from repro.core.lookup import LookupTable, build_lookup
from repro.core.tree import VocabTree
from repro.distributed.meshutil import data_axis_size, round_up

# one shared jitted lookup build: repeated eager searches (and the
# per-segment Index.search path) reuse the compiled program instead of
# re-lowering per call
jit_build_lookup = jax.jit(build_lookup, static_argnames=("probes",))


@lru_cache(maxsize=128)
def _cached_executor(mesh, plan: SearchPlan, n_leaves: int, shard_rows: int,
                     q_total: int):
    """Jitted executor cache keyed by everything that shapes the program.

    Segment searches hit the same (plan, shapes) repeatedly — once per
    search call per segment — and must not recompile each time.
    """
    return jax.jit(make_executor(
        mesh, plan, n_leaves=n_leaves, shard_rows=shard_rows, q_total=q_total
    ))


def lookup_q_total(p: SearchPlan, n_queries: int, n_shards: int) -> int:
    """Padded lookup-row count an executor for ``p`` needs.

    Query-routed rows must land on the ``(q_tile * n_shards)`` routing grid
    *and* stay a multiple of ``probes`` for the probe-group merge;
    point-major only needs the slab budget covered.
    """
    q_rows = n_queries * p.probes
    if p.layout == "query_routed":
        return round_up(q_rows, p.q_tile * n_shards * p.probes)
    return round_up(max(q_rows, p.q_cap), p.probes)


def search_with_lookup(
    index: DistributedIndex,
    lookup: LookupTable,
    plan: SearchPlan,
    mesh: Mesh,
    *,
    n_queries: int,
    codes=None,
    codebooks=None,
) -> SearchResult:
    """Run one resolved plan's executor over a pre-built lookup table.

    ``lookup`` is the unpadded ``n_queries * probes``-row table from
    :func:`~repro.core.lookup.build_lookup`; it is padded here to the
    executor's row count. Results are trimmed back to ``n_queries`` rows.

    For a ``scan_codes`` plan, ``codes`` (the segment's ``(rows, m)``
    uint8 PQ codes, row-aligned with ``index``) and ``codebooks`` (the
    quantizer's ``(m, C, dsub)`` table) are required, and the returned
    tables hold ``plan.rerank`` approximate ADC candidates per query —
    the caller reranks exactly (docs/compressed_codes.md).
    """
    n_shards = data_axis_size(mesh)
    shard_rows = index.rows // n_shards
    q_total = lookup_q_total(plan, n_queries, n_shards)
    fn = _cached_executor(mesh, plan, index.n_leaves, shard_rows, q_total)
    padded = pad_lookup(lookup, q_total)
    if plan.layout == "scan_codes":
        if codes is None or codebooks is None:
            raise ValueError("scan_codes plan needs codes + codebooks")
        res = fn(index, padded, jnp.asarray(codes), jnp.asarray(codebooks))
    else:
        res = fn(index, padded)
    return SearchResult(
        ids=res.ids[:n_queries],
        dists=res.dists[:n_queries],
        pairs=res.pairs,
        q_cap_overflow=res.q_cap_overflow,
    )


def batch_search_fn(
    mesh: Mesh,
    *,
    n_leaves: int,
    shard_rows: int,
    q_total: int,
    block_rows: int,
    q_cap: int,
    k: int,
    probes: int = 1,
    impl: str = "xla",
    axes=None,
):
    """Build the point-major (index, lookup) -> SearchResult pipeline."""
    p = SearchPlan(
        layout="point_major", k=k, probes=probes, impl=impl,
        block_rows=block_rows, q_cap=q_cap,
    )
    return make_executor(
        mesh, p, n_leaves=n_leaves, shard_rows=shard_rows, q_total=q_total,
        axes=axes,
    )


def routed_search_fn(
    mesh: Mesh,
    *,
    n_leaves: int,
    shard_rows: int,
    q_total: int,
    q_tile: int,
    p_cap: int,
    k: int,
    probes: int = 1,
    query_capacity_factor: float = 4.0,
    impl: str = "xla",
    wire_dtype=jnp.float32,
    axes=None,
):
    """Build the query-routed (index, lookup) -> SearchResult pipeline."""
    p = SearchPlan(
        layout="query_routed", k=k, probes=probes, impl=impl,
        wire_dtype=wire_dtype, q_tile=q_tile, p_cap=p_cap,
        query_capacity_factor=query_capacity_factor,
    )
    return make_executor(
        mesh, p, n_leaves=n_leaves, shard_rows=shard_rows, q_total=q_total,
        axes=axes,
    )


def batch_search(
    index: DistributedIndex,
    tree: VocabTree,
    queries: jax.Array,
    k: int,
    mesh: Mesh,
    *,
    layout: str = "point_major",
    probes: int = 1,
    block_rows: int | None = None,
    q_cap: int | None = None,
    impl: str = "xla",
    p_cap: int | None = None,
    q_tile: int | None = None,
    cost_model="auto",
    calibration=None,
) -> SearchResult:
    """Eager convenience wrapper: plan, build lookup, pad, jit, run, trim.

    ``layout`` is one of ``point_major`` (paper-faithful wave scan),
    ``query_routed`` (beyond-paper shuffle), or ``auto`` (the ``plan()``
    cost model picks — ``cost_model``/``calibration`` select which model
    and which calibration store, see
    :mod:`repro.core.engine.costmodel`). ``impl`` selects the executor
    implementation (``"fused"`` = the fast path, ``"auto"`` = the cost
    model prices it; docs/kernels.md). ``probes=T`` visits each query's
    T nearest leaves — the multi-probe recall lever (docs/engine.md).
    """
    n_shards = data_axis_size(mesh)
    q = queries.shape[0]
    p = make_plan(
        rows=index.rows,
        n_leaves=index.n_leaves,
        n_queries=q,
        n_shards=n_shards,
        k=k,
        probes=probes,
        layout=layout,
        impl=impl,
        block_rows=block_rows,
        q_cap=q_cap,
        q_tile=q_tile,
        p_cap=p_cap,
        model=cost_model,
        calibration=calibration,
    )
    lookup = jit_build_lookup(tree, queries, probes=probes)
    return search_with_lookup(index, lookup, p, mesh, n_queries=q)
