"""Padding/sentinel constants shared across the core, kernels, and engine.

Historically each layer hand-rolled its own magic values (``-2`` for padded
lookup rows, ``-9``/``-8`` inside the l2topk tile padding, ``2**31 - 1`` for
routed rows). They are hoisted here so the invariants are visible in one
place:

  * all real leaf ids are ``>= 0``;
  * every sentinel below is distinct and negative **or** larger than any
    real leaf, so no sentinel ever equals a real leaf and no two different
    kinds of padding ever match each other inside the leaf-equality mask of
    the distance kernels.

Plain Python ints on purpose: module-level jax arrays would initialise the
backend at import time and break the dry-run's forced device count.
"""

from __future__ import annotations

# Invalid/padded rows in the routed exchange. Sorts *after* every real leaf
# so cluster_sort pushes padding to the tail of each shard.
LEAF_SENTINEL = 2**31 - 1

# Padded lookup-table rows (pad_lookup). Negative: never matches a real
# leaf, and distinct from the tile padding below.
PAD_QUERY_LEAF = -2

# Tile padding inside the l2topk kernel wrapper: point-side and query-side
# padding use *different* values so padded points never match padded
# queries.
PAD_TILE_POINT_LEAF = -9
PAD_TILE_QUERY_LEAF = -8

# Invalid descriptor/query ids (dropped or padding rows).
INVALID_ID = -1
