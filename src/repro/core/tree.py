"""Hierarchical vocabulary tree (Nistér–Stewénius-style unstructured
quantization, paper §2.3), TPU-adapted.

The paper organises C random representatives in a hierarchy of L levels with
modest fanout. On TPU we make the fanout *wide and MXU-aligned* (e.g.
256 x 256 = 65k leaves in two levels): every level's assignment is then a
dense ``(n, d) @ (d, fanout)`` GEMM + argmin, the exact shape the MXU and the
``l2nn`` Pallas kernel want. Levels are kept (the paper's hierarchy matters:
it is what keeps assignment cost at ``O(sum(fanouts))`` instead of
``O(prod(fanouts))``), but L stays small (2-3) — DESIGN.md §2.

Tree layout (L levels, fanouts ``(f0, f1, ..)``):
  level 0: ``(f0, d)``  roots
  level i: ``(n_nodes_{i-1}, f_i, d)`` children per parent node
Leaf id of a descriptor = mixed-radix path ``((b0*f1)+b1)*f2+...``.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.distance import nearest, sq_norms


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class VocabTree:
    """Index tree: the paper's broadcast auxiliary data (§2.5)."""

    levels: tuple  # level 0: (f0, d); level i: (nodes_{i-1}, f_i, d)

    def tree_flatten(self):
        return (self.levels,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(levels=children[0])

    @property
    def fanouts(self) -> tuple[int, ...]:
        f = [self.levels[0].shape[0]]
        f.extend(lvl.shape[1] for lvl in self.levels[1:])
        return tuple(f)

    @property
    def n_leaves(self) -> int:
        return math.prod(self.fanouts)

    @property
    def dim(self) -> int:
        return self.levels[0].shape[-1]

    @property
    def nbytes(self) -> int:
        return sum(lvl.size * lvl.dtype.itemsize for lvl in self.levels)


def _segmented_pick(order, starts, counts, fanout, fallback, key):
    """For each of ``n_nodes`` segments pick ``fanout`` member indices.

    Strided picks inside each segment; empty segments fall back to random
    global indices (the paper picks representatives at random, so a sparse
    branch simply re-samples).
    """
    n_nodes = starts.shape[0]
    j = jnp.arange(fanout)
    # (n_nodes, fanout) positions inside each segment (strided, wrap-safe)
    pos = starts[:, None] + (j[None, :] * jnp.maximum(counts, 1)[:, None]) // fanout
    pos = jnp.clip(pos, 0, order.shape[0] - 1)
    picked = order[pos]
    rnd = jax.random.randint(key, (n_nodes, fanout), 0, fallback)
    return jnp.where(counts[:, None] > 0, picked, rnd)


@partial(jax.jit, static_argnames=("fanouts", "refine_iters"))
def build_tree(
    vecs: jax.Array,
    fanouts: Sequence[int] = (64, 64),
    *,
    key: jax.Array,
    refine_iters: int = 0,
) -> VocabTree:
    """Create the index tree from a (sample of a) descriptor collection.

    Paper-faithful mode (``refine_iters=0``): representatives are random
    picks, hierarchically organised. ``refine_iters>0`` adds Lloyd (k-means)
    sweeps per level — a beyond-paper quality knob (the paper cites
    hierarchical k-means lineage but uses random picks for scale).
    """
    fanouts = tuple(int(f) for f in fanouts)
    n, d = vecs.shape
    keys = jax.random.split(key, 2 * len(fanouts))
    vf = vecs.astype(jnp.float32)

    # ---- level 0: random roots ------------------------------------------
    idx0 = jax.random.choice(keys[0], n, (fanouts[0],), replace=n < fanouts[0])
    roots = vf[idx0]
    levels = [roots]
    node_of = jnp.zeros((n,), jnp.int32)  # current node path per sample row
    n_nodes = 1

    for li, f in enumerate(fanouts):
        centroids = levels[li]
        if li == 0:
            branch, _ = nearest(vf, centroids)
        else:
            gathered = centroids[node_of]  # (n, f, d)
            d2 = (
                sq_norms(gathered)
                - 2.0
                * jnp.einsum("nd,nfd->nf", vf, gathered,
                             preferred_element_type=jnp.float32)
            )
            branch = jnp.argmin(d2, axis=1).astype(jnp.int32)
        node_of = node_of * f + branch
        n_nodes *= f

        # Lloyd refinement of this level's centroids (optional)
        for r in range(refine_iters):
            sums = jax.ops.segment_sum(vf, node_of, num_segments=n_nodes)
            cnts = jax.ops.segment_sum(
                jnp.ones((n,), jnp.float32), node_of, num_segments=n_nodes
            )
            means = sums / jnp.maximum(cnts, 1.0)[:, None]
            flat_old = levels[li].reshape(n_nodes, d)
            flat_new = jnp.where(cnts[:, None] > 0, means, flat_old)
            levels[li] = flat_new.reshape(levels[li].shape)
            # re-assign branch within the (unchanged) parent partition
            if li == 0:
                branch, _ = nearest(vf, levels[0])
                node_of = branch
            else:
                parent = node_of // f
                gathered = levels[li][parent]
                d2 = (
                    sq_norms(gathered)
                    - 2.0
                    * jnp.einsum("nd,nfd->nf", vf, gathered,
                                 preferred_element_type=jnp.float32)
                )
                node_of = parent * f + jnp.argmin(d2, axis=1).astype(jnp.int32)

        # ---- pick children of every node for the next level --------------
        if li + 1 < len(fanouts):
            fnext = fanouts[li + 1]
            order = jnp.argsort(node_of)
            sorted_nodes = node_of[order]
            cnts = jax.ops.segment_sum(
                jnp.ones((n,), jnp.int32), node_of, num_segments=n_nodes
            )
            starts = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32), jnp.cumsum(cnts)[:-1]]
            )
            del sorted_nodes
            pick = _segmented_pick(
                order, starts, cnts, fnext, n, keys[2 * li + 1]
            )  # (n_nodes, fnext) sample-row indices
            levels.append(vf[pick])  # (n_nodes, fnext, d)

    return VocabTree(levels=tuple(levels))


def tree_assign(tree: VocabTree, x: jax.Array) -> jax.Array:
    """Leaf id per row of x — the paper's map-side descriptor assignment.

    Level 0 is a dense GEMM+argmin (`l2nn` kernel shape); deeper levels
    gather each row's branch children and reduce. Bulk callers should chunk
    rows (the index pipeline does this per wave).
    """
    xf = x.astype(jnp.float32)
    node, _ = nearest(xf, tree.levels[0])
    for lvl in tree.levels[1:]:
        f = lvl.shape[1]
        # child norms from the (nodes, f, d) table — loop-invariant, so XLA
        # hoists it out of wave loops (vs norms of the per-row gathered
        # tensor, which cost O(rows * f * d) HBM traffic per wave)
        cn = jnp.sum(
            lvl.astype(jnp.float32) ** 2, axis=-1
        )  # (nodes, f)
        gathered = lvl[node]  # (n, f, d)
        d2 = cn[node] - 2.0 * jnp.einsum(
            "nd,nfd->nf", xf, gathered, preferred_element_type=jnp.float32
        )
        node = node * f + jnp.argmin(d2, axis=1).astype(jnp.int32)
    return node


def leaf_centroids(tree: VocabTree) -> jax.Array:
    """(n_leaves, d) flattened deepest-level centroids (for diagnostics)."""
    last = tree.levels[-1]
    return last.reshape(-1, last.shape[-1]) if last.ndim == 3 else last
