# Data substrate: synthetic generators standing in for the paper's 30B-SIFT
# collection (synth.py), Copydays-style distorted-query evaluation sets
# (copydays.py), the sharded descriptor store / sequence-file analog
# (store.py), graph generators + neighbor sampler (graph.py), and LM/recsys
# batch synthesis (batches.py).
