"""Synthetic LM / recsys batch generators (numpy, seeded)."""

from __future__ import annotations

import numpy as np


def lm_batch(batch: int, seq: int, vocab: int, *, seed: int = 0):
    """Zipf-distributed token stream with next-token labels."""
    rng = np.random.default_rng(seed)
    toks = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
    toks = np.minimum(toks, vocab - 1)
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }


def dlrm_batch(batch: int, n_dense: int, n_sparse: int, vocab: int, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    dense = rng.standard_normal((batch, n_dense)).astype(np.float32)
    sparse = np.minimum(rng.zipf(1.2, (batch, n_sparse)), vocab - 1).astype(np.int32)
    # planted signal: label correlates with a dense feature + sparse parity
    logit = dense[:, 0] + 0.5 * ((sparse[:, 0] % 2) * 2 - 1)
    label = (logit + rng.standard_normal(batch) > 0).astype(np.float32)
    return {"dense": dense, "sparse": sparse, "label": label}


def din_batch(batch: int, seq_len: int, vocab: int, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    hist = np.minimum(rng.zipf(1.3, (batch, seq_len)), vocab - 1).astype(np.int32)
    # half positives: target drawn from the user's history
    pos_target = hist[np.arange(batch), rng.integers(0, seq_len, batch)]
    neg_target = np.minimum(rng.zipf(1.3, batch), vocab - 1).astype(np.int32)
    label = (rng.random(batch) < 0.5).astype(np.float32)
    target = np.where(label > 0, pos_target, neg_target).astype(np.int32)
    target = np.maximum(target, 1)
    return {"hist": hist, "target": target, "label": label}


def twotower_batch(batch: int, n_user_fields: int, n_item_fields: int, vocab: int,
                   *, seed: int = 0):
    rng = np.random.default_rng(seed)
    user = rng.integers(0, vocab, (batch, n_user_fields)).astype(np.int32)
    # positive item correlated with the user's first field
    item = rng.integers(0, vocab, (batch, n_item_fields)).astype(np.int32)
    item[:, 0] = (user[:, 0] * 7919 + 13) % vocab
    return {"user_ids": user, "item_ids": item}
