"""Copydays-style distorted-query evaluation (paper §4.2, Fig 4).

The paper drowns 127 originals + 3055 generated variants (crop+scale,
jpeg, strong manual distortions) in 20M/100M distractors and counts
originals returned at rank 1. We synthesise the same protocol: 'images' are
descriptor sets; variants perturb a fraction of descriptors with increasing
severity; strong variants keep only a few descriptors — the paper notes
some attacked queries retain only a handful (or zero) descriptors.
"""

from __future__ import annotations

import dataclasses

import numpy as np

#: (name, kept descriptor fraction, additive noise scale) — severity ladder
VARIANTS = (
    ("crop10", 0.90, 4.0),
    ("crop30", 0.70, 6.0),
    ("crop50", 0.50, 8.0),
    ("crop80", 0.20, 12.0),
    ("jpeg75", 1.00, 10.0),
    ("jpeg30", 1.00, 20.0),
    ("strong", 0.10, 40.0),
)


@dataclasses.dataclass
class CopydaysSet:
    query_vecs: np.ndarray  # (Q, d)
    query_img: np.ndarray  # (Q,) original image id each query row comes from
    query_variant: np.ndarray  # (Q,) index into VARIANTS
    n_originals: int


def make_copydays(
    orig_vecs: np.ndarray,
    orig_img_ids: np.ndarray,
    *,
    seed: int = 0,
    variants=VARIANTS,
) -> CopydaysSet:
    """Build the distorted-query set from original images' descriptors."""
    rng = np.random.default_rng(seed)
    originals = np.unique(orig_img_ids)
    q_vecs, q_img, q_var = [], [], []
    for img in originals:
        rows = np.flatnonzero(orig_img_ids == img)
        for vi, (_, keep, noise) in enumerate(variants):
            m = max(1, int(len(rows) * keep))
            pick = rng.choice(rows, size=m, replace=False)
            v = orig_vecs[pick].astype(np.float32)
            v = v + rng.standard_normal(v.shape).astype(np.float32) * noise
            np.clip(v, 0.0, 255.0, out=v)
            q_vecs.append(v)
            q_img.append(np.full(m, img, np.int32))
            q_var.append(np.full(m, vi, np.int32))
    return CopydaysSet(
        query_vecs=np.concatenate(q_vecs),
        query_img=np.concatenate(q_img),
        query_variant=np.concatenate(q_var),
        n_originals=len(originals),
    )


def vote_images(result_ids: np.ndarray, db_img_ids: np.ndarray,
                query_img: np.ndarray, query_variant: np.ndarray,
                n_variants: int):
    """Paper's scoring: per (original, variant), vote k-NN hits by image and
    check the original wins rank 1. Returns per-variant recall@1 + average.

    result_ids: (Q, k) descriptor ids (-1 = none); db_img_ids maps
    descriptor id -> image id.
    """
    recalls = np.zeros(n_variants)
    counts = np.zeros(n_variants)
    keys = np.stack([query_img, query_variant], axis=1)
    uniq = np.unique(keys, axis=0)
    for img, var in uniq:
        rows = np.flatnonzero((query_img == img) & (query_variant == var))
        ids = result_ids[rows].reshape(-1)
        ids = ids[ids >= 0]
        counts[var] += 1
        if len(ids) == 0:
            continue
        imgs = db_img_ids[ids]
        vals, cnt = np.unique(imgs, return_counts=True)
        if vals[np.argmax(cnt)] == img:
            recalls[var] += 1
    per_variant = recalls / np.maximum(counts, 1)
    return per_variant, float(recalls.sum() / max(1, counts.sum()))
