"""Graph data substrate: generators, CSR, and a real neighbor sampler.

``minibatch_lg`` (GraphSAGE-style sampled training) needs an actual
neighbor sampler, not a stub: ``neighbor_sample`` draws a fanout-bounded
k-hop subgraph from a CSR adjacency, relabels nodes compactly (seeds
first), and pads to static shapes so one jitted train step serves every
batch.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # (N+1,)
    indices: np.ndarray  # (E,) neighbor ids (out-edges)
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])


def random_graph(n_nodes: int, avg_degree: float, *, seed: int = 0,
                 power_law: bool = True) -> CSRGraph:
    """Random directed graph with (optionally) power-law out-degrees."""
    rng = np.random.default_rng(seed)
    if power_law:
        raw = rng.pareto(1.5, n_nodes) + 1.0
        deg = np.minimum(
            (raw / raw.mean() * avg_degree).astype(np.int64), n_nodes - 1
        )
    else:
        deg = np.full(n_nodes, int(avg_degree), np.int64)
    deg = np.maximum(deg, 1)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, n_nodes, size=int(indptr[-1]), dtype=np.int64)
    return CSRGraph(indptr=indptr, indices=indices, n_nodes=n_nodes)


def to_edge_list(g: CSRGraph):
    """(2, E) [src, dst] int32 edge list from CSR (src = row owner)."""
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int64), np.diff(g.indptr))
    return np.stack([src, g.indices]).astype(np.int64)


def neighbor_sample(g: CSRGraph, seeds: np.ndarray, fanouts, *, seed: int = 0):
    """GraphSAGE sampling: per hop, draw <= fanout neighbors of the frontier.

    Returns (sub_nodes, edges (2, E_sub) *relabelled*, n_seeds) with seeds
    occupying rows [0, n_seeds). Edges point child -> parent (message flows
    sampled-neighbor -> frontier node), matching GIN aggregation.
    """
    rng = np.random.default_rng(seed)
    id_of = {int(s): i for i, s in enumerate(seeds)}
    sub_nodes = list(int(s) for s in seeds)
    edges_src, edges_dst = [], []
    frontier = list(int(s) for s in seeds)
    for fanout in fanouts:
        nxt = []
        for u in frontier:
            lo, hi = g.indptr[u], g.indptr[u + 1]
            nbrs = g.indices[lo:hi]
            if len(nbrs) == 0:
                continue
            take = min(fanout, len(nbrs))
            picks = rng.choice(nbrs, size=take, replace=False)
            for v in picks:
                v = int(v)
                if v not in id_of:
                    id_of[v] = len(sub_nodes)
                    sub_nodes.append(v)
                    nxt.append(v)
                edges_src.append(id_of[v])
                edges_dst.append(id_of[u])
        frontier = nxt
    edges = np.stack(
        [np.asarray(edges_src, np.int64), np.asarray(edges_dst, np.int64)]
    ) if edges_src else np.zeros((2, 0), np.int64)
    return np.asarray(sub_nodes, np.int64), edges, len(seeds)


def pad_graph_batch(feats, edges, labels, *, n_nodes_pad: int, n_edges_pad: int):
    """Pad to static shapes: padded edges get weight 0, padded labels -1."""
    n, e = feats.shape[0], edges.shape[1]
    if n > n_nodes_pad or e > n_edges_pad:
        raise ValueError(f"batch ({n},{e}) exceeds pad ({n_nodes_pad},{n_edges_pad})")
    f = np.zeros((n_nodes_pad, feats.shape[1]), feats.dtype)
    f[:n] = feats
    ee = np.zeros((2, n_edges_pad), np.int32)
    ee[:, :e] = edges
    w = np.zeros(n_edges_pad, np.float32)
    w[:e] = 1.0
    ll = np.full(n_nodes_pad, -1, np.int32)
    ll[:n] = labels
    return {"feats": f, "edges": ee, "edge_w": w, "labels": ll}


def molecule_batch(n_graphs: int, nodes_per_graph: int, edges_per_graph: int,
                   d_feat: int, n_classes: int, *, seed: int = 0):
    """Disjoint union of small graphs (graph classification -> node-level
    labels on a virtual readout node kept simple: label every node with the
    graph label; loss masking handles the rest)."""
    rng = np.random.default_rng(seed)
    N = n_graphs * nodes_per_graph
    feats = rng.standard_normal((N, d_feat)).astype(np.float32)
    src = rng.integers(0, nodes_per_graph, (n_graphs, edges_per_graph))
    dst = rng.integers(0, nodes_per_graph, (n_graphs, edges_per_graph))
    offs = (np.arange(n_graphs) * nodes_per_graph)[:, None]
    edges = np.stack([(src + offs).reshape(-1), (dst + offs).reshape(-1)])
    labels = np.repeat(rng.integers(0, n_classes, n_graphs), nodes_per_graph)
    return {
        "feats": feats,
        "edges": edges.astype(np.int32),
        "edge_w": np.ones(edges.shape[1], np.float32),
        "labels": labels.astype(np.int32),
    }
