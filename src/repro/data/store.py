"""DescriptorStore: the Hadoop sequence-file analog (paper §2.3 step 1).

A store is a directory of fixed-size *blocks* (``block_*.npy`` pairs of
vectors + ids) plus a JSON manifest — the same role HDFS chunks play for the
paper: the unit of map-task input, of streaming, and of re-execution. Blocks
are read lazily, so terabyte-scale collections stream through the index
pipeline wave-by-wave (launch/index.py) without ever being resident.

For synthetic corpora a *virtual* store generates blocks on the fly from a
seed — same interface, zero disk.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterator, Optional

import numpy as np

from repro.data import synth


@dataclasses.dataclass
class Block:
    index: int
    vecs: np.ndarray  # (rows, dim)
    ids: np.ndarray  # (rows,) global descriptor ids


class DescriptorStore:
    """On-disk block store."""

    def __init__(self, directory: str):
        self.directory = directory
        with open(os.path.join(directory, "manifest.json")) as f:
            m = json.load(f)
        self.n_rows = m["n_rows"]
        self.dim = m["dim"]
        self.block_rows = m["block_rows"]
        self.n_blocks = m["n_blocks"]

    @staticmethod
    def create(
        directory: str,
        vecs: np.ndarray,
        *,
        block_rows: int = 65536,
        ids: Optional[np.ndarray] = None,
    ) -> "DescriptorStore":
        os.makedirs(directory, exist_ok=True)
        n, dim = vecs.shape
        if ids is None:
            ids = np.arange(n, dtype=np.int64)
        n_blocks = (n + block_rows - 1) // block_rows
        for b in range(n_blocks):
            sl = slice(b * block_rows, min(n, (b + 1) * block_rows))
            np.save(os.path.join(directory, f"block_{b:06d}_vecs.npy"), vecs[sl])
            np.save(os.path.join(directory, f"block_{b:06d}_ids.npy"), ids[sl])
        with open(os.path.join(directory, "manifest.json"), "w") as f:
            json.dump(
                {
                    "n_rows": int(n),
                    "dim": int(dim),
                    "block_rows": int(block_rows),
                    "n_blocks": int(n_blocks),
                },
                f,
            )
        return DescriptorStore(directory)

    def read_block(self, b: int) -> Block:
        vecs = np.load(os.path.join(self.directory, f"block_{b:06d}_vecs.npy"))
        ids = np.load(os.path.join(self.directory, f"block_{b:06d}_ids.npy"))
        return Block(index=b, vecs=vecs, ids=ids)

    def blocks(self) -> Iterator[Block]:
        for b in range(self.n_blocks):
            yield self.read_block(b)

    def read_rows(self, rows: np.ndarray) -> np.ndarray:
        return _read_rows(self, rows)


class VirtualStore:
    """Seeded on-the-fly store: block b is a pure function of (seed, b)."""

    def __init__(
        self,
        n_rows: int,
        dim: int = 128,
        *,
        block_rows: int = 65536,
        seed: int = 0,
        n_centers: int = 1024,
    ):
        self.n_rows = n_rows
        self.dim = dim
        self.block_rows = block_rows
        self.n_blocks = (n_rows + block_rows - 1) // block_rows
        self.seed = seed
        self.mixture = synth.make_mixture(n_centers, dim, seed=seed ^ 0x5EED)

    def read_block(self, b: int) -> Block:
        start = b * self.block_rows
        rows = min(self.block_rows, self.n_rows - start)
        vecs, _ = synth.sample_descriptors(
            rows, self.dim, mixture=self.mixture, seed=self.seed + 7919 * b
        )
        ids = np.arange(start, start + rows, dtype=np.int64)
        return Block(index=b, vecs=vecs, ids=ids)

    def blocks(self) -> Iterator[Block]:
        for b in range(self.n_blocks):
            yield self.read_block(b)

    def read_rows(self, rows: np.ndarray) -> np.ndarray:
        return _read_rows(self, rows)

    def sample_for_tree(self, n: int) -> np.ndarray:
        vecs, _ = synth.sample_descriptors(
            n, self.dim, mixture=self.mixture, seed=self.seed ^ 0x7EEE
        )
        return vecs


def _read_rows(store, rows: np.ndarray) -> np.ndarray:
    """Gather arbitrary global rows, touching each containing block once.

    The serving trace replay and the index lifecycle rely on its edge-case
    contract: rows may arrive in any order (with duplicates), may span the
    final partial block, and an empty selection returns an empty ``(0,
    dim)`` gather; the output row ``i`` is always ``store`` row
    ``rows[i]``, regardless of gather order.
    """
    rows = np.atleast_1d(np.asarray(rows, np.int64))
    if rows.ndim != 1:
        raise ValueError(f"rows must be 1-D; got shape {rows.shape}")
    if rows.size == 0:
        return np.empty((0, store.dim), np.float32)
    if rows.min() < 0 or rows.max() >= store.n_rows:
        raise IndexError(
            f"row ids must be in [0, {store.n_rows}); got "
            f"[{rows.min()}, {rows.max()}]"
        )
    out = np.empty((rows.size, store.dim), np.float32)
    blocks = rows // store.block_rows
    for b in np.unique(blocks):
        sel = blocks == b
        blk = store.read_block(int(b))
        out[sel] = blk.vecs[rows[sel] - int(b) * store.block_rows]
    return out
