"""Synthetic SIFT-like descriptor collections.

Real SIFT: 128-d, non-negative, heavy-tailed, strongly clustered (gradients
of natural image patches). The generator draws a Gaussian-mixture with
power-law cluster masses and per-cluster anisotropic scales, then clips to
[0, 255] and quantises like SIFT byte descriptors — clustered enough that a
vocabulary tree behaves like it does on real data (unbalanced leaves,
Table 7's variance in per-block work), cheap enough to synthesise billions
of rows wave-by-wave from a seed (the store never materialises the corpus).
"""

from __future__ import annotations

import numpy as np


def make_mixture(n_centers: int, dim: int = 128, seed: int = 0):
    rng = np.random.default_rng(seed)
    centers = rng.gamma(2.0, 24.0, size=(n_centers, dim)).astype(np.float32)
    scales = rng.uniform(4.0, 18.0, size=(n_centers, 1)).astype(np.float32)
    # power-law cluster masses (zipf-ish) -> unbalanced tree leaves
    w = 1.0 / np.arange(1, n_centers + 1) ** 1.1
    weights = (w / w.sum()).astype(np.float64)
    return centers, scales, weights


def sample_descriptors(
    n: int,
    dim: int = 128,
    *,
    mixture=None,
    n_centers: int = 256,
    seed: int = 0,
    quantize: bool = True,
):
    """(n, dim) float32 SIFT-like rows + (n,) their mixture component."""
    rng = np.random.default_rng(seed)
    centers, scales, weights = mixture or make_mixture(n_centers, dim, seed=seed ^ 0x5EED)
    comp = rng.choice(len(weights), size=n, p=weights)
    x = centers[comp] + rng.standard_normal((n, dim)).astype(np.float32) * scales[comp]
    np.clip(x, 0.0, 255.0, out=x)
    if quantize:
        x = np.rint(x).astype(np.float32)
    return x, comp.astype(np.int32)


def sample_images(
    n_images: int,
    desc_per_image: int,
    dim: int = 128,
    *,
    seed: int = 0,
    n_centers: int = 256,
):
    """A collection of 'images': (vecs (n_images*dpi, dim), img_ids)."""
    mix = make_mixture(n_centers, dim, seed=seed ^ 0xA11CE)
    vecs, _ = sample_descriptors(
        n_images * desc_per_image, dim, mixture=mix, seed=seed
    )
    img_ids = np.repeat(np.arange(n_images, dtype=np.int32), desc_per_image)
    return vecs, img_ids


def zipf_weights(n: int, s: float = 1.1) -> np.ndarray:
    """Zipf popularity over ``n`` items: weight of rank r is ``1/r^s``."""
    if n < 1:
        raise ValueError(f"{n=} must be positive")
    w = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** s
    return w / w.sum()


def sample_trace(
    n_requests: int,
    n_images: int,
    *,
    skew: str = "uniform",
    zipf_s: float = 1.1,
    rate: float | None = None,
    seed: int = 0,
):
    """A replayable request trace: ``(image_ids, arrivals)``.

    ``image_ids`` — which image each request queries, drawn uniformly or
    Zipf-skewed (popular images repeat: the hot-leaf-cache workload).
    Popularity ranks are themselves shuffled so "hot" images are spread
    over the id space rather than clustered at low ids.
    ``arrivals`` — seconds, Poisson arrivals at ``rate`` req/s (``None`` =
    everything arrives at t=0: the paper's offline batch as a degenerate
    trace). Deterministic under ``seed``; tests assert bit-equality.
    """
    if skew not in ("uniform", "zipf"):
        raise ValueError(f"unknown {skew=}; want uniform|zipf")
    rng = np.random.default_rng(seed)
    if skew == "zipf":
        ranks = rng.permutation(n_images)
        p = zipf_weights(n_images, zipf_s)[ranks]
        image_ids = rng.choice(n_images, size=n_requests, p=p)
    else:
        image_ids = rng.integers(0, n_images, size=n_requests)
    if rate is None:
        arrivals = np.zeros(n_requests, np.float64)
    else:
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    return image_ids.astype(np.int64), arrivals
