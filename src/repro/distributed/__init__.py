from repro.distributed.partitioning import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    logical_sharding,
    shard_specs,
)
from repro.distributed.meshutil import (  # noqa: F401
    batch_axes,
    batch_spec,
    data_axis_size,
    local_mesh,
    mesh_axis_size,
)
