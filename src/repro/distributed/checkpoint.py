"""Mesh-agnostic checkpointing with integrity manifests.

Checkpoints store *global* arrays (one ``.npy`` per pytree leaf, keyed by
its tree path) plus a JSON manifest carrying step, shapes, dtypes and
crc32s. Because the on-disk format is mesh-free, a run can restart on a
different device count — ``restore(..., shardings=...)`` re-lays every leaf
out for the new mesh (elastic restart). Writes are atomic
(``<step>.tmp`` -> rename) so a failure mid-save never corrupts the latest
checkpoint; this is the durability analog of the paper's HDFS replication
(§3: "the checkpoint is the replica").
"""

from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Optional

import jax
import numpy as np


def _leaf_key(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- paths ------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_manifest(self, step: Optional[int] = None) -> dict:
        """The manifest of ``step`` (default: latest) without loading any
        array — callers peek at ``extra``/shapes to rebuild pytree
        skeletons before a restore (serving.persist does)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f)

    # -- save / restore ----------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None) -> str:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for path, leaf in leaves:
            key = _leaf_key(path)
            arr = np.asarray(leaf)  # gathers the global array
            fname = key.replace("/", "__") + ".npy"
            dtype_str = str(jax.numpy.asarray(leaf).dtype) if hasattr(
                leaf, "dtype"
            ) else str(arr.dtype)
            if arr.dtype.kind not in "biufc":
                # custom dtypes (bfloat16, fp8) -> raw bytes on disk
                raw = np.frombuffer(arr.tobytes(), dtype=np.uint8)
                np.save(os.path.join(tmp, fname), raw)
                crc = zlib.crc32(raw.tobytes())
            else:
                np.save(os.path.join(tmp, fname), arr)
                crc = zlib.crc32(arr.tobytes())
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": dtype_str,
                "crc32": crc,
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def restore(self, tree_like, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``tree_like`` (specs or arrays).

        ``shardings``: optional matching pytree of NamedShardings — each
        leaf is device_put with its target layout (elastic restart path).
        Returns (tree, manifest).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        out = []
        for i, (path, _) in enumerate(paths):
            key = _leaf_key(path)
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"leaf {key} missing from checkpoint {d}")
            arr = np.load(os.path.join(d, meta["file"]))
            if zlib.crc32(arr.tobytes()) != meta["crc32"]:
                raise IOError(f"crc mismatch for {key} in {d}")
            want_dtype = jax.numpy.dtype(meta["dtype"])
            if arr.dtype == np.uint8 and want_dtype.kind not in "biufc":
                arr = np.frombuffer(arr.tobytes(), dtype=want_dtype).reshape(
                    meta["shape"]
                )
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest
