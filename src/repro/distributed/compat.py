"""jax version compatibility for the SPMD substrate.

The library targets current jax (``jax.shard_map``, ``jax.lax.pcast``) but
must run on 0.4.x containers where ``shard_map`` still lives in
``jax.experimental`` and varying-type casts don't exist. Everything that
needs either imports it from here.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)

else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        # check_rep=False: the legacy replication checker mis-tracks
        # lax.map/scan carries (jax-ml/jax#...-era bug, fixed by the typed
        # rewrite); correctness is covered by the oracle-equality tests.
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def tpu_compiler_params():
    """``pltpu.CompilerParams`` across jax versions (0.4.x: TPUCompilerParams)."""
    from jax.experimental.pallas import tpu as pltpu

    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def pcast_varying(x, axes):
    """Mark ``x`` as varying over ``axes`` inside shard_map.

    New jax's typed shard_map requires an explicit cast when a replicated
    value becomes per-shard state; classic shard_map has no varying types,
    so the cast degrades to identity.
    """
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, axes, to="varying")
