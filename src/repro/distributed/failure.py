"""Deterministic failure injection for fault-tolerance tests/benchmarks.

Grid'5000 gave the paper 1-5 node failures per 60-hour run (§3); we inject
the analogous events deterministically so tests can assert that retry +
checkpoint/resume reproduce the no-failure results bit-for-bit.
"""

from __future__ import annotations

from typing import Iterable


class InjectedFailure(RuntimeError):
    pass


class FailureInjector:
    """Raises on configured (wave, attempt) pairs; callable for WaveScheduler."""

    def __init__(self, fail_at: Iterable[tuple] = ()):
        self.fail_at = set(fail_at)
        self.fired = []

    def __call__(self, wave: int, attempt: int):
        if (wave, attempt) in self.fail_at:
            self.fired.append((wave, attempt))
            raise InjectedFailure(f"injected failure at wave={wave} attempt={attempt}")


class CrashAfter:
    """Simulates a whole-job crash (process death) after N successful waves —
    used to exercise checkpoint/restart."""

    def __init__(self, n_waves: int):
        self.n_waves = n_waves
        self.count = 0

    def __call__(self, wave: int, attempt: int):
        if wave >= self.n_waves:
            raise KeyboardInterrupt(f"simulated crash before wave {wave}")
