"""Mesh helpers shared by the library and the launchers.

The production meshes (see ``repro.launch.mesh``) use axis names:

  * ``pod``   -- pod axis (multi-pod only); batch/data parallel across pods
  * ``data``  -- intra-pod data axis; descriptor rows / batch shards
  * ``model`` -- model axis; weights / embedding tables / experts / vocab

Library code never hardcodes sizes: everything is derived from the mesh that
is current (or passed explicitly), so the same program runs on the 1-device
CPU mesh used in tests and the 512-chip multi-pod mesh used in the dry-run.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
from jax.sharding import Mesh, PartitionSpec as P


def local_mesh(axes: Sequence[str] = ("data", "model")) -> Mesh:
    """A degenerate mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    shape = [1] * len(axes)
    shape[0] = n
    return jax.make_mesh(tuple(shape), tuple(axes))


def abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``AbstractMesh`` across jax versions.

    Newer jax takes ``(sizes, names)``; 0.4.x takes a tuple of
    ``(name, size)`` pairs. Tests and dry-runs use this so they never need
    real devices.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(tuple(axes), tuple(shape))))


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes over which batch-like (row) dimensions shard."""
    if "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def batch_spec(mesh: Mesh, *trailing) -> P:
    """PartitionSpec sharding dim 0 over the batch axes."""
    return P(batch_axes(mesh), *trailing)


def data_axis_size(mesh: Mesh) -> int:
    """Total number of row shards (pod*data)."""
    return math.prod(mesh_axis_size(mesh, a) for a in batch_axes(mesh))


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def shard_submeshes(mesh: Mesh, n_shards: int) -> tuple[Mesh, ...]:
    """Per-shard meshes for scatter-gather serving (one entry per shard).

    When the mesh's devices split evenly over ``n_shards`` (and there is
    more than one device), each shard gets its own disjoint device group —
    shard scans then run on separate hardware. Otherwise every shard
    shares ``mesh`` unchanged: the sequential-but-isolated fallback, where
    shard scans run one after another on the same devices with identical
    numerics (the bit-identity tests run in this regime).
    """
    if n_shards < 1:
        raise ValueError(f"{n_shards=} must be >= 1")
    if n_shards == 1:
        return (mesh,)
    devs = mesh.devices  # shaped (axis0, axis1, ...) in axis_names order
    rows = devs.shape[0]
    per = rows // n_shards
    if per < 1 or rows % n_shards or devs.size == 1:
        return (mesh,) * n_shards
    # slice along the leading (batch) axis only: every other axis — e.g.
    # a model axis — keeps its devices and its meaning inside each shard
    return tuple(
        Mesh(devs[s * per:(s + 1) * per], mesh.axis_names)
        for s in range(n_shards)
    )
