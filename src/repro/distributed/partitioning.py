"""Logical-axis partitioning with divisibility fallback.

Every parameter / activation names its dims with *logical* axes
(``("layers", "embed", "ffn")``); a rule table maps logical axes to mesh
axes. A mesh axis is applied only if the dim is divisible by the product of
the mapped mesh-axis sizes — otherwise that dim silently falls back to
replicated. This is what lets e.g. llama3.2's 24 query heads (not divisible
by model=16) keep the rest of the layer sharded: the head axis replicates,
the fused head*dim projection axis shards.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name -> mesh axis (or tuple of mesh axes)."""

    rules: Mapping[str, MeshAxes]

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.rules.get(logical, None)

    def extend(self, **updates: MeshAxes) -> "AxisRules":
        merged = dict(self.rules)
        merged.update(updates)
        return AxisRules(merged)


#: Default rules shared by all architectures. ``rows`` is the HDFS-block /
#: batch analog; ``model_dim``-family axes go to the model axis.
DEFAULT_RULES = AxisRules(
    {
        # batch-like / row-like axes -> data parallel (incl. pod axis)
        "batch": ("pod", "data"),
        "rows": ("pod", "data"),
        "edges": ("pod", "data"),
        # KV-cache sequence: context parallelism over whatever axes the
        # batch dim left free (decode_32k -> model; long_500k -> all three)
        "kv_seq": ("pod", "data", "model"),
        # model-parallel axes
        "vocab": "model",
        "ffn": "model",
        "heads": "model",
        "kv_heads": "model",
        "qkv": "model",
        "experts": "model",
        "table_rows": "model",
        "clusters": "model",
        "candidates": "model",
        "nodes": "model",
        # never sharded
        "layers": None,
        "embed": None,
        "head_dim": None,
        "seq": None,
        "feat": None,
    }
)


def _axis_sizes(mesh) -> Mapping[str, int]:
    # works for both Mesh and AbstractMesh (tests use the latter)
    return dict(mesh.shape)


def partition_spec(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: AxisRules = DEFAULT_RULES,
) -> P:
    """Build a PartitionSpec for ``shape`` with divisibility fallback.

    A mesh axis may be used at most once across dims (first dim wins);
    non-divisible dims replicate.
    """
    if len(shape) != len(logical_axes):
        raise ValueError(
            f"shape {tuple(shape)} and logical axes {tuple(logical_axes)} "
            "must have equal rank"
        )
    sizes = _axis_sizes(mesh)
    used: set[str] = set()
    out: list[MeshAxes] = []
    for dim, logical in zip(shape, logical_axes):
        axes = rules.mesh_axes(logical)
        if axes is None:
            out.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        # only mesh axes that exist on this mesh and are still free
        axes = tuple(a for a in axes if a in sizes and a not in used)
        total = math.prod(sizes[a] for a in axes) if axes else 1
        if axes and dim % total == 0 and total > 1:
            out.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            out.append(None)
    return P(*out)


def logical_sharding(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: AxisRules = DEFAULT_RULES,
) -> NamedSharding:
    return NamedSharding(mesh, partition_spec(shape, logical_axes, mesh, rules))


def shard_specs(tree_of_specs, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    """Map a pytree of ``ParamSpec`` (see repro.models.module) to shardings."""
    from repro.models.module import ParamSpec  # local import, avoid cycle

    def one(spec):
        if isinstance(spec, ParamSpec):
            return logical_sharding(spec.shape, spec.axes, mesh, rules)
        raise TypeError(f"expected ParamSpec, got {type(spec)}")

    return jax.tree.map(one, tree_of_specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def constrain(x: jax.Array, logical_axes, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    """with_sharding_constraint by logical axes (no-op outside jit tracing)."""
    spec = partition_spec(x.shape, logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
