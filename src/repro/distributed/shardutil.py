"""Helpers bridging param spec trees and train-state shardings."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def abstract_opt_state(params_abstract, params_shardings, mesh: Mesh):
    """(abstract, shardings) for the AdamW state matching a params tree.

    Moments inherit the parameter layout (fp32); the step counter is
    replicated. Mirrors repro.train.optimizer.init_opt_state.
    """
    m_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), params_abstract
    )
    abstract = {
        "m": m_abs,
        "v": jax.tree.map(lambda a: a, m_abs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    shardings = {
        "m": params_shardings,
        "v": jax.tree.map(lambda s: s, params_shardings),
        "step": NamedSharding(mesh, P()),
    }
    return abstract, shardings


def tree_shardings(abstract_tree, mesh: Mesh, axes_fn):
    """Shardings for an arbitrary abstract tree via axes_fn(path)->axes."""
    from repro.distributed.partitioning import DEFAULT_RULES, partition_spec

    def one(path, a):
        axes = axes_fn(path)
        return NamedSharding(mesh, partition_spec(a.shape, axes, mesh, DEFAULT_RULES))

    return jax.tree_util.tree_map_with_path(one, abstract_tree)
