"""Host-side wave scheduler: the jobtracker analog (paper §2.2, §5.1.3).

TPU steps are synchronous SPMD, but the *job* level — streaming a
terabyte-scale descriptor collection through the index pipeline, or a large
query log through search — is a sequence of **waves** (one jitted step per
resident window). This scheduler owns that level and provides what Hadoop's
jobtracker provided in the paper:

  * retry of failed waves (re-execution is deterministic: same inputs ->
    same outputs, so a retried wave is bit-identical — unlike Hadoop's
    speculative tasks there is no duplicate-output hazard);
  * wave statistics (durations, attempts, stragglers) — the data behind the
    paper's Figs 2/6/8 map-wave plots, re-exported by benchmarks/map_waves;
  * periodic checkpointing of the wave cursor + reduced state, and resume
    (the 60-hour-run / node-failure story of paper §3);
  * elastic replanning: waves are data-defined, so a restart may regroup
    remaining work for a different device count.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Optional

from repro.distributed.checkpoint import CheckpointManager


@dataclasses.dataclass
class WaveRecord:
    wave: int
    attempt: int
    duration_s: float
    ok: bool
    error: str = ""


@dataclasses.dataclass
class WaveRunResult:
    state: Any
    records: list
    completed: int

    @property
    def stragglers(self):
        """Waves slower than 2x the median successful duration."""
        ok = sorted(r.duration_s for r in self.records if r.ok)
        if not ok:
            return []
        median = ok[len(ok) // 2]
        return [r for r in self.records if r.ok and r.duration_s > 2 * median]


class WaveScheduler:
    """Runs ``state = fold(state, wave_fn(wave_input))`` over wave inputs."""

    def __init__(
        self,
        wave_fn: Callable[[Any], Any],
        fold: Callable[[Any, Any], Any] = lambda s, r: (s or []) + [r],
        *,
        max_retries: int = 2,
        failure_injector: Optional[Callable[[int, int], None]] = None,
        checkpoint: Optional[CheckpointManager] = None,
        checkpoint_every: int = 0,
        state_to_tree: Callable[[Any], Any] = lambda s: s,
        tree_to_state: Callable[[Any], Any] = lambda t: t,
    ):
        self.wave_fn = wave_fn
        self.fold = fold
        self.max_retries = max_retries
        self.failure_injector = failure_injector
        self.checkpoint = checkpoint
        self.checkpoint_every = checkpoint_every
        self.state_to_tree = state_to_tree
        self.tree_to_state = tree_to_state

    def _maybe_checkpoint(self, wave_idx: int, state):
        if (
            self.checkpoint
            and self.checkpoint_every
            and (wave_idx + 1) % self.checkpoint_every == 0
        ):
            self.checkpoint.save(
                wave_idx + 1, self.state_to_tree(state), extra={"cursor": wave_idx + 1}
            )

    def resume_cursor(self) -> int:
        if not self.checkpoint:
            return 0
        step = self.checkpoint.latest_step()
        return step or 0

    def resume_state(self, template):
        if not self.checkpoint or self.checkpoint.latest_step() is None:
            return None
        tree, _ = self.checkpoint.restore(self.state_to_tree(template))
        return self.tree_to_state(tree)

    def run(
        self,
        waves: Iterable[Any],
        *,
        init_state: Any = None,
        start_at: int = 0,
    ) -> WaveRunResult:
        state = init_state
        records = []
        completed = start_at
        for i, wave_input in enumerate(waves):
            if i < start_at:
                continue
            for attempt in range(self.max_retries + 1):
                t0 = time.perf_counter()
                try:
                    if self.failure_injector is not None:
                        self.failure_injector(i, attempt)
                    result = self.wave_fn(wave_input)
                    dt = time.perf_counter() - t0
                    records.append(WaveRecord(i, attempt, dt, True))
                    state = self.fold(state, result)
                    completed = i + 1
                    break
                except Exception as e:  # noqa: BLE001 - retry any wave failure
                    dt = time.perf_counter() - t0
                    records.append(WaveRecord(i, attempt, dt, False, repr(e)))
                    if attempt == self.max_retries:
                        raise
            self._maybe_checkpoint(i, state)
        return WaveRunResult(state=state, records=records, completed=completed)


def plan_waves(n_items: int, items_per_wave: int) -> list:
    """Split [0, n_items) into (start, size) waves — elastic replanning is
    just calling this again with a different ``items_per_wave``."""
    waves = []
    start = 0
    while start < n_items:
        size = min(items_per_wave, n_items - start)
        waves.append((start, size))
        start += size
    return waves
