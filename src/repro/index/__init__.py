"""Segment-based index lifecycle: create / open / append / commit /
delete / compact / search.

The single public facade over index building, persistence and search —
``launch/index.py`` and ``launch/serve.py`` are thin CLIs over it, the
serving :class:`~repro.serving.SearchSession` is constructed from it, and
the historical ``serving.persist.save_index``/``load_index`` pair are
deprecation shims around it. See docs/index_lifecycle.md.
"""

from repro.index.lifecycle import (  # noqa: F401
    CompactionPolicy,
    Index,
    IndexSnapshot,
    has_index,
    has_legacy_index,
)
from repro.index.manifest import Manifest  # noqa: F401
from repro.index.segment import Segment  # noqa: F401
from repro.index.sharding import ShardedIndex, ShardPlan  # noqa: F401
