"""The segment-based index lifecycle facade (paper §2.2–2.3 as an API).

The paper's collection *grows between runs*: 30B descriptors are indexed in
grid-sized batches, and every search job runs against whatever index files
exist so far. :class:`Index` is that workflow as one object:

  ``Index.create(tree, dir)``   new index bound to a vocabulary tree
  ``Index.open(dir)``           restore the last committed state
  ``idx.append(vecs, ids)``     wave-based assignment (``build_index_fn``
                                under the eager wrapper) into a new
                                immutable, durably-written *segment*
  ``idx.commit()``              atomic manifest bump — the only operation
                                that makes appends/deletes visible to a
                                later ``open`` (crash-safe, idempotent)
  ``idx.delete(ids)``           tombstones (masked at search, dropped at
                                compaction)
  ``idx.compact()``             merge all segments into one, dropping
                                tombstoned rows; commits atomically
  ``idx.search(queries, ...)``  engine executors per segment over one
                                shared lookup build, merged across segments

Search over N segments is *bit-identical* to a one-shot ``build_index`` +
``batch_search`` over the concatenated rows (and after ``compact()`` the
index arrays themselves match a from-scratch rebuild): per-pair distances
depend only on the (point, query) vectors, tombstone masking reuses the
pipeline's own padding semantics, and the cross-segment merge applies the
same ascending-distance fold the executors use internally.

A handle sees its own uncommitted writes (staged segments and staged
tombstones); a fresh ``open`` sees only the last committed manifest.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import threading
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.codes import CODES_FORMAT, ProductQuantizer, rerank_exact
from repro.core.engine import (
    CalibrationStore,
    SearchPlan,
    plan as make_plan,
)
from repro.core.engine.executors import SearchResult
from repro.core.index_build import DistributedIndex, build_index
from repro.core.search import jit_build_lookup, search_with_lookup
from repro.core.tree import VocabTree
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.meshutil import data_axis_size, local_mesh
from repro.index import manifest as manifest_lib
from repro.index.manifest import Manifest
from repro.index.segment import (
    Segment,
    dead_counts,
    masked_view,
    next_seq,
    segment_name,
)
from repro.index.sharding import ShardPlan
from repro.obs import get_registry, get_tracer


# the pre-segment serving.persist format (one monolithic checkpoint);
# detected only to fail/warn actionably — there is no in-place migration
LEGACY_CKPT_SUBDIR = "index_ckpt"


def has_legacy_index(directory: str) -> bool:
    return bool(directory) and os.path.isdir(
        os.path.join(directory, LEGACY_CKPT_SUBDIR)
    )


def has_index(directory: str) -> bool:
    """True when ``directory`` holds at least one committed manifest."""
    return bool(directory) and manifest_lib.latest(directory) is not None


def _save_tree(directory: str, tree: VocabTree, meta: dict) -> None:
    mgr = CheckpointManager(
        os.path.join(directory, manifest_lib.TREE_SUBDIR), keep=1
    )
    mgr.save(0, {"tree": tree}, extra=meta)


def _load_tree(directory: str, mesh) -> tuple[VocabTree, dict]:
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(
        os.path.join(directory, manifest_lib.TREE_SUBDIR), keep=1
    )
    step = mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no index tree checkpoint under {directory}")
    meta = mgr.read_manifest(step)["extra"]
    rep = NamedSharding(mesh, P())
    n_levels = int(meta["n_levels"])
    skeleton = {"tree": VocabTree(levels=tuple(0.0 for _ in range(n_levels)))}
    shardings = {
        "tree": VocabTree(levels=tuple(rep for _ in range(n_levels)))
    }
    out, _ = mgr.restore(skeleton, step, shardings=shardings)
    return out["tree"], meta


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """When an *incremental* compaction step merges which segments.

    ``Index.compact(incremental=True)`` asks the policy for one batch of
    victims per call instead of merging everything:

      1. **Tombstone reclamation first** — any segment whose dead/valid
         ratio is at least ``tombstone_ratio`` is rewritten now; a
         delete-heavy segment is reclaimed within one step regardless of
         its size tier.
      2. **Smallest size tier** — otherwise the segments whose live-row
         counts sit within ``size_tier_factor`` of the smallest one are
         merged (classic size-tiered compaction: many small segments fold
         into one medium one, medium ones later fold into a big one, so
         total merge work stays O(n log n) rows instead of O(n^2)).

    A tier smaller than ``min_tier_segments`` is left alone — a fully
    compacted index is a fixed point and the step publishes nothing.
    ``max_segments_per_step`` bounds the rows any single step rewrites,
    which bounds the stall a serving session could observe.
    """

    size_tier_factor: float = 4.0
    min_tier_segments: int = 2
    tombstone_ratio: float = 0.25
    max_segments_per_step: int = 8

    def select(
        self, segments: Sequence[Segment], tombstones: np.ndarray
    ) -> list[Segment]:
        """The victims of one incremental step, in index order (possibly
        empty). Pure function of committed state — callers may dry-run it."""
        segments = list(segments)
        if not segments:
            return []
        dead = dead_counts(segments, tombstones)
        heavy = {
            s.name
            for s, d in zip(segments, dead)
            if s.valid_rows and d / s.valid_rows >= self.tombstone_ratio
        }
        if heavy:
            victims = [s for s in segments if s.name in heavy]
            return victims[: self.max_segments_per_step]
        live = {
            s.name: int(s.valid_rows - d) for s, d in zip(segments, dead)
        }
        order = sorted(segments, key=lambda s: (live[s.name], s.name))
        tier = [order[0]]
        for s in order[1:]:
            if live[s.name] <= self.size_tier_factor * max(
                1, live[tier[0].name]
            ):
                tier.append(s)
            else:
                break
        if len(tier) < self.min_tier_segments:
            return []
        chosen = {s.name for s in tier[: self.max_segments_per_step]}
        return [s for s in segments if s.name in chosen]


@dataclasses.dataclass(frozen=True)
class IndexSnapshot:
    """One consistent, immutable cut of an :class:`Index`'s state.

    Serving sessions pin a snapshot and keep answering from it while the
    writer appends/deletes/compacts underneath — every array here is
    either immutable (segments, views) or a private copy (tombstones),
    so a pinned reader never observes a half-applied mutation. ``stamp``
    is the index's monotone mutation counter: equal stamps mean nothing
    changed, which is how ``maybe_refresh()`` stays O(1) when idle.
    """

    stamp: int
    version: int
    segments: tuple[Segment, ...]
    views: tuple[DistributedIndex, ...]
    tombstones: np.ndarray
    shard_plan: ShardPlan | None
    quantizer: ProductQuantizer | None
    codes: dict


class Index:
    """Segment-based distributed index with a durable lifecycle."""

    def __init__(
        self,
        directory: str | None,
        tree: VocabTree,
        mesh=None,
        *,
        segments: Sequence[Segment] = (),
        tombstones: np.ndarray | None = None,
        version: int = 0,
        next_id: int = 0,
        meta: dict | None = None,
        wire_dtype=jnp.float32,
        shard_plan: ShardPlan | None = None,
        calibration: CalibrationStore | None = None,
        quantizer: ProductQuantizer | None = None,
        codes: dict | None = None,
        codes_paths: dict | None = None,
    ):
        self.directory = directory
        self.tree = tree
        self._mesh = mesh
        self.wire_dtype = wire_dtype
        self._committed: list[Segment] = list(segments)
        self._staged: list[Segment] = []
        self._shard_plan = shard_plan
        self._shard_plan_dirty = False
        # compressed-codes tier: the PQ quantizer (manifest-persisted like
        # shard_plan/calibration), per-segment (rows, m) uint8 code arrays,
        # and the relative paths of already-published code files
        self.quantizer = quantizer
        self._codes: dict[str, np.ndarray] = dict(codes or {})
        self._codes_paths: dict[str, str] = dict(codes_paths or {})
        self._codes_dirty = False
        # index-scoped cost-model calibration: measured ms/image per plan
        # signature, persisted in the manifest (its own dirty flag drives
        # commit), consulted by search()/serving via plan(model="auto")
        self.calibration = (
            calibration if calibration is not None else CalibrationStore()
        )
        self._tombstones = (
            np.sort(np.asarray(tombstones, np.int64))
            if tombstones is not None and len(tombstones)
            else np.empty((0,), np.int64)
        )
        self._tombstones_dirty = False
        self._version = version
        self._next_id = int(next_id)
        self._user_meta = dict(meta or {})
        self._meta_dirty = False
        self._views: tuple[DistributedIndex, ...] | None = None
        self._mem_seq = 0  # segment naming for ephemeral (dir-less) indexes
        # single-writer / many-pinned-reader support: the lock guards the
        # (cheap) memory-state swaps, never the expensive builds; the stamp
        # is bumped by every mutation so snapshot holders can detect
        # staleness in O(1) (see IndexSnapshot / SearchSession.maybe_refresh)
        self._lock = threading.RLock()
        self._stamp = 0

    # -- construction -------------------------------------------------------
    @classmethod
    def create(
        cls,
        tree: VocabTree,
        directory: str | None = None,
        *,
        mesh=None,
        wire_dtype=jnp.float32,
        extra: dict | None = None,
        overwrite: bool = False,
    ) -> "Index":
        """New empty index bound to ``tree``.

        Args:
          tree: the vocabulary :class:`~repro.core.tree.VocabTree` every
            later append/search routes through.
          directory: durable home of the index; ``None`` gives an
            *ephemeral* index (same API, nothing on disk) — the adapter
            the legacy in-memory paths wrap themselves in.
          mesh: device mesh (default: ``meshutil.local_mesh()``).
          wire_dtype: routed-shuffle payload dtype for appends (float32
            keeps grown indexes bit-identical to one-shot rebuilds).
          extra: user metadata carried in every manifest.
          overwrite: clear a previous index's artifacts (manifests,
            segments, tree, tombstones) — unrelated files (e.g. a
            ``corpus/`` store) are left alone.

        Returns:
          The new handle. With a ``directory``, the tree checkpoint and
          an empty manifest are written immediately, so even an index
          that crashes before its first commit reopens cleanly.

        Raises:
          FileExistsError: ``directory`` already holds an index and
            ``overwrite`` is False.
        """
        idx = cls(directory, tree, mesh, wire_dtype=wire_dtype, meta=extra)
        if directory:
            if has_index(directory) and not overwrite:
                raise FileExistsError(
                    f"{directory} already holds an index; use Index.open "
                    "or create(..., overwrite=True)"
                )
            if overwrite and os.path.isdir(directory):
                for v in manifest_lib.list_versions(directory):
                    os.remove(manifest_lib.manifest_path(directory, v))
                for sub in (
                    manifest_lib.SEGMENTS_SUBDIR,
                    manifest_lib.TOMBSTONES_SUBDIR,
                    manifest_lib.TREE_SUBDIR,
                ):
                    shutil.rmtree(os.path.join(directory, sub),
                                  ignore_errors=True)
            os.makedirs(directory, exist_ok=True)
            _save_tree(directory, tree, idx._tree_meta())
            manifest_lib.write(directory, idx._manifest())
        return idx

    @classmethod
    def open(cls, directory: str, mesh=None) -> "Index":
        """Restore the last *committed* state from ``directory``.

        Args:
          directory: an index home previously written by :meth:`create` +
            :meth:`commit`.
          mesh: device mesh to place segments on (default: local mesh).

        Returns:
          An :class:`Index` at the highest complete manifest version —
          orphan segments from an interrupted append (no manifest
          references them) are ignored.

        Raises:
          FileNotFoundError: no committed manifest (including the
            pre-segment legacy ``index_ckpt/`` format, reported
            actionably).
          ValueError: the committed segments were built for a different
            device-shard count than ``mesh`` provides.
        """
        m = manifest_lib.latest(directory)
        if m is None:
            if has_legacy_index(directory):
                raise FileNotFoundError(
                    f"{directory} holds a pre-segment-format index "
                    f"({LEGACY_CKPT_SUBDIR}/), which this version no longer "
                    "reads — rebuild it (e.g. serve --rebuild, or "
                    "Index.create + append + commit)"
                )
            raise FileNotFoundError(f"no index manifest under {directory}")
        mesh = mesh if mesh is not None else local_mesh()
        tree, tree_meta = _load_tree(directory, mesh)
        seg_dir = os.path.join(directory, manifest_lib.SEGMENTS_SUBDIR)
        segments = [Segment.load(seg_dir, name, mesh) for name in m.segments]
        want = data_axis_size(mesh)
        for seg in segments:
            if seg.n_shards != want:
                raise ValueError(
                    f"index segment {seg.name} was built for "
                    f"{seg.n_shards} shards; current mesh has {want} — "
                    "rebuild the index for this mesh"
                )
        wire = jnp.dtype(tree_meta.get("wire_dtype", "float32"))
        quantizer, codes, codes_paths = None, {}, {}
        if m.codes:
            quantizer = ProductQuantizer.from_json(m.codes["quantizer"])
            codes_paths = dict(m.codes.get("segments", {}))
            codes = {
                name: manifest_lib.read_codes(directory, rel)
                for name, rel in codes_paths.items()
                if name in m.segments
            }
        return cls(
            directory,
            tree,
            mesh,
            segments=segments,
            tombstones=manifest_lib.read_tombstones(directory, m.tombstones),
            version=m.version,
            next_id=m.next_id,
            meta=m.meta,
            wire_dtype=wire,
            shard_plan=(
                ShardPlan.from_json(m.shard_plan) if m.shard_plan else None
            ),
            calibration=(
                CalibrationStore.from_json(m.calibration)
                if m.calibration else None
            ),
            quantizer=quantizer,
            codes=codes,
            codes_paths=codes_paths,
        )

    @classmethod
    def from_built(
        cls,
        built: DistributedIndex,
        tree: VocabTree,
        *,
        mesh=None,
        extra: dict | None = None,
    ) -> "Index":
        """Ephemeral single-segment wrapper around an already-built
        ``DistributedIndex`` — the legacy-constructor adapter."""
        idx = cls.create(tree, None, mesh=mesh, extra=extra)
        idx.append_built(built)
        idx.commit()
        return idx

    # -- basic accessors ----------------------------------------------------
    @property
    def mesh(self):
        if self._mesh is None:
            self._mesh = local_mesh()
        return self._mesh

    @property
    def n_leaves(self) -> int:
        return self.tree.n_leaves

    @property
    def dim(self) -> int:
        return self.tree.dim

    @property
    def version(self) -> int:
        return self._version

    @property
    def next_id(self) -> int:
        """Next auto-assigned descriptor id (the id-space high-water mark)."""
        return self._next_id

    @property
    def stamp(self) -> int:
        """Monotone mutation counter: bumped by every append / delete /
        meta / plan / codes / commit / compact on this handle. Two equal
        stamps mean the index state is unchanged between them."""
        return self._stamp

    def snapshot(self) -> "IndexSnapshot":
        """A consistent :class:`IndexSnapshot` of the current state (this
        handle's view: committed + staged). Taken under the writer lock,
        so a concurrent mutator can never hand out a torn cut."""
        with self._lock:
            segs = self.segments
            return IndexSnapshot(
                stamp=self._stamp,
                version=self._version,
                segments=segs,
                views=self.segment_views(),
                tombstones=self._tombstones.copy(),
                shard_plan=self._shard_plan,
                quantizer=self.quantizer,
                codes=(
                    {s.name: self._codes[s.name] for s in segs}
                    if self.quantizer is not None else {}
                ),
            )

    @property
    def segments(self) -> tuple[Segment, ...]:
        """Committed + staged segments, in append order."""
        return tuple(self._committed) + tuple(self._staged)

    @property
    def n_segments(self) -> int:
        return len(self._committed) + len(self._staged)

    @property
    def staged_segments(self) -> tuple[str, ...]:
        return tuple(s.name for s in self._staged)

    @property
    def tombstones(self) -> np.ndarray:
        return self._tombstones.copy()

    @property
    def shard_plan(self) -> ShardPlan | None:
        """The scatter-gather :class:`~repro.index.sharding.ShardPlan`
        bound to this index (persisted in the manifest), or ``None``."""
        return self._shard_plan

    def set_shard_plan(self, plan: ShardPlan | None) -> None:
        """Stage a shard plan (or clear with ``None``); durable in the
        manifest at the next :meth:`commit`.

        Raises ``ValueError`` when ``plan`` does not assign exactly this
        index's current segments — derive one with
        ``ShardPlan.for_index(index, n_shards, strategy)``.
        """
        if plan is not None and not plan.covers(
            [s.name for s in self.segments]
        ):
            raise ValueError(
                "shard plan does not cover the index's current segments; "
                "derive one with ShardPlan.for_index"
            )
        with self._lock:
            self._shard_plan = plan
            self._shard_plan_dirty = True
            self._stamp += 1

    # -- compressed-codes tier ----------------------------------------------
    def enable_codes(
        self,
        *,
        m: int = 8,
        bits: int = 8,
        sample: int = 65_536,
        iters: int = 16,
        seed: int = 0,
    ) -> ProductQuantizer:
        """Train a :class:`~repro.codes.ProductQuantizer` on this index's
        live rows and encode every segment (staged; durable after
        :meth:`commit`, versioned in the manifest like ``shard_plan``).

        Once enabled, later appends and compactions re-encode their new
        segments automatically, and ``search(layout="auto")`` may pick the
        ``scan_codes`` layout (ADC scan + exact rerank) when the cost model
        prices it cheaper — ``search(layout="scan_codes")`` forces it.

        Raises:
          ValueError: no live rows to train on, or ``dim`` is not
            divisible by ``m``.
        """
        segs = self.segments
        parts = []
        for seg in segs:
            ids = seg.host_ids()
            parts.append(seg.host_vecs()[ids >= 0])
        train = (
            np.concatenate(parts) if parts
            else np.empty((0, self.dim), np.float32)
        )
        if train.shape[0] == 0:
            raise ValueError("enable_codes needs at least one indexed row")
        with get_tracer().span("index.enable_codes", rows=train.shape[0],
                               m=m, bits=bits):
            pq = ProductQuantizer.train(
                train, m=m, bits=bits, seed=seed, sample=sample, iters=iters
            )
            codes = {seg.name: pq.encode(seg.host_vecs()) for seg in segs}
        with self._lock:
            self.quantizer = pq
            self._codes = codes
            self._codes_paths = {}
            self._codes_dirty = True
            self._stamp += 1
        return self.quantizer

    def codes_stats(self) -> dict | None:
        """Footprint of the compressed tier, or ``None`` when disabled."""
        pq = self.quantizer
        if pq is None:
            return None
        return {
            "code_m": pq.m,
            "code_bits": pq.bits,
            "bytes_per_row": pq.bytes_per_row,
            "raw_bytes_per_row": 4 * self.dim,
            "compression_ratio": pq.compression_ratio(),
            "codebook_bytes": pq.codebook_bytes,
        }

    @property
    def rows(self) -> int:
        """Live (searchable) descriptor rows: valid minus tombstoned."""
        return sum(s.valid_rows for s in self.segments) - len(self._tombstones)

    @property
    def meta(self) -> dict:
        """User extra merged with the derived structure/stats keys the old
        ``persist.load_index`` manifest carried."""
        out = dict(self._user_meta)
        out.update(self._tree_meta())
        out.update(
            rows=sum(s.rows for s in self.segments),
            valid_rows=sum(s.valid_rows for s in self.segments),
            live_rows=self.rows,
            n_shards=data_axis_size(self.mesh),
            n_segments=self.n_segments,
            n_tombstones=int(len(self._tombstones)),
            next_id=self._next_id,
            version=self._version,
        )
        return out

    def stats(self) -> dict:
        return dict(
            self.meta,
            segments=[s.stats() for s in self.segments],
            staged=list(self.staged_segments),
        )

    def _tree_meta(self) -> dict:
        return {
            "n_leaves": int(self.tree.n_leaves),
            "n_levels": len(self.tree.levels),
            "fanouts": [int(f) for f in self.tree.fanouts],
            "dim": int(self.tree.dim),
            "wire_dtype": str(jnp.dtype(self.wire_dtype)),
        }

    def _manifest(
        self,
        tombstones_rel: str | None = None,
        *,
        version: int | None = None,
        segments: Sequence[Segment] | None = None,
        shard_plan: ShardPlan | None = None,
        codes_paths: dict | None = None,
    ) -> Manifest:
        segs = self._committed if segments is None else segments
        return Manifest(
            version=self._version if version is None else version,
            segments=[s.name for s in segs],
            tombstones=tombstones_rel,
            next_id=self._next_id,
            meta=self._user_meta,
            shard_plan=shard_plan.to_json() if shard_plan else None,
            calibration=(
                self.calibration.to_json() if len(self.calibration) else None
            ),
            codes=self._codes_payload(segs, codes_paths),
        )

    def _codes_payload(
        self, segments: Sequence[Segment], paths: dict | None = None
    ) -> dict | None:
        if self.quantizer is None:
            return None
        paths = self._codes_paths if paths is None else paths
        return {
            "format": CODES_FORMAT,
            "quantizer": self.quantizer.to_json(),
            "segments": {
                s.name: paths[s.name] for s in segments if s.name in paths
            },
        }

    def _plan_for(self, segments: Sequence[Segment]) -> ShardPlan | None:
        """The bound shard plan updated to ``segments``: unchanged when it
        still covers them, re-derived (same strategy, same shard count)
        after an append/compact changed the segment set. Explicit plans
        cannot follow a changed set and are dropped."""
        p = self._shard_plan
        if p is None:
            return None
        names = [s.name for s in segments]
        if p.covers(names):
            return p
        if p.strategy == "round_robin":
            return ShardPlan.round_robin(names, p.n_shards)
        if p.strategy == "balanced":
            return ShardPlan.balanced(
                names, [s.valid_rows for s in segments], p.n_shards
            )
        return None

    # -- write path ---------------------------------------------------------
    def _segments_dir(self) -> str:
        return os.path.join(self.directory, manifest_lib.SEGMENTS_SUBDIR)

    def _next_name(self) -> str:
        if self.directory:
            return segment_name(next_seq(self._segments_dir()))
        self._mem_seq += 1
        return segment_name(self._mem_seq)

    def _existing_ids(self, within: np.ndarray | None = None) -> np.ndarray:
        """Indexed descriptor ids, pruned to segments whose [min_id,
        max_id] range can overlap ``within`` — membership probes (delete,
        collision checks) skip segments that cannot possibly match."""
        segs = self.segments
        if within is not None and within.size:
            segs = [s for s in segs if s.overlaps(within)]
        parts = [s.host_ids() for s in segs]
        if not parts:
            return np.empty((0,), np.int64)
        ids = np.concatenate(parts)
        return ids[ids >= 0]

    def append(
        self,
        vecs,
        ids=None,
        *,
        wave_rows: int | None = None,
        capacity_factor: float = 2.0,
    ) -> str:
        """Assign + route + cluster-sort ``vecs`` into a new immutable
        segment (staged; durable after :meth:`commit`).

        Assignment runs in waves through ``build_index_fn`` exactly like a
        one-shot build, so an index grown by appends is the same index a
        monolithic job would have produced.

        Args:
          vecs: ``(n, dim)`` descriptor rows (cast to float32).
          ids: explicit non-negative descriptor ids; default is the next
            contiguous range of the global id space.
          wave_rows: assignment wave size (default: auto-snapped).
          capacity_factor: routing headroom for skewed leaves.

        Returns:
          The staged segment's name.

        Raises:
          ValueError: wrong shape, zero rows, negative/duplicate/
            colliding ids, or an id past the int32 id space.
        """
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim != 2 or vecs.shape[1] != self.dim:
            raise ValueError(
                f"append expects (n, {self.dim}) rows; got {vecs.shape}"
            )
        n = vecs.shape[0]
        if n == 0:
            raise ValueError("append of zero rows")
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + n, dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            if ids.shape != (n,):
                raise ValueError(f"ids shape {ids.shape} != ({n},)")
            if ids.size and ids.min() < 0:
                raise ValueError("descriptor ids must be non-negative")
            if len(np.unique(ids)) != n:
                raise ValueError("duplicate ids within the appended batch")
            if ids.min() < self._next_id and np.isin(
                ids, self._existing_ids(within=ids)
            ).any():
                raise ValueError("appended ids collide with indexed ids")
        if int(ids.max()) > np.iinfo(np.int32).max:
            # the engine carries ids as int32; a wrapped id would silently
            # become padding (-1 family) and the row would vanish
            raise ValueError(
                f"descriptor id {int(ids.max())} exceeds int32 — the id "
                "space is full; compact() after deletes or re-id the corpus"
            )
        with get_tracer().span("index.append", rows=n):
            built = build_index(
                jnp.asarray(vecs),
                self.tree,
                self.mesh,
                ids=jnp.asarray(ids.astype(np.int32)),
                wave_rows=wave_rows,
                capacity_factor=capacity_factor,
                wire_dtype=self.wire_dtype,
            )
            jax.block_until_ready(built.vecs)
            name = self.append_built(built)
        reg = get_registry()
        reg.counter("index.appends").inc()
        reg.counter("index.rows_appended").inc(n)
        return name

    def append_built(self, built: DistributedIndex, *, name=None) -> str:
        """Adopt an already-built ``DistributedIndex`` as a staged segment
        (the ``save_index`` shim and the legacy session path use this)."""
        if int(built.n_leaves) != self.n_leaves:
            raise ValueError(
                f"built index has {built.n_leaves} leaves; tree has "
                f"{self.n_leaves}"
            )
        if self.segments and built.offsets.shape[0] != self.segments[0].n_shards:
            raise ValueError(
                f"built index has {built.offsets.shape[0]} shards; index "
                f"segments have {self.segments[0].n_shards}"
            )
        seg = Segment.from_built(name or self._next_name(), built)
        if self.directory:
            seg.save(self._segments_dir())  # durable *before* it is staged
        new_codes = None
        if self.quantizer is not None:
            # the codes tier follows every append: encode the new segment's
            # padded rows (pad rows carry the LEAF_SENTINEL and never match)
            new_codes = self.quantizer.encode(seg.host_vecs())
        with self._lock:
            self._staged.append(seg)
            if new_codes is not None:
                self._codes[seg.name] = new_codes
                self._codes_dirty = True
            self._next_id = max(self._next_id, seg.max_id + 1)
            self._views = None
            self._stamp += 1
        return seg.name

    def update_meta(self, **kw) -> None:
        """Stage user-metadata updates (e.g. an ingest cursor); durable at
        the next :meth:`commit` alongside whatever else is staged."""
        with self._lock:
            self._user_meta.update(kw)
            self._meta_dirty = True
            self._stamp += 1

    def delete(self, ids) -> int:
        """Tombstone descriptor ids (staged; durable after :meth:`commit`).

        Args:
          ids: descriptor ids to delete; absent or already-deleted ids
            are ignored (idempotent).

        Returns:
          How many ids were *newly* tombstoned. Tombstoned rows stop
          matching immediately for this handle and are physically
          dropped at the next :meth:`compact`.
        """
        ids = np.unique(np.asarray(ids, np.int64))
        ids = ids[~np.isin(ids, self._tombstones)]
        if ids.size:
            ids = ids[np.isin(ids, self._existing_ids(within=ids))]
        if ids.size == 0:
            return 0
        with self._lock:
            self._tombstones = np.sort(
                np.concatenate([self._tombstones, ids])
            )
            self._tombstones_dirty = True
            self._views = None
            self._stamp += 1
        reg = get_registry()
        reg.counter("index.tombstoned").inc(int(ids.size))
        reg.gauge("index.tombstones_live").set(int(self._tombstones.size))
        return int(ids.size)

    def commit(self) -> int:
        """Publish staged segments + tombstones + metadata + shard plan +
        cost-model calibration: one atomic manifest bump.

        Idempotent — committing with nothing staged returns the current
        version without writing. A crash *before* the manifest rename
        leaves the previous committed state fully intact (staged segment
        checkpoints become ignorable orphans); a crash *after* it leaves
        the new state fully committed. There is no in-between. A bound
        shard plan that no longer covers the staged segment set is
        re-derived (same strategy) in the same bump.

        Returns:
          The committed manifest version.

        Raises:
          FileExistsError: another handle committed this version
            concurrently (exclusive publication) — reopen and retry.
          OSError: the durable write failed; the handle stays staged so
            a retried ``commit()`` re-attempts publication.
        """
        if not (self._staged or self._tombstones_dirty or self._meta_dirty
                or self._shard_plan_dirty or self._codes_dirty
                or self.calibration.dirty):
            return self._version
        # durable writes FIRST, memory state only after they succeed — a
        # failed write leaves the handle still-staged, so a retried
        # commit() re-attempts the publication instead of no-opping
        version = self._version + 1
        segments = self._committed + self._staged
        plan = self._plan_for(segments)
        with get_tracer().span("index.commit", version=version,
                               staged=len(self._staged)):
            if self.directory:
                rel = None
                if len(self._tombstones):
                    rel = manifest_lib.write_tombstones(
                        self.directory, version, self._tombstones
                    )
                if self.quantizer is not None:
                    # code files are durable *before* the manifest that
                    # references them, same as segments and tombstones
                    for seg in segments:
                        if seg.name not in self._codes_paths:
                            self._codes_paths[seg.name] = (
                                manifest_lib.write_codes(
                                    self.directory, seg.name,
                                    self._codes[seg.name],
                                )
                            )
                manifest_lib.write(
                    self.directory,
                    self._manifest(rel, version=version, segments=segments,
                                   shard_plan=plan),
                )
        get_registry().counter("index.commits").inc()
        with self._lock:
            self._version = version
            self._committed = segments
            self._staged = []
            self._shard_plan = plan
            self._tombstones_dirty = False
            self._meta_dirty = False
            self._shard_plan_dirty = False
            self._codes_dirty = False
            self.calibration.mark_clean()
            self._stamp += 1
        return version

    def compact(
        self,
        incremental: bool = False,
        policy: CompactionPolicy | None = None,
    ) -> str | None:
        """Merge segments into one, dropping their tombstoned rows.

        ``compact()`` merges *every* segment (the stop-the-world full
        merge); ``compact(incremental=True)`` asks the
        :class:`CompactionPolicy` for one tier of small or
        tombstone-heavy segments and merges only those — surviving
        segments, their codes files, and the tombstones that belong to
        them are carried through untouched, so each step is a small,
        bounded unit of work that can run between serving refreshes.
        Either way the step publishes through the same stage-then-publish
        manifest path an append commit uses, and search results are
        bit-identical before and after (victims' live rows reappear,
        id-sorted, in the merged segment at the first victim's position;
        masking already made their dead rows unmatchable).

        Commits atomically; victim segment checkpoints are
        garbage-collected only after the manifest bump; a bound derivable
        shard plan is re-derived over the new segment set (explicit plans
        are dropped).

        Args:
          incremental: merge only the policy-selected tier instead of
            everything.
          policy: the :class:`CompactionPolicy` an incremental step
            consults (default: ``CompactionPolicy()``); ignored for a
            full compact.

        Returns:
          The new merged segment's name; ``None`` when no merged segment
          was produced — the victims had no live rows (their space is
          still reclaimed and a version published), or, for an
          incremental step, no tier crossed the policy's thresholds (a
          fixed point: nothing is published at all).

        Raises:
          FileExistsError: a concurrent commit won the version race.
          Exception: a failed rebuild/write propagates with segments AND
            tombstones exactly as committed (no resurrection, no loss).
        """
        tr = get_tracer()
        t_start = tr.now() if tr.enabled else 0.0
        old = self.segments
        if incremental:
            pol = policy if policy is not None else CompactionPolicy()
            victims = pol.select(old, self._tombstones)
            if not victims:
                return None
        else:
            victims = list(old)
        victim_names = {s.name for s in victims}
        keep_v, keep_i = [], []
        for seg in victims:
            ids = np.asarray(seg.index.ids).astype(np.int64)
            live = ids >= 0
            if self._tombstones.size:
                live &= ~np.isin(ids, self._tombstones)
            keep_v.append(np.asarray(seg.index.vecs)[live])
            keep_i.append(ids[live])
        all_v = np.concatenate(keep_v) if keep_v else np.empty((0, self.dim))
        all_i = (
            np.concatenate(keep_i) if keep_i else np.empty((0,), np.int64)
        )
        order = np.argsort(all_i, kind="stable")
        # build + durably publish first; the handle's state is only
        # replaced once the new manifest exists, so a failed rebuild
        # leaves segments AND tombstones exactly as they were
        if all_i.size == 0:
            merged: list[Segment] = []
        else:
            built = build_index(
                jnp.asarray(all_v[order], jnp.float32),
                self.tree,
                self.mesh,
                ids=jnp.asarray(all_i[order].astype(np.int32)),
                wire_dtype=self.wire_dtype,
            )
            jax.block_until_ready(built.vecs)
            seg = Segment.from_built(self._next_name(), built)
            if self.directory:
                seg.save(self._segments_dir())
            merged = [seg]
        # survivors keep their order; the merged segment takes the first
        # victim's slot, so the cross-segment merge visits candidates in
        # the same segment-major order as before (stable on ties)
        new_committed: list[Segment] = []
        placed = False
        for s in old:
            if s.name in victim_names:
                if not placed:
                    new_committed.extend(merged)
                    placed = True
                continue
            new_committed.append(s)
        if not placed:
            new_committed.extend(merged)
        # tombstones pointing into the victims died with them; the rest
        # (ids living in surviving segments) stay masked
        new_tombstones = np.empty((0,), np.int64)
        if incremental and self._tombstones.size:
            survivors = [s for s in old if s.name not in victim_names]
            keep_ts = np.zeros(self._tombstones.shape, bool)
            for s in survivors:
                if not s.valid_rows or not s.overlaps(self._tombstones):
                    continue
                sorted_ids, _ = s.id_index()
                pos = np.searchsorted(sorted_ids, self._tombstones)
                keep_ts |= (pos < sorted_ids.size) & (
                    sorted_ids[np.minimum(pos, sorted_ids.size - 1)]
                    == self._tombstones
                )
            new_tombstones = self._tombstones[keep_ts]
        new_codes, new_codes_paths = self._codes, self._codes_paths
        if self.quantizer is not None:
            # the quantizer survives compaction unchanged (codebooks are
            # trained, not positional); only the merged segment's codes
            # are re-encoded — survivors keep their code files
            new_codes = {
                name: c for name, c in self._codes.items()
                if name not in victim_names
            }
            for s in merged:
                new_codes[s.name] = self.quantizer.encode(s.host_vecs())
            new_codes_paths = {
                name: p for name, p in self._codes_paths.items()
                if name not in victim_names
            }
            if self.directory:
                for s in new_committed:
                    if s.name not in new_codes_paths:
                        new_codes_paths[s.name] = manifest_lib.write_codes(
                            self.directory, s.name, new_codes[s.name]
                        )
        version = self._version + 1
        plan = self._plan_for(new_committed)
        if self.directory:
            rel = None
            if new_tombstones.size:
                rel = manifest_lib.write_tombstones(
                    self.directory, version, new_tombstones
                )
            manifest_lib.write(
                self.directory,
                self._manifest(rel, version=version,
                               segments=new_committed, shard_plan=plan,
                               codes_paths=new_codes_paths),
            )
        with self._lock:
            self._committed = new_committed
            self._staged = []
            self._shard_plan = plan
            self._shard_plan_dirty = False
            self._tombstones = new_tombstones
            self._tombstones_dirty = False
            self._meta_dirty = False
            self._codes = new_codes
            self._codes_paths = new_codes_paths
            self._codes_dirty = False
            self.calibration.mark_clean()
            self._version = version
            self._views = None
            self._stamp += 1
        if self.directory:
            self._gc_segments(old)
        if tr.enabled:
            tr.add_span(
                "index.compact", t_start, tr.now(),
                segments_in=len(victims), rows_out=int(all_i.size),
                version=version, incremental=bool(incremental),
            )
        reg = get_registry()
        reg.counter("index.compacts").inc()
        reg.gauge("index.tombstones_live").set(int(new_tombstones.size))
        return merged[0].name if merged else None

    def _gc_segments(self, old: Sequence[Segment]) -> None:
        live = {s.name for s in self._committed}
        for seg in old:
            if seg.name in live:
                continue
            shutil.rmtree(
                os.path.join(self._segments_dir(), seg.name),
                ignore_errors=True,
            )
            try:
                os.remove(os.path.join(
                    self.directory, manifest_lib.CODES_SUBDIR,
                    f"{seg.name}.npy",
                ))
            except OSError:
                pass

    def gc(self, *, dry_run: bool = False) -> dict:
        """Collect artifacts unreachable from the newest *on-disk* manifest:
        superseded manifest versions, orphan segment checkpoints from
        interrupted appends/compactions, unreferenced tombstone/code
        files, and stray ``*.tmp`` files from crashed publications.

        This handle's own staged (not-yet-committed) segments are never
        collected — only orphans no live handle can still publish.
        Removing an orphan segment directory un-reserves its name;
        that is safe because its code file (if any) is removed in the
        same pass.

        Args:
          dry_run: report what *would* be removed without touching disk.

        Returns:
          ``{"manifests": [...], "segments": [...], "tombstones": [...],
          "codes": [...], "tmp": [...]}`` — relative paths, collected (or
          merely listed, under ``dry_run``). All lists empty for an
          ephemeral index.
        """
        report: dict[str, list[str]] = {
            "manifests": [], "segments": [], "tombstones": [],
            "codes": [], "tmp": [],
        }
        d = self.directory
        if not d:
            return report
        m = manifest_lib.latest(d)
        if m is None:
            return report
        keep_segments = set(m.segments) | {s.name for s in self._staged}
        keep_files = {m.tombstones} if m.tombstones else set()
        if m.codes:
            keep_files |= set(m.codes.get("segments", {}).values())
        for v in manifest_lib.list_versions(d):
            if v != m.version:
                report["manifests"].append(
                    os.path.basename(manifest_lib.manifest_path(d, v))
                )
        seg_dir = os.path.join(d, manifest_lib.SEGMENTS_SUBDIR)
        if os.path.isdir(seg_dir):
            for name in sorted(os.listdir(seg_dir)):
                if name.startswith("seg_") and name not in keep_segments:
                    report["segments"].append(
                        os.path.join(manifest_lib.SEGMENTS_SUBDIR, name)
                    )
        for sub, key in (
            (manifest_lib.TOMBSTONES_SUBDIR, "tombstones"),
            (manifest_lib.CODES_SUBDIR, "codes"),
        ):
            p = os.path.join(d, sub)
            if not os.path.isdir(p):
                continue
            for name in sorted(os.listdir(p)):
                rel = os.path.join(sub, name)
                if name.endswith(".tmp"):
                    report["tmp"].append(rel)
                elif rel not in keep_files:
                    report[key].append(rel)
        for name in sorted(os.listdir(d)):
            if name.endswith(".tmp") and os.path.isfile(os.path.join(d, name)):
                report["tmp"].append(name)
        if not dry_run:
            for rel in report["segments"]:
                shutil.rmtree(os.path.join(d, rel), ignore_errors=True)
            for key in ("manifests", "tombstones", "codes", "tmp"):
                for rel in report[key]:
                    try:
                        os.remove(os.path.join(d, rel))
                    except OSError:
                        pass
        return report

    # -- read path ----------------------------------------------------------
    def read_rows(self, ids, *, segments=None, tombstones=None) -> np.ndarray:
        """Host gather of stored descriptor vectors by id — the corpus
        rows live inside the segments, so anything that consumes a
        ``read_rows``/``dim`` block store (e.g. the serving trace
        generator) can read straight from the index; a grown ``--index-
        dir`` needs no separate ``corpus/`` store. Probes each
        range-overlapping segment through its cached id index — no
        resident concatenated corpus copy is built.

        Tombstoned ids read as missing *immediately* (not only after the
        compaction that physically drops them), so the result never
        depends on compaction timing.

        Requested ids may repeat and arrive in any order: probes are
        deduplicated to one *sorted* unique set, each segment is gathered
        at most once, and results scatter back to the request order — the
        rerank fetch path hands whole candidate tables here without
        pre-sorting.

        ``segments`` / ``tombstones`` override the live state with a
        pinned :class:`IndexSnapshot`'s cut — serving sessions rerank
        against the exact state their candidates came from, so a
        concurrent delete or compaction can never make an in-flight
        request's candidate id unreadable."""
        segs = self.segments if segments is None else tuple(segments)
        ts = (
            self._tombstones if tombstones is None
            else np.asarray(tombstones, np.int64)
        )
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if ids.size and ids.min() < 0:
            # never let a requested -1 match a padding row's -1 id
            raise IndexError(f"descriptor ids must be >= 0; got {ids.min()}")
        if ids.size == 0:
            return np.empty((0, self.dim), np.float32)
        uniq, inverse = np.unique(ids, return_inverse=True)
        u_out = np.empty((uniq.size, self.dim), np.float32)
        u_found = np.zeros(uniq.size, bool)
        for seg in segs:
            if u_found.all() or not seg.overlaps(uniq):
                continue
            sorted_ids, order = seg.id_index()
            pos = np.searchsorted(sorted_ids, uniq)
            hit = (
                ~u_found
                & (pos < sorted_ids.size)
                & (sorted_ids[np.minimum(pos, sorted_ids.size - 1)] == uniq)
            )
            if hit.any():
                u_out[hit] = seg.host_vecs()[order[pos[hit]]]
                u_found |= hit
        if ts.size:
            u_found &= ~np.isin(uniq, ts)
        if not u_found.all():
            found = u_found[inverse]
            missing = ids[~found]
            raise IndexError(
                f"descriptor ids not in the index (absent or deleted): "
                f"{missing[:8].tolist()}"
                + ("..." if missing.size > 8 else "")
            )
        return u_out[inverse]

    def segment_views(self) -> tuple[DistributedIndex, ...]:
        """Per-segment indexes with tombstones masked (cached until the
        next append/delete/compact)."""
        if self._views is None:
            self._views = tuple(
                masked_view(s, self._tombstones) for s in self.segments
            )
        return self._views

    def search(
        self,
        queries,
        k: int = 10,
        *,
        plan: SearchPlan | None = None,
        layout: str = "auto",
        probes: int = 1,
        impl: str = "xla",
        block_rows: int | None = None,
        q_cap: int | None = None,
        q_tile: int | None = None,
        p_cap: int | None = None,
        rerank: int | None = None,
        cost_model="auto",
    ) -> SearchResult:
        """k-NN over every live row: one shared lookup build, one executor
        run per segment, one ascending-distance merge across segments.

        Args:
          queries: ``(q, dim)`` query rows (cast to float32).
          k: neighbours per query.
          plan: optional :class:`SearchPlan` template whose fields
            (layout, k, probes, impl, budgets) override the keyword
            arguments; budgets are still re-resolved per segment, since
            tile sizes must divide each segment's shard rows.
          layout/probes/impl/block_rows/q_cap/q_tile/p_cap: per-call plan
            knobs, as in :func:`repro.core.engine.plan`. ``layout`` also
            accepts ``"scan_codes"`` (ADC scan over PQ codes + exact
            rerank) once :meth:`enable_codes` has run; ``"auto"`` lets
            the cost model pick the codes tier on its own.
          rerank: ADC candidates per query to fetch + exactly rerank for
            the ``scan_codes`` layout (default from
            :func:`~repro.core.engine.plan.default_rerank`).
          cost_model: which model ranks an ``"auto"`` layout (``"auto"``
            / ``"heuristic"`` / ``"observed"`` / ``"fitted"``), consulting
            *this index's* manifest-persisted calibration store.

        Returns:
          A :class:`SearchResult`: ``(q, k)`` ids (``-1`` where fewer
          than ``k`` live rows matched) and squared-L2 dists (``inf``
          there), plus exact pairs/overflow counters. Dense layouts are
          bit-identical to a one-shot build+search over the concatenated
          live rows; ``scan_codes`` returns the exact-reranked top-k of
          the ADC candidate set (approximate recall, exact ordering).

        Raises:
          ValueError: invalid plan knobs (see
            :func:`repro.core.engine.plan`), or
            ``layout="scan_codes"`` without :meth:`enable_codes`.
        """
        if plan is not None:
            layout, k, probes, impl = plan.layout, plan.k, plan.probes, plan.impl
            block_rows = plan.block_rows if block_rows is None else block_rows
            q_cap = plan.q_cap if q_cap is None else q_cap
            q_tile = plan.q_tile if q_tile is None else q_tile
            p_cap = plan.p_cap if p_cap is None else p_cap
            rerank = plan.rerank if rerank is None else rerank
        queries = jnp.asarray(queries, jnp.float32)
        q = queries.shape[0]
        views = self.segment_views()
        if not views:
            return SearchResult(
                ids=jnp.full((q, k), -1, jnp.int32),
                dists=jnp.full((q, k), jnp.inf, jnp.float32),
                pairs=jnp.zeros((), jnp.float32),
                q_cap_overflow=jnp.zeros((), jnp.int32),
            )
        n_shards = data_axis_size(self.mesh)
        # ADC distances are approximations, incomparable with the dense
        # layouts' exact partial distances, so the codes-vs-exact decision
        # is resolved ONCE on the aggregate shape — per-segment plans then
        # all run the same tier and the cross-segment merge stays sound
        if layout == "scan_codes" and self.quantizer is None:
            raise ValueError(
                "layout='scan_codes' needs PQ codes; call "
                "enable_codes() first"
            )
        use_codes = False
        if self.quantizer is not None and layout in ("auto", "scan_codes"):
            agg = make_plan(
                rows=sum(v.rows for v in views),
                n_leaves=self.n_leaves, n_queries=q, n_shards=n_shards,
                k=k, probes=probes, layout=layout, impl=impl,
                model=cost_model, calibration=self.calibration,
                dim=self.dim, rerank=rerank,
                code_m=self.quantizer.m, code_bits=self.quantizer.bits,
            )
            use_codes = agg.layout == "scan_codes"
        lookup = jit_build_lookup(self.tree, queries, probes=probes)
        per = []
        pruned = 0
        segs_all = self.segments
        live_counts = np.array(
            [s.valid_rows for s in segs_all], np.int64
        ) - dead_counts(segs_all, self._tombstones)
        # dense-tier norm-bound pruning: a segment whose valid rows' L2
        # norms all sit outside [kth_dist - margin] of every query's
        # running top-k cannot contribute (||p - q||^2 >= (||p|| - ||q||)^2)
        # — result-safe by construction, and only exact dense distances
        # qualify (ADC distances are approximations, so the codes tier
        # never norm-prunes). Tracking the running top-k forces each
        # segment's result before the next dispatch, which is the price of
        # the bound; skipped entirely when no segment carries norm stats.
        q_norms = best_d = None
        if not use_codes and any(s.min_norm >= 0.0 for s in segs_all):
            q_norms = np.linalg.norm(np.asarray(queries, np.float64), axis=1)
            best_d = np.full((q, k), np.inf)
        for i, (seg, view) in enumerate(zip(segs_all, views)):
            if live_counts[i] == 0:
                # every row is padding or tombstoned: nothing to match
                pruned += 1
                continue
            if (
                best_d is not None
                and seg.min_norm >= 0.0
                and np.isfinite(best_d[:, -1]).all()
            ):
                gap = np.maximum(
                    seg.min_norm - q_norms, q_norms - seg.max_norm
                )
                lb = np.maximum(gap, 0.0) ** 2
                # margin absorbs fp32 accumulation error in the exact
                # distances (~1e-7 relative; 1e-4 is overwhelmingly safe)
                margin = 1e-4 * (seg.max_norm + q_norms) ** 2 + 1e-6
                if (lb > best_d[:, -1] + margin).all():
                    pruned += 1
                    continue
            if use_codes:
                p = make_plan(
                    rows=view.rows, n_leaves=self.n_leaves, n_queries=q,
                    n_shards=n_shards, k=k, probes=probes,
                    layout="scan_codes", impl=impl, block_rows=block_rows,
                    q_cap=q_cap, model=cost_model,
                    calibration=self.calibration,
                    dim=self.dim, rerank=rerank,
                    code_m=self.quantizer.m, code_bits=self.quantizer.bits,
                )
                per.append(search_with_lookup(
                    view, lookup, p, self.mesh, n_queries=q,
                    codes=self._codes[seg.name],
                    codebooks=self.quantizer.codebooks,
                ))
                continue
            p = make_plan(
                rows=view.rows,
                n_leaves=self.n_leaves,
                n_queries=q,
                n_shards=n_shards,
                k=k,
                probes=probes,
                layout=layout,
                impl=impl,
                block_rows=block_rows,
                q_cap=q_cap,
                q_tile=q_tile,
                p_cap=p_cap,
                model=cost_model,
                calibration=self.calibration,
            )
            per.append(
                search_with_lookup(view, lookup, p, self.mesh, n_queries=q)
            )
            if best_d is not None:
                best_d = np.sort(
                    np.concatenate(
                        [best_d, np.asarray(per[-1].dists, np.float64)],
                        axis=1,
                    ),
                    axis=1,
                )[:, :k]
        if pruned:
            get_registry().counter("index.segments_pruned").inc(pruned)
        if not per:
            # every segment was pruned — same sentinel as an empty index
            return SearchResult(
                ids=jnp.full((q, k), -1, jnp.int32),
                dists=jnp.full((q, k), jnp.inf, jnp.float32),
                pairs=jnp.zeros((), jnp.float32),
                q_cap_overflow=jnp.zeros((), jnp.int32),
            )
        if use_codes:
            r_max = max(r.ids.shape[1] for r in per)
            cand = per[0] if len(per) == 1 else _merge_results(per, r_max)
            cand_ids = np.asarray(cand.ids)
            with get_tracer().span("engine.rerank", k=k,
                                   candidates=int(cand_ids.shape[1])):
                ids_r, dists_r = rerank_exact(
                    self.read_rows, np.asarray(queries), cand_ids, k
                )
            return SearchResult(
                ids=jnp.asarray(ids_r),
                dists=jnp.asarray(dists_r),
                pairs=cand.pairs,
                q_cap_overflow=cand.q_cap_overflow,
            )
        if len(per) == 1:
            return per[0]
        return _merge_results(per, k)


def _merge_results(per: Sequence[SearchResult], k: int) -> SearchResult:
    """Fold per-segment k-NN tables into one — the same ascending-distance
    merge the executors apply across shards (stable on ties, so
    segment-major order mirrors the one-shot table's candidate order)."""
    all_i = np.concatenate([np.asarray(r.ids) for r in per], axis=1)
    all_d = np.concatenate([np.asarray(r.dists) for r in per], axis=1)
    sel = np.argsort(all_d, axis=1, kind="stable")[:, :k]
    return SearchResult(
        ids=jnp.asarray(np.take_along_axis(all_i, sel, axis=1)),
        dists=jnp.asarray(np.take_along_axis(all_d, sel, axis=1)),
        pairs=sum(r.pairs for r in per),
        q_cap_overflow=sum(r.q_cap_overflow for r in per),
    )
