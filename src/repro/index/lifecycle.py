"""The segment-based index lifecycle facade (paper §2.2–2.3 as an API).

The paper's collection *grows between runs*: 30B descriptors are indexed in
grid-sized batches, and every search job runs against whatever index files
exist so far. :class:`Index` is that workflow as one object:

  ``Index.create(tree, dir)``   new index bound to a vocabulary tree
  ``Index.open(dir)``           restore the last committed state
  ``idx.append(vecs, ids)``     wave-based assignment (``build_index_fn``
                                under the eager wrapper) into a new
                                immutable, durably-written *segment*
  ``idx.commit()``              atomic manifest bump — the only operation
                                that makes appends/deletes visible to a
                                later ``open`` (crash-safe, idempotent)
  ``idx.delete(ids)``           tombstones (masked at search, dropped at
                                compaction)
  ``idx.compact()``             merge all segments into one, dropping
                                tombstoned rows; commits atomically
  ``idx.search(queries, ...)``  engine executors per segment over one
                                shared lookup build, merged across segments

Search over N segments is *bit-identical* to a one-shot ``build_index`` +
``batch_search`` over the concatenated rows (and after ``compact()`` the
index arrays themselves match a from-scratch rebuild): per-pair distances
depend only on the (point, query) vectors, tombstone masking reuses the
pipeline's own padding semantics, and the cross-segment merge applies the
same ascending-distance fold the executors use internally.

A handle sees its own uncommitted writes (staged segments and staged
tombstones); a fresh ``open`` sees only the last committed manifest.
"""

from __future__ import annotations

import os
import shutil
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.codes import CODES_FORMAT, ProductQuantizer, rerank_exact
from repro.core.engine import (
    CalibrationStore,
    SearchPlan,
    plan as make_plan,
)
from repro.core.engine.executors import SearchResult
from repro.core.index_build import DistributedIndex, build_index
from repro.core.search import jit_build_lookup, search_with_lookup
from repro.core.tree import VocabTree
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.meshutil import data_axis_size, local_mesh
from repro.index import manifest as manifest_lib
from repro.index.manifest import Manifest
from repro.index.segment import Segment, masked_view, next_seq, segment_name
from repro.index.sharding import ShardPlan
from repro.obs import get_registry, get_tracer


# the pre-segment serving.persist format (one monolithic checkpoint);
# detected only to fail/warn actionably — there is no in-place migration
LEGACY_CKPT_SUBDIR = "index_ckpt"


def has_legacy_index(directory: str) -> bool:
    return bool(directory) and os.path.isdir(
        os.path.join(directory, LEGACY_CKPT_SUBDIR)
    )


def has_index(directory: str) -> bool:
    """True when ``directory`` holds at least one committed manifest."""
    return bool(directory) and manifest_lib.latest(directory) is not None


def _save_tree(directory: str, tree: VocabTree, meta: dict) -> None:
    mgr = CheckpointManager(
        os.path.join(directory, manifest_lib.TREE_SUBDIR), keep=1
    )
    mgr.save(0, {"tree": tree}, extra=meta)


def _load_tree(directory: str, mesh) -> tuple[VocabTree, dict]:
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(
        os.path.join(directory, manifest_lib.TREE_SUBDIR), keep=1
    )
    step = mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no index tree checkpoint under {directory}")
    meta = mgr.read_manifest(step)["extra"]
    rep = NamedSharding(mesh, P())
    n_levels = int(meta["n_levels"])
    skeleton = {"tree": VocabTree(levels=tuple(0.0 for _ in range(n_levels)))}
    shardings = {
        "tree": VocabTree(levels=tuple(rep for _ in range(n_levels)))
    }
    out, _ = mgr.restore(skeleton, step, shardings=shardings)
    return out["tree"], meta


class Index:
    """Segment-based distributed index with a durable lifecycle."""

    def __init__(
        self,
        directory: str | None,
        tree: VocabTree,
        mesh=None,
        *,
        segments: Sequence[Segment] = (),
        tombstones: np.ndarray | None = None,
        version: int = 0,
        next_id: int = 0,
        meta: dict | None = None,
        wire_dtype=jnp.float32,
        shard_plan: ShardPlan | None = None,
        calibration: CalibrationStore | None = None,
        quantizer: ProductQuantizer | None = None,
        codes: dict | None = None,
        codes_paths: dict | None = None,
    ):
        self.directory = directory
        self.tree = tree
        self._mesh = mesh
        self.wire_dtype = wire_dtype
        self._committed: list[Segment] = list(segments)
        self._staged: list[Segment] = []
        self._shard_plan = shard_plan
        self._shard_plan_dirty = False
        # compressed-codes tier: the PQ quantizer (manifest-persisted like
        # shard_plan/calibration), per-segment (rows, m) uint8 code arrays,
        # and the relative paths of already-published code files
        self.quantizer = quantizer
        self._codes: dict[str, np.ndarray] = dict(codes or {})
        self._codes_paths: dict[str, str] = dict(codes_paths or {})
        self._codes_dirty = False
        # index-scoped cost-model calibration: measured ms/image per plan
        # signature, persisted in the manifest (its own dirty flag drives
        # commit), consulted by search()/serving via plan(model="auto")
        self.calibration = (
            calibration if calibration is not None else CalibrationStore()
        )
        self._tombstones = (
            np.sort(np.asarray(tombstones, np.int64))
            if tombstones is not None and len(tombstones)
            else np.empty((0,), np.int64)
        )
        self._tombstones_dirty = False
        self._version = version
        self._next_id = int(next_id)
        self._user_meta = dict(meta or {})
        self._meta_dirty = False
        self._views: tuple[DistributedIndex, ...] | None = None
        self._mem_seq = 0  # segment naming for ephemeral (dir-less) indexes

    # -- construction -------------------------------------------------------
    @classmethod
    def create(
        cls,
        tree: VocabTree,
        directory: str | None = None,
        *,
        mesh=None,
        wire_dtype=jnp.float32,
        extra: dict | None = None,
        overwrite: bool = False,
    ) -> "Index":
        """New empty index bound to ``tree``.

        Args:
          tree: the vocabulary :class:`~repro.core.tree.VocabTree` every
            later append/search routes through.
          directory: durable home of the index; ``None`` gives an
            *ephemeral* index (same API, nothing on disk) — the adapter
            the legacy in-memory paths wrap themselves in.
          mesh: device mesh (default: ``meshutil.local_mesh()``).
          wire_dtype: routed-shuffle payload dtype for appends (float32
            keeps grown indexes bit-identical to one-shot rebuilds).
          extra: user metadata carried in every manifest.
          overwrite: clear a previous index's artifacts (manifests,
            segments, tree, tombstones) — unrelated files (e.g. a
            ``corpus/`` store) are left alone.

        Returns:
          The new handle. With a ``directory``, the tree checkpoint and
          an empty manifest are written immediately, so even an index
          that crashes before its first commit reopens cleanly.

        Raises:
          FileExistsError: ``directory`` already holds an index and
            ``overwrite`` is False.
        """
        idx = cls(directory, tree, mesh, wire_dtype=wire_dtype, meta=extra)
        if directory:
            if has_index(directory) and not overwrite:
                raise FileExistsError(
                    f"{directory} already holds an index; use Index.open "
                    "or create(..., overwrite=True)"
                )
            if overwrite and os.path.isdir(directory):
                for v in manifest_lib.list_versions(directory):
                    os.remove(manifest_lib.manifest_path(directory, v))
                for sub in (
                    manifest_lib.SEGMENTS_SUBDIR,
                    manifest_lib.TOMBSTONES_SUBDIR,
                    manifest_lib.TREE_SUBDIR,
                ):
                    shutil.rmtree(os.path.join(directory, sub),
                                  ignore_errors=True)
            os.makedirs(directory, exist_ok=True)
            _save_tree(directory, tree, idx._tree_meta())
            manifest_lib.write(directory, idx._manifest())
        return idx

    @classmethod
    def open(cls, directory: str, mesh=None) -> "Index":
        """Restore the last *committed* state from ``directory``.

        Args:
          directory: an index home previously written by :meth:`create` +
            :meth:`commit`.
          mesh: device mesh to place segments on (default: local mesh).

        Returns:
          An :class:`Index` at the highest complete manifest version —
          orphan segments from an interrupted append (no manifest
          references them) are ignored.

        Raises:
          FileNotFoundError: no committed manifest (including the
            pre-segment legacy ``index_ckpt/`` format, reported
            actionably).
          ValueError: the committed segments were built for a different
            device-shard count than ``mesh`` provides.
        """
        m = manifest_lib.latest(directory)
        if m is None:
            if has_legacy_index(directory):
                raise FileNotFoundError(
                    f"{directory} holds a pre-segment-format index "
                    f"({LEGACY_CKPT_SUBDIR}/), which this version no longer "
                    "reads — rebuild it (e.g. serve --rebuild, or "
                    "Index.create + append + commit)"
                )
            raise FileNotFoundError(f"no index manifest under {directory}")
        mesh = mesh if mesh is not None else local_mesh()
        tree, tree_meta = _load_tree(directory, mesh)
        seg_dir = os.path.join(directory, manifest_lib.SEGMENTS_SUBDIR)
        segments = [Segment.load(seg_dir, name, mesh) for name in m.segments]
        want = data_axis_size(mesh)
        for seg in segments:
            if seg.n_shards != want:
                raise ValueError(
                    f"index segment {seg.name} was built for "
                    f"{seg.n_shards} shards; current mesh has {want} — "
                    "rebuild the index for this mesh"
                )
        wire = jnp.dtype(tree_meta.get("wire_dtype", "float32"))
        quantizer, codes, codes_paths = None, {}, {}
        if m.codes:
            quantizer = ProductQuantizer.from_json(m.codes["quantizer"])
            codes_paths = dict(m.codes.get("segments", {}))
            codes = {
                name: manifest_lib.read_codes(directory, rel)
                for name, rel in codes_paths.items()
                if name in m.segments
            }
        return cls(
            directory,
            tree,
            mesh,
            segments=segments,
            tombstones=manifest_lib.read_tombstones(directory, m.tombstones),
            version=m.version,
            next_id=m.next_id,
            meta=m.meta,
            wire_dtype=wire,
            shard_plan=(
                ShardPlan.from_json(m.shard_plan) if m.shard_plan else None
            ),
            calibration=(
                CalibrationStore.from_json(m.calibration)
                if m.calibration else None
            ),
            quantizer=quantizer,
            codes=codes,
            codes_paths=codes_paths,
        )

    @classmethod
    def from_built(
        cls,
        built: DistributedIndex,
        tree: VocabTree,
        *,
        mesh=None,
        extra: dict | None = None,
    ) -> "Index":
        """Ephemeral single-segment wrapper around an already-built
        ``DistributedIndex`` — the legacy-constructor adapter."""
        idx = cls.create(tree, None, mesh=mesh, extra=extra)
        idx.append_built(built)
        idx.commit()
        return idx

    # -- basic accessors ----------------------------------------------------
    @property
    def mesh(self):
        if self._mesh is None:
            self._mesh = local_mesh()
        return self._mesh

    @property
    def n_leaves(self) -> int:
        return self.tree.n_leaves

    @property
    def dim(self) -> int:
        return self.tree.dim

    @property
    def version(self) -> int:
        return self._version

    @property
    def next_id(self) -> int:
        """Next auto-assigned descriptor id (the id-space high-water mark)."""
        return self._next_id

    @property
    def segments(self) -> tuple[Segment, ...]:
        """Committed + staged segments, in append order."""
        return tuple(self._committed) + tuple(self._staged)

    @property
    def n_segments(self) -> int:
        return len(self._committed) + len(self._staged)

    @property
    def staged_segments(self) -> tuple[str, ...]:
        return tuple(s.name for s in self._staged)

    @property
    def tombstones(self) -> np.ndarray:
        return self._tombstones.copy()

    @property
    def shard_plan(self) -> ShardPlan | None:
        """The scatter-gather :class:`~repro.index.sharding.ShardPlan`
        bound to this index (persisted in the manifest), or ``None``."""
        return self._shard_plan

    def set_shard_plan(self, plan: ShardPlan | None) -> None:
        """Stage a shard plan (or clear with ``None``); durable in the
        manifest at the next :meth:`commit`.

        Raises ``ValueError`` when ``plan`` does not assign exactly this
        index's current segments — derive one with
        ``ShardPlan.for_index(index, n_shards, strategy)``.
        """
        if plan is not None and not plan.covers(
            [s.name for s in self.segments]
        ):
            raise ValueError(
                "shard plan does not cover the index's current segments; "
                "derive one with ShardPlan.for_index"
            )
        self._shard_plan = plan
        self._shard_plan_dirty = True

    # -- compressed-codes tier ----------------------------------------------
    def enable_codes(
        self,
        *,
        m: int = 8,
        bits: int = 8,
        sample: int = 65_536,
        iters: int = 16,
        seed: int = 0,
    ) -> ProductQuantizer:
        """Train a :class:`~repro.codes.ProductQuantizer` on this index's
        live rows and encode every segment (staged; durable after
        :meth:`commit`, versioned in the manifest like ``shard_plan``).

        Once enabled, later appends and compactions re-encode their new
        segments automatically, and ``search(layout="auto")`` may pick the
        ``scan_codes`` layout (ADC scan + exact rerank) when the cost model
        prices it cheaper — ``search(layout="scan_codes")`` forces it.

        Raises:
          ValueError: no live rows to train on, or ``dim`` is not
            divisible by ``m``.
        """
        segs = self.segments
        parts = []
        for seg in segs:
            ids = seg.host_ids()
            parts.append(seg.host_vecs()[ids >= 0])
        train = (
            np.concatenate(parts) if parts
            else np.empty((0, self.dim), np.float32)
        )
        if train.shape[0] == 0:
            raise ValueError("enable_codes needs at least one indexed row")
        with get_tracer().span("index.enable_codes", rows=train.shape[0],
                               m=m, bits=bits):
            self.quantizer = ProductQuantizer.train(
                train, m=m, bits=bits, seed=seed, sample=sample, iters=iters
            )
            self._codes = {
                seg.name: self.quantizer.encode(seg.host_vecs())
                for seg in segs
            }
        self._codes_paths = {}
        self._codes_dirty = True
        return self.quantizer

    def codes_stats(self) -> dict | None:
        """Footprint of the compressed tier, or ``None`` when disabled."""
        pq = self.quantizer
        if pq is None:
            return None
        return {
            "code_m": pq.m,
            "code_bits": pq.bits,
            "bytes_per_row": pq.bytes_per_row,
            "raw_bytes_per_row": 4 * self.dim,
            "compression_ratio": pq.compression_ratio(),
            "codebook_bytes": pq.codebook_bytes,
        }

    @property
    def rows(self) -> int:
        """Live (searchable) descriptor rows: valid minus tombstoned."""
        return sum(s.valid_rows for s in self.segments) - len(self._tombstones)

    @property
    def meta(self) -> dict:
        """User extra merged with the derived structure/stats keys the old
        ``persist.load_index`` manifest carried."""
        out = dict(self._user_meta)
        out.update(self._tree_meta())
        out.update(
            rows=sum(s.rows for s in self.segments),
            valid_rows=sum(s.valid_rows for s in self.segments),
            live_rows=self.rows,
            n_shards=data_axis_size(self.mesh),
            n_segments=self.n_segments,
            n_tombstones=int(len(self._tombstones)),
            next_id=self._next_id,
            version=self._version,
        )
        return out

    def stats(self) -> dict:
        return dict(
            self.meta,
            segments=[s.stats() for s in self.segments],
            staged=list(self.staged_segments),
        )

    def _tree_meta(self) -> dict:
        return {
            "n_leaves": int(self.tree.n_leaves),
            "n_levels": len(self.tree.levels),
            "fanouts": [int(f) for f in self.tree.fanouts],
            "dim": int(self.tree.dim),
            "wire_dtype": str(jnp.dtype(self.wire_dtype)),
        }

    def _manifest(
        self,
        tombstones_rel: str | None = None,
        *,
        version: int | None = None,
        segments: Sequence[Segment] | None = None,
        shard_plan: ShardPlan | None = None,
        codes_paths: dict | None = None,
    ) -> Manifest:
        segs = self._committed if segments is None else segments
        return Manifest(
            version=self._version if version is None else version,
            segments=[s.name for s in segs],
            tombstones=tombstones_rel,
            next_id=self._next_id,
            meta=self._user_meta,
            shard_plan=shard_plan.to_json() if shard_plan else None,
            calibration=(
                self.calibration.to_json() if len(self.calibration) else None
            ),
            codes=self._codes_payload(segs, codes_paths),
        )

    def _codes_payload(
        self, segments: Sequence[Segment], paths: dict | None = None
    ) -> dict | None:
        if self.quantizer is None:
            return None
        paths = self._codes_paths if paths is None else paths
        return {
            "format": CODES_FORMAT,
            "quantizer": self.quantizer.to_json(),
            "segments": {
                s.name: paths[s.name] for s in segments if s.name in paths
            },
        }

    def _plan_for(self, segments: Sequence[Segment]) -> ShardPlan | None:
        """The bound shard plan updated to ``segments``: unchanged when it
        still covers them, re-derived (same strategy, same shard count)
        after an append/compact changed the segment set. Explicit plans
        cannot follow a changed set and are dropped."""
        p = self._shard_plan
        if p is None:
            return None
        names = [s.name for s in segments]
        if p.covers(names):
            return p
        if p.strategy == "round_robin":
            return ShardPlan.round_robin(names, p.n_shards)
        if p.strategy == "balanced":
            return ShardPlan.balanced(
                names, [s.valid_rows for s in segments], p.n_shards
            )
        return None

    # -- write path ---------------------------------------------------------
    def _segments_dir(self) -> str:
        return os.path.join(self.directory, manifest_lib.SEGMENTS_SUBDIR)

    def _next_name(self) -> str:
        if self.directory:
            return segment_name(next_seq(self._segments_dir()))
        self._mem_seq += 1
        return segment_name(self._mem_seq)

    def _existing_ids(self, within: np.ndarray | None = None) -> np.ndarray:
        """Indexed descriptor ids, pruned to segments whose [min_id,
        max_id] range can overlap ``within`` — membership probes (delete,
        collision checks) skip segments that cannot possibly match."""
        segs = self.segments
        if within is not None and within.size:
            segs = [s for s in segs if s.overlaps(within)]
        parts = [s.host_ids() for s in segs]
        if not parts:
            return np.empty((0,), np.int64)
        ids = np.concatenate(parts)
        return ids[ids >= 0]

    def append(
        self,
        vecs,
        ids=None,
        *,
        wave_rows: int | None = None,
        capacity_factor: float = 2.0,
    ) -> str:
        """Assign + route + cluster-sort ``vecs`` into a new immutable
        segment (staged; durable after :meth:`commit`).

        Assignment runs in waves through ``build_index_fn`` exactly like a
        one-shot build, so an index grown by appends is the same index a
        monolithic job would have produced.

        Args:
          vecs: ``(n, dim)`` descriptor rows (cast to float32).
          ids: explicit non-negative descriptor ids; default is the next
            contiguous range of the global id space.
          wave_rows: assignment wave size (default: auto-snapped).
          capacity_factor: routing headroom for skewed leaves.

        Returns:
          The staged segment's name.

        Raises:
          ValueError: wrong shape, zero rows, negative/duplicate/
            colliding ids, or an id past the int32 id space.
        """
        vecs = np.asarray(vecs, np.float32)
        if vecs.ndim != 2 or vecs.shape[1] != self.dim:
            raise ValueError(
                f"append expects (n, {self.dim}) rows; got {vecs.shape}"
            )
        n = vecs.shape[0]
        if n == 0:
            raise ValueError("append of zero rows")
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + n, dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            if ids.shape != (n,):
                raise ValueError(f"ids shape {ids.shape} != ({n},)")
            if ids.size and ids.min() < 0:
                raise ValueError("descriptor ids must be non-negative")
            if len(np.unique(ids)) != n:
                raise ValueError("duplicate ids within the appended batch")
            if ids.min() < self._next_id and np.isin(
                ids, self._existing_ids(within=ids)
            ).any():
                raise ValueError("appended ids collide with indexed ids")
        if int(ids.max()) > np.iinfo(np.int32).max:
            # the engine carries ids as int32; a wrapped id would silently
            # become padding (-1 family) and the row would vanish
            raise ValueError(
                f"descriptor id {int(ids.max())} exceeds int32 — the id "
                "space is full; compact() after deletes or re-id the corpus"
            )
        with get_tracer().span("index.append", rows=n):
            built = build_index(
                jnp.asarray(vecs),
                self.tree,
                self.mesh,
                ids=jnp.asarray(ids.astype(np.int32)),
                wave_rows=wave_rows,
                capacity_factor=capacity_factor,
                wire_dtype=self.wire_dtype,
            )
            jax.block_until_ready(built.vecs)
            name = self.append_built(built)
        reg = get_registry()
        reg.counter("index.appends").inc()
        reg.counter("index.rows_appended").inc(n)
        return name

    def append_built(self, built: DistributedIndex, *, name=None) -> str:
        """Adopt an already-built ``DistributedIndex`` as a staged segment
        (the ``save_index`` shim and the legacy session path use this)."""
        if int(built.n_leaves) != self.n_leaves:
            raise ValueError(
                f"built index has {built.n_leaves} leaves; tree has "
                f"{self.n_leaves}"
            )
        if self.segments and built.offsets.shape[0] != self.segments[0].n_shards:
            raise ValueError(
                f"built index has {built.offsets.shape[0]} shards; index "
                f"segments have {self.segments[0].n_shards}"
            )
        seg = Segment.from_built(name or self._next_name(), built)
        if self.directory:
            seg.save(self._segments_dir())  # durable *before* it is staged
        self._staged.append(seg)
        if self.quantizer is not None:
            # the codes tier follows every append: encode the new segment's
            # padded rows (pad rows carry the LEAF_SENTINEL and never match)
            self._codes[seg.name] = self.quantizer.encode(seg.host_vecs())
            self._codes_dirty = True
        self._next_id = max(self._next_id, seg.max_id + 1)
        self._views = None
        return seg.name

    def update_meta(self, **kw) -> None:
        """Stage user-metadata updates (e.g. an ingest cursor); durable at
        the next :meth:`commit` alongside whatever else is staged."""
        self._user_meta.update(kw)
        self._meta_dirty = True

    def delete(self, ids) -> int:
        """Tombstone descriptor ids (staged; durable after :meth:`commit`).

        Args:
          ids: descriptor ids to delete; absent or already-deleted ids
            are ignored (idempotent).

        Returns:
          How many ids were *newly* tombstoned. Tombstoned rows stop
          matching immediately for this handle and are physically
          dropped at the next :meth:`compact`.
        """
        ids = np.unique(np.asarray(ids, np.int64))
        ids = ids[~np.isin(ids, self._tombstones)]
        if ids.size:
            ids = ids[np.isin(ids, self._existing_ids(within=ids))]
        if ids.size == 0:
            return 0
        self._tombstones = np.sort(np.concatenate([self._tombstones, ids]))
        self._tombstones_dirty = True
        self._views = None
        get_registry().counter("index.tombstoned").inc(int(ids.size))
        return int(ids.size)

    def commit(self) -> int:
        """Publish staged segments + tombstones + metadata + shard plan +
        cost-model calibration: one atomic manifest bump.

        Idempotent — committing with nothing staged returns the current
        version without writing. A crash *before* the manifest rename
        leaves the previous committed state fully intact (staged segment
        checkpoints become ignorable orphans); a crash *after* it leaves
        the new state fully committed. There is no in-between. A bound
        shard plan that no longer covers the staged segment set is
        re-derived (same strategy) in the same bump.

        Returns:
          The committed manifest version.

        Raises:
          FileExistsError: another handle committed this version
            concurrently (exclusive publication) — reopen and retry.
          OSError: the durable write failed; the handle stays staged so
            a retried ``commit()`` re-attempts publication.
        """
        if not (self._staged or self._tombstones_dirty or self._meta_dirty
                or self._shard_plan_dirty or self._codes_dirty
                or self.calibration.dirty):
            return self._version
        # durable writes FIRST, memory state only after they succeed — a
        # failed write leaves the handle still-staged, so a retried
        # commit() re-attempts the publication instead of no-opping
        version = self._version + 1
        segments = self._committed + self._staged
        plan = self._plan_for(segments)
        with get_tracer().span("index.commit", version=version,
                               staged=len(self._staged)):
            if self.directory:
                rel = None
                if len(self._tombstones):
                    rel = manifest_lib.write_tombstones(
                        self.directory, version, self._tombstones
                    )
                if self.quantizer is not None:
                    # code files are durable *before* the manifest that
                    # references them, same as segments and tombstones
                    for seg in segments:
                        if seg.name not in self._codes_paths:
                            self._codes_paths[seg.name] = (
                                manifest_lib.write_codes(
                                    self.directory, seg.name,
                                    self._codes[seg.name],
                                )
                            )
                manifest_lib.write(
                    self.directory,
                    self._manifest(rel, version=version, segments=segments,
                                   shard_plan=plan),
                )
        get_registry().counter("index.commits").inc()
        self._version = version
        self._committed = segments
        self._staged = []
        self._shard_plan = plan
        self._tombstones_dirty = False
        self._meta_dirty = False
        self._shard_plan_dirty = False
        self._codes_dirty = False
        self.calibration.mark_clean()
        return version

    def compact(self) -> str | None:
        """Merge every segment into one, dropping tombstoned rows.

        Surviving rows are re-sorted by descriptor id before the rebuild,
        so the compacted segment is the index a from-scratch
        ``build_index`` over the remaining corpus (in original append
        order) would produce — arrays and all. Commits atomically; old
        segment checkpoints are garbage-collected only after the manifest
        bump; a bound derivable shard plan is re-derived over the single
        new segment (explicit plans are dropped).

        Returns:
          The new segment's name, or ``None`` for an index with no live
          rows.

        Raises:
          FileExistsError: a concurrent commit won the version race.
          Exception: a failed rebuild/write propagates with segments AND
            tombstones exactly as committed (no resurrection, no loss).
        """
        tr = get_tracer()
        t_start = tr.now() if tr.enabled else 0.0
        old = self.segments
        keep_v, keep_i = [], []
        for seg in old:
            ids = np.asarray(seg.index.ids).astype(np.int64)
            live = ids >= 0
            if self._tombstones.size:
                live &= ~np.isin(ids, self._tombstones)
            keep_v.append(np.asarray(seg.index.vecs)[live])
            keep_i.append(ids[live])
        all_v = np.concatenate(keep_v) if keep_v else np.empty((0, self.dim))
        all_i = (
            np.concatenate(keep_i) if keep_i else np.empty((0,), np.int64)
        )
        order = np.argsort(all_i, kind="stable")
        # build + durably publish first; the handle's state is only
        # replaced once the new manifest exists, so a failed rebuild
        # leaves segments AND tombstones exactly as they were
        if all_i.size == 0:
            new_committed = []
        else:
            built = build_index(
                jnp.asarray(all_v[order], jnp.float32),
                self.tree,
                self.mesh,
                ids=jnp.asarray(all_i[order].astype(np.int32)),
                wire_dtype=self.wire_dtype,
            )
            jax.block_until_ready(built.vecs)
            seg = Segment.from_built(self._next_name(), built)
            if self.directory:
                seg.save(self._segments_dir())
            new_committed = [seg]
        new_codes, new_codes_paths = self._codes, self._codes_paths
        if self.quantizer is not None:
            # the quantizer survives compaction unchanged (codebooks are
            # trained, not positional); only the codes are re-encoded for
            # the merged segment's new row order
            new_codes = {
                s.name: self.quantizer.encode(s.host_vecs())
                for s in new_committed
            }
            new_codes_paths = {}
            if self.directory:
                new_codes_paths = {
                    name: manifest_lib.write_codes(self.directory, name, c)
                    for name, c in new_codes.items()
                }
        version = self._version + 1
        plan = self._plan_for(new_committed)
        if self.directory:
            manifest_lib.write(
                self.directory,
                self._manifest(None, version=version,
                               segments=new_committed, shard_plan=plan,
                               codes_paths=new_codes_paths),
            )
        self._committed = new_committed
        self._staged = []
        self._shard_plan = plan
        self._shard_plan_dirty = False
        self._tombstones = np.empty((0,), np.int64)
        self._tombstones_dirty = False
        self._meta_dirty = False
        self._codes = new_codes
        self._codes_paths = new_codes_paths
        self._codes_dirty = False
        self.calibration.mark_clean()
        self._version = version
        self._views = None
        if self.directory:
            self._gc_segments(old)
        if tr.enabled:
            tr.add_span(
                "index.compact", t_start, tr.now(),
                segments_in=len(old), rows_out=int(all_i.size),
                version=version,
            )
        get_registry().counter("index.compacts").inc()
        return new_committed[0].name if new_committed else None

    def _gc_segments(self, old: Sequence[Segment]) -> None:
        live = {s.name for s in self._committed}
        for seg in old:
            if seg.name in live:
                continue
            shutil.rmtree(
                os.path.join(self._segments_dir(), seg.name),
                ignore_errors=True,
            )
            try:
                os.remove(os.path.join(
                    self.directory, manifest_lib.CODES_SUBDIR,
                    f"{seg.name}.npy",
                ))
            except OSError:
                pass

    # -- read path ----------------------------------------------------------
    def read_rows(self, ids) -> np.ndarray:
        """Host gather of stored descriptor vectors by id — the corpus
        rows live inside the segments, so anything that consumes a
        ``read_rows``/``dim`` block store (e.g. the serving trace
        generator) can read straight from the index; a grown ``--index-
        dir`` needs no separate ``corpus/`` store. Probes each
        range-overlapping segment through its cached id index — no
        resident concatenated corpus copy is built.

        Tombstoned ids read as missing *immediately* (not only after the
        compaction that physically drops them), so the result never
        depends on compaction timing.

        Requested ids may repeat and arrive in any order: probes are
        deduplicated to one *sorted* unique set, each segment is gathered
        at most once, and results scatter back to the request order — the
        rerank fetch path hands whole candidate tables here without
        pre-sorting."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        if ids.size and ids.min() < 0:
            # never let a requested -1 match a padding row's -1 id
            raise IndexError(f"descriptor ids must be >= 0; got {ids.min()}")
        if ids.size == 0:
            return np.empty((0, self.dim), np.float32)
        uniq, inverse = np.unique(ids, return_inverse=True)
        u_out = np.empty((uniq.size, self.dim), np.float32)
        u_found = np.zeros(uniq.size, bool)
        for seg in self.segments:
            if u_found.all() or not seg.overlaps(uniq):
                continue
            sorted_ids, order = seg.id_index()
            pos = np.searchsorted(sorted_ids, uniq)
            hit = (
                ~u_found
                & (pos < sorted_ids.size)
                & (sorted_ids[np.minimum(pos, sorted_ids.size - 1)] == uniq)
            )
            if hit.any():
                u_out[hit] = seg.host_vecs()[order[pos[hit]]]
                u_found |= hit
        if self._tombstones.size:
            u_found &= ~np.isin(uniq, self._tombstones)
        if not u_found.all():
            found = u_found[inverse]
            missing = ids[~found]
            raise IndexError(
                f"descriptor ids not in the index (absent or deleted): "
                f"{missing[:8].tolist()}"
                + ("..." if missing.size > 8 else "")
            )
        return u_out[inverse]

    def segment_views(self) -> tuple[DistributedIndex, ...]:
        """Per-segment indexes with tombstones masked (cached until the
        next append/delete/compact)."""
        if self._views is None:
            self._views = tuple(
                masked_view(s, self._tombstones) for s in self.segments
            )
        return self._views

    def search(
        self,
        queries,
        k: int = 10,
        *,
        plan: SearchPlan | None = None,
        layout: str = "auto",
        probes: int = 1,
        impl: str = "xla",
        block_rows: int | None = None,
        q_cap: int | None = None,
        q_tile: int | None = None,
        p_cap: int | None = None,
        rerank: int | None = None,
        cost_model="auto",
        use_observations: bool | None = None,
    ) -> SearchResult:
        """k-NN over every live row: one shared lookup build, one executor
        run per segment, one ascending-distance merge across segments.

        Args:
          queries: ``(q, dim)`` query rows (cast to float32).
          k: neighbours per query.
          plan: optional :class:`SearchPlan` template whose fields
            (layout, k, probes, impl, budgets) override the keyword
            arguments; budgets are still re-resolved per segment, since
            tile sizes must divide each segment's shard rows.
          layout/probes/impl/block_rows/q_cap/q_tile/p_cap: per-call plan
            knobs, as in :func:`repro.core.engine.plan`. ``layout`` also
            accepts ``"scan_codes"`` (ADC scan over PQ codes + exact
            rerank) once :meth:`enable_codes` has run; ``"auto"`` lets
            the cost model pick the codes tier on its own.
          rerank: ADC candidates per query to fetch + exactly rerank for
            the ``scan_codes`` layout (default from
            :func:`~repro.core.engine.plan.default_rerank`).
          cost_model: which model ranks an ``"auto"`` layout (``"auto"``
            / ``"heuristic"`` / ``"observed"`` / ``"fitted"``), consulting
            *this index's* manifest-persisted calibration store.
          use_observations: deprecated spelling of
            ``cost_model="observed"`` (see :func:`repro.core.engine.plan`).

        Returns:
          A :class:`SearchResult`: ``(q, k)`` ids (``-1`` where fewer
          than ``k`` live rows matched) and squared-L2 dists (``inf``
          there), plus exact pairs/overflow counters. Dense layouts are
          bit-identical to a one-shot build+search over the concatenated
          live rows; ``scan_codes`` returns the exact-reranked top-k of
          the ADC candidate set (approximate recall, exact ordering).

        Raises:
          ValueError: invalid plan knobs (see
            :func:`repro.core.engine.plan`), or
            ``layout="scan_codes"`` without :meth:`enable_codes`.
        """
        if plan is not None:
            layout, k, probes, impl = plan.layout, plan.k, plan.probes, plan.impl
            block_rows = plan.block_rows if block_rows is None else block_rows
            q_cap = plan.q_cap if q_cap is None else q_cap
            q_tile = plan.q_tile if q_tile is None else q_tile
            p_cap = plan.p_cap if p_cap is None else p_cap
            rerank = plan.rerank if rerank is None else rerank
        queries = jnp.asarray(queries, jnp.float32)
        q = queries.shape[0]
        views = self.segment_views()
        if not views:
            return SearchResult(
                ids=jnp.full((q, k), -1, jnp.int32),
                dists=jnp.full((q, k), jnp.inf, jnp.float32),
                pairs=jnp.zeros((), jnp.float32),
                q_cap_overflow=jnp.zeros((), jnp.int32),
            )
        n_shards = data_axis_size(self.mesh)
        # ADC distances are approximations, incomparable with the dense
        # layouts' exact partial distances, so the codes-vs-exact decision
        # is resolved ONCE on the aggregate shape — per-segment plans then
        # all run the same tier and the cross-segment merge stays sound
        if layout == "scan_codes" and self.quantizer is None:
            raise ValueError(
                "layout='scan_codes' needs PQ codes; call "
                "enable_codes() first"
            )
        use_codes = False
        if self.quantizer is not None and layout in ("auto", "scan_codes"):
            agg = make_plan(
                rows=sum(v.rows for v in views),
                n_leaves=self.n_leaves, n_queries=q, n_shards=n_shards,
                k=k, probes=probes, layout=layout, impl=impl,
                model=cost_model, calibration=self.calibration,
                use_observations=use_observations,
                dim=self.dim, rerank=rerank,
                code_m=self.quantizer.m, code_bits=self.quantizer.bits,
            )
            use_codes = agg.layout == "scan_codes"
        lookup = jit_build_lookup(self.tree, queries, probes=probes)
        per = []
        for seg, view in zip(self.segments, views):
            if use_codes:
                p = make_plan(
                    rows=view.rows, n_leaves=self.n_leaves, n_queries=q,
                    n_shards=n_shards, k=k, probes=probes,
                    layout="scan_codes", impl=impl, block_rows=block_rows,
                    q_cap=q_cap, model=cost_model,
                    calibration=self.calibration,
                    dim=self.dim, rerank=rerank,
                    code_m=self.quantizer.m, code_bits=self.quantizer.bits,
                )
                per.append(search_with_lookup(
                    view, lookup, p, self.mesh, n_queries=q,
                    codes=self._codes[seg.name],
                    codebooks=self.quantizer.codebooks,
                ))
                continue
            p = make_plan(
                rows=view.rows,
                n_leaves=self.n_leaves,
                n_queries=q,
                n_shards=n_shards,
                k=k,
                probes=probes,
                layout=layout,
                impl=impl,
                block_rows=block_rows,
                q_cap=q_cap,
                q_tile=q_tile,
                p_cap=p_cap,
                model=cost_model,
                calibration=self.calibration,
                use_observations=use_observations,
            )
            per.append(
                search_with_lookup(view, lookup, p, self.mesh, n_queries=q)
            )
        if use_codes:
            r_max = max(r.ids.shape[1] for r in per)
            cand = per[0] if len(per) == 1 else _merge_results(per, r_max)
            cand_ids = np.asarray(cand.ids)
            with get_tracer().span("engine.rerank", k=k,
                                   candidates=int(cand_ids.shape[1])):
                ids_r, dists_r = rerank_exact(
                    self.read_rows, np.asarray(queries), cand_ids, k
                )
            return SearchResult(
                ids=jnp.asarray(ids_r),
                dists=jnp.asarray(dists_r),
                pairs=cand.pairs,
                q_cap_overflow=cand.q_cap_overflow,
            )
        if len(per) == 1:
            return per[0]
        return _merge_results(per, k)


def _merge_results(per: Sequence[SearchResult], k: int) -> SearchResult:
    """Fold per-segment k-NN tables into one — the same ascending-distance
    merge the executors apply across shards (stable on ties, so
    segment-major order mirrors the one-shot table's candidate order)."""
    all_i = np.concatenate([np.asarray(r.ids) for r in per], axis=1)
    all_d = np.concatenate([np.asarray(r.dists) for r in per], axis=1)
    sel = np.argsort(all_d, axis=1, kind="stable")[:, :k]
    return SearchResult(
        ids=jnp.asarray(np.take_along_axis(all_i, sel, axis=1)),
        dists=jnp.asarray(np.take_along_axis(all_d, sel, axis=1)),
        pairs=sum(r.pairs for r in per),
        q_cap_overflow=sum(r.q_cap_overflow for r in per),
    )
