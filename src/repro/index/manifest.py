"""Versioned index manifests: the commit log of a segment-based index.

A manifest is one JSON file, ``MANIFEST-<version>.json``, naming the exact
set of committed segments, the current tombstone file, and the id
allocator's high-water mark. Commits follow the CheckpointManager pattern
(write ``*.tmp``, then one atomic ``os.replace``), so a crash mid-commit
leaves at worst an ignorable ``.tmp`` and the previous manifest intact:
``latest()`` always resolves to the highest *complete* version. Segment
checkpoints and tombstone files are written *before* the manifest that
references them — an interrupted ``append``/``delete`` leaves orphan files
that no manifest names and that ``Index.open`` therefore never sees.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re

import numpy as np

_MANIFEST_RE = re.compile(r"^MANIFEST-(\d{6})\.json$")

SEGMENTS_SUBDIR = "segments"
TOMBSTONES_SUBDIR = "tombstones"
TREE_SUBDIR = "tree"
CODES_SUBDIR = "codes"

FORMAT_VERSION = 1


@dataclasses.dataclass
class Manifest:
    """One committed state of the index."""

    version: int
    segments: list[str]  # committed segment names, append order
    tombstones: str | None  # relative path of the tombstone .npy, if any
    next_id: int  # id allocator high-water mark
    meta: dict  # user extra + static structure (fanouts, dim, ...)
    # serialized repro.index.sharding.ShardPlan (scatter-gather serving);
    # absent on pre-sharding manifests, so from_json defaults it
    shard_plan: dict | None = None
    # serialized repro.core.engine.costmodel.CalibrationStore (measured
    # ms/image per plan signature, the cost-model calibration data);
    # versioned like shard_plan — absent on pre-calibration manifests
    calibration: dict | None = None
    # compressed-codes tier (repro.codes): the serialized ProductQuantizer
    # plus per-segment relative paths of the uint8 code files; versioned
    # like shard_plan — absent on pre-codes manifests and on indexes that
    # never called enable_codes
    codes: dict | None = None

    def to_json(self) -> dict:
        return {
            "format": FORMAT_VERSION,
            "version": self.version,
            "segments": list(self.segments),
            "tombstones": self.tombstones,
            "next_id": int(self.next_id),
            "meta": dict(self.meta),
            "shard_plan": self.shard_plan,
            "calibration": self.calibration,
            "codes": self.codes,
        }

    @classmethod
    def from_json(cls, d: dict) -> "Manifest":
        return cls(
            version=int(d["version"]),
            segments=list(d["segments"]),
            tombstones=d.get("tombstones"),
            next_id=int(d.get("next_id", 0)),
            meta=dict(d.get("meta", {})),
            shard_plan=d.get("shard_plan"),
            calibration=d.get("calibration"),
            codes=d.get("codes"),
        )


def manifest_path(directory: str, version: int) -> str:
    return os.path.join(directory, f"MANIFEST-{version:06d}.json")


def list_versions(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _MANIFEST_RE.match(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest(directory: str) -> Manifest | None:
    """The highest complete (parseable) manifest, or ``None``.

    A truncated manifest cannot exist under the exclusive-link protocol,
    but a corrupt one must not take the versions below it down with it —
    walk downward to the newest readable state. Only corruption (bad
    JSON/fields) and concurrent removal are tolerated; other IO errors
    (permissions, EIO) propagate rather than silently serving stale data.
    """
    for version in reversed(list_versions(directory)):
        try:
            with open(manifest_path(directory, version)) as f:
                return Manifest.from_json(json.load(f))
        except (json.JSONDecodeError, KeyError, ValueError,
                FileNotFoundError):
            continue
    return None


def write(directory: str, manifest: Manifest) -> str:
    """Atomically *and exclusively* publish ``manifest``.

    ``os.link`` of the fsynced tmp file is both atomic (the complete file
    appears or nothing does) and exclusive (it fails with
    ``FileExistsError`` if the version was already published) — so two
    handles racing to commit the same next version cannot silently
    overwrite each other's manifest and orphan committed segments; the
    loser gets an error and must re-open. The one benign collision — the
    same handle retrying a commit that crashed *after* the link landed —
    re-publishes identical bytes (``json.dump`` is deterministic over the
    same state) and passes through.
    """
    final = manifest_path(directory, manifest.version)
    tmp = final + ".tmp"
    payload = json.dumps(manifest.to_json(), indent=1)
    with open(tmp, "w") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    try:
        os.link(tmp, final)
    except FileExistsError:
        with open(final) as f:
            if f.read() == payload:
                return final  # same handle retrying an interrupted commit
        raise FileExistsError(
            f"manifest version {manifest.version} already exists in "
            f"{directory} — another handle committed concurrently; reopen "
            "the index and retry"
        ) from None
    finally:
        os.unlink(tmp)
    return final


def write_tombstones(directory: str, version: int, ids: np.ndarray) -> str:
    """Persist the tombstone set for ``version``; returns the relative path.

    Written *before* the manifest that references it — an orphaned file
    from a crashed commit is ignored by every open. Publication is
    exclusive like the manifest's: a losing concurrent committer must not
    clobber the winner's already-linked tombstone file. The one benign
    collision — the same handle retrying a commit whose manifest write
    failed — re-publishes identical bytes and passes through.
    """
    sub = os.path.join(directory, TOMBSTONES_SUBDIR)
    os.makedirs(sub, exist_ok=True)
    payload = np.asarray(sorted(int(i) for i in ids), np.int64)
    rel = os.path.join(TOMBSTONES_SUBDIR, f"ts_{version:06d}.npy")
    final = os.path.join(directory, rel)
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        np.save(f, payload)
        f.flush()
        os.fsync(f.fileno())
    try:
        os.link(tmp, final)
    except FileExistsError:
        if np.array_equal(np.load(final), payload):
            return rel  # same handle retrying an interrupted commit
        raise FileExistsError(
            f"tombstone set for version {version} already exists in "
            f"{directory} with different contents — another handle "
            "committed concurrently; reopen the index and retry"
        ) from None
    finally:
        os.unlink(tmp)
    return rel


def write_codes(directory: str, name: str, codes: np.ndarray) -> str:
    """Persist one segment's ``(rows, m)`` uint8 PQ codes; returns the
    relative path.

    Same durability contract as :func:`write_tombstones`: written *before*
    the manifest that references it, fsynced, published with an exclusive
    ``os.link``. Segment names are never reused (``next_seq`` reserves
    orphans), so the only collision is the same handle retrying an
    interrupted commit — identical bytes pass through.
    """
    sub = os.path.join(directory, CODES_SUBDIR)
    os.makedirs(sub, exist_ok=True)
    payload = np.ascontiguousarray(codes, np.uint8)
    rel = os.path.join(CODES_SUBDIR, f"{name}.npy")
    final = os.path.join(directory, rel)
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        np.save(f, payload)
        f.flush()
        os.fsync(f.fileno())
    try:
        os.link(tmp, final)
    except FileExistsError:
        if np.array_equal(np.load(final), payload):
            return rel  # same handle retrying an interrupted commit
        raise FileExistsError(
            f"codes file for segment {name} already exists in {directory} "
            "with different contents — another handle committed "
            "concurrently; reopen the index and retry"
        ) from None
    finally:
        os.unlink(tmp)
    return rel


def read_codes(directory: str, rel_path: str) -> np.ndarray:
    return np.load(os.path.join(directory, rel_path)).astype(np.uint8)


def read_tombstones(directory: str, rel_path: str | None) -> np.ndarray:
    if not rel_path:
        return np.empty((0,), np.int64)
    return np.load(os.path.join(directory, rel_path)).astype(np.int64)
