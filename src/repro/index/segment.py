"""Immutable index segments.

A segment is one cluster-sorted :class:`~repro.core.index_build.
DistributedIndex` — the output of one ``append`` wave batch (or of a
compaction) — persisted as a single CheckpointManager checkpoint
(mesh-free on disk, crc-checked, atomic). Segments are written once and
never mutated; deletions are expressed as tombstones in the manifest and
applied as an id mask at search time (a masked row behaves exactly like the
pipeline's own padding rows: routed, scanned, never matched).
"""

from __future__ import annotations

import dataclasses
import os
import re

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.index_build import DistributedIndex
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.meshutil import batch_axes

_SEGMENT_RE = re.compile(r"^seg_(\d{6})$")


def segment_name(seq: int) -> str:
    return f"seg_{seq:06d}"


def next_seq(segments_dir: str) -> int:
    """1 + the highest segment sequence number present on disk — committed
    or orphaned. Orphans (crash between append and commit) keep their name
    reserved so a retried append never collides with them."""
    if not os.path.isdir(segments_dir):
        return 1
    seqs = [
        int(m.group(1))
        for name in os.listdir(segments_dir)
        if (m := _SEGMENT_RE.match(name))
    ]
    return max(seqs, default=0) + 1


def _index_shardings(mesh: Mesh):
    ax = batch_axes(mesh)
    rows = NamedSharding(mesh, P(ax, None))
    flat = NamedSharding(mesh, P(ax))
    rep = NamedSharding(mesh, P())
    return {
        "index": DistributedIndex(
            vecs=rows, ids=flat, leaves=flat, offsets=rows, n_valid=flat,
            overflow=rep,
        )
    }


@dataclasses.dataclass
class Segment:
    """One immutable segment plus its static stats."""

    name: str
    index: DistributedIndex
    rows: int  # padded row count (index.rows)
    valid_rows: int  # rows with a real descriptor id
    min_id: int  # -1 when empty
    max_id: int  # -1 when empty
    # L2 norm range of the *valid* rows — the dense-tier pruning bound
    # (docs/dynamicity.md). -1.0 = unknown (segment written before these
    # stats existed, or empty); pruning is skipped for such segments.
    min_norm: float = -1.0
    max_norm: float = -1.0
    _ids_np: object = dataclasses.field(default=None, repr=False,
                                        compare=False)
    _id_index: object = dataclasses.field(default=None, repr=False,
                                          compare=False)
    _vecs_np: object = dataclasses.field(default=None, repr=False,
                                         compare=False)

    def host_ids(self) -> np.ndarray:
        """Host copy of the segment's id column (cached — segments are
        immutable). ``-1`` padding rows included, callers filter."""
        if self._ids_np is None:
            self._ids_np = np.asarray(self.index.ids).astype(np.int64)
        return self._ids_np

    def id_index(self) -> tuple[np.ndarray, np.ndarray]:
        """Cached ``(sorted_ids, row_order)`` for id->row probes. Padding
        ``-1`` ids sort first and never match a probed (non-negative) id."""
        if self._id_index is None:
            ids = self.host_ids()
            order = np.argsort(ids, kind="stable")
            self._id_index = (ids[order], order)
        return self._id_index

    def host_vecs(self) -> np.ndarray:
        """Host copy of the stored vectors (cached — on an accelerator
        backend the device-to-host transfer must not repeat per read)."""
        if self._vecs_np is None:
            self._vecs_np = np.asarray(self.index.vecs, np.float32)
        return self._vecs_np

    def overlaps(self, ids: np.ndarray) -> bool:
        """Can any of ``ids`` (non-empty) live in this segment?"""
        return (
            self.valid_rows > 0
            and int(ids.min()) <= self.max_id
            and int(ids.max()) >= self.min_id
        )

    @classmethod
    def from_built(cls, name: str, index: DistributedIndex) -> "Segment":
        ids = np.asarray(index.ids)
        valid = ids >= 0
        real = ids[valid]
        if real.size:
            norms = np.linalg.norm(
                np.asarray(index.vecs, np.float32)[valid].astype(np.float64),
                axis=1,
            )
            min_norm, max_norm = float(norms.min()), float(norms.max())
        else:
            min_norm = max_norm = -1.0
        return cls(
            name=name,
            index=index,
            rows=int(index.rows),
            valid_rows=int(real.size),
            min_id=int(real.min()) if real.size else -1,
            max_id=int(real.max()) if real.size else -1,
            min_norm=min_norm,
            max_norm=max_norm,
        )

    @property
    def n_shards(self) -> int:
        return int(self.index.offsets.shape[0])

    def stats(self) -> dict:
        return {
            "name": self.name,
            "rows": self.rows,
            "valid_rows": self.valid_rows,
            "min_id": self.min_id,
            "max_id": self.max_id,
            "min_norm": self.min_norm,
            "max_norm": self.max_norm,
            "n_shards": self.n_shards,
        }

    # -- persistence --------------------------------------------------------
    def save(self, segments_dir: str) -> str:
        mgr = CheckpointManager(os.path.join(segments_dir, self.name), keep=1)
        return mgr.save(
            0,
            {"index": self.index},
            extra=dict(
                self.stats(),
                n_leaves=int(self.index.n_leaves),
                dim=int(self.index.vecs.shape[-1]),
            ),
        )

    @classmethod
    def load(cls, segments_dir: str, name: str, mesh: Mesh) -> "Segment":
        mgr = CheckpointManager(os.path.join(segments_dir, name), keep=1)
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"segment {name} has no complete checkpoint under "
                f"{segments_dir}"
            )
        meta = mgr.read_manifest(step)["extra"]
        skeleton = {
            "index": DistributedIndex(
                vecs=0.0, ids=0, leaves=0, offsets=0, n_valid=0, overflow=0,
                n_leaves=int(meta["n_leaves"]),
            )
        }
        tree_out, _ = mgr.restore(skeleton, step,
                                  shardings=_index_shardings(mesh))
        index = tree_out["index"]
        index = DistributedIndex(
            vecs=index.vecs,
            ids=jnp.asarray(index.ids, jnp.int32),
            leaves=jnp.asarray(index.leaves, jnp.int32),
            offsets=jnp.asarray(index.offsets, jnp.int32),
            n_valid=jnp.asarray(index.n_valid, jnp.int32),
            overflow=jnp.asarray(index.overflow, jnp.int32),
            n_leaves=int(meta["n_leaves"]),
        )
        return cls(
            name=name,
            index=index,
            rows=int(meta["rows"]),
            valid_rows=int(meta["valid_rows"]),
            min_id=int(meta.get("min_id", -1)),
            max_id=int(meta.get("max_id", -1)),
            min_norm=float(meta.get("min_norm", -1.0)),
            max_norm=float(meta.get("max_norm", -1.0)),
        )


def dead_counts(segments, tombstones: np.ndarray) -> np.ndarray:
    """Per-segment count of valid rows killed by ``tombstones`` (a sorted
    array of unique ids — each id lives in exactly one segment, so the
    counts partition the tombstone set). Feeds the compaction policy's
    tombstone-ratio trigger and the search-time zero-live-segment prune.
    """
    out = np.zeros(len(segments), np.int64)
    ts = np.asarray(tombstones, np.int64)
    if ts.size == 0:
        return out
    for i, seg in enumerate(segments):
        if not seg.overlaps(ts):
            continue
        sorted_ids, _ = seg.id_index()
        pos = np.searchsorted(sorted_ids, ts)
        hit = (pos < sorted_ids.size) & (
            sorted_ids[np.minimum(pos, sorted_ids.size - 1)] == ts
        )
        out[i] = int(hit.sum())
    return out


# Tombstoned rows keep their leaf (CSR offsets stay valid) but get this
# magnitude written into every vector lane: the partial distance
# ||p||^2 - 2 p.q becomes ~1e30f — finite (no inf/nan propagation into the
# fused scan) yet astronomically above any real candidate, so a dead row
# can never displace a live neighbour from a tile's top-k. Its id is -1, so
# even when it *is* selected (a leaf with fewer than k live rows) scan_tile
# masks it to INVALID_ID/inf — exactly a padding row's fate.
TOMBSTONE_VEC = 1e15


def masked_view(segment: Segment, tombstones: np.ndarray) -> DistributedIndex:
    """The segment's index with tombstoned rows masked out of every scan.

    Bit-identical to rebuilding without the dead rows: live rows'
    distances are untouched, dead rows sort behind every live candidate,
    and a selected dead row degenerates to the ``-1``/``inf`` slot an
    absent row would have produced.
    """
    if tombstones.size == 0 or segment.valid_rows == 0:
        return segment.index
    lo = np.searchsorted(tombstones, segment.min_id)
    hi = np.searchsorted(tombstones, segment.max_id, side="right")
    if lo == hi:
        return segment.index  # no tombstone inside this segment's id range
    ids = segment.index.ids
    vecs = segment.index.vecs
    ts = jnp.asarray(tombstones, jnp.int32)
    pos = jnp.searchsorted(ts, ids)
    hit = (pos < ts.shape[0]) & (ts[jnp.clip(pos, 0, ts.shape[0] - 1)] == ids)
    return dataclasses.replace(
        segment.index,
        ids=jnp.where(hit, jnp.int32(-1), ids),
        vecs=jnp.where(hit[:, None], jnp.asarray(TOMBSTONE_VEC, vecs.dtype),
                       vecs),
    )
