"""Sharded scatter-gather search: partition an :class:`Index` across shards.

The paper's scalability story (§2.4, Fig 5) is distributed search: the 30B-
descriptor collection is split into partitions, map tasks scan partitions
independently, and a reduce step fuses per-partition candidate lists into
the final top-k. :class:`ShardPlan` + :class:`ShardedIndex` are that
workflow over the segment lifecycle: an explicit, manifest-persisted
mapping of the index's immutable segments onto N shards, and a
scatter-gather ``search`` that scans each shard's segments independently
and merges the per-shard candidates.

Exactness. The gather merge is **bit-identical** to the unsharded
``Index.search`` because every candidate carries its *global merge slot*
``segment_ordinal * k + position``: the unsharded merge is a stable
ascending-distance sort over the segment-ordered concatenation, i.e. a
total order by ``(distance, slot)``. Each shard keeps its local top-k
under that same total order (shard-local segment lists preserve global
append order, so a stable local sort *is* slot order), and the top-k of a
union of per-shard top-k lists under a total order equals the top-k of all
candidates. Ties — exact duplicate vectors included — therefore resolve
identically at any shard count.

Parallelism. Per-shard scans reuse the engine's jit-cached executors
(:func:`repro.core.search.search_with_lookup`); the lookup table is built
once and broadcast to every shard (the paper ships it to every map task
via HDFS). With enough devices, :func:`repro.distributed.meshutil.
shard_submeshes` gives each shard its own device group so shard scans run
on disjoint hardware; on one device every shard shares the mesh and runs
sequentially-but-isolated — same results, summed wall time.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.codes import rerank_exact
from repro.core.engine import (
    PlanShapes,
    SearchPlan,
    fitted_component,
    plan as make_plan,
    scale_slab_budget,
    shard_slab_scales,
)
from repro.core.engine.executors import SearchResult
from repro.core.search import jit_build_lookup, search_with_lookup
from repro.distributed.meshutil import data_axis_size, shard_submeshes
from repro.index.segment import dead_counts
from repro.obs import get_registry

STRATEGIES = ("round_robin", "balanced", "explicit")


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Explicit mapping of segment names onto shards.

    ``assignment[s]`` lists the segment names owned by shard ``s``, each in
    global append order (the order the index's manifest lists them) — the
    invariant the bit-identical merge relies on. Plans are value objects:
    derive one with :meth:`round_robin` / :meth:`balanced` /
    :meth:`explicit` (or :meth:`for_index`), persist it via
    ``Index.set_shard_plan`` + ``commit`` and it comes back from
    ``Index.open``.
    """

    n_shards: int
    strategy: str  # "round_robin" | "balanced" | "explicit"
    assignment: tuple[tuple[str, ...], ...]  # per shard, global order

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"{self.n_shards=} must be >= 1")
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown shard strategy {self.strategy!r}; want {STRATEGIES}"
            )
        if len(self.assignment) != self.n_shards:
            raise ValueError(
                f"assignment has {len(self.assignment)} shards; plan says "
                f"{self.n_shards}"
            )
        flat = [name for shard in self.assignment for name in shard]
        if len(set(flat)) != len(flat):
            raise ValueError("shard plan assigns a segment twice")

    # -- derivation ---------------------------------------------------------
    @classmethod
    def round_robin(cls, segment_names: Sequence[str],
                    n_shards: int) -> "ShardPlan":
        """Segment ``i`` goes to shard ``i % n_shards`` — the paper's
        partition-by-arrival default; even counts, arbitrary sizes."""
        names = list(segment_names)
        return cls(
            n_shards=n_shards,
            strategy="round_robin",
            assignment=tuple(
                tuple(names[s::n_shards]) for s in range(n_shards)
            ),
        )

    @classmethod
    def balanced(cls, segment_names: Sequence[str], sizes: Sequence[int],
                 n_shards: int) -> "ShardPlan":
        """Size-balanced greedy (LPT): biggest segment first onto the
        least-loaded shard, so shard scan times stay even when segment
        sizes are skewed (many small appends + one compacted giant)."""
        names = list(segment_names)
        if len(sizes) != len(names):
            raise ValueError(f"{len(sizes)} sizes for {len(names)} segments")
        order = sorted(range(len(names)), key=lambda i: (-int(sizes[i]), i))
        loads = [0] * n_shards
        owner: dict[int, int] = {}
        for i in order:
            s = min(range(n_shards), key=lambda j: (loads[j], j))
            owner[i] = s
            loads[s] += int(sizes[i])
        return cls(
            n_shards=n_shards,
            strategy="balanced",
            # global (append) order within each shard, not LPT pick order
            assignment=tuple(
                tuple(names[i] for i in range(len(names)) if owner[i] == s)
                for s in range(n_shards)
            ),
        )

    @classmethod
    def explicit(cls, assignment: Sequence[Sequence[str]]) -> "ShardPlan":
        """Pin segments to shards by hand (operator override)."""
        return cls(
            n_shards=len(assignment),
            strategy="explicit",
            assignment=tuple(tuple(s) for s in assignment),
        )

    @classmethod
    def for_index(cls, index, n_shards: int,
                  strategy: str = "round_robin") -> "ShardPlan":
        """Derive a plan over ``index``'s current segments (committed +
        staged, in append order).

        Raises ``ValueError`` for an unknown or non-derivable strategy
        (``explicit`` plans cannot be derived — build one with
        :meth:`explicit`).
        """
        segs = index.segments
        if strategy == "round_robin":
            return cls.round_robin([s.name for s in segs], n_shards)
        if strategy == "balanced":
            return cls.balanced(
                [s.name for s in segs], [s.valid_rows for s in segs], n_shards
            )
        raise ValueError(
            f"cannot derive a {strategy!r} plan; want one of "
            "('round_robin', 'balanced')"
        )

    # -- queries ------------------------------------------------------------
    def shard_of(self, segment_name: str) -> int:
        for s, names in enumerate(self.assignment):
            if segment_name in names:
                return s
        raise KeyError(f"segment {segment_name!r} not in shard plan")

    def covers(self, segment_names: Sequence[str]) -> bool:
        """True when the plan assigns exactly the given segment set (the
        staleness check: an append/compact since the plan was made means a
        re-derive is needed)."""
        flat = {n for shard in self.assignment for n in shard}
        return flat == set(segment_names)

    def rederived(self, index) -> "ShardPlan":
        """The same strategy re-applied to ``index``'s current segments —
        how a persisted plan follows appends and compactions. Explicit
        plans cannot be re-derived and raise ``ValueError``."""
        return self.for_index(index, self.n_shards, self.strategy)

    # -- persistence --------------------------------------------------------
    def to_json(self) -> dict:
        return {
            "n_shards": self.n_shards,
            "strategy": self.strategy,
            "assignment": [list(s) for s in self.assignment],
        }

    @classmethod
    def from_json(cls, d: dict) -> "ShardPlan":
        return cls(
            n_shards=int(d["n_shards"]),
            strategy=d["strategy"],
            assignment=tuple(tuple(s) for s in d["assignment"]),
        )

    def describe(self) -> str:
        sizes = "/".join(str(len(s)) for s in self.assignment)
        return f"{self.strategy} x{self.n_shards} (segments {sizes})"


# ---------------------------------------------------------------------------
# merge helpers — shared by ShardedIndex (host path) and the sharded
# serving session's gather. A *slot* is a candidate's position in the
# unsharded segment-ordered concatenation: segment_ordinal * k + column.
# ---------------------------------------------------------------------------


def shard_local_partial(
    per_segment: Sequence[SearchResult], ordinals: Sequence[int], k: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fold one shard's per-segment k-NN tables into its local top-k.

    ``ordinals`` are the segments' global append positions (ascending, so
    the concatenated slot row is strictly increasing and a *stable* sort by
    distance is exactly the ``(distance, slot)`` total order). Returns
    ``(ids, dists, slots)`` of shape ``(q, k)`` each.
    """
    ids = np.concatenate([np.asarray(r.ids) for r in per_segment], axis=1)
    dists = np.concatenate([np.asarray(r.dists) for r in per_segment], axis=1)
    q = ids.shape[0]
    slots = np.concatenate(
        [np.arange(g * k, g * k + k, dtype=np.int64) for g in ordinals]
    )
    slots = np.broadcast_to(slots, (q, slots.size))
    sel = np.argsort(dists, axis=1, kind="stable")[:, :k]
    return (
        np.take_along_axis(ids, sel, axis=1),
        np.take_along_axis(dists, sel, axis=1),
        np.take_along_axis(slots, sel, axis=1),
    )


def fitted_shard_scales(
    index,
    shard_views,
    meshes,
    *,
    cost_model,
    n_queries: int,
    k: int,
    probes: int,
    layout: str,
    impl: str,
    max_scale: float = 2.0,
) -> list[float]:
    """Per-shard slab-headroom multipliers from fitted per-shard costs —
    shared by :meth:`ShardedIndex.search` and the sharded serving
    session's bucket ladders.

    Each non-empty shard's total rows are priced by the fitted model;
    the probe plan supplying the tile features is derived under the SAME
    ``cost_model`` the per-segment plans will use, so the priced layout
    matches the one that actually executes (a fitted flip prices the
    flipped layout). Shards above the mean earn proportionally more slab
    headroom (``engine.shard_slab_scales``, grow-only, so result-safe).
    All ones — the uniform-split fallback — until ``index.calibration``
    yields a usable fit, or when any shard cannot be planned/priced.
    """
    fitted = fitted_component(cost_model, index.calibration)
    if fitted is None:
        return [1.0] * len(shard_views)
    probe_plans, shapes = [], []
    for shard, mesh in zip(shard_views, meshes):
        if not shard:
            continue
        rows = sum(int(v.rows) for _, v in shard)
        n_shards = data_axis_size(mesh)
        try:
            probe_plans.append(make_plan(
                rows=rows, n_leaves=index.n_leaves, n_queries=n_queries,
                n_shards=n_shards, k=k, probes=probes, layout=layout,
                impl=impl, model=cost_model,
                calibration=index.calibration,
            ))
        except ValueError:  # e.g. unroutable leaves at this shard
            return [1.0] * len(shard_views)
        shapes.append(PlanShapes(
            rows=rows, n_queries=n_queries, n_shards=n_shards,
            n_leaves=index.n_leaves,
        ))
    scales = iter(shard_slab_scales(fitted, probe_plans, shapes,
                                    max_scale=max_scale))
    return [next(scales) if shard else 1.0 for shard in shard_views]


def _pad_cols(res: SearchResult, width: int) -> SearchResult:
    """Right-pad a candidate table to ``width`` columns with the engine's
    absent-row sentinels (``-1``/``inf`` sort behind every candidate)."""
    w = int(res.ids.shape[1])
    if w == width:
        return res
    q = int(res.ids.shape[0])
    ids = np.full((q, width), -1, np.int32)
    dists = np.full((q, width), np.inf, np.float32)
    ids[:, :w] = np.asarray(res.ids)
    dists[:, :w] = np.asarray(res.dists)
    return SearchResult(
        ids=jnp.asarray(ids), dists=jnp.asarray(dists),
        pairs=res.pairs, q_cap_overflow=res.q_cap_overflow,
    )


def gather_merge(
    partials: Sequence[tuple[np.ndarray, np.ndarray, np.ndarray]], k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Fuse per-shard ``(ids, dists, slots)`` partials into the global
    top-k, ordered by ``(distance, slot)`` — bit-identical to the unsharded
    stable merge over the segment-ordered concatenation."""
    ids = np.concatenate([p[0] for p in partials], axis=1)
    dists = np.concatenate([p[1] for p in partials], axis=1)
    slots = np.concatenate([p[2] for p in partials], axis=1)
    # primary key dists, ties by global slot (np.lexsort: last key wins)
    sel = np.lexsort((slots, dists), axis=1)[:, :k]
    return (
        np.take_along_axis(ids, sel, axis=1),
        np.take_along_axis(dists, sel, axis=1),
    )


class ShardedIndex:
    """Scatter-gather search view over an :class:`Index` and a
    :class:`ShardPlan`.

    Wraps — never copies — the underlying index: segments stay where the
    lifecycle put them, tombstones are applied by the same masked views,
    and the plan only decides which shard scans which segment. Construct
    with an explicit ``plan``, or give ``n_shards`` (+ ``strategy``) to
    derive one; a persisted plan on the index is picked up when neither is
    given.

    ``segments`` / ``views`` / ``codes`` / ``tombstones`` pin the scatter
    to one :class:`~repro.index.lifecycle.IndexSnapshot`'s cut instead of
    the index's live state — the read-during-write path: a serving
    session's sharded runtimes and its rerank fetches keep resolving
    against the pinned state while the index mutates underneath.
    """

    def __init__(
        self,
        index,
        plan: ShardPlan | None = None,
        *,
        n_shards: int | None = None,
        strategy: str = "round_robin",
        segments=None,
        views=None,
        codes=None,
        tombstones=None,
    ):
        self.index = index
        self._pin_segments = (
            tuple(segments) if segments is not None else None
        )
        self._pin_views = tuple(views) if views is not None else None
        self._pin_codes = dict(codes) if codes is not None else None
        self._pin_tombstones = (
            np.asarray(tombstones, np.int64)
            if tombstones is not None else None
        )
        if plan is None:
            if n_shards is not None:
                plan = ShardPlan.for_index(index, n_shards, strategy)
            elif getattr(index, "shard_plan", None) is not None:
                plan = index.shard_plan
            else:
                raise ValueError(
                    "need a ShardPlan, n_shards, or an index with a "
                    "persisted shard plan"
                )
        if not plan.covers([s.name for s in self.segments]):
            raise ValueError(
                "shard plan does not cover the index's current segments "
                f"({plan.describe()} vs {len(self.segments)} segments); "
                "re-derive with plan.rederived(index)"
            )
        self.plan = plan
        self._meshes = shard_submeshes(index.mesh, plan.n_shards)

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    @property
    def segments(self) -> tuple:
        """The segment cut this view scatters over: the pinned snapshot's
        when given, else the index's live committed + staged set."""
        if self._pin_segments is not None:
            return self._pin_segments
        return tuple(self.index.segments)

    def segment_views(self) -> tuple:
        if self._pin_views is not None:
            return self._pin_views
        return tuple(self.index.segment_views())

    @property
    def tombstones(self) -> np.ndarray:
        if self._pin_tombstones is not None:
            return self._pin_tombstones
        return self.index.tombstones

    def _codes_for(self, name: str) -> np.ndarray:
        codes = (
            self._pin_codes if self._pin_codes is not None
            else self.index._codes
        )
        return codes[name]

    def persist_plan(self) -> None:
        """Stage the plan into the index manifest (durable at the next
        ``commit``)."""
        self.index.set_shard_plan(self.plan)

    def shard_views(self) -> list[list[tuple[int, object]]]:
        """Per shard: ``(global_ordinal, masked DistributedIndex view)``
        pairs in global append order. Views are the index's cached
        tombstone-masked views — refreshed automatically after
        append/delete/compact on the underlying index."""
        by_name = {
            s.name: (g, v)
            for g, (s, v) in enumerate(
                zip(self.segments, self.segment_views())
            )
        }
        return [
            [by_name[name] for name in shard] for shard in self.plan.assignment
        ]

    def stats(self) -> dict:
        segs = {s.name: s for s in self.segments}
        per = [
            {
                "shard": s,
                "segments": list(names),
                "rows": sum(segs[n].valid_rows for n in names),
            }
            for s, names in enumerate(self.plan.assignment)
        ]
        return {"plan": self.plan.to_json(), "shards": per}

    def search(
        self,
        queries,
        k: int = 10,
        *,
        plan: SearchPlan | None = None,
        layout: str = "auto",
        probes: int = 1,
        impl: str = "xla",
        block_rows: int | None = None,
        q_cap: int | None = None,
        q_tile: int | None = None,
        p_cap: int | None = None,
        rerank: int | None = None,
        cost_model="auto",
    ) -> SearchResult:
        """Scatter-gather k-NN: one shared lookup build, each shard scans
        its segments with the engine's jit-cached executors, per-shard
        candidates merge by ``(distance, slot)``.

        Args mirror :meth:`Index.search` exactly — including the
        ``plan`` template, whose fields override the keyword arguments,
        and ``cost_model``, which consults the index's calibration store.
        When a fitted model is available, per-shard predicted costs set
        per-shard slab budgets (``shard_slab_scales``): a shard the fit
        prices above the mean gets proportionally more slab headroom in
        place of the uniform split. Scales only ever *grow* budgets, so
        in the zero-overflow regime (``q_cap_overflow == 0``, the one
        every identity test pins down) results are bit-identical to
        :meth:`Index.search` (ids and distances, both layouts, any
        ``probes``, tombstones respected) at every shard count and under
        every ``cost_model``; when a derived slab *would* overflow, a
        grown slab can only recover candidates the uniform split
        truncated — strictly closer to the true k-NN, overflow still
        counted — see the module docstring for the slot argument.

        Returns a :class:`SearchResult`; ``pairs`` / ``q_cap_overflow``
        are summed across shards. Raises ``ValueError`` via ``plan()``
        for invalid layout/probes combinations.
        """
        if plan is not None:
            layout, k, probes, impl = (
                plan.layout, plan.k, plan.probes, plan.impl,
            )
            block_rows = plan.block_rows if block_rows is None else block_rows
            q_cap = plan.q_cap if q_cap is None else q_cap
            q_tile = plan.q_tile if q_tile is None else q_tile
            p_cap = plan.p_cap if p_cap is None else p_cap
            rerank = plan.rerank if rerank is None else rerank
        queries = jnp.asarray(queries, jnp.float32)
        q = queries.shape[0]
        views = self.shard_views()
        if not any(views):
            return SearchResult(
                ids=jnp.full((q, k), -1, jnp.int32),
                dists=jnp.full((q, k), jnp.inf, jnp.float32),
                pairs=jnp.zeros((), jnp.float32),
                q_cap_overflow=jnp.zeros((), jnp.int32),
            )
        # codes-vs-exact resolves ONCE on the aggregate shape (ADC and
        # exact distances are incomparable), exactly like Index.search
        pq = getattr(self.index, "quantizer", None)
        if layout == "scan_codes" and pq is None:
            raise ValueError(
                "layout='scan_codes' needs PQ codes; call "
                "enable_codes() first"
            )
        use_codes = False
        if pq is not None and layout in ("auto", "scan_codes"):
            agg = make_plan(
                rows=sum(int(v.rows) for shard in views for _, v in shard),
                n_leaves=self.index.n_leaves, n_queries=q,
                n_shards=data_axis_size(self.index.mesh), k=k,
                probes=probes, layout=layout, impl=impl, model=cost_model,
                calibration=self.index.calibration,
                dim=self.index.dim, rerank=rerank,
                code_m=pq.m, code_bits=pq.bits,
            )
            use_codes = agg.layout == "scan_codes"
        lookup = jit_build_lookup(self.index.tree, queries, probes=probes)
        scales = fitted_shard_scales(
            self.index, views, self._meshes, cost_model=cost_model,
            n_queries=q, k=k, probes=probes,
            layout="auto" if use_codes else layout, impl=impl,
        )
        if use_codes:
            return self._search_codes(
                queries, k, views, lookup, scales, probes=probes,
                impl=impl, block_rows=block_rows, q_cap=q_cap,
                rerank=rerank, cost_model=cost_model,
            )
        partials = []
        pairs = overflow = 0
        pruned = 0
        live = self._live_counts()
        for shard, mesh, scale in zip(views, self._meshes, scales):
            if not shard:
                continue  # more shards than segments: an empty scatter leg
            n_shards = data_axis_size(mesh)
            per_seg, ordinals = [], []
            for g, view in shard:
                if live[g] == 0:
                    # every row is padding or tombstoned — the segment can
                    # only emit (-1, inf) sentinels, so skipping it is
                    # result-identical (same prune as Index.search)
                    pruned += 1
                    continue
                p = make_plan(
                    rows=view.rows,
                    n_leaves=self.index.n_leaves,
                    n_queries=q,
                    n_shards=n_shards,
                    k=k,
                    probes=probes,
                    layout=layout,
                    impl=impl,
                    block_rows=block_rows,
                    q_cap=q_cap,
                    q_tile=q_tile,
                    p_cap=p_cap,
                    model=cost_model,
                    calibration=self.index.calibration,
                )
                # never scale a budget the caller pinned: a pinned
                # slab must reproduce exactly (Args mirror Index.search)
                pinned = (q_cap is not None
                          if p.layout == "point_major"
                          else p_cap is not None)
                if not pinned:
                    p = scale_slab_budget(
                        p, scale, n_queries=q,
                        shard_rows=view.rows // n_shards,
                    )
                per_seg.append(
                    search_with_lookup(view, lookup, p, mesh, n_queries=q)
                )
                ordinals.append(g)
            if not per_seg:
                continue  # every segment of this shard was pruned
            partials.append(shard_local_partial(per_seg, ordinals, k))
            pairs = pairs + sum(r.pairs for r in per_seg)
            overflow = overflow + sum(r.q_cap_overflow for r in per_seg)
        if pruned:
            get_registry().counter("index.segments_pruned").inc(pruned)
        if not partials:
            return SearchResult(
                ids=jnp.full((q, k), -1, jnp.int32),
                dists=jnp.full((q, k), jnp.inf, jnp.float32),
                pairs=jnp.zeros((), jnp.float32),
                q_cap_overflow=jnp.zeros((), jnp.int32),
            )
        ids, dists = gather_merge(partials, k)
        return SearchResult(
            ids=jnp.asarray(ids),
            dists=jnp.asarray(dists),
            pairs=pairs,
            q_cap_overflow=overflow,
        )

    def _live_counts(self) -> np.ndarray:
        """Per-segment (global ordinal order) live-row counts under the
        active tombstone cut — the zero-live prune's input."""
        segs = self.segments
        valid = np.array([s.valid_rows for s in segs], np.int64)
        return valid - dead_counts(segs, self.tombstones)

    def _search_codes(
        self, queries, k, views, lookup, scales, *, probes, impl,
        block_rows, q_cap, rerank, cost_model,
    ) -> SearchResult:
        """Sharded ``scan_codes`` tier: every shard ADC-scans its segments,
        the gather merges *candidate* tables (slot-tagged, so the merged
        candidate set is deterministic at any shard count), and one global
        exact rerank over ``Index.read_rows`` produces the final top-k —
        the rerank is shard-count-invariant because it re-sorts candidates
        by id before fetching."""
        pq = self.index.quantizer
        q = queries.shape[0]
        shard_entries = []  # per shard: [(ordinal, SearchResult), ...]
        pairs = overflow = 0
        pruned = 0
        live = self._live_counts()
        segs = self.segments
        for shard, mesh, scale in zip(views, self._meshes, scales):
            if not shard:
                continue
            n_shards = data_axis_size(mesh)
            entries = []
            for g, view in shard:
                if live[g] == 0:
                    pruned += 1
                    continue
                p = make_plan(
                    rows=view.rows, n_leaves=self.index.n_leaves,
                    n_queries=q, n_shards=n_shards, k=k, probes=probes,
                    layout="scan_codes", impl=impl, block_rows=block_rows,
                    q_cap=q_cap, model=cost_model,
                    calibration=self.index.calibration,
                    dim=self.index.dim, rerank=rerank,
                    code_m=pq.m, code_bits=pq.bits,
                )
                # scan_codes slabs budget by q_cap (point-major family);
                # never scale a budget the caller pinned
                if q_cap is None:
                    p = scale_slab_budget(
                        p, scale, n_queries=q,
                        shard_rows=view.rows // n_shards,
                    )
                res = search_with_lookup(
                    view, lookup, p, mesh, n_queries=q,
                    codes=self._codes_for(segs[g].name),
                    codebooks=pq.codebooks,
                )
                entries.append((g, res))
                pairs = pairs + res.pairs
                overflow = overflow + res.q_cap_overflow
            if entries:
                shard_entries.append(entries)
        if pruned:
            get_registry().counter("index.segments_pruned").inc(pruned)
        if not shard_entries:
            return SearchResult(
                ids=jnp.full((q, k), -1, jnp.int32),
                dists=jnp.full((q, k), jnp.inf, jnp.float32),
                pairs=jnp.zeros((), jnp.float32),
                q_cap_overflow=jnp.zeros((), jnp.int32),
            )
        # per-segment candidate widths can differ (rerank clamps to each
        # segment's block_rows); pad to one width so slots stay uniform
        r_max = max(
            int(res.ids.shape[1]) for e in shard_entries for _, res in e
        )
        partials = []
        for entries in shard_entries:
            per_seg = [_pad_cols(res, r_max) for _, res in entries]
            partials.append(shard_local_partial(
                per_seg, [g for g, _ in entries], r_max
            ))
        cand_ids, _ = gather_merge(partials, r_max)
        # rerank fetches resolve against the same (possibly pinned) cut
        # the candidates came from — a concurrent delete cannot turn a
        # candidate id into an IndexError mid-request
        ids_r, dists_r = rerank_exact(
            lambda ids: self.index.read_rows(
                ids, segments=segs, tombstones=self.tombstones
            ),
            np.asarray(queries), cand_ids, k,
        )
        return SearchResult(
            ids=jnp.asarray(ids_r),
            dists=jnp.asarray(dists_r),
            pairs=pairs,
            q_cap_overflow=overflow,
        )
