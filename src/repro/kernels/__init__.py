# Pallas TPU kernels for the compute hot-spots of the paper's workflow:
#   l2nn   — fused L2 distance + argmin   (index build: descriptor -> leaf)
#   l2topk — fused L2 distance + top-k    (search: tile x query-slab k-NN)
# Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with impl selection), ref.py (pure-jnp oracle).
