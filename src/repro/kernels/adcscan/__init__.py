from repro.kernels.adcscan.ops import adc_topk  # noqa: F401
from repro.kernels.adcscan.ref import adc_topk_ref  # noqa: F401
