"""Pallas TPU kernel: fused ADC code-scan + per-query running top-k.

Compressed-tier hot path (docs/compressed_codes.md): one tile of
cluster-sorted uint8 code rows against one contiguous query-LUT slab. As
in l2topk, the running (k-best distance, index) table lives in VMEM
scratch across point tiles so the full (Q, P) ADC matrix never exists in
HBM; only (Q, k) leaves the kernel.

TPU mapping notes:
  * the ADC gather ``sum_j lut[q, j, codes[p, j]]`` is re-expressed as
    ``m`` small one-hot GEMMs on the MXU:
        d2 += lut[:, j*C:(j+1)*C] @ onehot(codes[:, j], C).T
    — a (TQ, C) x (C, TP) dot per subspace, which beats a per-element
    VPU gather on TPU and needs no scatter/gather addressing.
  * reductions run along the lane (last) axis of a (TQ, TP) layout.
  * top-k is k rounds of min-extraction + replace-current-max insertion,
    identical to l2topk (k here is the *rerank depth*, kept <= 128).
  * grid = (q_tiles, p_tiles), p innermost ("arbitrary") so scratch
    carries across code tiles; q tiles are parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.distributed.compat import tpu_compiler_params as _tpu_compiler_params


def _extract_min(d2, iota, bound):
    """(value, first-index) min along the last axis, keepdims, inf-safe."""
    m = jnp.min(d2, axis=1, keepdims=True)
    is_min = d2 == m
    a = jnp.min(jnp.where(is_min, iota, bound), axis=1, keepdims=True)
    return m, a


def adcscan_kernel(
    lut_ref, qlf_ref, codes_ref, plf_ref, out_d_ref, out_i_ref, run_d, run_i,
    *, k: int, m: int, n_centers: int
):
    j = pl.program_id(1)
    np_tiles = pl.num_programs(1)
    tq = lut_ref.shape[0]
    tp = codes_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        run_d[...] = jnp.full((tq, k), jnp.inf, jnp.float32)
        run_i[...] = jnp.full((tq, k), jnp.int32(-1), jnp.int32)

    lut = lut_ref[...]  # (TQ, m * C)
    codes = codes_ref[...]  # (TP, m) int32
    c_iota = jax.lax.broadcasted_iota(jnp.int32, (tp, n_centers), 1)
    d2 = jnp.zeros((tq, tp), jnp.float32)
    for s in range(m):
        onehot = (c_iota == codes[:, s][:, None]).astype(jnp.float32)
        d2 = d2 + jax.lax.dot_general(
            lut[:, s * n_centers:(s + 1) * n_centers], onehot,
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
        )  # (TQ, TP)
    match = qlf_ref[...] == plf_ref[...]  # (TQ,1) == (1,TP) -> (TQ, TP)
    d2 = jnp.where(match, d2, jnp.inf)

    p_iota = jax.lax.broadcasted_iota(jnp.int32, (tq, tp), 1)
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (tq, k), 1)
    rd = run_d[...]
    ri = run_i[...]
    for _ in range(k):
        mv, a = _extract_min(d2, p_iota, tp)  # (TQ,1) tile-best
        d2 = jnp.where(p_iota == a, jnp.inf, d2)  # remove from tile
        cur_max = jnp.max(rd, axis=1, keepdims=True)
        is_max = rd == cur_max
        amax = jnp.min(jnp.where(is_max, k_iota, k), axis=1, keepdims=True)
        repl = (k_iota == amax) & (mv < cur_max)
        rd = jnp.where(repl, mv, rd)
        ri = jnp.where(repl, a + j * tp, ri)
    run_d[...] = rd
    run_i[...] = ri

    @pl.when(j == np_tiles - 1)
    def _emit():
        rd2 = run_d[...]
        ri2 = run_i[...]
        cols_d, cols_i = [], []
        for _ in range(k):
            mv, am = _extract_min(rd2, k_iota, k)
            sel = k_iota == am
            ci = jnp.sum(jnp.where(sel, ri2, 0), axis=1, keepdims=True)
            rd2 = jnp.where(sel, jnp.inf, rd2)
            cols_d.append(mv)
            cols_i.append(jnp.where(jnp.isfinite(mv), ci, jnp.int32(-1)))
        out_d_ref[...] = jnp.concatenate(cols_d, axis=1)
        out_i_ref[...] = jnp.concatenate(cols_i, axis=1)


def adcscan_pallas(
    codes: jax.Array,  # (P, m) int32 code rows
    point_leaves: jax.Array,  # (1, P) int32
    lut: jax.Array,  # (Q, m * C) f32 per-query distance tables
    query_leaves: jax.Array,  # (Q, 1) int32
    *,
    k: int,
    n_centers: int,
    tile_p: int = 512,
    tile_q: int = 256,
    interpret: bool = False,
):
    P, m = codes.shape
    Q = lut.shape[0]
    if lut.shape[1] != m * n_centers:
        raise ValueError(f"lut width {lut.shape[1]} != {m=} * {n_centers=}")
    if P % tile_p or Q % tile_q:
        raise ValueError(f"{P=} % {tile_p=} or {Q=} % {tile_q=} nonzero")
    grid = (Q // tile_q, P // tile_p)
    kernel = functools.partial(adcscan_kernel, k=k, m=m, n_centers=n_centers)
    out_d, out_i = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m * n_centers), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_p, m), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tile_p), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_q, k), jnp.float32),
            pltpu.VMEM((tile_q, k), jnp.int32),
        ],
        compiler_params=_tpu_compiler_params()(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lut, query_leaves, codes, point_leaves)
    return out_d, out_i
