"""Jit'd wrapper for the fused ADC code-scan + top-k tile with impl
selection.

``impl`` (shared contract with l2topk):
  * ``"xla"``    — the pure-jnp oracle (efficient XLA; default off-TPU)
  * ``"pallas"`` — the Pallas kernel (``interpret=True`` off-TPU)
  * ``"auto"``   — pallas on TPU, xla elsewhere
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sentinels import PAD_TILE_POINT_LEAF, PAD_TILE_QUERY_LEAF
from repro.kernels.adcscan.kernel import adcscan_pallas
from repro.kernels.adcscan.ref import adc_topk_ref
from repro.kernels.l2topk.ops import resolve_impl

# Probe-aware padding, same scheme as l2topk: point-side and query-side
# tile padding use distinct negative sentinels so padded rows never match
# anything.
_PAD_P_LEAF = PAD_TILE_POINT_LEAF
_PAD_Q_LEAF = PAD_TILE_QUERY_LEAF


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@partial(jax.jit, static_argnames=("k", "impl", "tile_p", "tile_q"))
def adc_topk(
    codes: jax.Array,  # (P, m) uint8/int32 code rows
    point_leaves: jax.Array,  # (P,) int32
    lut: jax.Array,  # (Q, m, C) f32 per-query distance tables
    query_leaves: jax.Array,  # (Q,) int32
    *,
    k: int,
    impl: str = "auto",
    tile_p: int | None = None,
    tile_q: int | None = None,
):
    """(dists (Q,k), idx (Q,k)) of same-leaf ADC k-NN; see ref.py."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return adc_topk_ref(codes, point_leaves, lut, query_leaves, k)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    P, m = codes.shape
    Q, _, n_centers = lut.shape
    tp = tile_p or min(512, _round_up(P, 128))
    tq = tile_q or min(256, _round_up(Q, 128))
    Pp, Qp = _round_up(P, tp), _round_up(Q, tq)
    cds = jnp.zeros((Pp, m), jnp.int32).at[:P].set(codes.astype(jnp.int32))
    lt = jnp.zeros((Qp, m * n_centers), jnp.float32).at[:Q].set(
        lut.astype(jnp.float32).reshape(Q, m * n_centers)
    )
    plf = jnp.full((Pp,), _PAD_P_LEAF, jnp.int32).at[:P].set(
        point_leaves.astype(jnp.int32)
    )
    qlf = jnp.full((Qp,), _PAD_Q_LEAF, jnp.int32).at[:Q].set(
        query_leaves.astype(jnp.int32)
    )
    out_d, out_i = adcscan_pallas(
        cds,
        plf[None, :],
        lt,
        qlf[:, None],
        k=k,
        n_centers=n_centers,
        tile_p=tp,
        tile_q=tq,
        interpret=jax.default_backend() != "tpu",
    )
    return out_d[:Q], out_i[:Q]
