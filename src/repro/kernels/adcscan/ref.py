"""Pure-jnp oracle for the fused ADC (asymmetric-distance) code scan.

Semantics (shared by kernel and XLA fallback):

  given uint8 codes (P, m) with leaf ids (P,), and per-query distance
  lookup tables lut (Q, m, C) f32 with query leaf ids (Q,), return for
  every query the k approximately-nearest code rows *within the same
  leaf* under the asymmetric distance

      d2[q, p] = sum_j lut[q, j, codes[p, j]]

  (``lut[q, j, c] = ||q_j - codebook[j, c]||^2``, so d2 is a full squared
  distance estimate — unlike l2topk there is no deferred ``||q||^2``
  term):
    dists (Q, k) fp32  — ascending ADC squared distance, +inf no match
    idx   (Q, k) int32 — row index into the code tile, -1 where no match

Ordering contract: ascending by distance (the Pallas kernel also emits
ascending order via iterative min-extraction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_topk_ref(codes, point_leaves, lut, query_leaves, k: int):
    c = codes.astype(jnp.int32)
    m = c.shape[1]
    d2 = jnp.zeros((lut.shape[0], c.shape[0]), jnp.float32)
    for j in range(m):  # m is static and small (bytes per row)
        d2 = d2 + jnp.take(lut[:, j, :], c[:, j], axis=1)  # (Q, P)
    match = query_leaves[:, None] == point_leaves[None, :]
    d2 = jnp.where(match, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-d2, k)  # (Q, k) over code rows
    dists = -neg
    idx = jnp.where(jnp.isfinite(dists), sel, -1).astype(jnp.int32)
    return dists, idx
