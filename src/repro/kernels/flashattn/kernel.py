"""Pallas TPU kernel: fused causal/windowed GQA attention (FlashAttention
dataflow, arXiv:2205.14135 adapted to the MXU/VMEM hierarchy).

EXPERIMENTS.md §Perf Cell 3 measured that the flash *dataflow* in pure XLA
(lax.scan over KV chunks) is counterproductive — the running
(max, denom, accumulator) carry churns HBM every chunk. This kernel is the
correct home for that state: it lives in VMEM scratch across the KV-tile
grid dimension, the (Sq, Skv) score matrix never reaches HBM, and HBM
traffic collapses to reading q/k/v once and writing o once.

Mapping notes:
  * grid = (B*Hq, q_tiles, kv_tiles), kv innermost ("arbitrary") so scratch
    carries; batch*head and q tiles are parallel.
  * GQA without materialising repeated KV: the k/v BlockSpec index_map
    divides the fused (b*Hq + h) grid index by the group size, so each
    query head streams its shared KV head's tiles straight from HBM.
  * causal + sliding-window masking from absolute positions (q offset =
    Skv - Sq supports prefill-with-history shapes).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.distributed.compat import tpu_compiler_params as _tpu_compiler_params


def flashattn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                     scale: float, window: int, q_offset: int):
    i = pl.program_id(1)  # q tile
    j = pl.program_id(2)  # kv tile
    nj = pl.num_programs(2)
    tq = q_ref.shape[1]
    tk = k_ref.shape[1]
    hd = q_ref.shape[2]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full((tq, 1), -jnp.inf, jnp.float32)
        l_scr[...] = jnp.zeros((tq, 1), jnp.float32)
        acc_scr[...] = jnp.zeros((tq, hd), jnp.float32)

    q = q_ref[0].astype(jnp.float32)  # (tq, hd)
    k = k_ref[0].astype(jnp.float32)  # (tk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (tq, tk)

    q_pos = q_offset + i * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    k_pos = j * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    dist = q_pos - k_pos
    mask = dist >= 0
    if window > 0:
        mask &= dist < window
    s = jnp.where(mask, s, -1e30)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flashattn_pallas(
    q: jax.Array,  # (BH, Sq, hd)   BH = B * Hq
    k: jax.Array,  # (BHkv, Skv, hd)
    v: jax.Array,
    *,
    group: int,  # Hq // Hkv
    window: int = -1,
    tile_q: int = 128,
    tile_kv: int = 128,
    interpret: bool = False,
):
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    if Sq % tile_q or Skv % tile_kv:
        raise ValueError(f"{Sq=}%{tile_q=} or {Skv=}%{tile_kv=} nonzero")
    grid = (BH, Sq // tile_q, Skv // tile_kv)
    kernel = functools.partial(
        flashattn_kernel,
        scale=1.0 / math.sqrt(hd),
        window=window,
        q_offset=Skv - Sq,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tile_kv, hd), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, tile_kv, hd), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, hd), jnp.float32),
        ],
        compiler_params=_tpu_compiler_params()(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
