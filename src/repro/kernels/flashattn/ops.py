"""Jit'd wrapper for fused GQA flash attention with impl selection."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flashattn.kernel import flashattn_pallas
from repro.kernels.flashattn.ref import flash_attention_ref


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


@partial(jax.jit, static_argnames=("window", "impl", "tile_q", "tile_kv"))
def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, hd)
    k: jax.Array,  # (B, Skv, Hkv, hd)
    v: jax.Array,
    *,
    window: int = -1,
    impl: str = "auto",
    tile_q: int = 128,
    tile_kv: int = 128,
):
    """Causal (optionally sliding-window) GQA attention; see ref.py."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return flash_attention_ref(q, k, v, window=window)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    tq = min(tile_q, Sq)
    tkv = min(tile_kv, Skv)
    if Sq % tq or Skv % tkv:
        raise ValueError(
            f"flash kernel needs Sq%{tq}==0 and Skv%{tkv}==0 (got {Sq},{Skv})"
        )
    # (B, S, H, hd) -> (B*H, S, hd) with head-major fusion for the BlockSpec
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, hd)
    out = flashattn_pallas(
        qf, kf, vf, group=group, window=window, tile_q=tq, tile_kv=tkv,
        interpret=jax.default_backend() != "tpu",
    )
    return out.reshape(B, Hq, Sq, hd).transpose(0, 2, 1, 3)
