"""Pure-jnp oracle for fused (flash) attention.

Semantics: grouped-query causal attention with optional sliding window —
exactly ``repro.models.transformer.attend`` with q_pos/kv_pos = arange.

  q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd); Hq % Hkv == 0
  causal mask uses absolute positions with q offset = Skv - Sq
  window > 0 limits attention to the last ``window`` positions.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, window: int = -1):
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    logits = jnp.einsum(
        "bqkgh,bskh->bkgqs", qg, k, preferred_element_type=jnp.float32
    ) * (1.0 / math.sqrt(hd))
    q_pos = jnp.arange(Sq) + (Skv - Sq)
    kv_pos = jnp.arange(Skv)
    dist = q_pos[:, None] - kv_pos[None, :]
    mask = dist >= 0
    if window > 0:
        mask &= dist < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, Hq, hd)
