from repro.kernels.fusedscan.ops import (  # noqa: F401
    fused_adc_topk,
    fused_topk,
)
from repro.kernels.fusedscan.ref import (  # noqa: F401
    fused_adc_topk_ref,
    fused_topk_ref,
)
