"""Pallas TPU kernel: whole-shard fused multi-probe scan + k-selection.

Search fast path (``SearchPlan.impl="fused"``): the full cluster-sorted
shard meets the full probe-expanded lookup table in one kernel launch.
The grid walks (query tiles x point tiles); a per-query-tile running
top-k table lives in VMEM scratch across point tiles, so neither the
(P, Q) distance slab nor any per-tile candidate list ever round-trips to
HBM/host between scan and select — only (Q, k) leaves the kernel.

Where l2topk/adcscan keep an *unordered* running table (insertion into
the current-max slot), this kernel must be bit-identical to the
wave-folded ``impl="xla"`` executor, whose selection contract is the k
smallest by ``(distance, shard row)`` lexicographic (``top_k`` breaks
ties toward the earlier row; ``fold_topk`` keeps earlier waves ahead).
So the running table is kept *sorted*: each point tile's top-k is
extracted in ascending ``(distance, row)`` order, then merged with the
run table via k rounds of positional min-extraction over the
concatenated 2k-list — run entries (earlier tiles = lower shard rows)
sit at lower positions and win distance ties, reproducing the fold
exactly.

TPU mapping notes:
  * the distance tile is computed exactly as the XLA reference does —
        d2[q, p] = ||p||^2 - 2 q.p
    (norm broadcast + one MXU ``dot_general`` over d) so the float
    results match the reference bit for bit; the l2topk augmentation
    trick contracts over d+1 and may round differently.
  * tiles whose leaf ranges cannot overlap (both sides cluster-sorted)
    skip the GEMM + selection entirely under ``pl.when`` — the fused
    analogue of the executor's CSR slab slicing.
  * grid = (q_tiles, p_tiles), p innermost ("arbitrary") so scratch
    carries across point tiles; q tiles are parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.distributed.compat import tpu_compiler_params as _tpu_compiler_params


def _extract_min(d2, iota, bound):
    """(value, first-index) min along the last axis, keepdims, inf-safe."""
    m = jnp.min(d2, axis=1, keepdims=True)
    is_min = d2 == m
    a = jnp.min(jnp.where(is_min, iota, bound), axis=1, keepdims=True)
    return m, a


def _tile_topk_sorted(d2, *, k: int, row_base):
    """Tile top-k in ascending ``(distance, row)`` order.

    Returns ``(tile_d, tile_i)`` of shape (TQ, k); ``tile_i`` carries
    *global* shard row indices (``row_base`` + tile row). Rows backing
    ``inf`` distances are garbage — the emit step maps them to -1.
    """
    tq, tp = d2.shape
    p_iota = jax.lax.broadcasted_iota(jnp.int32, (tq, tp), 1)
    cols_d, cols_i = [], []
    for _ in range(k):
        m, a = _extract_min(d2, p_iota, tp)
        d2 = jnp.where(p_iota == a, jnp.inf, d2)
        cols_d.append(m)
        cols_i.append(a + row_base)
    return jnp.concatenate(cols_d, axis=1), jnp.concatenate(cols_i, axis=1)


def _merge_sorted(run_d, run_i, cand_d, cand_i, *, k: int):
    """Merge two ascending k-lists into one, run entries winning ties.

    k rounds of positional min-extraction over the concatenated 2k-list:
    the run table occupies positions 0..k-1, so on a distance tie the
    run entry (an earlier tile = lower shard row) is selected first —
    the same order ``tilescan.fold_topk`` produces.
    """
    tq = run_d.shape[0]
    md = jnp.concatenate([run_d, cand_d], axis=1)  # (TQ, 2k)
    mi = jnp.concatenate([run_i, cand_i], axis=1)
    pos = jax.lax.broadcasted_iota(jnp.int32, (tq, 2 * k), 1)
    cols_d, cols_i = [], []
    for _ in range(k):
        m, a = _extract_min(md, pos, 2 * k)
        sel = pos == a
        ci = jnp.sum(jnp.where(sel, mi, 0), axis=1, keepdims=True)
        md = jnp.where(sel, jnp.inf, md)
        cols_d.append(m)
        cols_i.append(ci)
    return jnp.concatenate(cols_d, axis=1), jnp.concatenate(cols_i, axis=1)


def _select_and_carry(d2, qlf, plf, out_d_ref, out_i_ref, run_d, run_i,
                      *, k: int):
    """The shared tail of both fused kernels: leaf-mask the distance
    tile, fold its sorted top-k into the VMEM run table, emit at the
    last point tile (leaf-disjoint tiles skip the fold entirely)."""
    j = pl.program_id(1)
    np_tiles = pl.num_programs(1)
    tq = d2.shape[0]

    @pl.when(j == 0)
    def _init():
        run_d[...] = jnp.full((tq, k), jnp.inf, jnp.float32)
        run_i[...] = jnp.full((tq, k), jnp.int32(-1), jnp.int32)

    # cluster-sorted on both sides: a tile pair whose [min, max] leaf
    # ranges are disjoint contributes nothing — skip GEMM fold + merge
    q_lo = jnp.min(qlf)
    q_hi = jnp.max(qlf)
    p_lo = jnp.min(plf)
    p_hi = jnp.max(plf)
    overlap = (p_lo <= q_hi) & (q_lo <= p_hi)

    @pl.when(overlap)
    def _fold():
        match = qlf[:, None] == plf[None, :]  # (TQ, TP)
        masked = jnp.where(match, d2, jnp.inf)
        tile_d, tile_i = _tile_topk_sorted(
            masked, k=k, row_base=j * plf.shape[0]
        )
        new_d, new_i = _merge_sorted(run_d[...], run_i[...], tile_d, tile_i,
                                     k=k)
        run_d[...] = new_d
        run_i[...] = new_i

    @pl.when(j == np_tiles - 1)
    def _emit():
        rd = run_d[...]
        out_d_ref[...] = rd
        out_i_ref[...] = jnp.where(jnp.isfinite(rd), run_i[...],
                                   jnp.int32(-1))


def fusedscan_kernel(q_ref, qlf_ref, p_ref, plf_ref, out_d_ref, out_i_ref,
                     run_d, run_i, *, k: int):
    pf = p_ref[...].astype(jnp.float32)
    qf = q_ref[...].astype(jnp.float32)
    # reference-identical partial distance: ||p||^2 - 2 q.p, contraction
    # over d (NOT the augmented d+1 trick — it can round differently)
    pn = jnp.sum(pf * pf, axis=1)  # (TP,)
    d2 = pn[None, :] - 2.0 * jax.lax.dot_general(
        qf, pf, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TQ, TP)
    _select_and_carry(d2, qlf_ref[...][:, 0], plf_ref[...][0, :],
                      out_d_ref, out_i_ref, run_d, run_i, k=k)


def fusedadc_kernel(lut_ref, qlf_ref, codes_ref, plf_ref, out_d_ref,
                    out_i_ref, run_d, run_i, *, k: int, m: int,
                    n_centers: int):
    lut = lut_ref[...]  # (TQ, m * C)
    codes = codes_ref[...]  # (TP, m) int32
    tq = lut.shape[0]
    tp = codes.shape[0]
    c_iota = jax.lax.broadcasted_iota(jnp.int32, (tp, n_centers), 1)
    d2 = jnp.zeros((tq, tp), jnp.float32)
    for s in range(m):
        onehot = (c_iota == codes[:, s][:, None]).astype(jnp.float32)
        d2 = d2 + jax.lax.dot_general(
            lut[:, s * n_centers:(s + 1) * n_centers], onehot,
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
        )  # (TQ, TP)
    _select_and_carry(d2, qlf_ref[...][:, 0], plf_ref[...][0, :],
                      out_d_ref, out_i_ref, run_d, run_i, k=k)


def _pallas_scan(kernel, q_side, qlf, p_side, plf, *, k, tile_p, tile_q,
                 interpret):
    P = p_side.shape[0]
    Q = q_side.shape[0]
    if P % tile_p or Q % tile_q:
        raise ValueError(f"{P=} % {tile_p=} or {Q=} % {tile_q=} nonzero")
    grid = (Q // tile_q, P // tile_p)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, q_side.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_p, p_side.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tile_p), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_q, k), jnp.float32),
            pltpu.VMEM((tile_q, k), jnp.int32),
        ],
        compiler_params=_tpu_compiler_params()(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q_side, qlf, p_side, plf)


def fusedscan_pallas(
    points: jax.Array,  # (P, d)
    point_leaves: jax.Array,  # (1, P) int32
    queries: jax.Array,  # (Q, d)
    query_leaves: jax.Array,  # (Q, 1) int32
    *,
    k: int,
    tile_p: int = 512,
    tile_q: int = 256,
    interpret: bool = False,
):
    kernel = functools.partial(fusedscan_kernel, k=k)
    return _pallas_scan(kernel, queries, query_leaves, points, point_leaves,
                        k=k, tile_p=tile_p, tile_q=tile_q,
                        interpret=interpret)


def fusedadc_pallas(
    codes: jax.Array,  # (P, m) int32 code rows
    point_leaves: jax.Array,  # (1, P) int32
    lut: jax.Array,  # (Q, m * C) f32 per-query distance tables
    query_leaves: jax.Array,  # (Q, 1) int32
    *,
    k: int,
    n_centers: int,
    tile_p: int = 512,
    tile_q: int = 256,
    interpret: bool = False,
):
    m = codes.shape[1]
    if lut.shape[1] != m * n_centers:
        raise ValueError(f"lut width {lut.shape[1]} != {m=} * {n_centers=}")
    kernel = functools.partial(fusedadc_kernel, k=k, m=m, n_centers=n_centers)
    return _pallas_scan(kernel, lut, query_leaves, codes, point_leaves,
                        k=k, tile_p=tile_p, tile_q=tile_q,
                        interpret=interpret)
