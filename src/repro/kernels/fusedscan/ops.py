"""Jit'd wrappers for the whole-shard fused scan with impl selection.

``impl`` (shared contract with l2topk/adcscan):
  * ``"xla"``    — the pure-jnp oracle (efficient XLA; default off-TPU)
  * ``"pallas"`` — the Pallas kernel (``interpret=True`` off-TPU; the
    interpreter is an eval loop, so off-TPU this is for parity tests —
    the fused *executor* uses a ``jax.lax``-pipelined XLA path instead,
    see docs/kernels.md)
  * ``"auto"``   — pallas on TPU, xla elsewhere

Unlike the per-tile kernels these return *global descriptor ids* (mapped
through ``point_ids``, -1 where no match or tombstoned), because the
whole shard is scanned in one call — there is no per-wave id mapping
left for the executor to do.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sentinels import (
    INVALID_ID,
    PAD_TILE_POINT_LEAF,
    PAD_TILE_QUERY_LEAF,
)
from repro.kernels.fusedscan.kernel import fusedadc_pallas, fusedscan_pallas
from repro.kernels.fusedscan.ref import fused_adc_topk_ref, fused_topk_ref
from repro.kernels.l2topk.ops import resolve_impl

_PAD_P_LEAF = PAD_TILE_POINT_LEAF
_PAD_Q_LEAF = PAD_TILE_QUERY_LEAF


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _tiles(P: int, Q: int, tile_p, tile_q) -> tuple[int, int]:
    tp = tile_p or min(512, _round_up(P, 128))
    tq = tile_q or min(256, _round_up(Q, 128))
    return tp, tq


def _pad_leaves(leaves, n: int, pad_leaf: int):
    out = jnp.full((n,), pad_leaf, jnp.int32)
    return out.at[: leaves.shape[0]].set(leaves.astype(jnp.int32))


def _map_ids(out_d, sel, point_ids, Q: int):
    ids = jnp.where(
        sel >= 0, point_ids[jnp.clip(sel, 0)], jnp.int32(INVALID_ID)
    ).astype(jnp.int32)
    return jnp.where(ids >= 0, out_d, jnp.inf)[:Q], ids[:Q]


@partial(jax.jit, static_argnames=("k", "impl", "tile_p", "tile_q"))
def fused_topk(
    points: jax.Array,  # (P, d) whole cluster-sorted shard
    point_leaves: jax.Array,  # (P,) int32
    point_ids: jax.Array,  # (P,) int32 global descriptor ids (-1 dead)
    queries: jax.Array,  # (Q, d) whole probe-expanded lookup table
    query_leaves: jax.Array,  # (Q,) int32
    *,
    k: int,
    impl: str = "auto",
    tile_p: int | None = None,
    tile_q: int | None = None,
):
    """(dists (Q,k), ids (Q,k)) whole-shard fused k-NN; see ref.py."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return fused_topk_ref(points, point_leaves, point_ids, queries,
                              query_leaves, k)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    P, d = points.shape
    Q = queries.shape[0]
    tp, tq = _tiles(P, Q, tile_p, tile_q)
    Pp, Qp = _round_up(P, tp), _round_up(Q, tq)
    pts = jnp.zeros((Pp, d), points.dtype).at[:P].set(points)
    qrs = jnp.zeros((Qp, d), queries.dtype).at[:Q].set(queries)
    plf = _pad_leaves(point_leaves, Pp, _PAD_P_LEAF)
    qlf = _pad_leaves(query_leaves, Qp, _PAD_Q_LEAF)
    out_d, sel = fusedscan_pallas(
        pts, plf[None, :], qrs, qlf[:, None], k=k, tile_p=tp, tile_q=tq,
        interpret=jax.default_backend() != "tpu",
    )
    return _map_ids(out_d, sel, point_ids, Q)


@partial(jax.jit, static_argnames=("k", "impl", "tile_p", "tile_q"))
def fused_adc_topk(
    codes: jax.Array,  # (P, m) uint8/int32 code rows (whole shard)
    point_leaves: jax.Array,  # (P,) int32 (tombstones pre-masked)
    point_ids: jax.Array,  # (P,) int32 global descriptor ids (-1 dead)
    lut: jax.Array,  # (Q, m, C) f32 per-query distance tables
    query_leaves: jax.Array,  # (Q,) int32
    *,
    k: int,
    impl: str = "auto",
    tile_p: int | None = None,
    tile_q: int | None = None,
):
    """(dists (Q,k), ids (Q,k)) whole-shard fused ADC k-NN; see ref.py."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return fused_adc_topk_ref(codes, point_leaves, point_ids, lut,
                                  query_leaves, k)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    P, m = codes.shape
    Q, _, n_centers = lut.shape
    tp, tq = _tiles(P, Q, tile_p, tile_q)
    Pp, Qp = _round_up(P, tp), _round_up(Q, tq)
    cds = jnp.zeros((Pp, m), jnp.int32).at[:P].set(codes.astype(jnp.int32))
    lt = jnp.zeros((Qp, m * n_centers), jnp.float32).at[:Q].set(
        lut.astype(jnp.float32).reshape(Q, m * n_centers)
    )
    plf = _pad_leaves(point_leaves, Pp, _PAD_P_LEAF)
    qlf = _pad_leaves(query_leaves, Qp, _PAD_Q_LEAF)
    out_d, sel = fusedadc_pallas(
        cds, plf[None, :], lt, qlf[:, None], k=k, n_centers=n_centers,
        tile_p=tp, tile_q=tq,
        interpret=jax.default_backend() != "tpu",
    )
    return _map_ids(out_d, sel, point_ids, Q)
