"""Pure-jnp oracle for the whole-shard fused scan + k-selection.

Semantics (shared by kernel and XLA fallback):

  given a shard's cluster-sorted points (P, d) with leaf ids (P,) and
  global descriptor ids (P,), and a probe-expanded lookup table
  queries (Q, d) with leaf ids (Q,), return for every lookup row the k
  nearest same-leaf points across the *whole shard* in one pass:
    dists (Q, k) fp32  — partial squared distance ||p||^2 - 2 p.q
                         (the ||q||^2 term is a per-query constant and is
                         added back by the caller), +inf where no match
    ids   (Q, k) int32 — global descriptor ids, -1 where no match (or
                         where the row is tombstoned: id < 0)

Selection contract: the k smallest by ``(distance, shard row)``
lexicographic — exactly what the wave-folded ``impl="xla"`` executor
produces (``jax.lax.top_k`` breaks distance ties toward the earlier row,
and ``tilescan.fold_topk`` keeps earlier waves ahead of later ones), so
the fused path is bit-identical to the reference executor.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.sentinels import INVALID_ID
from repro.kernels.adcscan.ref import adc_topk_ref
from repro.kernels.l2topk.ref import l2_topk_ref


def _map_ids(dists, sel, point_ids):
    ids = jnp.where(
        sel >= 0, point_ids[jnp.clip(sel, 0)], jnp.int32(INVALID_ID)
    ).astype(jnp.int32)
    return jnp.where(ids >= 0, dists, jnp.inf), ids


def fused_topk_ref(points, point_leaves, point_ids, queries, query_leaves,
                   k: int):
    dists, sel = l2_topk_ref(points, point_leaves, queries, query_leaves, k)
    return _map_ids(dists, sel, point_ids)


def fused_adc_topk_ref(codes, point_leaves, point_ids, lut, query_leaves,
                       k: int):
    """ADC variant over PQ code rows (``lut`` is (Q, m, C) f32); distances
    are *full* squared estimates — no deferred ``||q||^2`` term."""
    dists, sel = adc_topk_ref(codes, point_leaves, lut, query_leaves, k)
    return _map_ids(dists, sel, point_ids)
