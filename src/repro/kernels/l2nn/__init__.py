from repro.kernels.l2nn.ops import l2_nearest  # noqa: F401
