"""Pallas TPU kernel: fused L2 distance + argmin (nearest centroid).

Index-build hot path (paper §2.3 map task): assign a tile of descriptors to
their nearest representative. Centroid tiles stream through VMEM while the
(best-distance, best-index) pair per descriptor rides in scratch — the
(N, C) distance matrix never reaches HBM. Same augmented-GEMM trick as
``l2topk``: d2[n, c] = [-2x | 1] . [c | ||c||^2] in a single MXU dot.

Grid = (n_tiles, c_tiles), centroid axis innermost so scratch accumulates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.distributed.compat import tpu_compiler_params as _tpu_compiler_params


def l2nn_kernel(x_ref, c_ref, out_i_ref, out_d_ref, best_d, best_i, *, n_valid_c: int):
    j = pl.program_id(1)
    nc_tiles = pl.num_programs(1)
    tn = x_ref.shape[0]
    tc = c_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        best_d[...] = jnp.full((tn, 1), jnp.inf, jnp.float32)
        best_i[...] = jnp.full((tn, 1), -1, jnp.int32)

    xf = x_ref[...].astype(jnp.float32)
    cf = c_ref[...].astype(jnp.float32)
    cn = jnp.sum(cf * cf, axis=1, keepdims=True)  # (TC, 1)
    ca = jnp.concatenate([cf, cn], axis=1)  # (TC, d+1)
    xa = jnp.concatenate([-2.0 * xf, jnp.ones_like(xf[:, :1])], axis=1)
    d2 = jax.lax.dot_general(
        xa, ca, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TN, TC) partial: ||c||^2 - 2 x.c

    c_iota = jax.lax.broadcasted_iota(jnp.int32, (tn, tc), 1)
    # mask out zero-padded centroid columns
    d2 = jnp.where(c_iota + j * tc < n_valid_c, d2, jnp.inf)
    m = jnp.min(d2, axis=1, keepdims=True)
    a = jnp.min(jnp.where(d2 == m, c_iota, tc), axis=1, keepdims=True) + j * tc
    upd = m < best_d[...]
    best_d[...] = jnp.where(upd, m, best_d[...])
    best_i[...] = jnp.where(upd, a, best_i[...])

    @pl.when(j == nc_tiles - 1)
    def _emit():
        xn = jnp.sum(xf * xf, axis=1, keepdims=True)
        out_d_ref[...] = best_d[...] + xn  # back to true squared distance
        out_i_ref[...] = best_i[...]


def l2nn_pallas(
    x: jax.Array,  # (N, d)
    centroids: jax.Array,  # (C, d)
    *,
    tile_n: int = 1024,
    tile_c: int = 512,
    interpret: bool = False,
    n_valid_c: int = 0,
):
    N, d = x.shape
    C = centroids.shape[0]
    if N % tile_n or C % tile_c:
        raise ValueError(f"{N=} % {tile_n=} or {C=} % {tile_c=} nonzero")
    grid = (N // tile_n, C // tile_c)
    out_i, out_d = pl.pallas_call(
        functools.partial(l2nn_kernel, n_valid_c=n_valid_c if n_valid_c else C),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_c, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_n, 1), jnp.float32),
            pltpu.VMEM((tile_n, 1), jnp.int32),
        ],
        compiler_params=_tpu_compiler_params()(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, centroids)
    return out_i, out_d
