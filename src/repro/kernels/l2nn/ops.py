"""Jit'd wrapper for fused nearest-centroid with impl selection."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.l2nn.kernel import l2nn_pallas
from repro.kernels.l2nn.ref import l2_nearest_ref


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


@partial(jax.jit, static_argnames=("impl", "tile_n", "tile_c"))
def l2_nearest(
    x: jax.Array,
    centroids: jax.Array,
    *,
    impl: str = "auto",
    tile_n: int | None = None,
    tile_c: int | None = None,
):
    """(idx (N,), dist (N,)) nearest centroid per row; see ref.py."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return l2_nearest_ref(x, centroids)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    N, d = x.shape
    C = centroids.shape[0]
    tn = tile_n or min(1024, _round_up(N, 128))
    tc = tile_c or min(512, _round_up(C, 128))
    Np, Cp = _round_up(N, tn), _round_up(C, tc)
    xp = jnp.zeros((Np, d), x.dtype).at[:N].set(x)
    # zero-padded centroids are masked out inside the kernel (n_valid_c)
    cp = jnp.zeros((Cp, d), centroids.dtype).at[:C].set(centroids)
    out_i, out_d = l2nn_pallas(
        xp,
        cp,
        tile_n=tn,
        tile_c=tc,
        interpret=jax.default_backend() != "tpu",
        n_valid_c=C,
    )
    return out_i[:N, 0], out_d[:N, 0]
