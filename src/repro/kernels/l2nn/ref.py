"""Pure-jnp oracle for fused L2 nearest-centroid assignment.

Given x (N, d) and centroids (C, d), return
  idx  (N,) int32   — argmin_c ||x - c||^2 (first index on ties)
  dist (N,) float32 — the true squared distance at the argmin
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.distance import nearest


def l2_nearest_ref(x, centroids):
    idx, dist = nearest(x, centroids)
    return idx.astype(jnp.int32), dist.astype(jnp.float32)
