from repro.kernels.l2topk.ops import l2_topk  # noqa: F401
