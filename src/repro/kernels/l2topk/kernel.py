"""Pallas TPU kernel: fused L2-distance GEMM + per-query running top-k.

Search hot path (paper §2.4 map task): one tile of cluster-sorted index
points against one contiguous query slab. The kernel keeps the running
(k-best distance, index) table in VMEM scratch across point tiles, so the
full (P, Q) distance matrix never exists in HBM — the MXU produces a
(TQ, TP) tile, the VPU folds it into the running table, and only (Q, k)
leaves the kernel.

TPU mapping notes:
  * the distance GEMM uses the augmentation trick
        d2[q, p] = [-2q | 1] . [p | ||p||^2]
    so the whole partial distance is a single ``dot_general`` on the MXU —
    no transposes, no separate norm broadcast (d+1 contraction pads to the
    next lane multiple inside the MXU).
  * reductions run along the lane (last) axis of a (TQ, TP) layout.
  * top-k is k rounds of min-extraction + replace-current-max insertion;
    k <= 64 keeps this VPU-cheap relative to the MXU tile.
  * grid = (q_tiles, p_tiles), p innermost ("arbitrary") so scratch carries
    across point tiles; q tiles are parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.distributed.compat import tpu_compiler_params as _tpu_compiler_params



def _augment(q_tile, p_tile):
    """Build the (TQ, TP) partial squared-distance tile with one dot."""
    pf = p_tile.astype(jnp.float32)
    qf = q_tile.astype(jnp.float32)
    pn = jnp.sum(pf * pf, axis=1, keepdims=True)  # (TP, 1)
    pa = jnp.concatenate([pf, pn], axis=1)  # (TP, d+1)
    qa = jnp.concatenate([-2.0 * qf, jnp.ones_like(qf[:, :1])], axis=1)
    return jax.lax.dot_general(
        qa, pa, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TQ, TP)


def _extract_min(d2, iota, bound):
    """(value, first-index) min along the last axis, keepdims, inf-safe."""
    m = jnp.min(d2, axis=1, keepdims=True)
    is_min = d2 == m
    a = jnp.min(jnp.where(is_min, iota, bound), axis=1, keepdims=True)
    return m, a


def l2topk_kernel(
    q_ref, qlf_ref, p_ref, plf_ref, out_d_ref, out_i_ref, run_d, run_i, *, k: int
):
    j = pl.program_id(1)
    np_tiles = pl.num_programs(1)
    tq = q_ref.shape[0]
    tp = p_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        run_d[...] = jnp.full((tq, k), jnp.inf, jnp.float32)
        run_i[...] = jnp.full((tq, k), jnp.int32(-1), jnp.int32)

    d2 = _augment(q_ref[...], p_ref[...])  # (TQ, TP)
    match = qlf_ref[...] == plf_ref[...]  # (TQ,1) == (1,TP) -> (TQ, TP)
    d2 = jnp.where(match, d2, jnp.inf)

    p_iota = jax.lax.broadcasted_iota(jnp.int32, (tq, tp), 1)
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (tq, k), 1)
    rd = run_d[...]
    ri = run_i[...]
    for _ in range(k):
        m, a = _extract_min(d2, p_iota, tp)  # (TQ,1) tile-best
        d2 = jnp.where(p_iota == a, jnp.inf, d2)  # remove from tile
        cur_max = jnp.max(rd, axis=1, keepdims=True)
        is_max = rd == cur_max
        amax = jnp.min(jnp.where(is_max, k_iota, k), axis=1, keepdims=True)
        repl = (k_iota == amax) & (m < cur_max)
        rd = jnp.where(repl, m, rd)
        ri = jnp.where(repl, a + j * tp, ri)
    run_d[...] = rd
    run_i[...] = ri

    @pl.when(j == np_tiles - 1)
    def _emit():
        rd2 = run_d[...]
        ri2 = run_i[...]
        cols_d, cols_i = [], []
        for _ in range(k):
            m, am = _extract_min(rd2, k_iota, k)
            sel = k_iota == am
            ci = jnp.sum(jnp.where(sel, ri2, 0), axis=1, keepdims=True)
            rd2 = jnp.where(sel, jnp.inf, rd2)
            cols_d.append(m)
            cols_i.append(jnp.where(jnp.isfinite(m), ci, jnp.int32(-1)))
        out_d_ref[...] = jnp.concatenate(cols_d, axis=1)
        out_i_ref[...] = jnp.concatenate(cols_i, axis=1)


def l2topk_pallas(
    points: jax.Array,  # (P, d)
    point_leaves: jax.Array,  # (1, P) int32
    queries: jax.Array,  # (Q, d)
    query_leaves: jax.Array,  # (Q, 1) int32
    *,
    k: int,
    tile_p: int = 512,
    tile_q: int = 256,
    interpret: bool = False,
):
    P, d = points.shape
    Q = queries.shape[0]
    if P % tile_p or Q % tile_q:
        raise ValueError(f"{P=} % {tile_p=} or {Q=} % {tile_q=} nonzero")
    grid = (Q // tile_q, P // tile_p)
    kernel = functools.partial(l2topk_kernel, k=k)
    out_d, out_i = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_p, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tile_p), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_q, k), jnp.float32),
            pltpu.VMEM((tile_q, k), jnp.int32),
        ],
        compiler_params=_tpu_compiler_params()(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(queries, query_leaves, points, point_leaves)
    return out_d, out_i
