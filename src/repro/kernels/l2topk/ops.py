"""Jit'd wrapper for the fused distance+top-k tile with impl selection.

``impl``:
  * ``"xla"``    — the pure-jnp oracle (efficient XLA; default off-TPU)
  * ``"pallas"`` — the Pallas kernel (``interpret=True`` off-TPU)
  * ``"auto"``   — pallas on TPU, xla elsewhere
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sentinels import PAD_TILE_POINT_LEAF, PAD_TILE_QUERY_LEAF
from repro.kernels.l2topk.kernel import l2topk_pallas
from repro.kernels.l2topk.ref import l2_topk_ref

# Probe-aware padding: point-side and query-side tile padding use distinct
# negative sentinels so padded rows never match anything — not real leaves,
# not each other, and not padded multi-probe lookup rows (PAD_QUERY_LEAF).
_PAD_P_LEAF = PAD_TILE_POINT_LEAF
_PAD_Q_LEAF = PAD_TILE_QUERY_LEAF


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


@partial(jax.jit, static_argnames=("k", "impl", "tile_p", "tile_q"))
def l2_topk(
    points: jax.Array,
    point_leaves: jax.Array,
    queries: jax.Array,
    query_leaves: jax.Array,
    *,
    k: int,
    impl: str = "auto",
    tile_p: int | None = None,
    tile_q: int | None = None,
):
    """(dists (Q,k), idx (Q,k)) of same-leaf k-NN; see ref.py for semantics."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return l2_topk_ref(points, point_leaves, queries, query_leaves, k)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    P, d = points.shape
    Q = queries.shape[0]
    tp = tile_p or min(512, _round_up(P, 128))
    tq = tile_q or min(256, _round_up(Q, 128))
    Pp, Qp = _round_up(P, tp), _round_up(Q, tq)
    pts = jnp.zeros((Pp, d), points.dtype).at[:P].set(points)
    qrs = jnp.zeros((Qp, d), queries.dtype).at[:Q].set(queries)
    plf = jnp.full((Pp,), _PAD_P_LEAF, jnp.int32).at[:P].set(
        point_leaves.astype(jnp.int32)
    )
    qlf = jnp.full((Qp,), _PAD_Q_LEAF, jnp.int32).at[:Q].set(
        query_leaves.astype(jnp.int32)
    )
    out_d, out_i = l2topk_pallas(
        pts,
        plf[None, :],
        qrs,
        qlf[:, None],
        k=k,
        tile_p=tp,
        tile_q=tq,
        interpret=jax.default_backend() != "tpu",
    )
    return out_d[:Q], out_i[:Q]
