"""Pure-jnp oracle for the fused distance + top-k search tile.

Semantics (shared by kernel and XLA fallback):

  given points (P, d) with leaf ids (P,), queries (Q, d) with leaf ids (Q,),
  return for every query the k nearest points *within the same leaf*:
    dists (Q, k) fp32  — partial squared distance ||p||^2 - 2 p.q
                         (the ||q||^2 term is a per-query constant and is
                         added back by the caller), +inf where no match
    idx   (Q, k) int32 — row index into the point tile, -1 where no match

Ordering contract: ascending by distance (the Pallas kernel also emits
ascending order via iterative min-extraction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_topk_ref(points, point_leaves, queries, query_leaves, k: int):
    pf = points.astype(jnp.float32)
    qf = queries.astype(jnp.float32)
    pn = jnp.sum(pf * pf, axis=-1)
    d2 = pn[:, None] - 2.0 * jnp.einsum(
        "pd,qd->pq", pf, qf, preferred_element_type=jnp.float32
    )
    match = point_leaves[:, None] == query_leaves[None, :]
    d2 = jnp.where(match, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-d2.T, k)  # (Q, k) over point rows
    dists = -neg
    idx = jnp.where(jnp.isfinite(dists), sel, -1).astype(jnp.int32)
    return dists, idx
