# Launchers: mesh.py (production meshes), dryrun.py (multi-pod lower+compile
# + roofline capture; sets XLA_FLAGS before any jax import), train.py,
# serve.py (batched search serving), index.py (streaming index jobs).
