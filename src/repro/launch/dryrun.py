import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh) cell
lowers, SPMD-partitions, and compiles — and capture its roofline terms.

The two lines above MUST precede any jax import (jax locks the device count
at first init); everything else is imported lazily below them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
      --shape train_4k [--multi-pod] [--out results.jsonl]
  PYTHONPATH=src python -m repro.launch.dryrun --list
One cell per process is recommended (compiles are memory-hungry); the
benchmark driver scripts/run_dryruns.sh does exactly that.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402


def run_cell(arch: str, shape: str, *, multi_pod: bool, verbose: bool = True) -> dict:
    import jax

    from repro.configs import REGISTRY
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = REGISTRY[arch].cell(shape)
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": cell.kind,
        "model_flops": cell.model_flops,
        "n_devices": len(jax.devices()),
    }
    if cell.skip:
        rec["status"] = "skip"
        rec["skip_reason"] = cell.skip
        return rec
    t0 = time.time()
    lowered = cell.lower(mesh)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    rec["status"] = "ok"
    mem = rl.memory_stats(compiled)
    roof = rl.analyze(compiled)
    rec["memory"] = mem
    rec["roofline"] = roof.as_dict()
    rec["model_flops_per_device"] = cell.model_flops / len(jax.devices())
    if roof.flops > 0:
        rec["useful_flops_ratio"] = rec["model_flops_per_device"] / roof.flops
    if verbose:
        print(f"== {arch} / {shape} on {rec['mesh']} ==")
        print("memory_analysis:", json.dumps(mem))
        print(
            "cost_analysis: flops/device={:.3e} bytes/device={:.3e}".format(
                roof.flops, roof.hbm_bytes
            )
        )
        print(
            "roofline: compute={:.4f}s memory={:.4f}s collective={:.4f}s"
            " dominant={}".format(
                roof.t_compute, roof.t_memory, roof.t_collective, roof.dominant
            )
        )
        print("collectives:", json.dumps(roof.collectives))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="every cell, in-process")
    ap.add_argument("--out", help="append JSONL records here")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import REGISTRY

    if args.list:
        for name, arch in REGISTRY.items():
            print(name, "->", ", ".join(arch.cells))
        return 0

    jobs = []
    if args.all:
        for name, arch in REGISTRY.items():
            for shape in arch.cells:
                jobs.append((name, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all / --list)")
        jobs.append((args.arch, args.shape))

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    rc = 0
    for arch, shape in jobs:
        for mp in meshes:
            try:
                rec = run_cell(arch, shape, multi_pod=mp)
            except Exception as e:  # noqa: BLE001 - report and continue
                rec = {
                    "arch": arch,
                    "shape": shape,
                    "mesh": "2x16x16" if mp else "16x16",
                    "status": "error",
                    "error": repr(e)[:2000],
                }
                print(f"== {arch} / {shape} FAILED: {e!r}", file=sys.stderr)
                rc = 1
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    return rc


if __name__ == "__main__":
    sys.exit(main())
