"""While-aware cost model over optimized (post-SPMD) HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE —
for scan-over-layers models that undercounts flops/bytes by ~n_layers x.
This parser walks the printed HLO module, resolves operand shapes from each
computation's def lines, and multiplies loop bodies by the trip count XLA
itself records in ``backend_config={"known_trip_count":{"n":...}}``.

Cost model (per device — the module is the per-partition SPMD program):
  * flops       — dot ops: 2 * prod(out) * prod(lhs contracting dims)
                  (+ reduces at 1 flop/element; elementwise fusions are
                  ignored: matmul-dominated workloads, VPU not the wall)
  * hbm bytes   — per top-level op: operands + outputs (a fusion is one
                  kernel: reads its params, writes its outputs); free ops
                  (bitcast/tuple/get-tuple-element/parameter/constant)
                  excluded; while accounted via body x trip
  * wire bytes  — collective ops with ring-algorithm estimates:
                  all-gather out-in, all-reduce 2*in, reduce-scatter in-out,
                  all-to-all in, collective-permute out
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_TYPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%[\w.\-]+")
_TRIP_RE = re.compile(r'known_trip_count\D+(\d+)')
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=(%[\w.\-]+).*?body=(%[\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_META_RE = re.compile(r'op_name="([^"]+)"')


def _source_key(rest: str, fallback: str) -> str:
    m = _META_RE.search(rest)
    if not m:
        return fallback
    name = m.group(1)
    return re.sub(r"^jit\([^)]*\)/", "", name)

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shapes_in(text: str):
    """[(dtype, [dims...]), ...] for every array type token in text."""
    out = []
    for dt, dims in _TYPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shapes) -> int:
    return sum(
        _DTYPE_BYTES[dt] * (math.prod(dims) if dims else 1) for dt, dims in shapes
    )


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    wire_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES}
    )
    unknown_trip_whiles: int = 0
    # per source-op attribution (metadata op_name), for the perf loop
    by_source: Dict[str, list] = dataclasses.field(default_factory=dict)

    def _merge_source(self, o: "Cost", scale: float = 1.0):
        for k, (f, h, w) in o.by_source.items():
            cur = self.by_source.get(k, [0.0, 0.0, 0.0])
            self.by_source[k] = [
                cur[0] + f * scale, cur[1] + h * scale, cur[2] + w * scale
            ]

    def add_source(self, key: str, f: float, h: float, w: float):
        cur = self.by_source.get(key, [0.0, 0.0, 0.0])
        self.by_source[key] = [cur[0] + f, cur[1] + h, cur[2] + w]

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.wire_bytes += o.wire_bytes
        for k, v in o.wire_by_op.items():
            self.wire_by_op[k] = self.wire_by_op.get(k, 0.0) + v
        self.unknown_trip_whiles += o.unknown_trip_whiles
        self._merge_source(o)
        return self

    def scaled(self, f: float) -> "Cost":
        c = Cost(
            flops=self.flops * f,
            hbm_bytes=self.hbm_bytes * f,
            wire_bytes=self.wire_bytes * f,
            wire_by_op={k: v * f for k, v in self.wire_by_op.items()},
            unknown_trip_whiles=self.unknown_trip_whiles,
        )
        c._merge_source(self, f)
        return c

    def top_sources(self, n=15, key="hbm"):
        idx = {"flops": 0, "hbm": 1, "wire": 2}[key]
        rows = sorted(
            self.by_source.items(), key=lambda kv: -kv[1][idx]
        )[:n]
        return [(k, v[0], v[1], v[2]) for k, v in rows]


def _split_computations(text: str) -> Dict[str, list]:
    """name -> list of body lines. Entry computation keyed '__entry__' too."""
    comps: Dict[str, list] = {}
    cur: Optional[str] = None
    entry = None
    for line in text.splitlines():
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*->.*\{", line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = _split_computations(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self._param_reads: Dict[str, float] = {}

    def total(self) -> Cost:
        if "__entry__" not in self.comps:
            return Cost()
        return self._comp_cost("__entry__")

    # ------------------------------------------------------------------
    def _effective_param_reads(self, name: str) -> float:
        """Bytes a fusion actually reads from its operands: a parameter used
        ONLY by dynamic-slice/gather reads just the slices, not the array
        (the scan-over-layers case: stacked params sliced per trip)."""
        if name in self._param_reads:
            return self._param_reads[name]
        lines = self.comps.get(name, [])
        symbols: Dict[str, list] = {}
        params: Dict[str, float] = {}
        uses: Dict[str, list] = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            var, rest = m.group(2), m.group(3)
            shapes = _shapes_in(rest.split(" ", 1)[0])
            symbols[var] = shapes
            om = re.search(r"([a-z][a-z0-9\-]*)\(", rest)
            op = om.group(1) if om else ""
            if op == "parameter":
                params[var] = float(_bytes_of(shapes))
            else:
                args = rest[om.end() - 1:] if om else ""
                for ref in _OPND_RE.findall(args.split("),", 1)[0]):
                    uses.setdefault(ref, []).append((op, var))
        total = 0.0
        for pvar, pbytes in params.items():
            pu = uses.get(pvar, [])
            if pu and all(op in ("dynamic-slice", "gather") for op, _ in pu):
                total += sum(_bytes_of(symbols.get(v, [])) for _, v in pu)
            else:
                total += pbytes
        self._param_reads[name] = total
        return total

    # ------------------------------------------------------------------
    def _comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        lines = self.comps.get(name, [])
        # pass 1: symbol table of def -> output shapes
        symbols: Dict[str, list] = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            var, rest = m.group(2), m.group(3)
            # output type(s) = everything before the op name token
            op_split = re.match(r"^((?:\([^)]*\)|\S+)\s)", rest)
            head = op_split.group(1) if op_split else rest.split(" ", 1)[0]
            symbols[var] = _shapes_in(head)
        total = Cost()
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            rest = m.group(3)
            # op name = first bare token after the type annotation
            om = re.search(r"([a-z][a-z0-9\-]*)\(", rest)
            if not om:
                continue
            op = om.group(1)
            if op in _FREE_OPS or op.endswith("-done"):
                continue
            var = m.group(2)
            out_shapes = symbols.get(var, [])
            out_bytes = _bytes_of(out_shapes)
            # operand refs (inside the top-level parens only, best effort)
            args_text = rest[om.end() - 1:]
            opnd_refs = _OPND_RE.findall(args_text.split("),", 1)[0])
            opnd_shapes = [s for r in opnd_refs for s in symbols.get(r, [])]
            in_bytes = _bytes_of(opnd_shapes)

            base = op[:-6] if op.endswith("-start") else op
            if base == "while":
                cb = _COND_BODY_RE.search(rest)
                trip_m = _TRIP_RE.search(rest)
                trip = int(trip_m.group(1)) if trip_m else 1
                inner = Cost()
                if cb:
                    inner += self._comp_cost(cb.group(2))
                    inner += self._comp_cost(cb.group(1))
                if not trip_m:
                    inner.unknown_trip_whiles += 1
                total += inner.scaled(trip)
                continue
            if base in ("call", "fusion"):
                cm = _CALLS_RE.search(rest)
                if cm and base == "call":
                    total += self._comp_cost(cm.group(1))
                    total += Cost(hbm_bytes=in_bytes + out_bytes)
                elif cm:  # fusion: flops/wire from interior; reads are the
                    # interior's *effective* parameter reads (slice-aware)
                    interior = self._comp_cost(cm.group(1))
                    reads = self._effective_param_reads(cm.group(1))
                    c = Cost(flops=interior.flops,
                             wire_bytes=interior.wire_bytes,
                             wire_by_op=dict(interior.wire_by_op),
                             hbm_bytes=reads + out_bytes)
                    for k2, (f2, _h2, w2) in interior.by_source.items():
                        if f2 or w2:
                            c.add_source(k2, f2, 0.0, w2)
                    c.add_source(_source_key(rest, "fusion"),
                                 0.0, reads + out_bytes, 0.0)
                    total += c
                else:
                    total += Cost(hbm_bytes=in_bytes + out_bytes)
                continue
            if base == "conditional":
                for cn in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                     r"true_computation=(%[\w.\-]+)|"
                                     r"false_computation=(%[\w.\-]+))", rest):
                    for grp in cn:
                        for ref in _OPND_RE.findall(grp or ""):
                            total += self._comp_cost(ref)
                total += Cost(hbm_bytes=in_bytes + out_bytes)
                continue
            if base in _COLLECTIVES:
                if base == "all-gather":
                    wire = max(0, out_bytes - in_bytes) or out_bytes
                elif base == "all-reduce":
                    wire = 2 * in_bytes if in_bytes else 2 * out_bytes
                elif base == "reduce-scatter":
                    wire = max(0, in_bytes - out_bytes) or in_bytes
                elif base == "all-to-all":
                    wire = in_bytes or out_bytes
                else:
                    wire = out_bytes or in_bytes
                c = Cost(hbm_bytes=in_bytes + out_bytes, wire_bytes=float(wire))
                c.wire_by_op[base] += float(wire)
                c.add_source(_source_key(rest, base),
                             0.0, in_bytes + out_bytes, float(wire))
                total += c
                continue
            if base == "dot":
                lhs_contract = _LHS_CONTRACT_RE.search(rest)
                flops = 0.0
                if lhs_contract and opnd_refs:
                    lhs_shapes = symbols.get(opnd_refs[0], [])
                    if lhs_shapes:
                        dims = lhs_shapes[0][1]
                        cdims = [
                            int(d)
                            for d in lhs_contract.group(1).split(",")
                            if d
                        ]
                        contract = math.prod(
                            dims[d] for d in cdims if d < len(dims)
                        )
                        out_elems = sum(
                            math.prod(s[1]) if s[1] else 1 for s in out_shapes
                        )
                        flops = 2.0 * out_elems * contract
                c = Cost(flops=flops, hbm_bytes=in_bytes + out_bytes)
                c.add_source(_source_key(rest, "dot"),
                             flops, in_bytes + out_bytes, 0.0)
                total += c
                continue
            if base in ("reduce", "reduce-window"):
                in_elems = sum(
                    math.prod(s[1]) if s[1] else 1 for s in opnd_shapes
                )
                c = Cost(flops=float(in_elems),
                         hbm_bytes=in_bytes + out_bytes)
                c.add_source(_source_key(rest, base),
                             float(in_elems), in_bytes + out_bytes, 0.0)
                total += c
                continue
            if base in ("dynamic-slice", "gather"):
                # reads just the slice, writes the slice
                c = Cost(hbm_bytes=2.0 * out_bytes)
                c.add_source(_source_key(rest, base), 0.0, 2.0 * out_bytes, 0.0)
                total += c
                continue
            if base == "dynamic-update-slice":
                # reads + writes the update region (operand 1)
                upd = (
                    _bytes_of(symbols.get(opnd_refs[1], []))
                    if len(opnd_refs) > 1
                    else out_bytes
                )
                c = Cost(hbm_bytes=2.0 * upd)
                c.add_source(_source_key(rest, base), 0.0, 2.0 * upd, 0.0)
                total += c
                continue
            if base == "scatter":
                upd = (
                    _bytes_of(symbols.get(opnd_refs[-1], []))
                    if opnd_refs
                    else out_bytes
                )
                c = Cost(hbm_bytes=3.0 * upd)
                c.add_source(_source_key(rest, base), 0.0, 3.0 * upd, 0.0)
                total += c
                continue
            # everything else: IO bytes only (copy, sort, scatter, gather,
            # dynamic-slice, dynamic-update-slice, rng, convert, custom-call)
            c = Cost(hbm_bytes=in_bytes + out_bytes)
            c.add_source(_source_key(rest, base), 0.0, in_bytes + out_bytes, 0.0)
            total += c
        self._memo[name] = total
        return total


def analyze_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()
