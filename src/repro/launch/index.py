"""Streaming index-creation CLI — a thin shell over ``repro.index.Index``.

The paper's Table 2 workflow: descriptor blocks stream through wave-based
assignment into index files, and the searchable collection keeps growing
between runs. Each store block becomes one ``Index.append`` wave under the
WaveScheduler (retry + wave statistics, the jobtracker analog); ``commit``
publishes the appended segments atomically (``--commit-every`` controls
durability granularity); ``--index-dir`` makes the grown index reopenable
by later index/serve runs — the paper's "index once, search many, keep
growing" loop. ``--compact`` folds all segments into one at the end.

The historical flags (``--rows``/``--block-rows``/``--inject-failures``/
``--verify-queries``/``--layout``/``--probes``) keep their meaning.

Usage:
  PYTHONPATH=src python -m repro.launch.index --rows 300000 --block-rows 50000 \
      [--index-dir /tmp/idx] [--commit-every 2] [--compact] \
      [--inject-failures] [--verify-queries 64]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="streaming index creation over the segment lifecycle API"
    )
    ap.add_argument("--rows", type=int, default=300_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--block-rows", type=int, default=50_000)
    ap.add_argument("--fanout", type=int, nargs=2, default=(32, 32))
    ap.add_argument("--tree-sample", type=int, default=65_536)
    ap.add_argument("--inject-failures", action="store_true")
    ap.add_argument(
        "--index-dir", default=None,
        help="durable index directory (create or grow); default: ephemeral",
    )
    ap.add_argument(
        "--commit-every", type=int, default=0,
        help="commit after every N appended blocks (0 = one commit at the "
        "end)",
    )
    ap.add_argument(
        "--compact", action="store_true",
        help="merge all segments into one after the appends",
    )
    ap.add_argument(
        "--compact-incremental", action="store_true",
        help="run size-tiered incremental compaction steps (one small "
        "tier or tombstone-heavy batch per step, docs/dynamicity.md) "
        "until the policy reaches a fixed point, instead of one "
        "stop-the-world merge",
    )
    ap.add_argument(
        "--wire-dtype", choices=("float32", "bfloat16"), default="float32",
        help="routed-shuffle payload dtype for appends. NOTE: the old CLI "
        "always used bfloat16 (build_index's default); the lifecycle "
        "facade defaults to float32 so grown indexes stay bit-identical "
        "to one-shot rebuilds",
    )
    ap.add_argument(
        "--verify-queries", type=int, default=0,
        help="after indexing, search N perturbed corpus rows and report "
        "recall (0 = skip)",
    )
    ap.add_argument(
        "--layout",
        choices=("point_major", "query_routed", "scan_codes", "auto"),
        default="auto", help="scan layout for the verification search",
    )
    ap.add_argument(
        "--probes", type=int, default=1,
        help="multi-probe width for the verification search",
    )
    ap.add_argument(
        "--codes", action="store_true",
        help="train product-quantized codes over the grown index and "
        "persist them with the commit (docs/compressed_codes.md); an "
        "index that already carries codes re-encodes appended segments "
        "automatically, with or without this flag",
    )
    ap.add_argument(
        "--subvectors", type=int, default=8,
        help="PQ subvectors per row for --codes (= compressed bytes/row)",
    )
    ap.add_argument(
        "--code-bits", type=int, default=8,
        help="PQ bits per subvector code for --codes (8 = 256 centroids)",
    )
    ap.add_argument(
        "--rerank", type=int, default=None,
        help="ADC candidate depth for the verification search on the "
        "codes tier (default: engine heuristic)",
    )
    ap.add_argument(
        "--cost-model",
        choices=("auto", "heuristic", "observed", "fitted"),
        default="auto",
        help="cost model for the verification search's auto layout "
        "(consults the index's persisted calibration; docs/cost_model.md)",
    )
    ap.add_argument(
        "--trace-out", default=None,
        help="record index-lifecycle spans (append/commit/compact) and "
        "write them here: .jsonl = structured log, else Chrome "
        "trace_event JSON (docs/observability.md)",
    )
    ap.add_argument(
        "--trace-sample", type=float, default=1.0,
        help="trace sample rate (lifecycle spans are process-scoped and "
        "always kept; this only thins request-scoped spans)",
    )
    ap.add_argument(
        "--metrics-out", default=None,
        help="dump the unified metrics registry snapshot (index.appends/"
        "commits/compacts, calibration.records, ...) as JSON here",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.obs import NULL_TRACER, Tracer, tracing

    tracer = (
        Tracer(sample=args.trace_sample, seed=args.seed)
        if args.trace_out else NULL_TRACER
    )
    # scoped install: main() is called in-process by benchmarks/tests, so
    # the previous tracer must come back whatever happens below
    with tracing(tracer):
        return _run(args, tracer)


def _run(args, tracer) -> int:
    from repro.core.tree import build_tree
    from repro.data.store import VirtualStore
    from repro.distributed.failure import FailureInjector
    from repro.distributed.meshutil import local_mesh
    from repro.distributed.wavescheduler import WaveScheduler
    from repro.index import Index, has_index

    mesh = local_mesh()
    store = VirtualStore(
        args.rows, args.dim, block_rows=args.block_rows, seed=args.seed
    )
    print(f"store: {store.n_rows} rows in {store.n_blocks} blocks")

    if args.index_dir and has_index(args.index_dir):
        t0 = time.perf_counter()
        idx = Index.open(args.index_dir, mesh=mesh)
        print(
            f"index: opened {args.index_dir} v{idx.version} "
            f"({idx.n_segments} segments, {idx.rows} rows) in "
            f"{time.perf_counter() - t0:.2f}s — appending"
        )
        if jnp.dtype(args.wire_dtype) != jnp.dtype(idx.wire_dtype):
            print(
                f"warning: --wire-dtype {args.wire_dtype} ignored — the "
                f"index was created with {jnp.dtype(idx.wire_dtype)} and "
                "appends keep the creation-time dtype"
            )
        tree = idx.tree
    else:
        t0 = time.perf_counter()
        tree = build_tree(
            jnp.asarray(store.sample_for_tree(args.tree_sample)),
            tuple(args.fanout),
            key=jax.random.PRNGKey(args.seed),
        )
        jax.block_until_ready(tree.levels[-1])
        print(f"tree: {tree.n_leaves} leaves "
              f"({time.perf_counter() - t0:.2f}s)")
        idx = Index.create(tree, args.index_dir, mesh=mesh,
                           wire_dtype=jnp.dtype(args.wire_dtype),
                           extra={"corpus_seed": args.seed})

    # --- resumable ingest: a crashed --commit-every run must not re-append
    # its already-committed blocks on rerun. The cursor (store signature +
    # next block + base id) rides in the index meta and is bumped in the
    # same manifest as each commit, so it can never disagree with the data.
    sig = {"seed": args.seed, "rows": args.rows, "dim": args.dim,
           "block_rows": args.block_rows}
    cursor = idx.meta.get("ingest") or {}
    if cursor.get("sig") == sig and cursor.get("next_block", 0) > 0:
        start_block = int(cursor["next_block"])
        base_id = int(cursor["base_id"])
        print(f"ingest: resuming this store at block {start_block}/"
              f"{store.n_blocks} (base id {base_id})")
    else:
        start_block = 0
        base_id = idx.next_id  # appended block ids stay globally unique
    appended: dict[int, dict] = {}

    def wave_fn(block_id: int):
        # idempotent under WaveScheduler retries: a wave that failed
        # *after* its append staged durably (e.g. mid-commit IO error)
        # must not re-append the same ids on the retry
        if block_id not in appended:
            block = store.read_block(block_id)
            name = idx.append(block.vecs, ids=base_id + block.ids)
            seg = idx.segments[-1]
            appended[block_id] = {"name": name, "rows": seg.valid_rows,
                                  "overflow": int(seg.index.overflow)}
        if args.commit_every and (block_id + 1) % args.commit_every == 0:
            idx.update_meta(ingest={"sig": sig, "next_block": block_id + 1,
                                    "base_id": base_id})
            idx.commit()
        return appended[block_id]

    def fold(state, wave_out):
        state = state or {"segments": [], "rows": 0, "overflow": 0}
        state["segments"].append(wave_out["name"])
        state["rows"] += wave_out["rows"]
        state["overflow"] += wave_out["overflow"]
        return state

    injector = (
        FailureInjector(fail_at=[(1, 0), (3, 0)]) if args.inject_failures else None
    )
    sched = WaveScheduler(wave_fn, fold, failure_injector=injector, max_retries=2)
    t0 = time.perf_counter()
    result = sched.run(range(store.n_blocks), start_at=start_block)
    done = {"sig": sig, "next_block": result.completed, "base_id": base_id}
    if idx.meta.get("ingest") != done:
        idx.update_meta(ingest=done)
    if args.codes and idx.quantizer is None:
        # train once over everything appended so far; the codes artifacts
        # publish in the same commit as the final ingest cursor
        t_c = time.perf_counter()
        idx.enable_codes(m=args.subvectors, bits=args.code_bits,
                         seed=args.seed)
        cs = idx.codes_stats()
        print(f"codes: trained m={cs['code_m']} bits={cs['code_bits']} "
              f"({cs['bytes_per_row']} B/row vs "
              f"{cs['raw_bytes_per_row']} raw, "
              f"{cs['compression_ratio']:.1f}x) in "
              f"{time.perf_counter() - t_c:.2f}s")
    version = idx.commit()
    dt = time.perf_counter() - t0

    waves_run = store.n_blocks - start_block
    ok = [r for r in result.records if r.ok]
    failed = [r for r in result.records if not r.ok]
    durations = sorted(r.duration_s for r in ok) or [0.0]
    print(
        f"index job: {result.completed - start_block}/{waves_run} append "
        f"waves in {dt:.2f}s; {len(failed)} failed attempts (retried), "
        f"route overflow {result.state['overflow'] if result.state else 0}; "
        f"committed v{version} ({idx.n_segments} segments, {idx.rows} live "
        "rows)"
    )
    print(
        "wave stats: avg {:.2f}s min {:.2f}s max {:.2f}s median {:.2f}s "
        "(Table 5 analog)".format(
            float(np.mean(durations)),
            durations[0],
            durations[-1],
            durations[len(durations) // 2],
        )
    )
    n_indexed = result.state["rows"] if result.state else 0
    expected = store.n_rows - min(start_block * args.block_rows, store.n_rows)
    assert n_indexed == expected, (n_indexed, expected)
    print(f"indexed {n_indexed} descriptors == remaining corpus size OK")

    if args.compact:
        t0 = time.perf_counter()
        name = idx.compact()
        print(f"compacted -> {name} (v{idx.version}, {idx.rows} rows) in "
              f"{time.perf_counter() - t0:.2f}s")
    elif args.compact_incremental:
        # one published step per iteration; the policy's empty selection
        # (None without a version bump) is the fixed point
        steps = 0
        t0 = time.perf_counter()
        while steps < 64:
            v0 = idx.version
            name = idx.compact(incremental=True)
            if idx.version == v0:  # empty selection: nothing published
                break
            steps += 1
            print(f"compact step {steps}: -> {name or '(dropped dead rows)'} "
                  f"(v{idx.version}, {len(idx.segments)} segments)")
        print(f"incremental compaction: {steps} steps in "
              f"{time.perf_counter() - t0:.2f}s")

    if args.verify_queries:
        # verification search straight off the lifecycle facade: perturbed
        # corpus rows must find themselves under the requested plan
        rng = np.random.default_rng(args.seed + 7)
        rows = np.sort(rng.choice(store.n_rows, args.verify_queries,
                                  replace=False))
        queries = (
            store.read_rows(rows)
            + rng.standard_normal((len(rows), args.dim)).astype(np.float32)
        )
        res = idx.search(queries, k=1, layout=args.layout,
                         probes=args.probes, cost_model=args.cost_model,
                         rerank=args.rerank)
        got = np.array(res.ids[:, 0])
        hit = got == base_id + rows
        # a grown index may hold exact copies of the planted row (e.g. the
        # same seeded store appended twice): a returned neighbour at least
        # as close as the planted row is a find, not a miss (2.0 absolute
        # slack: fp32 ||p||^2-2pq+||q||^2 vs the (p-q)^2 oracle, as in
        # tests/test_index_search.py)
        planted_d = ((store.read_rows(rows) - queries) ** 2).sum(1)
        hit |= np.array(res.dists[:, 0]) <= planted_d + 2.0
        recall = float(hit.mean())
        print(
            f"verify: layout={args.layout} probes={args.probes} "
            f"recall@1 {recall:.3f} pairs {float(res.pairs):.3g} "
            f"q_cap_overflow {int(res.q_cap_overflow)}"
        )

    if args.trace_out:
        from repro.obs import export_trace

        export_trace(tracer, args.trace_out)
        d = tracer.describe()
        print(f"trace -> {args.trace_out} ({d['spans']} spans, "
              f"{d['events']} events)")
    if args.metrics_out:
        from repro.obs import get_registry

        get_registry().dump(args.metrics_out)
        print(f"metrics registry -> {args.metrics_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
