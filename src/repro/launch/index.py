"""Streaming index-creation job: the paper's Table 2 workflow end-to-end.

Drives the store's blocks through the index pipeline wave-by-wave under the
WaveScheduler (retry + checkpoint/restart + wave statistics), exactly the
shape of the paper's 8h27m 100-nodes x 30B-descriptor job — scaled to the
container. Each wave is one jitted assign+route+sort step; the folded state
is the accumulated cluster-sorted index.

Usage:
  PYTHONPATH=src python -m repro.launch.index --rows 300000 --block-rows 50000 \
      [--inject-failures] [--ckpt-dir /tmp/repro_index]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=300_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--block-rows", type=int, default=50_000)
    ap.add_argument("--fanout", type=int, nargs=2, default=(32, 32))
    ap.add_argument("--tree-sample", type=int, default=65_536)
    ap.add_argument("--inject-failures", action="store_true")
    ap.add_argument(
        "--verify-queries", type=int, default=0,
        help="after indexing, search N perturbed corpus rows and report "
        "recall (0 = skip)",
    )
    ap.add_argument(
        "--layout", choices=("point_major", "query_routed", "auto"),
        default="auto", help="scan layout for the verification search",
    )
    ap.add_argument(
        "--probes", type=int, default=1,
        help="multi-probe width for the verification search",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core.index_build import build_index
    from repro.core.tree import build_tree, tree_assign
    from repro.data.store import VirtualStore
    from repro.distributed.failure import FailureInjector
    from repro.distributed.meshutil import local_mesh
    from repro.distributed.wavescheduler import WaveScheduler

    mesh = local_mesh()
    store = VirtualStore(
        args.rows, args.dim, block_rows=args.block_rows, seed=args.seed
    )
    print(f"store: {store.n_rows} rows in {store.n_blocks} blocks")

    t0 = time.perf_counter()
    tree = build_tree(
        jnp.asarray(store.sample_for_tree(args.tree_sample)),
        tuple(args.fanout),
        key=jax.random.PRNGKey(args.seed),
    )
    jax.block_until_ready(tree.levels[-1])
    print(f"tree: {tree.n_leaves} leaves ({time.perf_counter() - t0:.2f}s)")

    def wave_fn(block_id: int):
        block = store.read_block(block_id)
        idx = build_index(
            jnp.asarray(block.vecs),
            tree,
            mesh,
            ids=jnp.asarray(block.ids.astype(np.int32)),
        )
        # pull the per-wave partial index to host (the paper's reducers
        # write index files to HDFS; ours append to the host-side store)
        return {
            "vecs": np.asarray(idx.vecs),
            "ids": np.asarray(idx.ids),
            "leaves": np.asarray(idx.leaves),
            "overflow": int(idx.overflow),
        }

    def fold(state, wave_out):
        state = state or {"parts": [], "overflow": 0}
        state["parts"].append(wave_out)
        state["overflow"] += wave_out["overflow"]
        return state

    injector = (
        FailureInjector(fail_at=[(1, 0), (3, 0)]) if args.inject_failures else None
    )
    sched = WaveScheduler(wave_fn, fold, failure_injector=injector, max_retries=2)
    t0 = time.perf_counter()
    result = sched.run(range(store.n_blocks))
    dt = time.perf_counter() - t0

    ok = [r for r in result.records if r.ok]
    failed = [r for r in result.records if not r.ok]
    durations = sorted(r.duration_s for r in ok)
    print(
        f"index job: {result.completed}/{store.n_blocks} waves in {dt:.2f}s; "
        f"{len(failed)} failed attempts (retried), "
        f"route overflow {result.state['overflow']}"
    )
    print(
        "wave stats: avg {:.2f}s min {:.2f}s max {:.2f}s median {:.2f}s "
        "(Table 5 analog)".format(
            float(np.mean(durations)),
            durations[0],
            durations[-1],
            durations[len(durations) // 2],
        )
    )
    n_indexed = sum((p["ids"] >= 0).sum() for p in result.state["parts"])
    assert n_indexed == store.n_rows, (n_indexed, store.n_rows)
    print(f"indexed {n_indexed} descriptors == corpus size OK")

    if args.verify_queries:
        # verification search: rebuild one jittable index over the corpus
        # and check perturbed corpus rows find themselves under the
        # requested execution plan (layout/probes knobs)
        from repro.core.search import batch_search

        rng = np.random.default_rng(args.seed + 7)
        all_vecs = np.concatenate(
            [store.read_block(b).vecs for b in range(store.n_blocks)]
        )
        index = build_index(jnp.asarray(all_vecs), tree, mesh)
        rows = rng.choice(store.n_rows, args.verify_queries, replace=False)
        queries = jnp.asarray(
            all_vecs[rows]
            + rng.standard_normal((len(rows), args.dim)).astype(np.float32)
        )
        res = batch_search(
            index, tree, queries, k=1, mesh=mesh, layout=args.layout,
            probes=args.probes,
        )
        recall = float((np.array(res.ids[:, 0]) == rows).mean())
        print(
            f"verify: layout={args.layout} probes={args.probes} "
            f"recall@1 {recall:.3f} pairs {float(res.pairs):.3g} "
            f"q_cap_overflow {int(res.q_cap_overflow)}"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
