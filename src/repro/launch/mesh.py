"""Production mesh definitions.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run pins the device count via XLA_FLAGS
before any jax initialisation).

  single pod : (data=16, model=16)             = 256 chips (TPU v5e pod)
  multi-pod  : (pod=2, data=16, model=16)      = 512 chips
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
