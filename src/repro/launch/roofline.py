"""Roofline-term extraction from a compiled dry-run artifact.

Three terms (seconds, per chip) against TPU v5e constants:

  compute    = HLO_FLOPs / peak_FLOPs            (cost_analysis, per-device)
  memory     = HLO_bytes / HBM_bw                (cost_analysis, per-device)
  collective = wire_bytes / ICI_link_bw          (parsed from optimized HLO)

``collective_bytes`` parses the post-SPMD optimized HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op contributes its estimated per-chip wire traffic (ring-algorithm
estimates; the (S-1)/S factor is folded to 1).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

#: TPU v5e per-chip hardware model (per task spec)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([0-9,]*)\]")


def _tensor_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-type estimated wire bytes (per chip) from optimized HLO."""
    out: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    out["ops"] = 0
    for line in hlo_text.splitlines():
        op = next(
            (c for c in _COLLECTIVES if f" {c}(" in line or f" {c}-start(" in line),
            None,
        )
        if op is None:
            continue
        # "-done" ops repeat the shape of their "-start"; count starts only
        if f"{op}-done" in line:
            continue
        idx = line.find(f" {op}")
        out_types = _SHAPE_RE.findall(line[:idx])
        in_types = _SHAPE_RE.findall(line[idx:])
        out_bytes = sum(_tensor_bytes(d, s) for d, s in out_types)
        in_bytes = sum(_tensor_bytes(d, s) for d, s in in_types)
        if op == "all-gather":
            wire = max(0, out_bytes - in_bytes) or out_bytes
        elif op == "all-reduce":
            wire = 2 * in_bytes if in_bytes else 2 * out_bytes
        elif op == "reduce-scatter":
            wire = max(0, in_bytes - out_bytes) or in_bytes
        elif op == "all-to-all":
            wire = in_bytes or out_bytes
        else:  # collective-permute
            wire = out_bytes or in_bytes
        out[op] += float(wire)
        out["ops"] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device HLO bytes accessed
    wire_bytes: float  # per-device collective bytes
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    collectives: Dict[str, float]

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled) -> Roofline:
    """Three-term roofline from the compiled per-device module.

    Uses the while-aware HLO text cost model (repro.launch.hlo_cost): XLA's
    own cost_analysis counts loop bodies once, undercounting scanned models
    by ~n_layers x; XLA's numbers are kept as cross-check fields.
    """
    from repro.launch import hlo_cost

    text = compiled.as_text()
    c = hlo_cost.analyze_text(text)
    xla_cost = compiled.cost_analysis() or {}
    t_c = c.flops / PEAK_FLOPS_BF16
    t_m = c.hbm_bytes / HBM_BW
    t_x = c.wire_bytes / ICI_LINK_BW
    dom = max(
        (("compute", t_c), ("memory", t_m), ("collective", t_x)),
        key=lambda kv: kv[1],
    )[0]
    coll = dict(c.wire_by_op)
    coll["total"] = c.wire_bytes
    coll["unknown_trip_whiles"] = c.unknown_trip_whiles
    coll["xla_flops_while_once"] = float(xla_cost.get("flops", 0.0) or 0.0)
    coll["xla_bytes_while_once"] = float(
        xla_cost.get("bytes accessed", 0.0) or 0.0
    )
    return Roofline(
        flops=c.flops,
        hbm_bytes=c.hbm_bytes,
        wire_bytes=c.wire_bytes,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        dominant=dom,
        collectives=coll,
    )


def fused_scan_estimate(
    *,
    rows: int,
    dim: int,
    q_rows: int,
    k: int,
    block_rows: int,
    dtype_bytes: int = 4,
) -> dict:
    """First-order roofline for the fused multi-probe tile scan.

    The flops are layout-independent (every (point, query) pair costs one
    ``dim``-wide MAC, times 2); what the fused kernel changes is the HBM
    story. The reference wave sweep materialises each wave's distance
    slab and folds a ``(q_rows, 2k)`` running table through memory once
    per wave; the fused kernel keeps the running top-k in VMEM and emits
    one ``(q_rows, k)`` table at the end — so its byte count is just the
    operand stream plus the output. The intensity gap between the two is
    the kernel's headroom, and it grows with ``rows / block_rows``
    (docs/kernels.md). All terms are per shard.
    """
    n_waves = max(1, int(rows) // max(1, int(block_rows)))
    flops = 2.0 * rows * q_rows * dim
    stream = float(rows + q_rows) * dim * dtype_bytes  # operands, once
    out = float(q_rows) * k * 8.0  # f32 dists + i32 ids
    fused_bytes = stream + out
    slab = float(rows) * q_rows * 4.0  # per-wave distance slabs, summed
    carry = float(n_waves) * q_rows * 2 * k * 8.0  # running-table folds
    reference_bytes = stream + out + slab + carry
    return {
        "flops": flops,
        "n_waves": n_waves,
        "fused_hbm_bytes": fused_bytes,
        "reference_hbm_bytes": reference_bytes,
        "fused_intensity": flops / max(1.0, fused_bytes),
        "reference_intensity": flops / max(1.0, reference_bytes),
        "t_compute": flops / PEAK_FLOPS_BF16,
        "t_memory_fused": fused_bytes / HBM_BW,
        "t_memory_reference": reference_bytes / HBM_BW,
    }


def memory_stats(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": int(m.argument_size_in_bytes),
            "output_bytes": int(m.output_size_in_bytes),
            "temp_bytes": int(m.temp_size_in_bytes),
            "alias_bytes": int(m.alias_size_in_bytes),
            "code_bytes": int(m.generated_code_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover - backend-specific
        return {"error": repr(e)}
