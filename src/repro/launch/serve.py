"""Batched search serving: the paper's throughput experiment (Exp #5) as a
runnable service loop.

Builds (or restores) an index over a synthetic SIFT-like collection, then
serves query batches of configurable size, reporting ms/image throughput —
the paper's 210 ms/image headline measurement. Batches are the unit of
scheduling exactly as in the paper: bigger batches amortise the lookup-table
broadcast (first map wave) and raise throughput.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --rows 200000 --images 2000 \
      --batches 3 --batch-images 256 [--layout auto] [--probes 3]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--images", type=int, default=2000)
    ap.add_argument("--fanout", type=int, nargs=2, default=(32, 32))
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--batch-images", type=int, default=256)
    ap.add_argument("--desc-per-image", type=int, default=None)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument(
        "--layout", choices=("point_major", "query_routed", "auto"),
        default="point_major",
        help="scan layout; auto lets the engine plan() heuristic pick",
    )
    ap.add_argument(
        "--probes", type=int, default=1,
        help="multi-probe width: leaves visited per query (recall lever)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core.index_build import build_index
    from repro.core.search import batch_search
    from repro.core.tree import build_tree
    from repro.data import synth
    from repro.distributed.meshutil import local_mesh

    mesh = local_mesh()
    dpi = args.desc_per_image or max(1, args.rows // args.images)
    print(f"corpus: {args.images} images x {dpi} descriptors x d={args.dim} "
          f"(layout={args.layout}, probes={args.probes})")
    vecs_np, img_ids = synth.sample_images(
        args.images, dpi, args.dim, seed=args.seed
    )
    vecs = jnp.asarray(vecs_np)

    t0 = time.perf_counter()
    tree = build_tree(vecs, tuple(args.fanout), key=jax.random.PRNGKey(1))
    jax.block_until_ready(tree.levels[-1])
    print(f"tree: {tree.n_leaves} leaves in {time.perf_counter() - t0:.2f}s")

    t0 = time.perf_counter()
    index = build_index(vecs, tree, mesh)
    jax.block_until_ready(index.vecs)
    print(
        f"index: {int(index.n_valid.sum())} rows in {time.perf_counter() - t0:.2f}s"
        f" (overflow {int(index.overflow)})"
    )

    rng = np.random.default_rng(args.seed + 1)
    for b in range(args.batches):
        pick = rng.choice(args.images, args.batch_images, replace=False)
        rows = np.concatenate([np.flatnonzero(img_ids == i) for i in pick])
        queries = jnp.asarray(
            vecs_np[rows] + rng.standard_normal((len(rows), args.dim)).astype(np.float32) * 4
        )
        t0 = time.perf_counter()
        res = batch_search(index, tree, queries, k=args.k, mesh=mesh,
                           layout=args.layout, probes=args.probes)
        jax.block_until_ready(res.ids)
        dt = time.perf_counter() - t0
        # image-level voting for top-1
        top_imgs = np.asarray(img_ids)[
            np.clip(np.array(res.ids[:, 0]), 0, None)
        ]
        correct = 0
        off = 0
        for i in pick:
            n_i = int((img_ids == i).sum())
            votes = top_imgs[off : off + n_i]
            vals, cnts = np.unique(votes, return_counts=True)
            correct += int(vals[np.argmax(cnts)] == i)
            off += n_i
        ms_per_image = dt / args.batch_images * 1e3
        print(
            f"batch {b}: {len(rows)} queries, {dt:.3f}s "
            f"= {ms_per_image:.1f} ms/image (paper: 210 ms/image), "
            f"recall@1 {correct}/{args.batch_images}, "
            f"pairs {float(res.pairs):.3g}, q_cap_overflow {int(res.q_cap_overflow)}"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
