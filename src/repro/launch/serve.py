"""Online search service CLI — a thin shell over ``repro.serving``.

The paper's Exp #5 measures batch-search throughput (~210 ms/image at 12k-
image batches); this launcher runs the same engine as a *service*: the
index is loaded-or-built once through the segment lifecycle facade
(``--index-dir`` holds a committed ``repro.index.Index``, so
index-once/serve-many works across invocations — including indexes grown
by ``repro.launch.index`` appends), a ladder of batch-size buckets is
compiled at warmup, and a trace-driven request stream is played through
the dynamic micro-batcher — reporting the latency distribution
(p50/p95/p99), engine ms/image, cache hit rate, and the steady-state
recompile count (the serving invariant: 0 after warmup).

Scheduling is deadline-aware by default (``--scheduler edf``): requests
carry priority classes, the batcher dispatches earliest-deadline-first
with fitted-cost admission control, and ``--target-p95-ms`` closes the
loop by letting the fitted cost model pick the bucket ladder for a
latency target (docs/slo_serving.md). ``--scheduler fifo`` keeps the
original arrival-order coalescing for comparability.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --trace zipf --requests 500
  PYTHONPATH=src python -m repro.launch.serve --index-dir /tmp/idx \\
      --trace uniform --requests 200 --rate 100 --cache-leaves 64
  # multi-tenant trace + latency target, FIFO baseline for comparison:
  PYTHONPATH=src python -m repro.launch.serve --trace multi \\
      --requests 600 --target-p95-ms 100 --scheduler fifo
  # legacy fixed-batch protocol (the old CLI):
  PYTHONPATH=src python -m repro.launch.serve --batches 3 --batch-images 256
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _build_corpus(args, dpi: int):
    """The synthetic image collection of the old CLI."""
    from repro.data import synth

    vecs, _ = synth.sample_images(args.images, dpi, args.dim, seed=args.seed)
    return vecs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="online search service over a (built or restored) index"
    )
    # corpus / index
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--images", type=int, default=2000)
    ap.add_argument("--fanout", type=int, nargs=2, default=(32, 32))
    ap.add_argument("--desc-per-image", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--index-dir", default=None,
                    help="persist/restore the built index + corpus here "
                         "(index-once/serve-many)")
    ap.add_argument("--rebuild", action="store_true",
                    help="ignore an existing --index-dir checkpoint")
    # engine
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument(
        "--layout",
        choices=("point_major", "query_routed", "scan_codes", "auto"),
        default="auto",
        help="scan layout; auto lets the engine plan() heuristic pick "
             "(scan_codes requires a codes-enabled index or --codes)",
    )
    ap.add_argument("--probes", type=int, default=1,
                    help="multi-probe width: leaves visited per query")
    ap.add_argument("--codes", action="store_true",
                    help="train PQ codes on the index (if not already "
                         "enabled) so auto planning may serve the "
                         "compressed tier (docs/compressed_codes.md)")
    ap.add_argument("--subvectors", type=int, default=8,
                    help="PQ subvectors per row for --codes (bytes/row)")
    ap.add_argument("--code-bits", type=int, default=8,
                    help="PQ bits per subvector code for --codes")
    ap.add_argument("--rerank", type=int, default=None,
                    help="ADC candidate depth refetched for the exact "
                         "rerank on the codes tier (default: engine "
                         "heuristic, max(k, min(8k, 64)) clamped)")
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--cost-model",
                    choices=("auto", "heuristic", "observed", "fitted"),
                    default="auto",
                    help="which cost model ranks an auto layout: auto "
                         "prefers fitted > observed > heuristic over the "
                         "index's manifest-persisted calibration "
                         "(docs/cost_model.md)")
    # serving
    ap.add_argument("--max-batch-rows", type=int, default=4096,
                    help="largest micro-batch bucket (query rows)")
    ap.add_argument("--n-buckets", type=int, default=3)
    ap.add_argument("--buckets", default=None,
                    help="explicit comma-separated bucket sizes (query rows)")
    ap.add_argument("--max-wait-ms", type=float, default=None,
                    help="micro-batcher base coalescing deadline "
                         "(default 5.0, or the tuned slack under "
                         "--target-p95-ms)")
    ap.add_argument("--max-queue", type=int, default=4096,
                    help="pending-request cap (backpressure)")
    ap.add_argument("--scheduler", choices=("edf", "fifo"), default="edf",
                    help="micro-batcher scheduler: edf (default) is "
                         "deadline-aware — earliest-deadline-first within "
                         "priority class, fitted-cost admission control "
                         "shedding overload batch work; fifo is the "
                         "original arrival-order coalescing (identical "
                         "results, different latency profile)")
    ap.add_argument("--refresh-every", type=int, default=0,
                    help="call session.maybe_refresh() after every N "
                         "engine dispatches, adopting index versions "
                         "committed by a concurrent writer between "
                         "batches (docs/dynamicity.md); 0 = serve the "
                         "pinned version for the whole trace")
    ap.add_argument("--target-p95-ms", type=float, default=None,
                    help="closed-loop latency target: the fitted cost "
                         "model picks the bucket ladder (and per-shard "
                         "slab budgets) whose largest dispatch fits this "
                         "p95 (ignored when --buckets is explicit; no-op "
                         "until the index carries a usable calibration)")
    ap.add_argument("--cache-leaves", type=int, default=0,
                    help="hot-leaf cache capacity in leaves (0 = off)")
    ap.add_argument("--cache-admit", type=int, default=2,
                    help="leaf routings before a leaf is admitted")
    ap.add_argument("--cache-eviction", choices=("cost", "lru"),
                    default="cost",
                    help="hot-leaf eviction policy: cost ranks resident "
                         "leaves by predicted ms-saved-per-resident-byte "
                         "(fitted cost model), lru is the original "
                         "recency policy")
    ap.add_argument("--shards", type=int, default=None,
                    help="scatter-gather serving over N index shards "
                         "(default: the index's persisted shard plan, or "
                         "unsharded)")
    ap.add_argument("--shard-plan", choices=("round_robin", "balanced"),
                    default=None,
                    help="segment->shard assignment strategy for --shards "
                         "(default: the index's persisted strategy, else "
                         "round_robin; persisted in the index manifest "
                         "when --index-dir is given)")
    # workload
    ap.add_argument("--trace", choices=("fixed", "uniform", "zipf", "multi"),
                    default=None,
                    help="request stream; fixed replays the legacy batch "
                         "protocol; multi is the multi-tenant mix "
                         "(bursty batch + steady interactive/standard "
                         "priority classes)")
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--zipf-s", type=float, default=1.1)
    ap.add_argument("--rate", type=float, default=None,
                    help="arrival rate req/s (default: all at t=0, the "
                         "paper's offline batch as a degenerate trace)")
    ap.add_argument("--trace-seed", type=int, default=1)
    ap.add_argument("--noise", type=float, default=4.0)
    ap.add_argument("--no-recall", action="store_true")
    ap.add_argument("--json", default=None,
                    help="dump the metrics JSON here")
    # observability
    ap.add_argument("--trace-out", default=None,
                    help="record a per-request span timeline and write it "
                         "here: .jsonl = structured event log, anything "
                         "else = Chrome trace_event JSON (open in "
                         "ui.perfetto.dev / chrome://tracing; "
                         "docs/observability.md). Tracing never changes "
                         "results — ids/dists are bit-identical on or off")
    ap.add_argument("--trace-sample", type=float, default=1.0,
                    help="fraction of requests traced (deterministic "
                         "per-request hash under --seed, so the same "
                         "subset is traced every replay)")
    ap.add_argument("--metrics-out", default=None,
                    help="dump the unified metrics registry snapshot "
                         "(serving + cache + index + calibration series) "
                         "as JSON here")
    # legacy fixed-batch protocol
    ap.add_argument("--batches", type=int, default=None)
    ap.add_argument("--batch-images", type=int, default=256)
    args = ap.parse_args(argv)

    from repro.obs import NULL_TRACER, Tracer, tracing

    tracer = (
        Tracer(sample=args.trace_sample, seed=args.seed)
        if args.trace_out else NULL_TRACER
    )
    # scoped install: main() is called in-process by benchmarks/tests, so
    # the previous tracer must come back whatever happens below
    with tracing(tracer):
        return _serve(args, tracer)


def _serve(args, tracer) -> int:
    import jax
    import jax.numpy as jnp

    from repro.core.index_build import build_index
    from repro.core.tree import build_tree
    from repro.data import synth
    from repro.distributed.meshutil import local_mesh
    from repro.serving import (
        MicroBatcher,
        SearchSession,
        ShardedSearchSession,
        TraceLoadGenerator,
        default_tenant_mix,
        tune_ladder,
    )
    from repro.serving import persist
    from repro.serving.session import load_or_build_index

    mesh = local_mesh()
    dpi = args.desc_per_image or max(1, args.rows // args.images)

    corpus_vecs = None  # resident fallback when no --index-dir

    def build_fn():
        nonlocal corpus_vecs
        from repro.index import Index

        vecs_np = _build_corpus(args, dpi)
        t0 = time.perf_counter()
        vecs = jnp.asarray(vecs_np)
        tree = build_tree(vecs, tuple(args.fanout), key=jax.random.PRNGKey(1))
        extra = {
            "images": args.images, "desc_per_image": dpi,
            "corpus_seed": args.seed,
        }
        corpus_vecs = vecs_np
        if args.index_dir:
            persist.save_corpus(args.index_dir, vecs_np)
        if args.shards and args.shards > 1:
            # one appended segment per shard so every scatter leg owns
            # real rows (segment search is bit-identical to one-shot, so
            # this only changes the partitioning, never the results)
            idx = Index.create(tree, args.index_dir or None, mesh=mesh,
                               extra=extra, overwrite=True)
            for chunk in np.array_split(vecs_np, args.shards):
                idx.append(chunk)
            idx.commit()
            print(f"index: built {idx.rows} rows ({tree.n_leaves} leaves, "
                  f"{idx.n_segments} segments) in "
                  f"{time.perf_counter() - t0:.2f}s")
            return idx
        # float32 wire, matching the lifecycle facade's recorded default —
        # a later `launch.index --index-dir` append then grows this index
        # with the same dtype instead of silently mixing bf16/f32 segments
        index = build_index(vecs, tree, mesh, wire_dtype=jnp.float32)
        jax.block_until_ready(index.vecs)
        print(f"index: built {int(index.n_valid.sum())} rows "
              f"({tree.n_leaves} leaves) in {time.perf_counter() - t0:.2f}s "
              f"(overflow {int(index.overflow)})")
        return index, tree, extra

    session_kw = dict(
        k=args.k, layout=args.layout, probes=args.probes, impl=args.impl,
        max_batch_rows=args.max_batch_rows, n_buckets=args.n_buckets,
        cache_leaves=args.cache_leaves, cache_admit_after=args.cache_admit,
        cache_eviction=args.cache_eviction, cost_model=args.cost_model,
        rerank=args.rerank,
    )
    if args.buckets:
        session_kw["buckets"] = [int(b) for b in args.buckets.split(",")]
    t0 = time.perf_counter()
    idx, meta = load_or_build_index(
        args.index_dir, build_fn=build_fn, mesh=mesh, rebuild=args.rebuild,
    )
    if args.codes and idx.quantizer is None:
        t_c = time.perf_counter()
        idx.enable_codes(m=args.subvectors, bits=args.code_bits,
                         seed=args.seed)
        if args.index_dir:
            idx.commit()
        cs = idx.codes_stats()
        print(f"codes: trained m={cs['code_m']} bits={cs['code_bits']} "
              f"({cs['bytes_per_row']} B/row vs {cs['raw_bytes_per_row']} "
              f"raw, {cs['compression_ratio']:.1f}x) in "
              f"{time.perf_counter() - t_c:.2f}s")
    elif idx.quantizer is not None:
        cs = idx.codes_stats()
        print(f"codes: restored m={cs['code_m']} bits={cs['code_bits']} "
              f"({cs['compression_ratio']:.1f}x compression)")
    dpi = int(meta.get("desc_per_image", dpi))
    max_wait_ms = args.max_wait_ms
    if args.target_p95_ms and not args.buckets:
        # closed loop: the fitted cost model picks the ladder whose
        # largest dispatch still fits the target (stock ladder until the
        # index carries a usable calibration)
        decision = tune_ladder(
            idx.calibration, target_p95_ms=args.target_p95_ms,
            rows=idx.rows, n_leaves=idx.n_leaves, desc_per_image=dpi,
            max_batch_rows=args.max_batch_rows, n_buckets=args.n_buckets,
            n_shards=args.shards
            or (idx.shard_plan.n_shards if idx.shard_plan else 1),
            k=args.k, probes=args.probes, layout=args.layout,
            impl=args.impl, cost_model=args.cost_model,
            base_max_wait_ms=args.max_wait_ms
            if args.max_wait_ms is not None else 5.0,
        )
        session_kw["buckets"] = list(decision.buckets)
        if max_wait_ms is None:
            max_wait_ms = decision.max_wait_ms
        pred = decision.predicted_dispatch_ms
        print(
            f"ladder tuner: target p95 {args.target_p95_ms:.0f} ms -> "
            f"buckets {list(decision.buckets)}, "
            f"max_wait {decision.max_wait_ms:.1f} ms "
            f"({decision.decided_by}"
            + (f", predicted dispatch {pred:.1f} ms)" if pred is not None
               else ")")
        )
    if max_wait_ms is None:
        max_wait_ms = 5.0
    if args.shards is not None or idx.shard_plan is not None:
        # strategy precedence: explicit flag > the index's persisted
        # strategy > round_robin — so `--shards N` alone never flips a
        # persisted balanced plan back to the flag default
        strategy = args.shard_plan or (
            idx.shard_plan.strategy
            if idx.shard_plan is not None
            and idx.shard_plan.strategy != "explicit"
            else "round_robin"
        )
        session = ShardedSearchSession(
            idx, mesh=mesh, shards=args.shards,
            shard_strategy=strategy, target_p95_ms=args.target_p95_ms,
            **session_kw,
        )
        shard_stats = session.per_shard_stats()["shards"]
        empty = [s["shard"] for s in shard_stats if not s["segments"]]
        if empty:
            # the shard unit is a segment: a restored index with fewer
            # segments than shards cannot spread — say so, and don't lock
            # the degenerate topology into the manifest
            print(
                f"warning: {len(empty)}/{session.n_shards} shards own no "
                f"segments (this index has {idx.n_segments}); grow it with "
                "repro.launch.index appends, or --rebuild to re-partition "
                "the corpus into one segment per shard"
            )
        # make the plan durable so later serve runs (and Index.open
        # consumers) reuse the same scatter topology without re-deriving —
        # only when the user explicitly asked for a real topology
        # (--shards > 1): a serve run must not rewrite a persisted plan,
        # or pin a pointless 1-shard plan, as a side effect
        elif (args.index_dir and args.shards is not None and args.shards > 1
              and session.shard_plan != idx.shard_plan):
            idx.set_shard_plan(session.shard_plan)
            idx.commit()
        print(f"shards: {session.shard_plan.describe()}")
        for s in shard_stats:
            print(f"  shard {s['shard']}: {len(s['segments'])} segments, "
                  f"{s['rows']} rows")
    else:
        session = SearchSession(idx, mesh=mesh, **session_kw)
    if meta.get("restored"):
        live = int(meta.get("live_rows", meta.get("valid_rows",
                                                  meta["rows"])))
        print(f"index: restored from {args.index_dir} in "
              f"{time.perf_counter() - t0:.2f}s "
              f"(v{meta.get('version', '?')}, "
              f"{meta.get('n_segments', 1)} segments, "
              f"{live} rows, {meta['n_leaves']} leaves)")
        dpi = int(meta.get("desc_per_image", dpi))
        # an index grown by repro.launch.index carries no image geometry;
        # treat its contiguous id space as images of dpi rows each
        n_images = int(meta.get("images", 0)) or max(1, live // dpi)
    else:
        n_images = args.images
    dim = int(meta.get("dim", args.dim))
    print(f"corpus: {n_images} images x {dpi} descriptors x d={dim} "
          f"(layout={args.layout}, probes={args.probes}, k={args.k})")
    print(f"cost model: {session.active_cost_model()} "
          f"({len(session.index.calibration)} calibration records)")
    for p in session.plan_summary():
        tail = (f" rerank={p['rerank']}"
                if p["layout"] == "scan_codes" else "")
        print(f"bucket {p['bucket']:>6} rows: layout={p['layout']} "
              f"q_total={p['q_total']} block_rows={p['block_rows']} "
              f"q_cap={p['q_cap']} q_tile={p['q_tile']} p_cap={p['p_cap']}"
              + tail)

    warm_ms = session.warmup()
    print(f"warmup: {session.recompiles()} bucket programs compiled in "
          f"{warm_ms / 1e3:.2f}s")

    # ---- workload ---------------------------------------------------------
    corpus = corpus_vecs
    if corpus is None and args.index_dir:
        import os

        if os.path.isdir(persist.corpus_dir(args.index_dir)):
            corpus = persist.load_corpus(args.index_dir)
        else:
            # no corpus/ store (index grown by repro.launch.index): the
            # descriptor rows live in the segments — read them by id
            corpus = session.index
            live = int(meta.get("live_rows", 0))
            if live and live != int(meta.get("next_id", live)):
                print(
                    "warning: the id space has gaps (deletes); trace "
                    "requests that touch a missing descriptor id will "
                    "fail — restrict with --images/--desc-per-image"
                )
    gen = TraceLoadGenerator(corpus, dpi, noise=args.noise,
                             seed=args.trace_seed)
    mode = args.trace or "fixed"
    if mode == "fixed":
        # legacy --batches overrides; otherwise --requests applies here too
        n_req = (
            args.batches * args.batch_images
            if args.batches is not None
            else args.requests
        )
        rng = np.random.default_rng(args.trace_seed)
        replace = n_req > n_images
        image_ids = rng.choice(n_images, n_req, replace=replace)
        arrivals = np.zeros(n_req)
    elif mode == "multi":
        classes = default_tenant_mix(args.requests, rate=args.rate or 100.0)
        reqs = gen.multi_tenant(classes, n_images, seed=args.trace_seed)
        image_ids = [r.image_id for r in reqs]
    else:
        image_ids, arrivals = synth.sample_trace(
            args.requests, n_images, skew=mode, zipf_s=args.zipf_s,
            rate=args.rate, seed=args.trace_seed,
        )
    if mode != "multi":
        reqs = gen.requests(image_ids, arrivals)
    uniq = len(set(int(i) for i in image_ids))
    # fixed mode always bursts at t=0; --rate only paces the others
    paced = (args.rate or 100.0) if mode == "multi" else (
        args.rate if mode != "fixed" else None
    )
    print(f"trace: {mode}, {len(reqs)} requests over {uniq} distinct images"
          + (f", rate={paced}/s" if paced else ", all at t=0"))
    if mode == "multi":
        by_class = {}
        for r in reqs:
            by_class[r.priority] = by_class.get(r.priority, 0) + 1
        print("classes: " + ", ".join(
            f"{c}={n}" for c, n in sorted(by_class.items())
        ))

    batcher = MicroBatcher(session, max_wait_ms=max_wait_ms,
                           max_queue=args.max_queue,
                           scheduler=args.scheduler,
                           refresh_every=args.refresh_every)
    t0 = time.perf_counter()
    completions = batcher.run(reqs)
    wall = time.perf_counter() - t0

    # ---- report -----------------------------------------------------------
    m = session.metrics
    lat = m.latency.summary()
    print(
        f"served {m.requests}/{len(reqs)} requests "
        f"({m.rejected} rejected, {m.shed} shed, {m.downgraded} downgraded, "
        f"{m.engine_batches} micro-batches, "
        f"{m.cache_images} cache-served) in {wall:.2f}s wall "
        f"[scheduler={batcher.scheduler}]"
    )
    if lat.get("count"):
        print(
            f"latency: p50 {lat['p50_ms']:.1f} ms, p95 {lat['p95_ms']:.1f} ms, "
            f"p99 {lat['p99_ms']:.1f} ms (mean {lat['mean_ms']:.1f} ms)"
        )
        wait, comp = m.wait.summary(), m.compute.summary()
        if wait.get("count"):
            print(
                f"breakdown: queue-wait p95 {wait['p95_ms']:.1f} ms "
                f"(mean {wait['mean_ms']:.1f}), compute p95 "
                f"{comp['p95_ms']:.1f} ms (mean {comp['mean_ms']:.1f})"
            )
    for name, cm in sorted(
        m.per_class.items(), key=lambda kv: kv[0]
    ):
        cl = cm.latency.summary()
        if not cl.get("count") and not (cm.shed or cm.rejected):
            continue
        slo = (f"SLO<{cm.deadline_ms:.0f}ms attained "
               f"{cm.slo_attainment:.2f}  " if cm.deadline_ms else "")
        print(
            f"  class {name:<12} p50 {cl.get('p50_ms', 0.0):7.1f} ms  "
            f"p95 {cl.get('p95_ms', 0.0):7.1f} ms  " + slo +
            f"(done {cm.completed}, shed {cm.shed}, rej {cm.rejected})"
        )
    print(
        f"throughput: {m.ms_per_image:.1f} ms/image engine "
        f"(paper Exp #5: 210 ms/image), queue depth mean "
        f"{np.mean(m.queue_depth) if m.queue_depth else 0:.1f} "
        f"max {max(m.queue_depth) if m.queue_depth else 0}, "
        f"q_cap_overflow {m.q_cap_overflow}"
    )
    if session.cache.enabled:
        c = session.cache.stats()
        print(f"hot-leaf cache: {c['cached_leaves']}/{c['capacity_leaves']} "
              f"leaves, hit rate {c['hit_rate']:.2f} "
              f"({c['hits']} hits / {c['misses']} misses)")
    n_recomp = session.steady_state_recompiles()
    print(f"steady-state recompiles after warmup: {n_recomp} "
          f"({'OK' if n_recomp == 0 else 'REGRESSION'})")

    # make this run's measured ms/image durable: the next serve run's
    # plan(model="auto") then opens with a warm calibration store
    if args.index_dir and session.index.calibration.dirty:
        # best-effort: a lost calibration commit (concurrent committer,
        # full/read-only disk) must not fail an otherwise-good serve run
        try:
            v = session.index.commit()
            print(f"calibration: {len(session.index.calibration)} plan "
                  f"signatures committed (manifest v{v})")
        except OSError as e:  # incl. FileExistsError from a commit race
            print(f"warning: calibration not persisted ({e})")

    if not args.no_recall:
        ok = n = 0
        for c in completions:
            if c.ids is None:
                continue
            votes = np.asarray(c.ids)[:, 0]
            votes = votes[votes >= 0] // dpi
            if votes.size:
                vals, cnts = np.unique(votes, return_counts=True)
                ok += int(vals[np.argmax(cnts)] == c.image_id)
            n += 1
        if n:
            print(f"recall@1 (image voting): {ok}/{n} = {ok / n:.3f}")

    if args.json:
        payload = {
            "metrics": m.to_dict(),
            "cache": session.cache.stats(),
            "plans": session.plan_summary(),
            "cost_model": session.active_cost_model(),
            "plan_observations": session.index.calibration.snapshot(),
            "wall_s": wall,
            "shards": (
                session.per_shard_stats()
                if isinstance(session, ShardedSearchSession)
                else None
            ),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"metrics JSON -> {args.json}")

    if args.trace_out:
        from repro.obs import export_trace, summary as trace_summary

        export_trace(tracer, args.trace_out)
        d = tracer.describe()
        print(f"trace -> {args.trace_out} ({d['spans']} spans, "
              f"{d['events']} events, {d['dropped']} dropped, "
              f"sample={d['sample']})")
        print(trace_summary(tracer, top=3))
    if args.metrics_out:
        from repro.obs import get_registry

        get_registry().dump(args.metrics_out)
        print(f"metrics registry -> {args.metrics_out}")
    return 0 if n_recomp == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
