"""Training launcher: ``--arch`` zoo training with checkpoint/restart.

On this CPU container it trains *reduced* configs end-to-end (the full
configs are exercised by the dry-run); on a real pod the same launcher runs
the full config — nothing here is CPU-specific. Fault tolerance: every
``--checkpoint-every`` steps the full train state goes through the
CheckpointManager; ``--resume`` restarts from the latest snapshot (a
different device count is fine — checkpoints are mesh-agnostic).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --steps 50 --batch 8 --seq 64 [--resume] [--ckpt-dir /tmp/ckpt]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def reduced_lm_config(arch: str):
    from repro.configs import get_arch

    module = {
        "llama3.2-3b": "repro.configs.llama32_3b",
        "gemma3-4b": "repro.configs.gemma3_4b",
        "internlm2-1.8b": "repro.configs.internlm2_18b",
        "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b",
        "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    }
    if arch not in module:
        raise SystemExit(f"train.py currently drives LM archs; got {arch}")
    import importlib

    return importlib.import_module(module[arch]).SMOKE_CONFIG


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", choices=["bf16", "topk"], default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.data.batches import lm_batch
    from repro.distributed.checkpoint import CheckpointManager
    from repro.models import transformer as tfm
    from repro.models.module import init_params
    from repro.train import AdamWConfig, make_train_step
    from repro.train.optimizer import warmup_cosine
    from repro.train.step import init_train_state

    cfg = reduced_lm_config(args.arch)
    params = init_params(cfg.param_specs(), jax.random.PRNGKey(args.seed))
    state = init_train_state(params, compress=args.compress)
    start_step = 0
    mgr = CheckpointManager(f"{args.ckpt_dir}/{args.arch}")
    if args.resume and mgr.latest_step() is not None:
        (params, state), manifest = mgr.restore((params, state))
        start_step = manifest["step"]
        print(f"resumed from step {start_step}")

    opt_cfg = AdamWConfig(
        lr=warmup_cosine(args.lr, 10, args.steps), weight_decay=0.01
    )
    step_fn = jax.jit(
        make_train_step(
            lambda p, b: tfm.loss_fn(p, cfg, b),
            opt_cfg,
            microbatches=args.microbatches,
            compress=args.compress,
        ),
        donate_argnums=(0, 1),
    )

    losses = []
    for step in range(start_step, args.steps):
        batch = jax.tree.map(
            jnp.asarray,
            lm_batch(args.batch, args.seq, cfg.vocab_size, seed=args.seed + step),
        )
        t0 = time.perf_counter()
        params, state, metrics = step_fn(params, state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        print(
            f"step {step:5d} loss {loss:8.4f} gnorm "
            f"{float(metrics['grad_norm']):8.4f} "
            f"({(time.perf_counter() - t0) * 1e3:7.1f} ms)"
        )
        if (step + 1) % args.checkpoint_every == 0 or step + 1 == args.steps:
            mgr.save(step + 1, (params, state))
    if len(losses) > 10:
        assert np.mean(losses[-5:]) < np.mean(losses[:5]), "loss did not drop"
        print(f"loss {np.mean(losses[:5]):.3f} -> {np.mean(losses[-5:]):.3f} OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
