# Architecture zoo: pure-JAX models with pytree params built from ParamSpec
# trees (repro.models.module). One family module per kernel regime:
#   transformer.py — dense/GQA/MoE/sliding-window LMs (scan-over-layers)
#   gnn.py         — GIN message passing via segment_sum over edge lists
#   recsys.py      — DLRM / DIN / DIEN / two-tower (EmbeddingBag substrate)
