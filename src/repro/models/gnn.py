"""GIN (Graph Isomorphism Network, arXiv:1810.00826) via segment ops.

JAX has no CSR SpMM; message passing is built (as the taxonomy prescribes)
from an edge list: gather source features -> ``jax.ops.segment_sum`` into
destinations. Edges shard over the data axes (the paper's HDFS-block analog
for graphs); node features are kept on the model axis for storage and
gathered for compute — the roofline for ogb_products is intentionally
collective-dominated and is a hillclimb candidate (EXPERIMENTS.md §Perf).

Padding convention: padded edges carry weight 0 (they still scatter, into
node 0, but contribute nothing); padded nodes carry label -1 (masked out of
the loss).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.module import ParamSpec, shard


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str
    n_layers: int = 5
    d_in: int = 1433
    d_hidden: int = 64
    n_classes: int = 7
    train_eps: bool = True  # learnable eps (GIN-eps)
    dtype: str = "float32"

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def param_specs(self):
        L, h = self.n_layers, self.d_hidden
        return {
            "in_w1": ParamSpec((self.d_in, h), ("feat", "ffn")),
            "in_b1": ParamSpec((h,), (None,), init="zeros"),
            "in_w2": ParamSpec((h, h), (None, "ffn")),
            "in_b2": ParamSpec((h,), (None,), init="zeros"),
            # layers 1..L-1 stacked (uniform dims)
            "w1": ParamSpec((L - 1, h, h), ("layers", None, "ffn")),
            "b1": ParamSpec((L - 1, h), ("layers", None), init="zeros"),
            "w2": ParamSpec((L - 1, h, h), ("layers", None, "ffn")),
            "b2": ParamSpec((L - 1, h), ("layers", None), init="zeros"),
            "eps": ParamSpec((L,), (None,), init="zeros"),
            "out_w": ParamSpec((h, self.n_classes), (None, None)),
            "out_b": ParamSpec((self.n_classes,), (None,), init="zeros"),
        }

    def param_count(self) -> int:
        from repro.models.module import param_count

        return param_count(self.param_specs())


def _aggregate(h, src, dst, edge_w, n_nodes):
    """Sum aggregation over the edge list (the GNN message-passing op)."""
    msg = h[src] * edge_w[:, None].astype(h.dtype)
    msg = shard(msg, "edges", None)
    return jax.ops.segment_sum(msg, dst, num_segments=n_nodes)


def forward(params, cfg: GINConfig, batch):
    """batch: feats (N, d_in), edges (2, E) int32, edge_w (E,) — logits (N, C)."""
    feats = batch["feats"].astype(cfg.compute_dtype)
    src, dst = batch["edges"][0], batch["edges"][1]
    edge_w = batch.get("edge_w", jnp.ones(src.shape, cfg.compute_dtype))
    n = feats.shape[0]

    eps = params["eps"].astype(cfg.compute_dtype)
    h = feats
    # layer 0 (input dims differ)
    agg = _aggregate(h, src, dst, edge_w, n)
    z = (1.0 + eps[0]) * h + agg
    h = jax.nn.relu(z @ params["in_w1"].astype(z.dtype) + params["in_b1"].astype(z.dtype))
    h = jax.nn.relu(h @ params["in_w2"].astype(h.dtype) + params["in_b2"].astype(h.dtype))
    h = shard(h, "nodes", None)

    def body(h, layer):
        agg = _aggregate(h, src, dst, edge_w, n)
        z = (1.0 + layer["eps"]) * h + agg
        y = jax.nn.relu(z @ layer["w1"] + layer["b1"])
        y = jax.nn.relu(y @ layer["w2"] + layer["b2"])
        return shard(y, "nodes", None), None

    xs = {
        "w1": params["w1"].astype(h.dtype),
        "b1": params["b1"].astype(h.dtype),
        "w2": params["w2"].astype(h.dtype),
        "b2": params["b2"].astype(h.dtype),
        "eps": eps[1:],
    }
    h, _ = jax.lax.scan(body, h, xs)
    return h @ params["out_w"].astype(h.dtype) + params["out_b"].astype(h.dtype)


def loss_fn(params, cfg: GINConfig, batch):
    """Node-classification CE over labels >= 0 (padding/masked = -1)."""
    logits = forward(params, cfg, batch).astype(jnp.float32)
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.clip(labels, 0)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
    per_node = (logz - ll) * valid
    loss = jnp.sum(per_node) / jnp.maximum(1, jnp.sum(valid))
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * valid) / jnp.maximum(
        1, jnp.sum(valid)
    )
    return loss, {"loss": loss, "acc": acc}
