"""Minimal parameter-spec module system (no flax on the cluster image).

A model is (a) a pytree of ``ParamSpec`` leaves describing every weight's
shape/dtype/init/logical axes, and (b) pure apply functions over the
materialised params pytree. One spec tree serves three consumers:

  * ``init_params``     — real arrays for training/tests
  * ``abstract_params`` — ShapeDtypeStructs for the multi-pod dry-run
  * ``shard_specs``     — NamedShardings via the logical-axis rules
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple  # logical axis names, same rank as shape (None = replicated)
    dtype: jnp.dtype = jnp.float32
    init: str = "normal"  # normal | zeros | ones | uniform
    scale: Optional[float] = None  # default: 1/sqrt(fan_in)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"rank mismatch: {self.shape} vs {self.axes}")


def _is_spec(x):
    return isinstance(x, ParamSpec)


def init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(1, fan_in))
    if spec.init == "normal":
        return (scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    if spec.init == "uniform":
        return (
            scale * jax.random.uniform(key, spec.shape, minval=-1.0, maxval=1.0)
        ).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init}")


def init_params(spec_tree, key: jax.Array):
    """Materialise every ParamSpec with a deterministic per-leaf key."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    arrays = [init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(spec_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=_is_spec
    )


def param_count(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def param_bytes(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves)


# ---------------------------------------------------------------------------
# sharding-context plumbing: model code calls ``shard(x, axes...)`` without
# threading mesh/rules through every call; step builders install the context.
# ---------------------------------------------------------------------------

_CTX: list = []


class shard_ctx:
    """Context manager installing (mesh, rules) for ``shard`` constraints."""

    def __init__(self, mesh, rules=None):
        from repro.distributed.partitioning import DEFAULT_RULES

        self.pair = (mesh, rules or DEFAULT_RULES)

    def __enter__(self):
        _CTX.append(self.pair)
        return self

    def __exit__(self, *exc):
        _CTX.pop()
        return False


def shard(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside shard_ctx."""
    if not _CTX:
        return x
    mesh, rules = _CTX[-1]
    from repro.distributed.partitioning import constrain

    return constrain(x, axes, mesh, rules)
