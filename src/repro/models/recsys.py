"""RecSys family: DLRM (arXiv:1906.00091), DIN (arXiv:1706.06978),
DIEN (arXiv:1809.03672), two-tower retrieval (Yi et al., RecSys'19).

The hot path is the sparse embedding lookup. JAX has no EmbeddingBag; it is
built here from ``jnp.take`` + ``segment``-style reduction (multi-hot bags
sum over the nnz axis with a validity mask). Tables shard row-wise over the
``model`` axis (``table_rows`` rule) — the gather across shards is the
routed-lookup pattern shared with the paper's index (DESIGN.md §5).

``two-tower`` additionally exposes the paper's technique directly: its
1M-candidate retrieval scoring can run dense (exact) or through the
vocabulary-tree ANN index (repro.core), benchmarked against each other.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.module import ParamSpec, shard


# ---------------------------------------------------------------------------
# shared substrate
# ---------------------------------------------------------------------------


def embedding_bag(table, ids, *, mode="sum", valid=None):
    """EmbeddingBag: table (V, D), ids (..., nnz) -> (..., D).

    ``valid`` masks padding ids; mean mode divides by the bag size.
    """
    emb = jnp.take(table, ids, axis=0)  # (..., nnz, D)
    if valid is not None:
        emb = emb * valid[..., None].astype(emb.dtype)
    out = jnp.sum(emb, axis=-2)
    if mode == "mean":
        denom = (
            jnp.sum(valid, axis=-1, keepdims=True)
            if valid is not None
            else ids.shape[-1]
        )
        out = out / jnp.maximum(1, denom).astype(out.dtype)
    return out


def field_lookup(tables, ids):
    """tables (F, V, D), ids (B, F) -> (B, F, D) one-hot-per-field lookup."""
    return jax.vmap(lambda t, i: jnp.take(t, i, axis=0), in_axes=(0, 1), out_axes=1)(
        tables, ids
    )


def mlp_specs(dims: Sequence[int], prefix: str, axes=(None, "ffn")):
    specs = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        specs[f"{prefix}_w{i}"] = ParamSpec((a, b), axes)
        specs[f"{prefix}_b{i}"] = ParamSpec((b,), (None,), init="zeros")
    return specs


def mlp_apply(params, prefix: str, x, n: int, *, final_act=False):
    for i in range(n):
        x = x @ params[f"{prefix}_w{i}"].astype(x.dtype) + params[
            f"{prefix}_b{i}"
        ].astype(x.dtype)
        if i + 1 < n or final_act:
            x = jax.nn.relu(x)
    return x


def bce_loss(logit, label):
    """Numerically stable sigmoid BCE. logit (B,), label (B,) in {0,1}."""
    logit = logit.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * label + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )


# ---------------------------------------------------------------------------
# DLRM
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab_per_field: int = 1_000_000
    bot_mlp: tuple = (512, 256, 64)
    top_mlp: tuple = (512, 512, 256, 1)
    dtype: str = "float32"

    def __post_init__(self):
        if self.bot_mlp[-1] != self.embed_dim:
            raise ValueError(
                f"DLRM bottom MLP must end at embed_dim "
                f"({self.bot_mlp[-1]} != {self.embed_dim})"
            )

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def param_specs(self):
        specs = {
            "tables": ParamSpec(
                (self.n_sparse, self.vocab_per_field, self.embed_dim),
                (None, "table_rows", "embed"),
                scale=0.01,
            )
        }
        specs.update(mlp_specs((self.n_dense, *self.bot_mlp), "bot"))
        n_pairs = (self.n_sparse + 1) * self.n_sparse // 2
        top_in = self.bot_mlp[-1] + n_pairs
        specs.update(mlp_specs((top_in, *self.top_mlp), "top"))
        return specs

    def param_count(self) -> int:
        from repro.models.module import param_count

        return param_count(self.param_specs())


def dlrm_forward(params, cfg: DLRMConfig, batch):
    """batch: dense (B, 13) float, sparse (B, 26) int32 -> logits (B,)."""
    dense = batch["dense"].astype(cfg.compute_dtype)
    dense = shard(dense, "batch", None)
    d0 = mlp_apply(params, "bot", dense, len(cfg.bot_mlp), final_act=True)
    embs = field_lookup(params["tables"].astype(cfg.compute_dtype), batch["sparse"])
    embs = shard(embs, "batch", None, None)
    z = jnp.concatenate([d0[:, None, :], embs], axis=1)  # (B, F+1, D)
    gram = jnp.einsum("bfd,bgd->bfg", z, z, preferred_element_type=jnp.float32)
    iu, ju = np.triu_indices(cfg.n_sparse + 1, k=1)
    inter = gram[:, iu, ju].astype(cfg.compute_dtype)  # (B, pairs)
    x = jnp.concatenate([d0, inter], axis=1)
    out = mlp_apply(params, "top", x, len(cfg.top_mlp))
    return out[:, 0]


def dlrm_loss(params, cfg: DLRMConfig, batch):
    logit = dlrm_forward(params, cfg, batch)
    loss = bce_loss(logit, batch["label"])
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# DIN / DIEN
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    vocab: int = 500_000
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)
    gru_dim: int = 0  # >0 switches on the DIEN interest-evolution path
    dtype: str = "float32"

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def param_specs(self):
        D = self.embed_dim
        specs = {
            "item_table": ParamSpec((self.vocab, D), ("table_rows", "embed"), scale=0.01)
        }
        if self.gru_dim:  # DIEN: GRU + AUGRU over the behaviour sequence
            H = self.gru_dim
            specs["gru_wx"] = ParamSpec((D, 3 * H), (None, "ffn"))
            specs["gru_wh"] = ParamSpec((H, 3 * H), (None, "ffn"))
            specs["gru_b"] = ParamSpec((3 * H,), (None,), init="zeros")
            specs["augru_wx"] = ParamSpec((H, 3 * H), (None, "ffn"))
            specs["augru_wh"] = ParamSpec((H, 3 * H), (None, "ffn"))
            specs["augru_b"] = ParamSpec((3 * H,), (None,), init="zeros")
            att_in = H + D
            final_in = H + D
        else:  # DIN: target attention over raw behaviour embeddings
            att_in = 4 * D
            final_in = 3 * D
        specs.update(mlp_specs((att_in, *self.attn_mlp, 1), "att"))
        specs.update(mlp_specs((final_in, *self.mlp, 1), "fin"))
        return specs

    def param_count(self) -> int:
        from repro.models.module import param_count

        return param_count(self.param_specs())


def _gru_scan(x_seq, h0, wx, wh, b, *, a_seq=None):
    """x_seq (T, B, D) -> h_seq (T, B, H). AUGRU when a_seq (T, B) given."""
    H = h0.shape[-1]

    def cell(h, inp):
        x, a = inp
        gates = x @ wx + h @ wh + b
        r = jax.nn.sigmoid(gates[..., :H])
        u = jax.nn.sigmoid(gates[..., H : 2 * H])
        cand = jnp.tanh(x @ wx[:, 2 * H :] + (r * h) @ wh[:, 2 * H :] + b[2 * H :])
        if a is not None:
            u = u * a[..., None]  # attentional update gate (AUGRU)
        h = (1.0 - u) * h + u * cand
        return h, h

    inputs = (x_seq, a_seq) if a_seq is not None else (x_seq, None)
    if a_seq is None:
        _, hs = jax.lax.scan(lambda h, x: cell(h, (x, None)), h0, x_seq)
    else:
        _, hs = jax.lax.scan(cell, h0, inputs)
    return hs


def din_forward(params, cfg: DINConfig, batch):
    """batch: hist (B, T) int32 (0 = pad), target (B,) int32 -> logits (B,)."""
    table = params["item_table"].astype(cfg.compute_dtype)
    hist = batch["hist"]
    target = batch["target"]
    B, T = hist.shape
    h_emb = jnp.take(table, hist, axis=0)  # (B, T, D)
    t_emb = jnp.take(table, target, axis=0)  # (B, D)
    h_emb = shard(h_emb, "batch", None, None)
    valid = (hist > 0).astype(cfg.compute_dtype)  # (B, T)

    if cfg.gru_dim:
        H = cfg.gru_dim
        hs = _gru_scan(
            jnp.swapaxes(h_emb, 0, 1),
            jnp.zeros((B, H), cfg.compute_dtype),
            params["gru_wx"].astype(cfg.compute_dtype),
            params["gru_wh"].astype(cfg.compute_dtype),
            params["gru_b"].astype(cfg.compute_dtype),
        )  # (T, B, H)
        att_in = jnp.concatenate(
            [hs, jnp.broadcast_to(t_emb[None], (T, B, t_emb.shape[-1]))], axis=-1
        )
        scores = mlp_apply(params, "att", att_in, len(cfg.attn_mlp) + 1)[..., 0]
        scores = jax.nn.sigmoid(scores) * jnp.swapaxes(valid, 0, 1)  # (T, B)
        h_final = _gru_scan(
            hs,
            jnp.zeros((B, H), cfg.compute_dtype),
            params["augru_wx"].astype(cfg.compute_dtype),
            params["augru_wh"].astype(cfg.compute_dtype),
            params["augru_b"].astype(cfg.compute_dtype),
            a_seq=scores,
        )[-1]  # (B, H)
        x = jnp.concatenate([h_final, t_emb], axis=-1)
    else:
        tb = jnp.broadcast_to(t_emb[:, None], h_emb.shape)
        att_in = jnp.concatenate([h_emb, tb, h_emb - tb, h_emb * tb], axis=-1)
        scores = mlp_apply(params, "att", att_in, len(cfg.attn_mlp) + 1)[..., 0]
        scores = jax.nn.sigmoid(scores) * valid  # DIN: no softmax (paper §4)
        pooled = jnp.einsum("btd,bt->bd", h_emb, scores.astype(h_emb.dtype))
        x = jnp.concatenate([pooled, t_emb, pooled * t_emb], axis=-1)
    out = mlp_apply(params, "fin", x, len(cfg.mlp) + 1)
    return out[:, 0]


def din_loss(params, cfg: DINConfig, batch):
    logit = din_forward(params, cfg, batch)
    loss = bce_loss(logit, batch["label"])
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# two-tower retrieval
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256  # final tower output dim
    field_dim: int = 64
    n_user_fields: int = 4
    n_item_fields: int = 4
    vocab_per_field: int = 100_000
    tower_mlp: tuple = (1024, 512, 256)
    temperature: float = 0.05
    dtype: str = "float32"

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def param_specs(self):
        specs = {
            "user_tables": ParamSpec(
                (self.n_user_fields, self.vocab_per_field, self.field_dim),
                (None, "table_rows", "embed"),
                scale=0.01,
            ),
            "item_tables": ParamSpec(
                (self.n_item_fields, self.vocab_per_field, self.field_dim),
                (None, "table_rows", "embed"),
                scale=0.01,
            ),
        }
        u_in = self.n_user_fields * self.field_dim
        i_in = self.n_item_fields * self.field_dim
        specs.update(mlp_specs((u_in, *self.tower_mlp), "user"))
        specs.update(mlp_specs((i_in, *self.tower_mlp), "item"))
        return specs

    def param_count(self) -> int:
        from repro.models.module import param_count

        return param_count(self.param_specs())


def tower(params, cfg: TwoTowerConfig, prefix: str, ids):
    tables = params[f"{prefix}_tables"].astype(cfg.compute_dtype)
    embs = field_lookup(tables, ids)  # (B, F, D)
    x = embs.reshape(ids.shape[0], -1)
    x = shard(x, "batch", None)
    x = mlp_apply(params, prefix, x, len(cfg.tower_mlp))
    return x / jnp.maximum(1e-6, jnp.linalg.norm(x, axis=-1, keepdims=True))


def twotower_loss(params, cfg: TwoTowerConfig, batch):
    """In-batch sampled softmax (negatives = other rows of the batch)."""
    u = tower(params, cfg, "user", batch["user_ids"])
    it = tower(params, cfg, "item", batch["item_ids"])
    logits = (u @ it.T).astype(jnp.float32) / cfg.temperature  # (B, B)
    labels = jnp.arange(u.shape[0])
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.diagonal(logits)
    loss = jnp.mean(logz - ll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}


def twotower_score(params, cfg: TwoTowerConfig, batch):
    """Retrieval scoring: one user against (Nc,) candidate items -> (Nc,)."""
    u = tower(params, cfg, "user", batch["user_ids"])  # (1, D)
    it = tower(params, cfg, "item", batch["cand_ids"])  # (Nc, D)
    it = shard(it, "batch", None)
    return (it @ u[0]).astype(jnp.float32)
