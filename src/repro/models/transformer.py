"""Decoder-only transformer family: dense / GQA / sliding-window / MoE.

Covers the five assigned LM architectures (llama3.2-3b, gemma3-4b,
internlm2-1.8b, moonshot-v1-16b-a3b, phi3.5-moe) from one config. Design
points for pod scale:

  * layers are scanned (stacked params), so HLO size is O(1) in depth —
    essential for the 512-device dry-run compiles;
  * MoE routing reuses ``repro.core.dispatch`` — the paper's lookup-table
    grouping applied to experts (DESIGN.md §4); dropped-token counts are the
    failed-map-task analog and are surfaced in metrics;
  * sliding-window vs global attention is a per-layer *traced* window size
    folded into the mask, so gemma3's 5:1 local:global pattern runs in one
    scanned layer body (no unrolled branches);
  * logical-axis sharding: qkv/ffn/experts/vocab shard over ``model``,
    batch over (``pod``, ``data``), decode KV caches over the free axes of
    (pod, data, model) via the ``kv_seq`` rule (context parallelism).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dispatch import combine_rows, dispatch_rows, make_dispatch
from repro.models.module import ParamSpec, shard


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden dim
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    window: int = 0  # 0 = all layers global attention
    global_every: int = 0  # >0: layer i is global iff (i+1) % global_every == 0
    moe: Optional[MoEConfig] = None
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-6
    scale_embed: bool = False  # gemma-style sqrt(d_model) input scaling
    qk_norm: bool = False
    dtype: str = "bfloat16"
    remat: str = "dots"  # none | full | dots
    # "global": pjit sort-based dispatch (baseline); "routed": shard_map
    # all_to_all routing over the expert axis — the paper's shuffle applied
    # to experts (EXPERIMENTS.md §Perf hillclimb #1)
    moe_impl: str = "global"
    # "full": one (Sq, Skv) logits tensor; "chunked": lax.scan over KV
    # chunks with running max/denominator (flash-attention dataflow in pure
    # XLA — bounds the materialised score tile to (Sq, chunk))
    attn_impl: str = "full"
    attn_chunk: int = 1024

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def window_sizes(self) -> jnp.ndarray:
        """(L,) per-layer attention window; -1 = unbounded (global)."""
        if self.window <= 0:
            return jnp.full((self.n_layers,), -1, jnp.int32)
        idx = jnp.arange(self.n_layers)
        if self.global_every > 0:
            is_global = (idx + 1) % self.global_every == 0
        else:
            is_global = jnp.zeros((self.n_layers,), bool)
        return jnp.where(is_global, -1, self.window).astype(jnp.int32)

    def param_specs(self):
        L, D, V = self.n_layers, self.d_model, self.vocab_size
        qd, kvd, hd = self.q_dim, self.kv_dim, self.head_dim
        layer = {
            "attn_norm": ParamSpec((L, D), ("layers", "embed"), init="ones"),
            "wq": ParamSpec((L, D, qd), ("layers", "embed", "qkv")),
            "wk": ParamSpec((L, D, kvd), ("layers", "embed", "qkv")),
            "wv": ParamSpec((L, D, kvd), ("layers", "embed", "qkv")),
            "wo": ParamSpec((L, qd, D), ("layers", "qkv", "embed")),
            "mlp_norm": ParamSpec((L, D), ("layers", "embed"), init="ones"),
        }
        if self.qk_norm:
            layer["q_norm"] = ParamSpec((L, hd), ("layers", "head_dim"), init="ones")
            layer["k_norm"] = ParamSpec((L, hd), ("layers", "head_dim"), init="ones")
        if self.moe is None:
            F = self.d_ff
            layer["w_gate"] = ParamSpec((L, D, F), ("layers", "embed", "ffn"))
            layer["w_up"] = ParamSpec((L, D, F), ("layers", "embed", "ffn"))
            layer["w_down"] = ParamSpec((L, F, D), ("layers", "ffn", "embed"))
        else:
            E, Fe = self.moe.n_experts, self.moe.d_ff
            layer["router"] = ParamSpec((L, D, E), ("layers", "embed", "experts"))
            layer["w_gate"] = ParamSpec(
                (L, E, D, Fe), ("layers", "experts", "embed", "ffn")
            )
            layer["w_up"] = ParamSpec(
                (L, E, D, Fe), ("layers", "experts", "embed", "ffn")
            )
            layer["w_down"] = ParamSpec(
                (L, E, Fe, D), ("layers", "experts", "ffn", "embed")
            )
        return {
            "embed": ParamSpec((V, D), ("vocab", "embed"), scale=1.0),
            "layers": layer,
            "final_norm": ParamSpec((D,), ("embed",), init="ones"),
        }

    def param_count(self) -> int:
        from repro.models.module import param_count

        return param_count(self.param_specs())

    def active_param_count(self) -> int:
        """6*N*D bookkeeping for MoE rooflines: only routed experts count."""
        total = self.param_count()
        if self.moe is None:
            return total
        E, k, Fe, L, D = (
            self.moe.n_experts,
            self.moe.top_k,
            self.moe.d_ff,
            self.n_layers,
            self.d_model,
        )
        expert_params = L * E * 3 * D * Fe
        return total - expert_params + L * k * 3 * D * Fe


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def rope(x, positions, theta):
    """x: (..., S, H, hd); positions broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def attend(q, k, v, *, q_pos, kv_pos, window, kv_valid_len=None):
    """Grouped-query attention with causal + sliding-window mask.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd); window: traced int32
    (-1 = unbounded). kv_valid_len: () — mask kv positions >= it (decode).
    """
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    logits = jnp.einsum(
        "bqkgh,bskh->bkgqs", qg, k, preferred_element_type=jnp.float32
    ) * (1.0 / math.sqrt(hd))
    dist = q_pos[:, None] - kv_pos[None, :]  # (Sq, Skv)
    mask = dist >= 0
    win = jnp.where(window > 0, window, jnp.int32(2**30))
    mask &= dist < win
    if kv_valid_len is not None:
        mask &= (kv_pos < kv_valid_len)[None, :]
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, Hq * hd)


def attend_chunked(q, k, v, *, q_pos, kv_pos, window, kv_valid_len=None,
                   chunk=1024):
    """Flash-attention dataflow: scan KV chunks with a running
    (max, denominator, accumulator) — the (Sq, Skv) score matrix never
    exists; only (Sq, chunk) tiles do. Same signature/semantics as
    ``attend``."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    Skv = k.shape[1]
    if Skv % chunk:
        chunk = Skv  # degenerate fallback
    n_chunks = Skv // chunk
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scale = 1.0 / math.sqrt(hd)

    kc = k.reshape(B, n_chunks, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(n_chunks, chunk)

    def body(carry, inp):
        m, l, acc = carry
        k_i, v_i, p_i = inp
        s = jnp.einsum(
            "bqkgh,bskh->bkgqs", qg, k_i, preferred_element_type=jnp.float32
        ) * scale  # (B, Hkv, G, Sq, chunk)
        dist = q_pos[:, None] - p_i[None, :]
        mask = dist >= 0
        win = jnp.where(window > 0, window, jnp.int32(2**30))
        mask &= dist < win
        if kv_valid_len is not None:
            mask &= (p_i < kv_valid_len)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(v_i.dtype), v_i,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, Hkv, G, Sq), -jnp.inf, jnp.float32),
        jnp.zeros((B, Hkv, G, Sq), jnp.float32),
        jnp.zeros((B, Hkv, G, Sq, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # (B, Hkv, G, Sq, hd) -> (B, Sq, Hq*hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq * hd)
    return out.astype(q.dtype)


def _moe_ffn(x2d, layer, cfg: TransformerConfig, capacity: int):
    """Expert FFN via the shared dispatch substrate. x2d: (T, D)."""
    moe = cfg.moe
    T = x2d.shape[0]
    router_logits = jnp.einsum(
        "td,de->te", x2d, layer["router"], preferred_element_type=jnp.float32
    )
    top_vals, top_idx = jax.lax.top_k(router_logits, moe.top_k)  # (T, k)
    gates = jax.nn.softmax(top_vals, axis=-1)  # (T, k)
    flat_assign = top_idx.reshape(T * moe.top_k)
    disp = make_dispatch(flat_assign, moe.n_experts, capacity)
    # gather tokens (row r of the flattened (T*k) space is token r // k)
    xd = x2d[disp.gather_idx // moe.top_k]
    xd = xd * disp.slot_valid[..., None].astype(xd.dtype)
    # 2D shard: experts over model, capacity rows over the data axes —
    # without the capacity sharding every data replica would redundantly
    # compute the full expert GEMM (16x waste on the production mesh).
    xd = shard(xd, "experts", "batch", None)
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", xd, layer["w_gate"].astype(xd.dtype))
    ) * jnp.einsum("ecd,edf->ecf", xd, layer["w_up"].astype(xd.dtype))
    h = shard(h, "experts", "batch", None)
    y = jnp.einsum("ecf,efd->ecd", h, layer["w_down"].astype(xd.dtype))
    y = shard(y, "experts", "batch", None)
    flat = combine_rows(disp, y)
    per_k = flat.reshape(T, moe.top_k, -1)
    out = jnp.einsum("tkd,tk->td", per_k, gates.astype(per_k.dtype))
    return out, disp.overflow


def _moe_ffn_routed(x2d, layer, cfg: TransformerConfig, capacity: int):
    """Expert FFN with explicit shard_map routing (paper's shuffle).

    Tokens are sharded over every mesh axis; each shard routes its rows to
    the model-axis shard owning the chosen expert via capacity-padded
    counting sort + ``all_to_all`` (exactly ``repro.core.route``), computes
    locally, and routes back through the same slots. Versus the pjit global
    dispatch this removes the all-gather of the full token array and the
    backward scatter-add all-reduces — wire drops from O(T*D) broadcast to
    O(T_local*k*D) point-to-point. Falls back to the global impl when the
    token count does not divide the mesh (tiny decode batches).
    """
    import math as _math

    from jax.sharding import PartitionSpec as P

    from repro.core.route import counting_layout, scatter_to_slots
    from repro.models.module import _CTX

    moe = cfg.moe
    mesh, _rules = _CTX[-1]
    axes_all = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    n_total = _math.prod(mesh.shape[a] for a in axes_all)
    n_model = mesh.shape.get("model", 1)
    T, D = x2d.shape
    if T % n_total or moe.n_experts % n_model:
        return _moe_ffn(x2d, layer, cfg, capacity)
    e_loc = moe.n_experts // n_model
    t_loc = T // n_total
    k = moe.top_k
    cap = max(8, -(-t_loc * k // n_model))
    cap = ((int(cap * moe.capacity_factor) + 7) // 8) * 8
    cap2 = ((int(n_model * cap / e_loc * 1.25) + 7) // 8) * 8 if e_loc > 1 else 0

    def inner(x_loc, router, wg, wu, wd):
        x_loc = x_loc  # (t_loc, D)
        logits = jnp.einsum(
            "td,de->te", x_loc, router, preferred_element_type=jnp.float32
        )
        top_vals, top_idx = jax.lax.top_k(logits, k)
        gates = jax.nn.softmax(top_vals, axis=-1)
        flat_e = top_idx.reshape(t_loc * k).astype(jnp.int32)
        dest = flat_e // e_loc  # destination model shard
        lay = counting_layout(dest, n_model, cap)
        rows = x_loc[jnp.arange(t_loc * k, dtype=jnp.int32) // k]
        send_x = scatter_to_slots(lay, rows, n_model, cap)
        send_e = scatter_to_slots(lay, flat_e, n_model, cap, fill=-1)
        used = scatter_to_slots(
            lay, jnp.ones((t_loc * k,), jnp.int8), n_model, cap
        )
        send_e = jnp.where(used > 0, send_e, -1)
        recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, "model", 0, 0, tiled=True)
        m_id = jax.lax.axis_index("model")
        local_e = recv_e - m_id * e_loc
        valid = (recv_e >= 0) & (local_e >= 0) & (local_e < e_loc)
        drops2 = jnp.zeros((), jnp.int32)
        if e_loc == 1:
            xr = recv_x * valid[:, None].astype(recv_x.dtype)
            h = jax.nn.silu(xr @ wg[0]) * (xr @ wu[0])
            y = (h @ wd[0]) * valid[:, None].astype(recv_x.dtype)
        else:
            disp2 = make_dispatch(
                jnp.where(valid, local_e, e_loc), e_loc, cap2
            )
            xd = dispatch_rows(disp2, recv_x)
            h = jax.nn.silu(
                jnp.einsum("ecd,edf->ecf", xd, wg)
            ) * jnp.einsum("ecd,edf->ecf", xd, wu)
            y2 = jnp.einsum("ecf,efd->ecd", h, wd)
            y = combine_rows(disp2, y2)
            drops2 = disp2.overflow - jnp.sum(~valid).astype(jnp.int32)
        back = jax.lax.all_to_all(y, "model", 0, 0, tiled=True)
        safe = jnp.clip(lay.slot_of_row, 0, n_model * cap - 1)
        out_rows = back[safe] * lay.fits[:, None].astype(back.dtype)
        per_k = out_rows.reshape(t_loc, k, D)
        out = jnp.einsum("tkd,tk->td", per_k, gates.astype(per_k.dtype))
        drops = jax.lax.psum(lay.overflow + jnp.maximum(drops2, 0), axes_all)
        return out, drops

    dt = x2d.dtype
    from repro.distributed.compat import shard_map

    out, drops = shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            P(axes_all, None),
            P(None, None),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(axes_all, None), P()),
    )(
        x2d,
        layer["router"].astype(dt),
        layer["w_gate"].astype(dt),
        layer["w_up"].astype(dt),
        layer["w_down"].astype(dt),
    )
    return out, drops


def _dense_ffn(x, layer):
    h = jax.nn.silu(
        jnp.einsum("bsd,df->bsf", x, layer["w_gate"].astype(x.dtype))
    ) * jnp.einsum("bsd,df->bsf", x, layer["w_up"].astype(x.dtype))
    h = shard(h, "batch", None, "ffn")
    return jnp.einsum("bsf,fd->bsd", h, layer["w_down"].astype(x.dtype))


def _layer_body(
    x,
    layer,
    cfg: TransformerConfig,
    *,
    q_pos,
    kv_pos,
    cache_kv=None,
    cache_pos=None,
    moe_capacity: int = 0,
):
    """One transformer block. Returns (x, new_cache_kv, moe_drops, kv)."""
    B, Sq, D = x.shape
    h = rms_norm(x, layer["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dq->bsq", h, layer["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dq->bsq", h, layer["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dq->bsq", h, layer["wv"].astype(h.dtype))
    q = shard(q, "batch", None, "qkv")
    q = q.reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, Sq, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, Sq, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, layer["q_norm"], cfg.norm_eps)
        k = rms_norm(k, layer["k_norm"], cfg.norm_eps)
    q = rope(q, q_pos, cfg.rope_theta)
    k = rope(k, q_pos, cfg.rope_theta)
    fresh_kv = (k, v)

    kv_valid_len = None
    if cache_kv is not None:
        ck, cv = cache_kv
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_pos, 0, 0))
        ck = shard(ck, "batch", "kv_seq", None, None)
        cv = shard(cv, "batch", "kv_seq", None, None)
        k, v = ck, cv
        new_cache = (ck, cv)
        kv_valid_len = cache_pos + Sq
    else:
        new_cache = None

    if cfg.attn_impl == "chunked" and Sq > 1:
        attn = attend_chunked(
            q,
            k.astype(q.dtype),
            v.astype(q.dtype),
            q_pos=q_pos,
            kv_pos=kv_pos,
            window=layer["window"],
            kv_valid_len=kv_valid_len,
            chunk=cfg.attn_chunk,
        )
    else:
        attn = attend(
            q,
            k.astype(q.dtype),
            v.astype(q.dtype),
            q_pos=q_pos,
            kv_pos=kv_pos,
            window=layer["window"],
            kv_valid_len=kv_valid_len,
        )
    attn = shard(attn, "batch", None, "qkv")
    x = x + jnp.einsum("bsq,qd->bsd", attn, layer["wo"].astype(attn.dtype))

    h = rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
    if cfg.moe is None:
        ffn = _dense_ffn(h, layer)
        drops = jnp.zeros((), jnp.int32)
    else:
        from repro.models.module import _CTX

        moe_fn = (
            _moe_ffn_routed if cfg.moe_impl == "routed" and _CTX else _moe_ffn
        )
        ffn2d, drops = moe_fn(h.reshape(B * Sq, D), layer, cfg, moe_capacity)
        ffn = ffn2d.reshape(B, Sq, D)
    x = x + ffn
    x = shard(x, "batch", None, None)
    return x, new_cache, drops, fresh_kv


def moe_capacity_for(cfg: TransformerConfig, n_tokens: int,
                     capacity_factor: float | None = None) -> int:
    if cfg.moe is None:
        return 0
    cf = capacity_factor or cfg.moe.capacity_factor
    cap = int(math.ceil(n_tokens * cfg.moe.top_k / cfg.moe.n_experts * cf))
    # round to 32 so the capacity dim divides the (pod, data) axes
    cap = ((max(cap, 32) + 31) // 32) * 32
    return min(n_tokens, cap)


def _remat_wrap(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )


def _scan_layers(params, cfg: TransformerConfig, x, body):
    """Scan ``body`` over stacked layer params (+ per-layer window size)."""
    xs = dict(params["layers"])
    xs["window"] = cfg.window_sizes()

    def step(carry, layer):
        return body(carry, layer)

    step = _remat_wrap(step, cfg.remat)
    return jax.lax.scan(step, x, xs)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def forward(params, cfg: TransformerConfig, tokens, *, capacity_factor=None):
    """Training/scoring forward: tokens (B, S) -> logits (B, S, V) fp32.

    Returns (logits, aux) with aux = {"moe_drops": total dropped rows}.
    """
    B, S = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    x = shard(x, "batch", None, None)
    pos = jnp.arange(S, dtype=jnp.int32)
    cap = moe_capacity_for(cfg, B * S, capacity_factor)

    def body(carry, layer):
        y, _, drops, _kv = _layer_body(
            carry, layer, cfg, q_pos=pos, kv_pos=pos, moe_capacity=cap
        )
        return y, drops

    x, drops = _scan_layers(params, cfg, x, body)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,vd->bsv", x, params["embed"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    logits = shard(logits, "batch", None, "vocab")
    return logits, {"moe_drops": jnp.sum(drops)}


def loss_fn(params, cfg: TransformerConfig, batch, *, capacity_factor=None):
    """Next-token cross entropy. batch = {tokens (B,S), labels (B,S)}."""
    logits, aux = forward(params, cfg, batch["tokens"], capacity_factor=capacity_factor)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - label_logit)
    aux["loss"] = loss
    return loss, aux


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int, dtype=None):
    """Stacked (L, B, S, Hkv, hd) KV cache pytree (zeros)."""
    dtype = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(cfg: TransformerConfig, batch: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    sds = jax.ShapeDtypeStruct(shape, dtype)
    return {"k": sds, "v": sds}


CACHE_AXES = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")


def decode_step(params, cfg: TransformerConfig, tokens, cache, pos,
                *, capacity_factor=None):
    """One decode step. tokens (B, 1); pos () int32 current length.

    Returns (logits (B, 1, V), new_cache). The KV cache rides through the
    layer scan as stacked xs/ys so HLO stays depth-independent.
    """
    B, Sq = tokens.shape
    S_max = cache["k"].shape[2]
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    q_pos = (pos + jnp.arange(Sq, dtype=jnp.int32))[None, :].astype(jnp.int32)
    kv_pos = jnp.arange(S_max, dtype=jnp.int32)
    cap = moe_capacity_for(cfg, B * Sq, capacity_factor or 4.0)

    xs = dict(params["layers"])
    xs["window"] = cfg.window_sizes()
    xs["cache_k"] = cache["k"]
    xs["cache_v"] = cache["v"]

    def step(carry, layer_and_cache):
        layer = {
            k2: v2
            for k2, v2 in layer_and_cache.items()
            if k2 not in ("cache_k", "cache_v")
        }
        ck, cv = layer_and_cache["cache_k"], layer_and_cache["cache_v"]
        y, new_cache, _, _kv = _layer_body(
            carry,
            layer,
            cfg,
            q_pos=q_pos[0],
            kv_pos=kv_pos,
            cache_kv=(ck, cv),
            cache_pos=pos,
            moe_capacity=cap,
        )
        return y, {"cache_k": new_cache[0], "cache_v": new_cache[1]}

    x, new_caches = jax.lax.scan(step, x, xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,vd->bsv", x, params["embed"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    return logits, {"k": new_caches["cache_k"], "v": new_caches["cache_v"]}


def prefill(params, cfg: TransformerConfig, tokens, max_seq: int,
            *, capacity_factor=None):
    """Prefill: run the full prompt, materialising the KV cache.

    tokens (B, S); returns (logits (B, S, V), cache with S_max=max_seq).
    """
    B, S = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    x = shard(x, "batch", None, None)
    pos = jnp.arange(S, dtype=jnp.int32)
    cap = moe_capacity_for(cfg, B * S, capacity_factor)
    pad = max_seq - S

    def body(carry, layer):
        y, _, drops, (k, v) = _layer_body(
            carry, layer, cfg, q_pos=pos, kv_pos=pos, moe_capacity=cap
        )
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ck = shard(ck, "batch", "kv_seq", None, None)
        cv = shard(cv, "batch", "kv_seq", None, None)
        return y, {"cache_k": ck, "cache_v": cv}

    x, caches = _scan_layers(params, cfg, x, body)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,vd->bsv", x, params["embed"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    return logits, {"k": caches["cache_k"], "v": caches["cache_v"]}
