"""Cross-layer observability: per-request span timelines + a unified
metrics registry + exportable trace artifacts.

The paper's headline claim (~210 ms/image at 100M images) is a
*distribution* property, and our serving benchmarks show the tail is
queueing, not compute — which aggregate percentiles can describe but
never *explain*. This package is the substrate that explains one slow
request: where it waited, which dispatch it coalesced into, which shard
was the straggler, and what the registry counters were doing meanwhile.

Three pieces (one module each):

  * :mod:`repro.obs.tracer` — ``Tracer`` records per-request span trees
    (queue wait → admission → coalesce → cache → per-shard engine scan →
    gather merge) on one timeline; the process-wide default is the no-op
    ``NULL_TRACER`` (near-zero cost when disabled, deterministic
    sampling when enabled, never perturbs results);
  * :mod:`repro.obs.registry` — ``MetricsRegistry`` of named counters /
    gauges / histograms with labeled series, unifying the serving,
    cache, index-lifecycle, and calibration accounting under one
    namespace (one dump = the whole system's health);
  * :mod:`repro.obs.export` — JSONL structured log, Chrome
    ``trace_event`` JSON (Perfetto / ``chrome://tracing``), and a
    human-readable summary; ``scripts/tracereport.py`` turns either
    trace format into a top-N-slowest breakdown.

Process-wide accessors: :func:`get_tracer` / :func:`set_tracer` /
:func:`tracing` for the tracer (default disabled), :func:`get_registry`
/ :func:`set_registry` for the registry (always on — a registry is cheap
enough to never gate). See docs/observability.md.
"""

from __future__ import annotations

import contextlib

from repro.obs.export import (  # noqa: F401
    chrome_trace_events,
    export_trace,
    summary,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.registry import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.tracer import (  # noqa: F401
    NULL_SPAN,
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
)

_tracer = NULL_TRACER
_registry = MetricsRegistry()


def get_tracer():
    """The process-wide active tracer (default: the no-op
    :data:`NULL_TRACER` — instrumentation costs nothing until a real
    :class:`Tracer` is installed)."""
    return _tracer


def set_tracer(tracer):
    """Install ``tracer`` (or :data:`NULL_TRACER` to disable) as the
    process-wide tracer; returns the previous one so callers can
    restore it."""
    global _tracer
    prev = _tracer
    _tracer = tracer if tracer is not None else NULL_TRACER
    return prev


@contextlib.contextmanager
def tracing(tracer):
    """Scoped :func:`set_tracer`: install for the block, restore after —
    the always-restores form CLIs and tests use."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


def get_registry() -> MetricsRegistry:
    """The process-wide metrics registry (always on)."""
    return _registry


def set_registry(registry: MetricsRegistry | None) -> MetricsRegistry:
    """Install a registry (``None`` = a fresh empty one); returns the
    previous one. Tests isolate through this."""
    global _registry
    prev = _registry
    _registry = registry if registry is not None else MetricsRegistry()
    return prev
