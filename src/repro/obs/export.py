"""Trace exporters: JSONL event log, Chrome ``trace_event`` JSON, and a
human-readable summary.

Three views of one recorded :class:`~repro.obs.tracer.Tracer`:

  * :func:`write_jsonl` — one JSON object per span/event line (the
    structured log ``scripts/tracereport.py`` and ad-hoc ``jq`` digest);
  * :func:`write_chrome_trace` — the Chrome ``trace_event`` format
    (open the file in https://ui.perfetto.dev or ``chrome://tracing``):
    request / queue-wait / compute bars per request under the
    ``requests`` process (one lane per request id), engine dispatches
    and the gather merge under ``engine``, and one *process per shard*
    (``shard 0``, ``shard 1``, …) so scatter legs render as parallel
    tracks. Events are sorted by timestamp (monotone ``ts``);
  * :func:`summary` — per-span-name count/total/mean table plus the
    slowest traced requests, for terminal eyes.

:func:`export_trace` picks the format from the extension (``.jsonl`` →
JSONL, anything else → Chrome JSON) — the ``--trace-out`` contract of
both CLIs and the serving benchmark. See docs/observability.md.
"""

from __future__ import annotations

import json
import os

# pid assignment for the Chrome trace (process lanes in Perfetto)
PID_PROCESS = 0  # warmup, index lifecycle, everything unclassified
PID_REQUESTS = 1  # per-request bars, one tid (lane) per request id
PID_ENGINE = 2  # engine dispatches (tid 0) + gather merge (tid 1)
PID_SHARD_BASE = 10  # shard N's scans land on pid PID_SHARD_BASE + N

_REQUEST_SPANS = ("request", "queue.wait", "compute", "cache.lookup")


def _placement(span) -> tuple[int, int]:
    """(pid, tid) for one span — the per-shard/pid mapping contract."""
    if span.name == "shard.scan":
        return PID_SHARD_BASE + int(span.attrs.get("shard", 0)), 0
    if span.name in ("engine.dispatch", "engine.execute"):
        return PID_ENGINE, 0
    if span.name == "gather.merge":
        return PID_ENGINE, 1
    if span.name in _REQUEST_SPANS or span.name.startswith("admission."):
        return PID_REQUESTS, int(span.trace_id or 0)
    return PID_PROCESS, 0


def chrome_trace_events(tracer) -> list[dict]:
    """The ``traceEvents`` list: process/thread-name metadata first, then
    one ``X`` (complete) or ``i`` (instant) event per span, sorted by
    timestamp."""
    events: list[dict] = []
    pids: dict[int, str] = {PID_PROCESS: "process",
                            PID_REQUESTS: "requests",
                            PID_ENGINE: "engine"}
    for span in tracer.spans:
        pid, tid = _placement(span)
        if pid >= PID_SHARD_BASE:
            pids[pid] = f"shard {pid - PID_SHARD_BASE}"
        ev = {
            "name": span.name,
            "cat": span.kind,
            "pid": pid,
            "tid": tid,
            "ts": round(span.t0 * 1e6, 3),  # microseconds
            "args": dict(span.attrs, trace_id=span.trace_id,
                         span_id=span.span_id, parent_id=span.parent_id),
        }
        if span.kind == "event" or span.t1 is None:
            ev["ph"] = "i"
            ev["s"] = "t"  # thread-scoped instant
        else:
            ev["ph"] = "X"
            ev["dur"] = round(max(0.0, span.t1 - span.t0) * 1e6, 3)
        events.append(ev)
    events.sort(key=lambda e: (e["ts"], e["pid"], e["tid"]))
    meta = [
        {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
         "args": {"name": label}}
        for pid, label in sorted(pids.items())
    ]
    return meta + events


def write_chrome_trace(tracer, path: str) -> str:
    """Write the Chrome ``trace_event`` JSON for ``tracer``; returns the
    path (dirs created)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {
        "traceEvents": chrome_trace_events(tracer),
        "displayTimeUnit": "ms",
        "otherData": tracer.describe(),
    }
    with open(path, "w") as f:
        json.dump(payload, f)
    return path


def write_jsonl(tracer, path: str) -> str:
    """Write one JSON object per span/event line; returns the path."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(json.dumps({"header": tracer.describe()}) + "\n")
        for span in tracer.spans:
            f.write(json.dumps(span.to_json()) + "\n")
    return path


def export_trace(tracer, path: str) -> str:
    """Format-by-extension exporter: ``.jsonl`` → structured event log,
    anything else → Chrome ``trace_event`` JSON."""
    if path.endswith(".jsonl"):
        return write_jsonl(tracer, path)
    return write_chrome_trace(tracer, path)


def summary(tracer, *, top: int = 5) -> str:
    """Human-readable report: per-name span accounting plus the ``top``
    slowest traced requests (wait vs compute split)."""
    by_name: dict[str, list[float]] = {}
    requests = []
    waits: dict[int, float] = {}
    computes: dict[int, float] = {}
    for s in tracer.spans:
        if s.kind == "event":
            continue
        by_name.setdefault(s.name, []).append(s.dur_ms)
        if s.name == "request":
            requests.append(s)
        elif s.name == "queue.wait" and s.trace_id is not None:
            waits[s.trace_id] = s.dur_ms
        elif s.name == "compute" and s.trace_id is not None:
            computes[s.trace_id] = s.dur_ms
    lines = [f"== trace summary ({len(tracer.spans)} records, "
             f"{tracer.dropped} dropped) =="]
    for name in sorted(by_name):
        ds = by_name[name]
        lines.append(
            f"{name:<16} n={len(ds):<6} total={sum(ds):9.1f} ms  "
            f"mean={sum(ds) / len(ds):7.2f} ms  max={max(ds):7.2f} ms"
        )
    requests.sort(key=lambda s: -s.dur_ms)
    if requests:
        lines.append(f"-- top {min(top, len(requests))} slowest requests --")
        for s in requests[:top]:
            rid = s.trace_id
            lines.append(
                f"rid={rid:<6} class={s.attrs.get('priority', '?'):<12} "
                f"total={s.dur_ms:8.2f} ms  "
                f"wait={waits.get(rid, 0.0):8.2f} ms  "
                f"compute={computes.get(rid, 0.0):8.2f} ms  "
                f"source={s.attrs.get('source', '?')}"
            )
    return "\n".join(lines)
