"""Unified metrics registry: named counters / gauges / histograms with
labeled series, plus weakly-held *sources* (live objects polled at
snapshot time).

Before this module, accounting was scattered: ``ServingMetrics`` held the
serving counters, ``HotLeafCache.stats()`` the cache view, the index
lifecycle printed its events, and calibration records lived in the
manifest. The registry unifies them under one namespace so one dump
(``launch/serve --metrics-out``, ``benchmarks.serving`` artifacts) carries
the whole system's health:

  * **instruments** — ``counter(name, **labels)`` / ``gauge`` /
    ``histogram``: created on first use, keyed by ``name`` + sorted
    labels, monotonically cheap to update (a dict hit + an add);
  * **sources** — ``register_source(name, obj, fn)`` holds ``obj``
    *weakly* and calls ``fn(obj)`` at snapshot time. ``ServingMetrics``
    and ``HotLeafCache`` register themselves this way, so their existing
    ``to_dict()`` / ``stats()`` shapes stay byte-identical while the
    registry's snapshot carries the same numbers under registry names —
    and a dead session's series vanish instead of leaking.

Naming convention (docs/observability.md): dotted lowercase paths,
subsystem first — ``serving.requests``, ``cache.hits``,
``index.appends``, ``calibration.records`` — labels for per-class /
per-shard splits (``serving.class.completed{class=interactive}``).
All plain Python — nothing here touches a device, and nothing feeds back
into planning or scheduling (observability must never perturb results).
"""

from __future__ import annotations

import json
import os
import weakref


def _series_key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone counter (floats allowed: ``engine_ms`` style totals)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def to_json(self):
        v = self.value
        return int(v) if float(v).is_integer() else v


class Gauge:
    """Last-write-wins value; any JSON-able value is allowed (strings
    carry identity facts like the active cost model)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = None

    def set(self, value) -> None:
        self.value = value

    def to_json(self):
        return self.value


# default histogram bucket upper bounds (ms-flavoured geometric ladder)
DEFAULT_BOUNDS = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0,
                  1000.0, 2000.0, 5000.0)


class Histogram:
    """Fixed-bound histogram: per-bucket counts plus exact count/sum/
    min/max — O(1) memory however long the replay runs."""

    __slots__ = ("name", "labels", "bounds", "counts", "count", "total",
                 "min", "max")

    def __init__(self, name: str, labels: dict, bounds=DEFAULT_BOUNDS):
        self.name = name
        self.labels = labels
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # +overflow bucket
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def to_json(self) -> dict:
        return {
            "bounds": list(self.bounds),
            "counts": list(self.counts),
            "count": self.count,
            "sum": self.total,
            "mean": self.total / self.count if self.count else None,
            "min": self.min,
            "max": self.max,
        }


class MetricsRegistry:
    """One process-wide namespace of instruments + weakly-held sources."""

    def __init__(self):
        self._instruments: dict[str, object] = {}
        self._sources: dict[str, tuple] = {}  # name -> (weakref, fn)

    # -- instruments ---------------------------------------------------------
    def _get(self, cls, name: str, labels: dict, **kw):
        key = _series_key(name, labels)
        inst = self._instruments.get(key)
        if inst is None:
            inst = self._instruments[key] = cls(name, labels, **kw)
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {key!r} already registered as "
                f"{type(inst).__name__}, not {cls.__name__}"
            )
        return inst

    def counter(self, name: str, **labels) -> Counter:
        """Get-or-create the counter for ``name`` + ``labels``."""
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        """Get-or-create the gauge for ``name`` + ``labels``."""
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, *, bounds=DEFAULT_BOUNDS,
                  **labels) -> Histogram:
        """Get-or-create the histogram for ``name`` + ``labels``.
        ``bounds`` apply only at creation (first caller wins)."""
        return self._get(Histogram, name, labels, bounds=bounds)

    # -- sources -------------------------------------------------------------
    def register_source(self, name: str, obj, fn) -> None:
        """Poll ``fn(obj)`` (returning a flat ``{series: value}`` dict)
        at snapshot time; ``obj`` is held weakly, so a garbage-collected
        owner silently drops out of later snapshots."""
        self._sources[name] = (weakref.ref(obj), fn)

    def unregister_source(self, name: str) -> None:
        self._sources.pop(name, None)

    # -- export --------------------------------------------------------------
    def snapshot(self) -> dict:
        """One JSON-able view of everything: ``{"metrics": {series:
        value-or-histogram}, "sources": {name: {series: value}}}``.
        Dead sources are pruned as a side effect."""
        metrics = {
            key: inst.to_json() for key, inst in sorted(
                self._instruments.items()
            )
        }
        sources = {}
        for name in sorted(self._sources):
            ref, fn = self._sources[name]
            obj = ref()
            if obj is None:
                del self._sources[name]
                continue
            sources[name] = fn(obj)
        return {"metrics": metrics, "sources": sources}

    def dump(self, path: str) -> str:
        """Write :meth:`snapshot` as JSON (dirs created); returns the
        path."""
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
        return path

    def summary(self) -> str:
        """Human-readable one-line-per-series report."""
        snap = self.snapshot()
        lines = ["== metrics registry =="]
        for key, v in snap["metrics"].items():
            if isinstance(v, dict):  # histogram
                mean = v["mean"]
                lines.append(
                    f"{key}: count={v['count']} mean="
                    + (f"{mean:.2f}" if mean is not None else "-")
                    + (f" max={v['max']:.2f}" if v["max"] is not None else "")
                )
            else:
                lines.append(f"{key}: {v}")
        for name, series in snap["sources"].items():
            lines.append(f"-- source {name} --")
            for k, v in series.items():
                lines.append(f"{k}: {v}")
        return "\n".join(lines)

    def __len__(self) -> int:
        return len(self._instruments)
