"""Per-request span trees: the tracing half of ``repro.obs``.

A *span* is one named, attributed time interval — ``[t0, t1)`` seconds on
the tracer's timeline — optionally tied to a *trace id* (the request it
belongs to) and a *parent span* (the tree). The serving replay runs on a
virtual clock (arrivals come from the trace, compute advances by measured
wall time), so the tracer supports both domains on one timeline:

  * ``tracer.span(name, ...)`` — a context manager measuring wall time
    (re-based through the active :meth:`Tracer.timebase`, so engine work
    nested inside a virtual-time dispatch lands at the dispatch's virtual
    timestamp);
  * ``tracer.add_span(name, t0, t1, ...)`` — an explicit interval in
    caller-supplied (virtual) seconds, used by the micro-batcher for the
    request / queue-wait / compute bars;
  * ``tracer.event(name, ...)`` — a zero-duration instant (admission
    shed/reject decisions and similar).

Two hard requirements shape the design:

  * **near-zero cost when disabled** — the process-wide default is the
    shared :data:`NULL_TRACER` whose every method is a no-op returning
    shared singletons; instrumented hot paths pay one attribute load and
    (at most) one kwargs dict build per dispatch, never per row;
  * **never perturb results** — the tracer only *records*; nothing in it
    feeds back into planning, scheduling, or the engine, so ids and
    distances are bit-identical with tracing on or off (asserted by
    tests/test_obs.py and the ``--obs-smoke`` gate).

Sampling is deterministic: :meth:`Tracer.sampled` hashes ``(seed,
trace_id)``, so the same seed always traces the same request subset
regardless of replay timing — replays stay comparable, and a high-QPS
trace can be thinned (``sample=0.01``) without losing specific requests
between runs. Unsampled request spans are counted in ``dropped`` (never
silent). See docs/observability.md for the span taxonomy.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
import zlib


@dataclasses.dataclass
class Span:
    """One recorded interval (or instant, for ``kind="event"``)."""

    name: str
    span_id: int
    t0: float  # seconds on the tracer timeline
    t1: float | None = None  # None while open
    trace_id: int | None = None  # owning request (rid), None = process span
    parent_id: int | None = None
    kind: str = "span"  # "span" | "event"
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def dur_ms(self) -> float:
        return 0.0 if self.t1 is None else (self.t1 - self.t0) * 1e3

    def set(self, **attrs) -> "Span":
        """Attach attributes after the span opened (chains)."""
        self.attrs.update(attrs)
        return self

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "span_id": self.span_id,
            "trace_id": self.trace_id,
            "parent_id": self.parent_id,
            "kind": self.kind,
            "t0_ms": self.t0 * 1e3,
            "t1_ms": None if self.t1 is None else self.t1 * 1e3,
            "dur_ms": self.dur_ms,
            "attrs": self.attrs,
        }


class _NullSpan:
    """Shared do-nothing span: context manager, ``set()``, the lot."""

    __slots__ = ()
    span_id = None
    attrs: dict = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every operation is a no-op on shared
    singletons, so instrumentation costs ~an attribute load when tracing
    is off. ``enabled`` is ``False`` so hot paths can skip building
    attribute dicts entirely."""

    __slots__ = ()
    enabled = False
    sample_rate = 0.0
    dropped = 0

    def sampled(self, trace_id) -> bool:
        return False

    def span(self, name, **attrs):
        return NULL_SPAN

    def add_span(self, name, t0, t1, **kw):
        return NULL_SPAN

    def event(self, name, t=None, **kw):
        return NULL_SPAN

    def timebase(self, t_virtual):
        return NULL_SPAN  # context manager no-op

    @property
    def spans(self):
        return ()

    def __len__(self) -> int:
        return 0

    def describe(self) -> dict:
        """The ``obs`` header block every benchmark artifact records."""
        return {"enabled": False, "sample": 0.0, "spans": 0, "events": 0,
                "dropped": 0}


NULL_TRACER = NullTracer()


class Tracer:
    """In-memory span recorder for one process/replay.

    Args:
      sample: fraction of *requests* traced (request-scoped spans whose
        trace id fails :meth:`sampled` are the caller's to skip; process
        spans — warmup, lifecycle, engine dispatches — are always kept).
      seed: sampling hash seed — same seed, same traced request subset.
      max_spans: hard in-memory cap; spans past it are dropped and
        counted in ``dropped`` (never silent). ``None`` = unbounded.

    Raises:
      ValueError: a sample rate outside ``[0, 1]``.
    """

    def __init__(self, *, sample: float = 1.0, seed: int = 0,
                 max_spans: int | None = None):
        if not 0.0 <= sample <= 1.0:
            raise ValueError(f"sample={sample} must be in [0, 1]")
        self.enabled = True
        self.sample_rate = float(sample)
        self.seed = int(seed)
        self.max_spans = max_spans
        self.spans: list[Span] = []
        self.dropped = 0  # sampled-out request spans + over-cap spans
        self._next_id = 1
        self._epoch = time.perf_counter()
        self._offset = 0.0  # virtual-timebase correction (see timebase())
        self._stack: list[Span] = []  # open context-manager spans

    # -- clock ---------------------------------------------------------------
    def now(self) -> float:
        """Seconds on the tracer timeline: wall time since construction,
        re-based by the active :meth:`timebase` (virtual replay time)."""
        return time.perf_counter() - self._epoch + self._offset

    @contextlib.contextmanager
    def timebase(self, t_virtual: float):
        """Pin the timeline to virtual time for the enclosed block.

        The micro-batcher replays on a virtual clock; wrapping each engine
        dispatch in ``timebase(dispatch_t)`` makes the session's
        wall-measured nested spans land at the dispatch's *virtual*
        timestamp (advancing with real elapsed time), so one trace file
        holds a single consistent timeline.
        """
        prev = self._offset
        self._offset = t_virtual - (time.perf_counter() - self._epoch)
        try:
            yield self
        finally:
            self._offset = prev

    # -- sampling ------------------------------------------------------------
    def sampled(self, trace_id) -> bool:
        """Deterministic per-request sampling decision: a hash of
        ``(seed, trace_id)`` against the sample rate — independent of
        call order and wall time, so the same seed traces the same
        request subset in every replay. A ``False`` bumps ``dropped``."""
        if self.sample_rate >= 1.0:
            return True
        if self.sample_rate <= 0.0:
            self.dropped += 1
            return False
        h = zlib.crc32(f"{self.seed}:{trace_id}".encode()) / 2**32
        if h < self.sample_rate:
            return True
        self.dropped += 1
        return False

    # -- recording -----------------------------------------------------------
    def _admit(self, span: Span) -> Span:
        if self.max_spans is not None and len(self.spans) >= self.max_spans:
            self.dropped += 1
            return NULL_SPAN  # type: ignore[return-value]
        self.spans.append(span)
        return span

    def add_span(self, name: str, t0: float, t1: float, *,
                 trace_id=None, parent=None, **attrs) -> Span:
        """Record one explicit interval (virtual-time path).

        Args:
          name: span name (see the taxonomy in docs/observability.md).
          t0/t1: interval bounds, seconds on the tracer timeline.
          trace_id: owning request id (``None`` for process spans).
          parent: parent ``Span`` (or its id) for the tree.
          **attrs: span attributes (JSON-able values).
        """
        pid = parent.span_id if isinstance(parent, (Span, _NullSpan)) \
            else parent
        span = Span(name=name, span_id=self._next_id, t0=float(t0),
                    t1=float(t1), trace_id=trace_id, parent_id=pid,
                    attrs=attrs)
        self._next_id += 1
        return self._admit(span)

    def event(self, name: str, t: float | None = None, *,
              trace_id=None, parent=None, **attrs) -> Span:
        """Record one instant (zero-duration ``kind="event"``)."""
        t = self.now() if t is None else float(t)
        pid = parent.span_id if isinstance(parent, (Span, _NullSpan)) \
            else parent
        span = Span(name=name, span_id=self._next_id, t0=t, t1=t,
                    trace_id=trace_id, parent_id=pid, kind="event",
                    attrs=attrs)
        self._next_id += 1
        return self._admit(span)

    @contextlib.contextmanager
    def span(self, name: str, *, trace_id=None, parent=None, **attrs):
        """Measure the enclosed block as one span (wall time, re-based by
        the active :meth:`timebase`). Nested ``span()`` blocks parent
        automatically; explicit ``parent`` overrides."""
        if parent is None and self._stack:
            parent = self._stack[-1]
        pid = parent.span_id if isinstance(parent, (Span, _NullSpan)) \
            else parent
        span = Span(name=name, span_id=self._next_id, t0=self.now(),
                    trace_id=trace_id, parent_id=pid, attrs=attrs)
        self._next_id += 1
        span = self._admit(span)
        real = isinstance(span, Span)
        if real:
            self._stack.append(span)
        try:
            yield span
        finally:
            if real:
                self._stack.pop()
                span.t1 = self.now()

    # -- reporting -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.spans)

    def n_events(self) -> int:
        return sum(1 for s in self.spans if s.kind == "event")

    def describe(self) -> dict:
        """The ``obs`` header block every benchmark artifact records:
        enabled flag, sample rate, span/event counts, drops."""
        n_ev = self.n_events()
        return {
            "enabled": True,
            "sample": self.sample_rate,
            "spans": len(self.spans) - n_ev,
            "events": n_ev,
            "dropped": self.dropped,
        }
