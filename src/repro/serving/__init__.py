"""Online search serving (beyond-paper: the Exp #5 batch job as a service).

``SearchSession`` (bucketed, recompile-free executors + hot-leaf cache +
metrics), ``ShardedSearchSession`` (scatter-gather over a
``repro.index.ShardPlan`` — same surface, bit-identical results),
``MicroBatcher`` (dynamic coalescing with deadline and backpressure),
``TraceLoadGenerator`` (uniform/Zipf replayable workloads), and
``persist`` (corpus store helpers + deprecated index shims). See
docs/serving.md and docs/sharding.md for the architecture.
"""

from repro.serving.batching import Completion, MicroBatcher  # noqa: F401
from repro.serving.cache import HotLeafCache  # noqa: F401
from repro.serving.metrics import LatencyStats, ServingMetrics  # noqa: F401
from repro.serving.session import SearchSession  # noqa: F401
from repro.serving.sharded import ShardedSearchSession  # noqa: F401
from repro.serving.trace import Request, TraceLoadGenerator  # noqa: F401
