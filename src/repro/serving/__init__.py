"""Online search serving (beyond-paper: the Exp #5 batch job as a service).

``SearchSession`` (bucketed, recompile-free executors + hot-leaf cache +
metrics), ``ShardedSearchSession`` (scatter-gather over a
``repro.index.ShardPlan`` — same surface, bit-identical results),
``MicroBatcher`` (deadline-aware EDF or arrival-order FIFO coalescing
with backpressure and fitted-cost admission control), ``SLOPolicy`` +
``tune_ladder`` (per-class deadlines, shedding depth, and closed-loop
bucket-ladder tuning for a target p95 — see :mod:`repro.serving.slo`),
``TraceLoadGenerator`` (uniform/Zipf replayable workloads, plus
multi-tenant bursty class mixes via ``TenantClass``), and ``persist``
(corpus store helpers + deprecated index shims). See docs/serving.md,
docs/slo_serving.md, and docs/sharding.md for the architecture.
"""

from repro.serving.batching import Completion, MicroBatcher  # noqa: F401
from repro.serving.cache import HotLeafCache  # noqa: F401
from repro.serving.metrics import LatencyStats, ServingMetrics  # noqa: F401
from repro.serving.session import SearchSession  # noqa: F401
from repro.serving.sharded import ShardedSearchSession  # noqa: F401
from repro.serving.slo import (  # noqa: F401
    LadderDecision,
    SLOPolicy,
    tune_ladder,
)
from repro.serving.trace import (  # noqa: F401
    Request,
    TenantClass,
    TraceLoadGenerator,
    default_tenant_mix,
)
