"""Dynamic micro-batching: the request queue in front of the engine.

The paper's throughput headline (~210 ms/image, Exp #5) comes from
batching: the lookup-table broadcast and the scan amortise over a big
batch. Online, nobody sends 12k-image batches — the *batcher* has to
manufacture them by coalescing the queue, trading a bounded wait for
amortisation:

  * dispatch when pending rows reach the largest warmed bucket
    (perfect amortisation), or
  * when the oldest pending request has waited ``max_wait_ms`` (bounded
    tail latency), whichever comes first;
  * reject arrivals beyond ``max_queue`` pending requests (backpressure —
    a bounded queue, not an unbounded latency cliff);
  * requests the hot-leaf cache can answer are served at admission and
    never occupy a batch slot.

Replay is a discrete-event simulation over a trace: *arrival times are
virtual* (from the trace), *compute times are real* (measured wall clock
of each engine dispatch / cache hit). That makes latency percentiles
honest about queueing + batching delay while staying exactly reproducible
in shape (same trace -> same batches) regardless of host speed.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.serving.session import SearchSession
from repro.serving.trace import Request


@dataclasses.dataclass
class Completion:
    """Terminal record of one request."""

    rid: int
    image_id: int
    arrival: float  # virtual seconds
    finish: float  # virtual seconds
    source: str  # "engine" | "cache" | "rejected"
    ids: np.ndarray | None = None  # (rows, k) or None when rejected
    dists: np.ndarray | None = None

    @property
    def latency_ms(self) -> float:
        return (self.finish - self.arrival) * 1e3


class MicroBatcher:
    """Coalesce a request stream into bucket-sized engine dispatches."""

    def __init__(
        self,
        session: SearchSession,
        *,
        max_wait_ms: float = 5.0,
        max_queue: int = 256,
    ):
        self.session = session
        self.max_wait = float(max_wait_ms) / 1e3
        self.max_queue = int(max_queue)

    def run(self, requests: list[Request]) -> list[Completion]:
        """Replay a trace to completion; returns one Completion per
        request (in completion order) and fills ``session.metrics``."""
        s = self.session
        m = s.metrics
        todo = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        now = 0.0
        pending: deque[Request] = deque()
        rows_pending = 0  # running row count of `pending`
        done: list[Completion] = []

        def admit(until: float):
            nonlocal i, rows_pending
            while i < len(todo) and todo[i].arrival <= until + 1e-12:
                r = todo[i]
                i += 1
                # cache first: a hit never occupies a queue slot, so it is
                # served even under backpressure
                t0 = time.perf_counter()
                hit = s.cache.try_serve(r.queries, s.k)
                dt = time.perf_counter() - t0
                if hit is not None:
                    m.cache_images += 1
                    m.requests += 1
                    lat_start = max(now, r.arrival)
                    done.append(Completion(
                        rid=r.rid, image_id=r.image_id, arrival=r.arrival,
                        finish=lat_start + dt, source="cache",
                        ids=hit[0], dists=hit[1],
                    ))
                    m.latency.add((lat_start + dt - r.arrival) * 1e3)
                    continue
                if len(pending) >= self.max_queue:
                    m.rejected += 1
                    done.append(Completion(
                        rid=r.rid, image_id=r.image_id, arrival=r.arrival,
                        finish=r.arrival, source="rejected",
                    ))
                    continue
                pending.append(r)
                rows_pending += r.rows

        while i < len(todo) or pending:
            if not pending:
                now = max(now, todo[i].arrival)
            admit(now)
            if not pending:
                continue
            deadline = pending[0].arrival + self.max_wait
            if rows_pending < s.max_batch_rows and now < deadline and i < len(todo):
                # wait for more coalescing: hop to the next event
                now = min(deadline, todo[i].arrival)
                admit(now)
                if rows_pending < s.max_batch_rows and now < deadline:
                    continue
            # ---- dispatch: fill up to the largest bucket ----------------
            m.observe_queue_depth(len(pending))
            batch: list[Request] = [pending.popleft()]
            rows = batch[0].rows
            while pending and rows + pending[0].rows <= s.max_batch_rows:
                r = pending.popleft()
                batch.append(r)
                rows += r.rows
            rows_pending -= rows
            busy0 = s.metrics.engine_ms
            if batch[0].rows > s.max_batch_rows:
                # a single request bigger than the top bucket: session.search
                # splits it across dispatches (it can never coalesce anyway)
                ids, dists = s.search(batch[0].queries, n_images=1)
                results = [(ids, dists)]
            else:
                results = s.serve_many([r.queries for r in batch])
            # advance the virtual clock by the measured engine wall time
            now += (s.metrics.engine_ms - busy0) * 1e-3
            for r, (ids, dists) in zip(batch, results):
                m.requests += 1
                done.append(Completion(
                    rid=r.rid, image_id=r.image_id, arrival=r.arrival,
                    finish=now, source="engine", ids=ids, dists=dists,
                ))
                m.latency.add((now - r.arrival) * 1e3)
        s.steady_state_recompiles()
        return done
