"""Deadline-aware micro-batching: the request scheduler in front of the
engine.

The paper's throughput headline (~210 ms/image, Exp #5) comes from
batching: the lookup-table broadcast and the scan amortise over a big
batch. Online, nobody sends 12k-image batches — the *batcher* has to
manufacture them by coalescing the queue, trading a bounded wait for
amortisation. Under sustained load the queue, not the kernel, owns the
tail: our serving benchmark measured ~15 ms/image engine cost but >1 s
p95, nearly all queueing. Two schedulers attack that:

  * ``scheduler="edf"`` (default) — deadline-aware dispatch. Every
    request carries a priority class (``interactive`` / ``standard`` /
    ``batch``, see :mod:`repro.serving.slo`); the pending set is ordered
    earliest-deadline-first within class, higher classes first. Each
    class owns its own coalescing budget (interactive holds briefly,
    batch holds long), and admission control sheds — or
    deadline-downgrades — incoming ``batch`` work once queue depth
    crosses the policy's fitted-cost-derived threshold, so bursts of
    bulk traffic cannot collapse the interactive tail.
  * ``scheduler="fifo"`` — the original arrival-order coalescing,
    kept bit-for-bit so existing benchmark trajectories stay comparable
    (``launch/serve --scheduler fifo``).

Scheduling never changes *what* a request returns: per-request results
are independent of batch composition (each query row routes and scans
independently; padding is masked), so the same trace replayed under
``fifo`` and ``edf`` yields bit-identical ids + distances per request —
the ``--slo-smoke`` gate asserts it.

Replay is a discrete-event simulation over a trace: *arrival times are
virtual* (from the trace), *compute times are real* (measured wall clock
of each engine dispatch / cache hit). That makes latency percentiles
honest about queueing + batching delay while staying exactly reproducible
in shape (same trace -> same batches) regardless of host speed.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from collections import deque

import numpy as np

from repro.obs import get_tracer
from repro.serving.session import SearchSession
from repro.serving.slo import SLOPolicy, class_rank
from repro.serving.trace import Request


@dataclasses.dataclass
class Completion:
    """Terminal record of one request."""

    rid: int
    image_id: int
    arrival: float  # virtual seconds
    finish: float  # virtual seconds
    source: str  # "engine" | "cache" | "rejected" | "shed"
    ids: np.ndarray | None = None  # (rows, k) or None when dropped
    dists: np.ndarray | None = None
    priority: str = "standard"
    wait_ms: float = 0.0  # arrival -> dispatch (queueing + coalescing)
    compute_ms: float = 0.0  # dispatch -> finish (engine / cache work)

    @property
    def latency_ms(self) -> float:
        return (self.finish - self.arrival) * 1e3


class MicroBatcher:
    """Coalesce a request stream into bucket-sized engine dispatches.

    Args:
      session: the warmed :class:`~repro.serving.SearchSession` (or
        sharded subclass) dispatches run on.
      max_wait_ms: base coalescing budget. FIFO applies it to the oldest
        pending request; EDF derives per-class budgets from it unless
        ``policy`` overrides them.
      max_queue: hard pending-request cap (backpressure) — arrivals
        beyond it are rejected under either scheduler.
      scheduler: ``"edf"`` (deadline-aware, the default) or ``"fifo"``
        (the original arrival-order coalescing, kept for comparability).
      policy: the :class:`~repro.serving.slo.SLOPolicy` EDF enforces;
        defaults to :meth:`SLOPolicy.for_session`, which derives the
        batch-shedding depth from the session's fitted cost model (no
        shedding when the index carries no usable calibration).
      refresh_every: when > 0, call ``session.maybe_refresh()`` after
        every N engine dispatches — the read-during-write hook
        (``docs/dynamicity.md``): a background writer's commits are
        adopted *between* batches, after the new snapshot's rungs are
        warmed, so no in-flight or queued request ever observes a
        half-adopted index. 0 (default) never refreshes: the session
        serves its pinned version for the whole trace.

    Raises:
      ValueError: an unknown ``scheduler``.
    """

    def __init__(
        self,
        session: SearchSession,
        *,
        max_wait_ms: float = 5.0,
        max_queue: int = 256,
        scheduler: str = "edf",
        policy: SLOPolicy | None = None,
        refresh_every: int = 0,
    ):
        if scheduler not in ("edf", "fifo"):
            raise ValueError(
                f"unknown scheduler {scheduler!r}; want edf|fifo"
            )
        self.session = session
        self.max_wait = float(max_wait_ms) / 1e3
        self.max_queue = int(max_queue)
        self.scheduler = scheduler
        self.refresh_every = int(refresh_every)
        self._dispatches = 0
        self.policy = policy if policy is not None else SLOPolicy.for_session(
            session, base_max_wait_ms=max_wait_ms,
        )

    def _after_dispatch(self) -> None:
        """Between-batch refresh point. Warmup cost lands in
        ``metrics.warmup_ms`` (not ``engine_ms``), so adopting a new
        index version never distorts the replay's virtual clock."""
        self._dispatches += 1
        if self.refresh_every and self._dispatches % self.refresh_every == 0:
            self.session.maybe_refresh()

    def run(self, requests: list[Request]) -> list[Completion]:
        """Replay a trace to completion; returns one Completion per
        request (in completion order) and fills ``session.metrics``."""
        if self.scheduler == "fifo":
            return self._run_fifo(requests)
        return self._run_edf(requests)

    # -- shared helpers ------------------------------------------------------

    def _try_cache(self, r: Request, now: float, done: list[Completion]
                   ) -> bool:
        """Serve ``r`` from the hot-leaf cache at admission if possible.
        A hit never occupies a queue slot, so it is served even under
        backpressure or shedding."""
        s = self.session
        m = s.metrics
        t0 = time.perf_counter()
        hit = s.cache.try_serve(r.queries, s.k)
        dt = time.perf_counter() - t0
        if hit is None:
            return False
        m.cache_images += 1
        m.requests += 1
        lat_start = max(now, r.arrival)
        wait_ms = (lat_start - r.arrival) * 1e3
        done.append(Completion(
            rid=r.rid, image_id=r.image_id, arrival=r.arrival,
            finish=lat_start + dt, source="cache",
            ids=hit[0], dists=hit[1], priority=r.priority,
            wait_ms=wait_ms, compute_ms=dt * 1e3,
        ))
        m.observe_latency(
            r.priority, wait_ms=wait_ms, compute_ms=dt * 1e3,
            deadline_ms=self.policy.deadlines_ms.get(r.priority),
        )
        tr = get_tracer()
        if tr.enabled and tr.sampled(r.rid):
            finish = lat_start + dt
            req = tr.add_span(
                "request", r.arrival, finish, trace_id=r.rid,
                priority=r.priority, source="cache", rows=r.rows,
                cache_hit=True,
            )
            tr.add_span("queue.wait", r.arrival, lat_start,
                        trace_id=r.rid, parent=req)
            comp = tr.add_span("compute", lat_start, finish,
                               trace_id=r.rid, parent=req, source="cache")
            tr.add_span("cache.lookup", lat_start, finish,
                        trace_id=r.rid, parent=comp, hit=True)
        return True

    def _dispatch(self, batch: list[Request], now: float,
                  done: list[Completion]) -> float:
        """Run one coalesced batch; returns the new virtual ``now``
        (advanced by the measured engine wall time) after appending one
        engine Completion per request."""
        s = self.session
        m = s.metrics
        tr = get_tracer()
        busy0 = m.engine_ms
        dispatch_t = now
        # pin the tracer's clock to virtual time for the dispatch, so the
        # session's wall-measured spans (engine.execute, shard.scan, ...)
        # land at the dispatch's virtual timestamp on one timeline
        with tr.timebase(dispatch_t):
            if batch[0].rows > s.max_batch_rows:
                # a single request bigger than the top bucket:
                # session.search splits it across dispatches (it can
                # never coalesce anyway)
                ids, dists = s.search(batch[0].queries, n_images=1)
                results = [(ids, dists)]
            else:
                results = s.serve_many([r.queries for r in batch])
        # advance the virtual clock by the measured engine wall time
        now += (m.engine_ms - busy0) * 1e-3
        compute_ms = (now - dispatch_t) * 1e3
        rows = sum(r.rows for r in batch)
        dsp = None
        if tr.enabled:
            # one engine span fanning in the batch's request spans
            dsp = tr.add_span(
                "engine.dispatch", dispatch_t, now,
                n_requests=len(batch), rows=rows,
                rids=[r.rid for r in batch],
            )
        for r, (ids, dists) in zip(batch, results):
            m.requests += 1
            wait_ms = (dispatch_t - r.arrival) * 1e3
            done.append(Completion(
                rid=r.rid, image_id=r.image_id, arrival=r.arrival,
                finish=now, source="engine", ids=ids, dists=dists,
                priority=r.priority, wait_ms=wait_ms, compute_ms=compute_ms,
            ))
            m.observe_latency(
                r.priority, wait_ms=wait_ms, compute_ms=compute_ms,
                deadline_ms=self.policy.deadlines_ms.get(r.priority),
            )
            if tr.enabled and tr.sampled(r.rid):
                req = tr.add_span(
                    "request", r.arrival, now, trace_id=r.rid,
                    priority=r.priority, source="engine", rows=r.rows,
                    cache_hit=False, dispatch_id=dsp.span_id,
                )
                tr.add_span("queue.wait", r.arrival, dispatch_t,
                            trace_id=r.rid, parent=req)
                tr.add_span("compute", dispatch_t, now, trace_id=r.rid,
                            parent=req, source="engine",
                            dispatch_id=dsp.span_id)
        return now

    # -- fifo: the original arrival-order coalescing -------------------------

    def _run_fifo(self, requests: list[Request]) -> list[Completion]:
        s = self.session
        m = s.metrics
        todo = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        now = 0.0
        pending: deque[Request] = deque()
        rows_pending = 0  # running row count of `pending`
        done: list[Completion] = []

        def admit(until: float):
            nonlocal i, rows_pending
            while i < len(todo) and todo[i].arrival <= until + 1e-12:
                r = todo[i]
                i += 1
                # cache first: a hit never occupies a queue slot, so it is
                # served even under backpressure
                if self._try_cache(r, now, done):
                    continue
                if len(pending) >= self.max_queue:
                    m.observe_drop(r.priority, "rejected")
                    get_tracer().event(
                        "admission.rejected", t=r.arrival, trace_id=r.rid,
                        priority=r.priority, queue_depth=len(pending),
                    )
                    done.append(Completion(
                        rid=r.rid, image_id=r.image_id, arrival=r.arrival,
                        finish=r.arrival, source="rejected",
                        priority=r.priority,
                    ))
                    continue
                pending.append(r)
                rows_pending += r.rows

        while i < len(todo) or pending:
            if not pending:
                now = max(now, todo[i].arrival)
            admit(now)
            if not pending:
                continue
            deadline = pending[0].arrival + self.max_wait
            if rows_pending < s.max_batch_rows and now < deadline and i < len(todo):
                # wait for more coalescing: hop to the next event
                now = min(deadline, todo[i].arrival)
                admit(now)
                if rows_pending < s.max_batch_rows and now < deadline:
                    continue
            # ---- dispatch: fill up to the largest bucket ----------------
            m.observe_queue_depth(len(pending))
            batch: list[Request] = [pending.popleft()]
            rows = batch[0].rows
            while pending and rows + pending[0].rows <= s.max_batch_rows:
                r = pending.popleft()
                batch.append(r)
                rows += r.rows
            rows_pending -= rows
            now = self._dispatch(batch, now, done)
            self._after_dispatch()
        s.steady_state_recompiles()
        return done

    # -- edf: deadline-aware scheduling with admission control ---------------

    def _run_edf(self, requests: list[Request]) -> list[Completion]:
        s = self.session
        m = s.metrics
        policy = self.policy
        todo = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        now = 0.0
        # heap entries: (class rank, effective deadline, rid, request) —
        # earliest-deadline-first within class, higher classes first
        heap: list[tuple] = []
        rows_pending = 0
        done: list[Completion] = []

        def admit(until: float):
            nonlocal i, rows_pending
            while i < len(todo) and todo[i].arrival <= until + 1e-12:
                r = todo[i]
                i += 1
                if self._try_cache(r, now, done):
                    continue
                deadline_t = r.arrival + policy.deadline_s(r.priority)
                # admission control: past the fitted-cost-derived depth,
                # queued work alone already exceeds the batch deadline —
                # shed (or deadline-downgrade) incoming batch work rather
                # than let it lengthen every class's queue
                if (policy.shed_depth is not None
                        and r.priority == "batch"
                        and len(heap) >= policy.shed_depth):
                    if policy.on_overload == "shed":
                        m.observe_drop(r.priority, "shed")
                        get_tracer().event(
                            "admission.shed", t=r.arrival, trace_id=r.rid,
                            priority=r.priority, queue_depth=len(heap),
                        )
                        done.append(Completion(
                            rid=r.rid, image_id=r.image_id,
                            arrival=r.arrival, finish=r.arrival,
                            source="shed", priority=r.priority,
                        ))
                        continue
                    m.downgraded += 1
                    get_tracer().event(
                        "admission.downgraded", t=r.arrival,
                        trace_id=r.rid, priority=r.priority,
                        queue_depth=len(heap),
                    )
                    deadline_t += policy.deadline_s("batch")
                if len(heap) >= self.max_queue:
                    m.observe_drop(r.priority, "rejected")
                    get_tracer().event(
                        "admission.rejected", t=r.arrival, trace_id=r.rid,
                        priority=r.priority, queue_depth=len(heap),
                    )
                    done.append(Completion(
                        rid=r.rid, image_id=r.image_id, arrival=r.arrival,
                        finish=r.arrival, source="rejected",
                        priority=r.priority,
                    ))
                    continue
                heapq.heappush(
                    heap, (class_rank(r.priority), deadline_t, r.rid, r)
                )
                rows_pending += r.rows

        while i < len(todo) or heap:
            if not heap:
                now = max(now, todo[i].arrival)
            admit(now)
            if not heap:
                continue
            head = heap[0][3]
            # the head's class decides how long the batcher may hold the
            # queue open to coalesce — interactive holds briefly, batch
            # holds long
            hold = head.arrival + policy.max_wait_s(head.priority)
            if rows_pending < s.max_batch_rows and now < hold and i < len(todo):
                now = min(hold, todo[i].arrival)
                admit(now)
                if rows_pending < s.max_batch_rows and now < hold:
                    continue  # head may have changed: re-evaluate
            # ---- dispatch: fill the bucket in (class, deadline) order ---
            m.observe_queue_depth(len(heap))
            batch: list[Request] = [heapq.heappop(heap)[3]]
            rows = batch[0].rows
            while heap and rows + heap[0][3].rows <= s.max_batch_rows:
                r = heapq.heappop(heap)[3]
                batch.append(r)
                rows += r.rows
            rows_pending -= rows
            now = self._dispatch(batch, now, done)
            self._after_dispatch()
        s.steady_state_recompiles()
        return done
