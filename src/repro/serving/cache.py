"""Hot-leaf cache: the in-memory analog of the paper's lookup-table
broadcast (§2.5), specialised to skewed online traffic.

The paper ships auxiliary data (tree + lookup table) to every map task once
per batch job so the scan itself never waits on it. An online service sees
the same effect *across requests*: under a skewed (Zipf) query stream a
small set of tree leaves absorbs most of the routed queries. This cache
pins those leaves' index slabs (vectors + descriptor ids, host-resident
numpy) and answers a repeated query locally — an exact scan over exactly
the leaves the engine would have scanned — without occupying a micro-batch
slot.

Two layers of keying:

  * ``leaf_id -> slab`` — admitted once a leaf has been routed to
    ``admit_after`` times, evicted when over ``capacity`` leaves;
  * ``query bytes -> probe leaves`` — the routing memo. Routing is a tree
    descent (device work), so a cache *hit* must not need it: only queries
    whose exact bytes have been routed before can be cache-served, which
    is precisely the hot-repeated-query population the cache targets.

Eviction is **cost-aware** by default (``eviction="cost"``): resident
leaves are ranked by predicted *ms saved per resident byte* — routing
frequency x the engine cost a hit avoids (the serving session feeds the
fitted :class:`~repro.core.engine.costmodel.CostModel`'s predicted
ms/image via :meth:`HotLeafCache.note_engine_cost`) / the slab's resident
bytes — and the lowest-value-per-byte leaf goes first. A huge lukewarm
slab is evicted before a small hot one even if touched more recently,
so a fixed budget holds the leaves that actually buy tail latency.
``eviction="lru"`` keeps the original recency policy.

Distances use the same algebraic form as the engine
(``||p||^2 - 2 p.q + ||q||^2`` in float32), so ids agree with the engine
scan; tests assert it.
"""

from __future__ import annotations

from collections import OrderedDict

import numpy as np


class HotLeafCache:
    """Hot-leaf slab cache + routing memo, with hit accounting.

    Args:
      capacity_leaves: resident-leaf budget (0 disables the cache).
      admit_after: leaf routings before a leaf's slab is admitted.
      memo_capacity: routing-memo entries kept (exact query bytes).
      eviction: ``"cost"`` (predicted ms-saved-per-resident-byte, the
        default) or ``"lru"`` (recency — the original policy).

    Raises:
      ValueError: an unknown ``eviction`` policy.
    """

    def __init__(self, capacity_leaves: int, *, admit_after: int = 2,
                 memo_capacity: int = 65536, eviction: str = "cost"):
        if eviction not in ("cost", "lru"):
            raise ValueError(
                f"unknown eviction policy {eviction!r}; want cost|lru"
            )
        self.capacity = int(capacity_leaves)
        self.admit_after = int(admit_after)
        self.memo_capacity = int(memo_capacity)
        self.eviction = eviction
        # leaf -> (vecs, ids, point sq-norms), norms precomputed at admission
        self._slabs: OrderedDict[int, tuple] = OrderedDict()
        self._freq: dict[int, int] = {}
        self._memo: OrderedDict[bytes, np.ndarray] = OrderedDict()
        self.hits = 0  # requests answered entirely from cache
        self.misses = 0  # requests that went to the engine
        self.evictions = 0  # slabs dropped to stay within capacity
        self.cost_hint_ms = None  # predicted/measured engine ms a hit saves
        # index-side tables (attach_index)
        self._vecs = self._ids = None
        self._order = self._starts = None
        # unified-registry source (held weakly there): one registry dump
        # carries the cache counters next to the serving/index series
        from repro.obs import get_registry

        get_registry().register_source(
            f"hot_leaf_cache@{id(self):x}", self,
            HotLeafCache.registry_series,
        )

    def registry_series(self) -> dict:
        """The registry view of :meth:`stats` under ``cache.*`` names."""
        s = self.stats()
        return {f"cache.{k}": v for k, v in s.items()}

    # -- index attachment ---------------------------------------------------
    def attach_index(self, vecs: np.ndarray, ids: np.ndarray,
                     leaves: np.ndarray, n_leaves: int) -> None:
        """Host copies of the index rows + a leaf -> rows map (one global
        sort; padding rows carry out-of-range leaves and fall off the
        end).

        Re-attaching (a serving session refresh after the index grew or
        rows were deleted) drops every admitted slab and memo: a stale
        slab would keep serving pre-delete rows the engine now masks.
        """
        self._slabs.clear()
        self._freq.clear()
        self._memo.clear()
        self._vecs = np.asarray(vecs, np.float32)
        self._ids = np.asarray(ids)
        lv = np.asarray(leaves).astype(np.int64)
        self._order = np.argsort(lv, kind="stable")
        sorted_leaves = lv[self._order]
        self._starts = np.searchsorted(
            sorted_leaves, np.arange(n_leaves + 1, dtype=np.int64)
        )

    def _leaf_rows(self, leaf: int) -> np.ndarray:
        return self._order[self._starts[leaf]: self._starts[leaf + 1]]

    # -- serve path ---------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self.capacity > 0 and self._vecs is not None

    @property
    def n_cached_leaves(self) -> int:
        return len(self._slabs)

    @property
    def hit_rate(self) -> float:
        """Hits / (hits + misses); 0.0 on an idle or never-attached cache
        (never a division by zero)."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def resident_bytes(self) -> int:
        """Host bytes held by the admitted slabs (vectors + ids + norms)."""
        return sum(
            sv.nbytes + si.nbytes + sn.nbytes
            for sv, si, sn in self._slabs.values()
        )

    def note_engine_cost(self, ms_per_image: float | None) -> None:
        """Feed the predicted (fitted cost model) or measured engine
        ms/image a cache hit saves — the numerator of the cost-aware
        eviction score. Folded as an EMA so one outlier dispatch cannot
        flip the ranking; ``None``/non-positive values are ignored."""
        if ms_per_image is None or ms_per_image <= 0:
            return
        ms = float(ms_per_image)
        if self.cost_hint_ms is None:
            self.cost_hint_ms = ms
        else:
            self.cost_hint_ms += 0.25 * (ms - self.cost_hint_ms)

    def _score(self, leaf: int) -> float:
        """Predicted ms saved per resident byte: routing frequency x the
        engine cost a hit avoids / the slab's resident bytes. Without a
        cost hint the hint cancels out of the ranking (frequency per
        byte). Empty slabs score 0 — first out."""
        sv, si, sn = self._slabs[leaf]
        nbytes = sv.nbytes + si.nbytes + sn.nbytes
        if not nbytes:
            return 0.0
        hint = self.cost_hint_ms if self.cost_hint_ms else 1.0
        return self._freq.get(leaf, 0) * hint / nbytes

    def _evict_one(self) -> None:
        """Drop one slab: the lowest ms-saved-per-byte leaf under
        ``eviction="cost"``, the least-recently-used under ``"lru"``."""
        if self.eviction == "cost":
            victim = min(self._slabs, key=self._score)
            del self._slabs[victim]
        else:
            self._slabs.popitem(last=False)
        self.evictions += 1

    def try_serve(
        self, queries: np.ndarray, k: int
    ) -> tuple[np.ndarray, np.ndarray] | None:
        """Answer a request's query rows entirely from cache, or ``None``.

        Serves only when *every* row's routing is memoised and *every*
        routed leaf is resident — a partial hit would still cost an engine
        dispatch, so it counts as a miss.
        """
        if not self.enabled:
            return None
        routed = []
        for q in queries:
            lv = self._memo.get(np.ascontiguousarray(q).tobytes())
            if lv is None or not all(int(l) in self._slabs for l in lv):
                self.misses += 1
                return None
            routed.append(lv)
        out_i = np.full((len(queries), k), -1, np.int32)
        out_d = np.full((len(queries), k), np.inf, np.float32)
        for r, (q, lv) in enumerate(zip(queries, routed)):
            cand_v, cand_i, cand_n = [], [], []
            for l in lv:
                sv, si, sn = self._slabs[int(l)]
                self._slabs.move_to_end(int(l))  # LRU touch
                cand_v.append(sv)
                cand_i.append(si)
                cand_n.append(sn)
            pv = np.concatenate(cand_v)
            pid = np.concatenate(cand_i)
            qf = np.asarray(q, np.float32)
            # same algebraic form as the engine's tile scan (point norms
            # precomputed at admission — slabs are immutable)
            d2 = (
                np.concatenate(cand_n)
                - 2.0 * pv @ qf
                + float((qf * qf).sum())
            ).astype(np.float32)
            top = min(k, len(pid))
            sel = np.argsort(d2, kind="stable")[:top]
            out_i[r, :top] = pid[sel]
            out_d[r, :top] = d2[sel]
        self.hits += 1
        return out_i, out_d

    # -- learn path (after an engine dispatch) ------------------------------
    def record(self, queries: np.ndarray, probe_leaves: np.ndarray, *,
               exact: bool = True) -> None:
        """Memoise routing for served queries and admit/evict hot leaves.

        ``exact=False`` (the dispatch reported slab-budget overflow) skips
        learning entirely: a cached full-slab scan would *disagree* with
        the starved engine answer for the same query."""
        if not self.enabled or not exact:
            return
        for q, lv in zip(queries, probe_leaves):
            key = np.ascontiguousarray(q).tobytes()
            if key not in self._memo:
                if len(self._memo) >= self.memo_capacity:
                    self._memo.popitem(last=False)
                self._memo[key] = np.asarray(lv, np.int64).copy()
            for l in lv:
                l = int(l)
                self._freq[l] = self._freq.get(l, 0) + 1
                if l in self._slabs:
                    self._slabs.move_to_end(l)
                elif self._freq[l] >= self.admit_after:
                    rows = self._leaf_rows(l)
                    sv = self._vecs[rows]
                    self._slabs[l] = (
                        sv, self._ids[rows].astype(np.int32),
                        (sv * sv).sum(1).astype(np.float32),
                    )
                    while len(self._slabs) > self.capacity:
                        self._evict_one()

    def stats(self) -> dict:
        """Well-formed counters at any lifecycle stage — including a
        cache that was never attached to an index or never served a
        request (all rates defined, no division by zero)."""
        return {
            "enabled": self.enabled,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
            "cached_leaves": self.n_cached_leaves,
            "capacity_leaves": self.capacity,
            "resident_bytes": self.resident_bytes,
            "memo_entries": len(self._memo),
            "eviction": self.eviction,
            "evictions": self.evictions,
            "cost_hint_ms": self.cost_hint_ms,
        }
