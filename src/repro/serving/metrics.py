"""Serving metrics: latency percentiles, throughput, queue/cache counters.

The paper's Exp #5 reports one number (ms/image at a fixed batch size); an
online service needs the full latency distribution (p50/p95/p99 — queueing
delay included), the throughput it was achieved at, and the health counters
that explain it (queue depth, recompiles, cache hit rate, rejects). All
accounting is plain Python/numpy — nothing here touches a device.
"""

from __future__ import annotations

import dataclasses

import numpy as np


class LatencyStats:
    """Streaming latency collector with exact percentiles at report time."""

    def __init__(self):
        self._ms: list[float] = []

    def add(self, ms: float) -> None:
        self._ms.append(float(ms))

    def __len__(self) -> int:
        return len(self._ms)

    def percentile(self, p: float) -> float:
        if not self._ms:
            return float("nan")
        return float(np.percentile(np.asarray(self._ms), p))

    def summary(self) -> dict:
        if not self._ms:
            return {"count": 0}
        a = np.asarray(self._ms)
        return {
            "count": int(a.size),
            "mean_ms": float(a.mean()),
            "p50_ms": float(np.percentile(a, 50)),
            "p95_ms": float(np.percentile(a, 95)),
            "p99_ms": float(np.percentile(a, 99)),
            "max_ms": float(a.max()),
        }


@dataclasses.dataclass
class ServingMetrics:
    """Counters + distributions for one serving session/replay."""

    latency: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    requests: int = 0  # completed requests (images)
    rejected: int = 0  # backpressure rejects
    query_rows: int = 0  # query descriptor rows served via the engine
    engine_batches: int = 0  # micro-batches dispatched to the engine
    engine_ms: float = 0.0  # wall-clock busy time inside the engine
    engine_images: int = 0  # images served by engine micro-batches
    cache_images: int = 0  # images served from the hot-leaf cache
    q_cap_overflow: int = 0  # slab-budget misses (counted, never silent)
    warmup_ms: float = 0.0
    recompiles_after_warmup: int = 0  # steady-state recompiles (want: 0)
    queue_depth: list = dataclasses.field(default_factory=list)  # samples

    def observe_queue_depth(self, depth: int) -> None:
        self.queue_depth.append(int(depth))

    @property
    def ms_per_image(self) -> float:
        """Engine busy time per engine-served image — the paper's Exp #5
        metric (cache-served images excluded: they cost ~0 engine time)."""
        if not self.engine_images:
            return float("nan")
        return self.engine_ms / self.engine_images

    def to_dict(self) -> dict:
        qd = np.asarray(self.queue_depth) if self.queue_depth else None
        return {
            "latency": self.latency.summary(),
            "requests": self.requests,
            "rejected": self.rejected,
            "query_rows": self.query_rows,
            "engine_batches": self.engine_batches,
            "engine_ms": self.engine_ms,
            "engine_images": self.engine_images,
            "cache_images": self.cache_images,
            "q_cap_overflow": self.q_cap_overflow,
            "ms_per_image": self.ms_per_image,
            "warmup_ms": self.warmup_ms,
            "recompiles_after_warmup": self.recompiles_after_warmup,
            "queue_depth_mean": float(qd.mean()) if qd is not None else 0.0,
            "queue_depth_max": int(qd.max()) if qd is not None else 0,
        }
