"""Serving metrics: latency percentiles, throughput, queue/cache counters.

The paper's Exp #5 reports one number (ms/image at a fixed batch size); an
online service needs the full latency distribution (p50/p95/p99 — queueing
delay included), the throughput it was achieved at, and the health counters
that explain it (queue depth, recompiles, cache hit rate, rejects). Since
nearly all tail latency in a loaded service is *queueing*, every completion
also splits into wait-ms (arrival -> dispatch) vs compute-ms (the engine /
cache work itself), and everything is kept per priority class so SLO
attainment can be reported per tenant. All accounting is plain
Python/numpy — nothing here touches a device.
"""

from __future__ import annotations

import dataclasses

import numpy as np


class LatencyStats:
    """Streaming latency collector with exact percentiles at report time."""

    def __init__(self):
        self._ms: list[float] = []

    def add(self, ms: float) -> None:
        self._ms.append(float(ms))

    def __len__(self) -> int:
        return len(self._ms)

    def percentile(self, p: float) -> float:
        if not self._ms:
            return float("nan")
        return float(np.percentile(np.asarray(self._ms), p))

    def summary(self) -> dict:
        if not self._ms:
            return {"count": 0}
        a = np.asarray(self._ms)
        return {
            "count": int(a.size),
            "mean_ms": float(a.mean()),
            "p50_ms": float(np.percentile(a, 50)),
            "p95_ms": float(np.percentile(a, 95)),
            "p99_ms": float(np.percentile(a, 99)),
            "max_ms": float(a.max()),
        }


@dataclasses.dataclass
class ClassMetrics:
    """Per-priority-class accounting: the SLO view of one tenant class."""

    latency: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    wait: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    compute: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    completed: int = 0
    attained: int = 0  # completions within the class deadline
    shed: int = 0  # admission-control drops
    rejected: int = 0  # hard max_queue drops
    deadline_ms: float | None = None

    @property
    def slo_attainment(self) -> float:
        """Fraction of *offered* requests that completed within the class
        deadline — shed and rejected requests count as misses (1.0 for an
        idle class: no offered request missed)."""
        offered = self.completed + self.shed + self.rejected
        if not offered:
            return 1.0
        return self.attained / offered

    def to_dict(self) -> dict:
        return {
            "completed": self.completed,
            "shed": self.shed,
            "rejected": self.rejected,
            "attained": self.attained,
            "slo_attainment": self.slo_attainment,
            "deadline_ms": self.deadline_ms,
            "latency": self.latency.summary(),
            "wait": self.wait.summary(),
            "compute": self.compute.summary(),
        }


@dataclasses.dataclass
class ServingMetrics:
    """Counters + distributions for one serving session/replay."""

    latency: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    wait: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    compute: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    requests: int = 0  # completed requests (images)
    rejected: int = 0  # backpressure rejects (hard max_queue cap)
    shed: int = 0  # admission-control drops (batch-class overload)
    downgraded: int = 0  # batch requests deadline-downgraded at admission
    query_rows: int = 0  # query descriptor rows served via the engine
    engine_batches: int = 0  # micro-batches dispatched to the engine
    engine_ms: float = 0.0  # wall-clock busy time inside the engine
    engine_images: int = 0  # images served by engine micro-batches
    cache_images: int = 0  # images served from the hot-leaf cache
    q_cap_overflow: int = 0  # slab-budget misses (counted, never silent)
    warmup_ms: float = 0.0
    recompiles_after_warmup: int = 0  # steady-state recompiles (want: 0)
    queue_depth: list = dataclasses.field(default_factory=list)  # samples
    per_class: dict = dataclasses.field(default_factory=dict)

    def observe_queue_depth(self, depth: int) -> None:
        self.queue_depth.append(int(depth))

    def _class(self, priority: str) -> ClassMetrics:
        cm = self.per_class.get(priority)
        if cm is None:
            cm = self.per_class[priority] = ClassMetrics()
        return cm

    def observe_latency(self, priority: str, *, wait_ms: float,
                        compute_ms: float,
                        deadline_ms: float | None = None) -> None:
        """Record one completion's wait/compute split (latency = sum),
        globally and under its priority class; with a ``deadline_ms``,
        also scores the class's SLO attainment."""
        lat = float(wait_ms) + float(compute_ms)
        self.latency.add(lat)
        self.wait.add(wait_ms)
        self.compute.add(compute_ms)
        cm = self._class(priority)
        cm.latency.add(lat)
        cm.wait.add(wait_ms)
        cm.compute.add(compute_ms)
        cm.completed += 1
        if deadline_ms is not None:
            cm.deadline_ms = float(deadline_ms)
            if lat <= deadline_ms:
                cm.attained += 1

    def observe_drop(self, priority: str, kind: str) -> None:
        """Count one dropped request: ``kind`` is ``"shed"`` (admission
        control) or ``"rejected"`` (hard queue cap)."""
        cm = self._class(priority)
        if kind == "shed":
            self.shed += 1
            cm.shed += 1
        elif kind == "rejected":
            self.rejected += 1
            cm.rejected += 1
        else:
            raise ValueError(f"unknown drop kind {kind!r}")

    @property
    def ms_per_image(self) -> float:
        """Engine busy time per engine-served image — the paper's Exp #5
        metric (cache-served images excluded: they cost ~0 engine time)."""
        if not self.engine_images:
            return float("nan")
        return self.engine_ms / self.engine_images

    def queue_summary(self) -> dict:
        """Queue-depth distribution at dispatch time (p50/p95/max/mean)."""
        if not self.queue_depth:
            return {"count": 0, "mean": 0.0, "p50": 0, "p95": 0, "max": 0}
        qd = np.asarray(self.queue_depth)
        return {
            "count": int(qd.size),
            "mean": float(qd.mean()),
            "p50": int(np.percentile(qd, 50)),
            "p95": int(np.percentile(qd, 95)),
            "max": int(qd.max()),
        }

    def to_dict(self) -> dict:
        q = self.queue_summary()
        return {
            "latency": self.latency.summary(),
            "wait": self.wait.summary(),
            "compute": self.compute.summary(),
            "requests": self.requests,
            "rejected": self.rejected,
            "shed": self.shed,
            "downgraded": self.downgraded,
            "query_rows": self.query_rows,
            "engine_batches": self.engine_batches,
            "engine_ms": self.engine_ms,
            "engine_images": self.engine_images,
            "cache_images": self.cache_images,
            "q_cap_overflow": self.q_cap_overflow,
            "ms_per_image": self.ms_per_image,
            "warmup_ms": self.warmup_ms,
            "recompiles_after_warmup": self.recompiles_after_warmup,
            "queue_depth_mean": q["mean"],
            "queue_depth_max": q["max"],
            "queue_depth_p50": q["p50"],
            "queue_depth_p95": q["p95"],
            "per_class": {
                name: cm.to_dict() for name, cm in sorted(
                    self.per_class.items()
                )
            },
        }
