"""Serving metrics: latency percentiles, throughput, queue/cache counters.

The paper's Exp #5 reports one number (ms/image at a fixed batch size); an
online service needs the full latency distribution (p50/p95/p99 — queueing
delay included), the throughput it was achieved at, and the health counters
that explain it (queue depth, recompiles, cache hit rate, rejects). Since
nearly all tail latency in a loaded service is *queueing*, every completion
also splits into wait-ms (arrival -> dispatch) vs compute-ms (the engine /
cache work itself), and everything is kept per priority class so SLO
attainment can be reported per tenant. All accounting is plain
Python/numpy — nothing here touches a device.

Every :class:`ServingMetrics` also registers itself as a *source* in the
process-wide :class:`~repro.obs.registry.MetricsRegistry` (held weakly —
a dead session's series vanish), so one registry dump carries the serving
counters next to the cache/index/calibration ones under the unified
naming scheme (docs/observability.md). ``to_dict()`` keeps its historical
shape byte-for-byte: the registry view is additive, never a rewrite.

Memory: collectors are *exact* by default (every sample kept — the
historical behavior, and what the percentile-asserting tests pin).
For long replays pass ``max_samples=N``: percentiles cut over to a
deterministic reservoir (Algorithm R, seeded) of N samples while count /
mean / max / histogram buckets stay exact — O(N) memory however many
requests complete.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import get_registry

# histogram bucket upper bounds for exported latency distributions (ms)
HIST_BOUNDS_MS = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0,
                  1000.0, 2000.0, 5000.0)


class LatencyStats:
    """Streaming latency collector.

    Args:
      max_samples: ``None`` (default) keeps every sample — report-time
        percentiles are exact. With ``max_samples=N``, a deterministic
        reservoir (Algorithm R under ``seed``) bounds memory at N
        samples; percentiles become reservoir estimates while ``count``,
        ``mean_ms``, ``max_ms``, and :meth:`histogram` buckets stay
        exact.
      seed: reservoir rng seed (same seed + same add sequence = same
        reservoir, so bounded replays stay reproducible).

    Raises:
      ValueError: a non-positive ``max_samples``.
    """

    def __init__(self, max_samples: int | None = None, *, seed: int = 0):
        if max_samples is not None and max_samples < 1:
            raise ValueError(f"max_samples={max_samples} must be >= 1")
        self._ms: list[float] = []
        self.max_samples = max_samples
        self._rng = (np.random.default_rng(seed)
                     if max_samples is not None else None)
        # exact running stats (bounded mode keeps these exact even when
        # the sample reservoir is lossy)
        self._count = 0
        self._total = 0.0
        self._max = float("-inf")
        self._hist = [0] * (len(HIST_BOUNDS_MS) + 1)  # + overflow bucket

    def add(self, ms: float) -> None:
        ms = float(ms)
        self._count += 1
        self._total += ms
        self._max = max(self._max, ms)
        i = 0
        for b in HIST_BOUNDS_MS:
            if ms <= b:
                break
            i += 1
        self._hist[i] += 1
        if self.max_samples is None or len(self._ms) < self.max_samples:
            self._ms.append(ms)
        else:
            # Algorithm R: keep each of the n samples seen so far with
            # probability max_samples/n
            j = int(self._rng.integers(0, self._count))
            if j < self.max_samples:
                self._ms[j] = ms

    def __len__(self) -> int:
        """Samples *observed* (not retained — bounded mode retains
        ``max_samples``)."""
        return self._count

    def percentile(self, p: float) -> float:
        if not self._ms:
            return float("nan")
        return float(np.percentile(np.asarray(self._ms), p))

    def histogram(self) -> dict:
        """Exact fixed-bucket counts for export (registry / artifacts):
        ``{"bounds_ms": [...], "counts": [...]}`` where ``counts`` has
        one overflow bucket past the last bound. Exact in both modes —
        this is the bounded-memory distribution long replays export."""
        return {"bounds_ms": list(HIST_BOUNDS_MS),
                "counts": list(self._hist)}

    def summary(self) -> dict:
        if not self._count:
            return {"count": 0}
        a = np.asarray(self._ms)
        return {
            "count": self._count,
            "mean_ms": self._total / self._count,
            "p50_ms": float(np.percentile(a, 50)),
            "p95_ms": float(np.percentile(a, 95)),
            "p99_ms": float(np.percentile(a, 99)),
            "max_ms": self._max,
        }


@dataclasses.dataclass
class ClassMetrics:
    """Per-priority-class accounting: the SLO view of one tenant class."""

    latency: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    wait: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    compute: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    completed: int = 0
    attained: int = 0  # completions within the class deadline
    shed: int = 0  # admission-control drops
    rejected: int = 0  # hard max_queue drops
    deadline_ms: float | None = None

    @classmethod
    def make(cls, max_samples: int | None = None) -> "ClassMetrics":
        """A ClassMetrics whose collectors share the owner's bound."""
        return cls(latency=LatencyStats(max_samples),
                   wait=LatencyStats(max_samples),
                   compute=LatencyStats(max_samples))

    @property
    def slo_attainment(self) -> float:
        """Fraction of *offered* requests that completed within the class
        deadline — shed and rejected requests count as misses (1.0 for an
        idle class: no offered request missed)."""
        offered = self.completed + self.shed + self.rejected
        if not offered:
            return 1.0
        return self.attained / offered

    def to_dict(self) -> dict:
        return {
            "completed": self.completed,
            "shed": self.shed,
            "rejected": self.rejected,
            "attained": self.attained,
            "slo_attainment": self.slo_attainment,
            "deadline_ms": self.deadline_ms,
            "latency": self.latency.summary(),
            "wait": self.wait.summary(),
            "compute": self.compute.summary(),
        }


@dataclasses.dataclass
class ServingMetrics:
    """Counters + distributions for one serving session/replay.

    ``max_samples`` bounds every latency collector and the queue-depth
    sample list for long replays (exact when ``None``, the default — see
    :class:`LatencyStats`).
    """

    latency: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    wait: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    compute: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    requests: int = 0  # completed requests (images)
    rejected: int = 0  # backpressure rejects (hard max_queue cap)
    shed: int = 0  # admission-control drops (batch-class overload)
    downgraded: int = 0  # batch requests deadline-downgraded at admission
    query_rows: int = 0  # query descriptor rows served via the engine
    engine_batches: int = 0  # micro-batches dispatched to the engine
    engine_ms: float = 0.0  # wall-clock busy time inside the engine
    engine_images: int = 0  # images served by engine micro-batches
    cache_images: int = 0  # images served from the hot-leaf cache
    q_cap_overflow: int = 0  # slab-budget misses (counted, never silent)
    warmup_ms: float = 0.0
    recompiles_after_warmup: int = 0  # steady-state recompiles (want: 0)
    queue_depth: list = dataclasses.field(default_factory=list)  # samples
    per_class: dict = dataclasses.field(default_factory=dict)
    max_samples: int | None = None  # bound per-collector memory (None=exact)

    def __post_init__(self):
        if self.max_samples is not None:
            self.latency = LatencyStats(self.max_samples)
            self.wait = LatencyStats(self.max_samples)
            self.compute = LatencyStats(self.max_samples)
            self._qd_rng = np.random.default_rng(1)
        self._qd_seen = len(self.queue_depth)
        # unified-registry source: held weakly, so a dropped session's
        # series disappear from later snapshots instead of leaking
        get_registry().register_source(
            f"serving_metrics@{id(self):x}", self,
            ServingMetrics.registry_series,
        )

    def observe_queue_depth(self, depth: int) -> None:
        self._qd_seen += 1
        if (self.max_samples is None
                or len(self.queue_depth) < self.max_samples):
            self.queue_depth.append(int(depth))
        else:
            j = int(self._qd_rng.integers(0, self._qd_seen))
            if j < self.max_samples:
                self.queue_depth[j] = int(depth)

    def _class(self, priority: str) -> ClassMetrics:
        cm = self.per_class.get(priority)
        if cm is None:
            cm = self.per_class[priority] = ClassMetrics.make(
                self.max_samples
            )
        return cm

    def observe_latency(self, priority: str, *, wait_ms: float,
                        compute_ms: float,
                        deadline_ms: float | None = None) -> None:
        """Record one completion's wait/compute split (latency = sum),
        globally and under its priority class; with a ``deadline_ms``,
        also scores the class's SLO attainment."""
        lat = float(wait_ms) + float(compute_ms)
        self.latency.add(lat)
        self.wait.add(wait_ms)
        self.compute.add(compute_ms)
        cm = self._class(priority)
        cm.latency.add(lat)
        cm.wait.add(wait_ms)
        cm.compute.add(compute_ms)
        cm.completed += 1
        if deadline_ms is not None:
            cm.deadline_ms = float(deadline_ms)
            if lat <= deadline_ms:
                cm.attained += 1

    def observe_drop(self, priority: str, kind: str) -> None:
        """Count one dropped request: ``kind`` is ``"shed"`` (admission
        control) or ``"rejected"`` (hard queue cap)."""
        cm = self._class(priority)
        if kind == "shed":
            self.shed += 1
            cm.shed += 1
        elif kind == "rejected":
            self.rejected += 1
            cm.rejected += 1
        else:
            raise ValueError(f"unknown drop kind {kind!r}")

    @property
    def ms_per_image(self) -> float:
        """Engine busy time per engine-served image — the paper's Exp #5
        metric (cache-served images excluded: they cost ~0 engine time)."""
        if not self.engine_images:
            return float("nan")
        return self.engine_ms / self.engine_images

    def queue_summary(self) -> dict:
        """Queue-depth distribution at dispatch time (p50/p95/max/mean).
        ``count`` is depths *observed* (bounded mode retains at most
        ``max_samples`` of them for the percentiles)."""
        if not self.queue_depth:
            return {"count": 0, "mean": 0.0, "p50": 0, "p95": 0, "max": 0}
        qd = np.asarray(self.queue_depth)
        return {
            "count": self._qd_seen,
            "mean": float(qd.mean()),
            "p50": int(np.percentile(qd, 50)),
            "p95": int(np.percentile(qd, 95)),
            "max": int(qd.max()),
        }

    def registry_series(self) -> dict:
        """The unified-registry view: flat ``{series: value}`` under the
        ``serving.*`` namespace (labeled per class), histograms from the
        exact bucket counts. Additive — ``to_dict()`` is unchanged."""
        q = self.queue_summary()
        out = {
            "serving.requests": self.requests,
            "serving.rejected": self.rejected,
            "serving.shed": self.shed,
            "serving.downgraded": self.downgraded,
            "serving.query_rows": self.query_rows,
            "serving.engine.batches": self.engine_batches,
            "serving.engine.ms": self.engine_ms,
            "serving.engine.images": self.engine_images,
            "serving.cache.images": self.cache_images,
            "serving.q_cap_overflow": self.q_cap_overflow,
            "serving.warmup_ms": self.warmup_ms,
            "serving.recompiles_after_warmup": self.recompiles_after_warmup,
            "serving.queue_depth.mean": q["mean"],
            "serving.queue_depth.p95": q["p95"],
            "serving.queue_depth.max": q["max"],
            "serving.latency.hist": self.latency.histogram(),
            "serving.wait.hist": self.wait.histogram(),
            "serving.compute.hist": self.compute.histogram(),
        }
        for name, cm in sorted(self.per_class.items()):
            lbl = f"{{class={name}}}"
            out[f"serving.class.completed{lbl}"] = cm.completed
            out[f"serving.class.shed{lbl}"] = cm.shed
            out[f"serving.class.rejected{lbl}"] = cm.rejected
            out[f"serving.class.attained{lbl}"] = cm.attained
            out[f"serving.class.latency.hist{lbl}"] = cm.latency.histogram()
        return out

    def to_dict(self) -> dict:
        q = self.queue_summary()
        return {
            "latency": self.latency.summary(),
            "wait": self.wait.summary(),
            "compute": self.compute.summary(),
            "requests": self.requests,
            "rejected": self.rejected,
            "shed": self.shed,
            "downgraded": self.downgraded,
            "query_rows": self.query_rows,
            "engine_batches": self.engine_batches,
            "engine_ms": self.engine_ms,
            "engine_images": self.engine_images,
            "cache_images": self.cache_images,
            "q_cap_overflow": self.q_cap_overflow,
            "ms_per_image": self.ms_per_image,
            "warmup_ms": self.warmup_ms,
            "recompiles_after_warmup": self.recompiles_after_warmup,
            "queue_depth_mean": q["mean"],
            "queue_depth_max": q["max"],
            "queue_depth_p50": q["p50"],
            "queue_depth_p95": q["p95"],
            "per_class": {
                name: cm.to_dict() for name, cm in sorted(
                    self.per_class.items()
                )
            },
        }
