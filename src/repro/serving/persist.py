"""Index persistence: index-once / serve-many.

The paper builds its index in one Hadoop job and then runs *many* search
jobs against the stored index files; our CLI used to rebuild the index on
every invocation. This module round-trips the built artifacts through
:class:`~repro.distributed.checkpoint.CheckpointManager` (mesh-free on
disk, crc-checked, atomic) so a serving process loads in seconds:

  ``<dir>/index_ckpt/``  tree + DistributedIndex leaves (one checkpoint)
  ``<dir>/corpus/``      DescriptorStore of the corpus rows (the trace
                         replay reads query images from it block-by-block)

The checkpoint ``extra`` carries the static structure (fanouts, n_leaves,
corpus geometry) needed to rebuild the pytree skeleton and the shardings
for the current mesh. The on-disk format is mesh-free, but a built index is
*semantically* tied to its shard count (rows are cluster-sorted per shard,
offsets are per-shard CSR) — ``load_index`` checks the mesh matches and
fails loudly rather than serving a silently mis-sharded index.
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.index_build import DistributedIndex
from repro.core.tree import VocabTree
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.meshutil import batch_axes

CORPUS_SUBDIR = "corpus"
CKPT_SUBDIR = "index_ckpt"


def _ckpt(directory: str) -> CheckpointManager:
    return CheckpointManager(os.path.join(directory, CKPT_SUBDIR), keep=1)


def has_index(directory: str) -> bool:
    d = os.path.join(directory, CKPT_SUBDIR)
    return os.path.isdir(d) and CheckpointManager(d).latest_step() is not None


def _index_shardings(mesh: Mesh, n_levels: int):
    ax = batch_axes(mesh)
    rows = NamedSharding(mesh, P(ax, None))
    flat = NamedSharding(mesh, P(ax))
    rep = NamedSharding(mesh, P())
    index = DistributedIndex(
        vecs=rows, ids=flat, leaves=flat, offsets=rows, n_valid=flat,
        overflow=rep,
    )
    tree = VocabTree(levels=tuple(rep for _ in range(n_levels)))
    return {"index": index, "tree": tree}


def save_index(
    directory: str,
    index: DistributedIndex,
    tree: VocabTree,
    *,
    extra: dict | None = None,
) -> str:
    """Persist (index, tree) + structure metadata; atomic, crc-checked."""
    meta = dict(extra or {})
    meta.update(
        n_leaves=int(index.n_leaves),
        n_levels=len(tree.levels),
        fanouts=[int(f) for f in tree.fanouts],
        rows=int(index.rows),
        valid_rows=int(np.asarray(index.n_valid).sum()),
        dim=int(index.vecs.shape[-1]),
        n_shards=int(index.offsets.shape[0]),
    )
    return _ckpt(directory).save(0, {"index": index, "tree": tree},
                                 extra=meta)


def load_index(
    directory: str, mesh: Mesh
) -> tuple[DistributedIndex, VocabTree, dict]:
    """Restore (index, tree, meta) laid out for ``mesh``."""
    mgr = _ckpt(directory)
    step = mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no index checkpoint under {directory}")
    # peek at the manifest for the pytree skeleton (leaf values are ignored
    # by restore; only structure and paths matter)
    meta = mgr.read_manifest(step)["extra"]
    from repro.distributed.meshutil import data_axis_size

    want_shards = int(meta.get("n_shards", 0))
    if want_shards and want_shards != data_axis_size(mesh):
        raise ValueError(
            f"index was built for {want_shards} shards; current mesh has "
            f"{data_axis_size(mesh)} — rebuild the index for this mesh"
        )
    skeleton = {
        "index": DistributedIndex(
            vecs=0.0, ids=0, leaves=0, offsets=0, n_valid=0, overflow=0,
            n_leaves=int(meta["n_leaves"]),
        ),
        "tree": VocabTree(levels=tuple(0.0 for _ in range(meta["n_levels"]))),
    }
    tree_out, _ = mgr.restore(
        skeleton, step, shardings=_index_shardings(mesh, meta["n_levels"])
    )
    index, tree = tree_out["index"], tree_out["tree"]
    # restore() returns arrays; re-wrap the static field
    index = DistributedIndex(
        vecs=index.vecs,
        ids=jnp.asarray(index.ids, jnp.int32),
        leaves=jnp.asarray(index.leaves, jnp.int32),
        offsets=jnp.asarray(index.offsets, jnp.int32),
        n_valid=jnp.asarray(index.n_valid, jnp.int32),
        overflow=jnp.asarray(index.overflow, jnp.int32),
        n_leaves=int(meta["n_leaves"]),
    )
    return index, tree, meta


def corpus_dir(directory: str) -> str:
    return os.path.join(directory, CORPUS_SUBDIR)


def save_corpus(directory: str, vecs: np.ndarray, *, block_rows: int = 65536):
    """Persist corpus rows as a DescriptorStore (trace replay reads query
    images from it without holding the collection resident)."""
    from repro.data.store import DescriptorStore

    return DescriptorStore.create(
        corpus_dir(directory), np.asarray(vecs), block_rows=block_rows
    )


def load_corpus(directory: str):
    from repro.data.store import DescriptorStore

    return DescriptorStore(corpus_dir(directory))
