"""Index persistence — deprecation shims over :mod:`repro.index`.

The historical index-once/serve-many pair (``save_index``/``load_index``)
predates the segment-based lifecycle: it persisted exactly one monolithic
``DistributedIndex``. The canonical API is now :class:`repro.index.Index`
(``create``/``open``/``append``/``commit``/``compact``), whose on-disk
format — versioned manifests over immutable segment checkpoints — is what
these shims read and write:

  * ``save_index(dir, index, tree)`` ≡ ``Index.create(tree, dir,
    overwrite=True)`` + ``append_built(index)`` + ``commit()``;
  * ``load_index(dir, mesh)`` ≡ ``Index.open(dir, mesh)`` restricted to a
    single-segment, tombstone-free index (anything richer has no faithful
    single-``DistributedIndex`` representation — open the facade instead).

Both emit ``DeprecationWarning``. The corpus-side helpers
(``save_corpus``/``load_corpus``) are not deprecated: the trace replay
still reads query images from a DescriptorStore block-by-block.
"""

from __future__ import annotations

import os
import warnings

import numpy as np
from jax.sharding import Mesh

from repro.core.index_build import DistributedIndex
from repro.core.tree import VocabTree

CORPUS_SUBDIR = "corpus"


def has_index(directory: str) -> bool:
    from repro.index import has_index as _has

    return _has(directory)


def save_index(
    directory: str,
    index: DistributedIndex,
    tree: VocabTree,
    *,
    extra: dict | None = None,
) -> str:
    """Deprecated: persist one built index as a single committed segment."""
    warnings.warn(
        "serving.persist.save_index is deprecated; use repro.index.Index"
        ".create(...).append_built(...)/commit()",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.index import Index

    idx = Index.create(tree, directory, extra=extra, overwrite=True)
    idx.append_built(index)
    idx.commit()
    return directory


def load_index(
    directory: str, mesh: Mesh
) -> tuple[DistributedIndex, VocabTree, dict]:
    """Deprecated: restore ``(index, tree, meta)`` from a one-segment
    index. Raises for grown (multi-segment or tombstoned) indexes — those
    only exist through the facade; open them with ``Index.open``."""
    warnings.warn(
        "serving.persist.load_index is deprecated; use "
        "repro.index.Index.open",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.index import Index

    idx = Index.open(directory, mesh=mesh)
    if idx.n_segments != 1 or len(idx.tombstones):
        raise ValueError(
            f"{directory} holds {idx.n_segments} segments and "
            f"{len(idx.tombstones)} tombstones — not representable as one "
            "DistributedIndex; use repro.index.Index.open"
        )
    return idx.segments[0].index, idx.tree, idx.meta


def corpus_dir(directory: str) -> str:
    return os.path.join(directory, CORPUS_SUBDIR)


def save_corpus(directory: str, vecs: np.ndarray, *, block_rows: int = 65536):
    """Persist corpus rows as a DescriptorStore (trace replay reads query
    images from it without holding the collection resident)."""
    from repro.data.store import DescriptorStore

    return DescriptorStore.create(
        corpus_dir(directory), np.asarray(vecs), block_rows=block_rows
    )


def load_corpus(directory: str):
    from repro.data.store import DescriptorStore

    return DescriptorStore(corpus_dir(directory))
