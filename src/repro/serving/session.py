"""SearchSession: the long-lived serving core.

The paper's search phase is a batch job: build (or load) the index, ship
the lookup table, scan. A *service* runs the same engine continuously, and
on an XLA backend the extra failure mode is recompilation — every new query
batch shape lowers a new program, which at serving latencies is the
difference between 5 ms and 5 s. The session closes that hole:

  * **load-or-build** — index + tree round-trip through
    ``serving.persist`` (checkpoint + DescriptorStore), so a process
    restart costs a restore, not an index build;
  * **bucketed executors** — a small ladder of padded batch-size buckets
    (``engine.bucket_ladder``), one fused jitted pipeline per rung
    (probe routing -> fixed-shape lookup -> executor). Requests snap up to
    a rung (``snap_to_bucket``) with the valid-row count passed as a
    *traced* scalar, so steady state never sees a new shape and never
    recompiles (``recompiles()`` exposes the jit cache stats; tests and
    the smoke gate assert it stays at the warmed count);
  * **hot-leaf cache** — ``serving.cache.HotLeafCache`` answers repeated
    hot queries locally (see its docstring);
  * **metrics** — ``serving.metrics.ServingMetrics`` plus per-plan
    measured ms/image fed to ``SearchPlan.observe`` (the ROADMAP cost-model
    calibration hook).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    SearchPlan,
    bucket_ladder,
    make_executor,
    plan as make_plan,
    snap_to_bucket,
)
from repro.core.index_build import DistributedIndex
from repro.core.lookup import build_lookup_bucketed
from repro.core.tree import VocabTree
from repro.distributed.meshutil import data_axis_size, local_mesh, round_up
from repro.serving.cache import HotLeafCache
from repro.serving.metrics import ServingMetrics


def _jit_cache_size(fn) -> int:
    # private jax API; if it moves we must NOT silently return 0 — the
    # zero-recompile serving gate would become vacuous
    return int(fn._cache_size())


@dataclasses.dataclass
class _BucketRuntime:
    """One warmed rung: plan + fused jitted pipeline at a fixed shape."""

    bucket: int  # query-row capacity of this rung
    plan: SearchPlan
    q_total: int  # padded lookup rows the executor was built for
    fn: object  # jitted (index, tree, queries, n_valid) -> (result, leaves)


class SearchSession:
    """Long-lived search service over one (index, tree, mesh)."""

    def __init__(
        self,
        index: DistributedIndex,
        tree: VocabTree,
        mesh=None,
        *,
        k: int = 10,
        layout: str = "auto",
        probes: int = 1,
        impl: str = "xla",
        max_batch_rows: int = 4096,
        n_buckets: int = 3,
        buckets: Sequence[int] | None = None,
        cache_leaves: int = 0,
        cache_admit_after: int = 2,
    ):
        self.mesh = mesh if mesh is not None else local_mesh()
        self.index = index
        self.tree = tree
        self.k = int(k)
        self.layout = layout
        self.probes = int(probes)
        self.impl = impl
        self.buckets = (
            tuple(sorted(int(b) for b in buckets))
            if buckets
            else bucket_ladder(max_batch_rows, n_buckets=n_buckets)
        )
        self.metrics = ServingMetrics()
        self.cache = HotLeafCache(cache_leaves, admit_after=cache_admit_after)
        if self.cache.capacity > 0:
            self.cache.attach_index(
                np.asarray(index.vecs), np.asarray(index.ids),
                np.asarray(index.leaves), index.n_leaves,
            )
        self._runtimes = {b: self._make_runtime(b) for b in self.buckets}
        self._warmed_compiles: int | None = None

    # -- construction -------------------------------------------------------
    @classmethod
    def load_or_build(
        cls,
        index_dir: str | None,
        *,
        build_fn,
        mesh=None,
        rebuild: bool = False,
        **session_kw,
    ) -> tuple["SearchSession", dict]:
        """Index-once / serve-many: restore from ``index_dir`` when a
        checkpoint exists, else call ``build_fn() -> (index, tree, extra)``
        and persist the result (when ``index_dir`` is given).

        Returns ``(session, meta)`` where ``meta`` is the checkpoint extra
        (corpus geometry etc.) on restore, or ``build_fn``'s extra.
        """
        from repro.serving import persist

        mesh = mesh if mesh is not None else local_mesh()
        if index_dir and not rebuild and persist.has_index(index_dir):
            index, tree, meta = persist.load_index(index_dir, mesh)
            meta = dict(meta, restored=True)
        else:
            index, tree, extra = build_fn()
            meta = dict(extra or {}, restored=False)
            if index_dir:
                persist.save_index(index_dir, index, tree, extra=extra)
        return cls(index, tree, mesh, **session_kw), meta

    def _make_runtime(self, bucket: int) -> _BucketRuntime:
        n_shards = data_axis_size(self.mesh)
        shard_rows = self.index.rows // n_shards
        p = make_plan(
            rows=self.index.rows,
            n_leaves=self.index.n_leaves,
            n_queries=bucket,
            n_shards=n_shards,
            k=self.k,
            probes=self.probes,
            layout=self.layout,
            impl=self.impl,
        )
        q_rows = bucket * self.probes
        if p.layout == "query_routed":
            q_total = round_up(q_rows, p.q_tile * n_shards * self.probes)
        else:
            q_total = round_up(max(q_rows, p.q_cap), self.probes)
        exec_fn = make_executor(
            self.mesh, p, n_leaves=self.index.n_leaves,
            shard_rows=shard_rows, q_total=q_total,
        )
        probes = self.probes

        def fused(index, tree, queries, n_valid):
            lookup, leaves = build_lookup_bucketed(
                tree, queries, n_valid, probes=probes, q_total=q_total
            )
            return exec_fn(index, lookup), leaves

        return _BucketRuntime(
            bucket=bucket, plan=p, q_total=q_total, fn=jax.jit(fused)
        )

    # -- compile accounting -------------------------------------------------
    def recompiles(self) -> int:
        """Total jitted-executor compilations so far (jit cache entries)."""
        return sum(_jit_cache_size(rt.fn) for rt in self._runtimes.values())

    def steady_state_recompiles(self) -> int:
        """Compilations after warmup — the serving invariant is 0."""
        if self._warmed_compiles is None:
            return 0
        n = self.recompiles() - self._warmed_compiles
        self.metrics.recompiles_after_warmup = n
        return n

    def warmup(self) -> float:
        """Compile every bucket rung once (dummy batch) — steady-state
        requests then only ever replay warmed programs."""
        d = self.index.vecs.shape[-1]
        t0 = time.perf_counter()
        for rt in self._runtimes.values():
            dummy = jnp.zeros((rt.bucket, d), jnp.float32)
            res, leaves = rt.fn(self.index, self.tree, dummy, np.int32(0))
            jax.block_until_ready((res.ids, leaves))
        dt_ms = (time.perf_counter() - t0) * 1e3
        self.metrics.warmup_ms += dt_ms
        self._warmed_compiles = self.recompiles()
        return dt_ms

    # -- serve path ---------------------------------------------------------
    @property
    def max_batch_rows(self) -> int:
        return self.buckets[-1]

    def _execute(
        self, queries: np.ndarray, *, n_images: int | None = None
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, float]:
        """Run one micro-batch through its snapped bucket rung.

        Returns ``(ids (n,k), dists (n,k), probe_leaves (n,probes),
        seconds)``; feeds metrics, the hot-leaf cache, and the plan's
        ms/image observations.
        """
        n, d = queries.shape
        if n > self.max_batch_rows:
            raise ValueError(
                f"batch of {n} rows exceeds largest bucket "
                f"{self.max_batch_rows}; split it across dispatches"
            )
        rt = self._runtimes[snap_to_bucket(n, self.buckets)]
        buf = np.zeros((rt.bucket, d), np.float32)
        buf[:n] = queries
        t0 = time.perf_counter()
        res, leaves = rt.fn(
            self.index, self.tree, jnp.asarray(buf), np.int32(n)
        )
        jax.block_until_ready((res.ids, res.dists, leaves))
        dt = time.perf_counter() - t0
        ids = np.asarray(res.ids[:n])
        dists = np.asarray(res.dists[:n])
        leaves_np = np.asarray(leaves[:n])
        self.metrics.engine_batches += 1
        self.metrics.engine_ms += dt * 1e3
        self.metrics.query_rows += n
        overflow = int(res.q_cap_overflow)
        self.metrics.q_cap_overflow += overflow
        if n_images:
            self.metrics.engine_images += n_images
            rt.plan.observe(dt * 1e3 / n_images)
        # a starved dispatch must not seed the cache: a cached full-slab
        # scan would disagree with the truncated engine answer
        self.cache.record(queries, leaves_np, exact=overflow == 0)
        return ids, dists, leaves_np, dt

    def search(
        self, queries: np.ndarray, *, n_images: int | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """One-shot search of ``(n, d)`` query rows (splits batches larger
        than the top bucket). Results are bit-identical to
        ``core.search.batch_search`` under the same plan budgets."""
        queries = np.asarray(queries, np.float32)
        if len(queries) <= self.max_batch_rows:
            ids, dists, _, _ = self._execute(queries, n_images=n_images)
            return ids, dists
        # split batches: per-chunk plan observations would mis-attribute the
        # whole request's images to one chunk's wall time, so only the
        # aggregate image/ms counters are fed (ms_per_image stays honest)
        out_i, out_d = [], []
        for s in range(0, len(queries), self.max_batch_rows):
            chunk = queries[s: s + self.max_batch_rows]
            ids, dists, _, _ = self._execute(chunk)
            out_i.append(ids)
            out_d.append(dists)
        if n_images:
            self.metrics.engine_images += n_images
        return np.concatenate(out_i), np.concatenate(out_d)

    def serve_many(self, request_batches) -> list[tuple[np.ndarray, np.ndarray]]:
        """Serve a coalesced micro-batch: ``request_batches`` is a list of
        per-request ``(rows, d)`` arrays whose total fits one bucket.
        Returns one ``(ids, dists)`` pair per request."""
        sizes = [len(q) for q in request_batches]
        ids, dists, _, _ = self._execute(
            np.concatenate(request_batches), n_images=len(request_batches)
        )
        out, off = [], 0
        for s in sizes:
            out.append((ids[off: off + s], dists[off: off + s]))
            off += s
        return out

    def plan_summary(self) -> list[dict]:
        return [
            {
                "bucket": rt.bucket,
                "layout": rt.plan.layout,
                "q_total": rt.q_total,
                "block_rows": rt.plan.block_rows,
                "q_cap": rt.plan.q_cap,
                "q_tile": rt.plan.q_tile,
                "p_cap": rt.plan.p_cap,
            }
            for rt in self._runtimes.values()
        ]
