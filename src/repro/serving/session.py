"""SearchSession: the long-lived serving core.

The paper's search phase is a batch job: build (or load) the index, ship
the lookup table, scan. A *service* runs the same engine continuously, and
on an XLA backend the extra failure mode is recompilation — every new query
batch shape lowers a new program, which at serving latencies is the
difference between 5 ms and 5 s. The session closes that hole:

  * **Index-backed** — a session is constructed from a segment-based
    :class:`repro.index.Index` (the legacy ``(DistributedIndex, tree)``
    pair still works and is wrapped in an ephemeral single-segment
    facade). Each bucket rung compiles ONE fused program that builds the
    lookup once and runs every segment's executor over it, merging the
    per-segment k-NN tables on device — so serving a grown, multi-segment
    index keeps the zero-recompile and bit-identity invariants;
  * **load-or-build** — ``Index.open`` when a committed manifest exists,
    else build + commit (index-once/serve-many across restarts);
  * **bucketed executors** — a small ladder of padded batch-size buckets
    (``engine.bucket_ladder``), one fused jitted pipeline per rung
    (probe routing -> fixed-shape lookup -> executor). Requests snap up to
    a rung (``snap_to_bucket``) with the valid-row count passed as a
    *traced* scalar, so steady state never sees a new shape and never
    recompiles (``recompiles()`` exposes the jit cache stats; tests and
    the smoke gate assert it stays at the warmed count);
  * **hot-leaf cache** — ``serving.cache.HotLeafCache`` answers repeated
    hot queries locally (see its docstring);
  * **metrics** — ``serving.metrics.ServingMetrics`` plus per-plan
    measured ms/image fed to ``SearchPlan.observe`` (the ROADMAP cost-model
    calibration hook).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.codes import rerank_exact
from repro.core.engine import (
    PlanShapes,
    SearchPlan,
    bucket_ladder,
    make_executor,
    plan as make_plan,
    resolve_model,
    scale_slab_budget,
    snap_to_bucket,
)
from repro.core.engine.executors import SearchResult, pad_lookup
from repro.core.index_build import DistributedIndex
from repro.core.lookup import build_lookup_bucketed
from repro.core.search import lookup_q_total
from repro.core.engine.costmodel import plan_signature, signature_key
from repro.core.tree import VocabTree
from repro.distributed.meshutil import data_axis_size, local_mesh
from repro.obs import get_tracer
from repro.serving.cache import HotLeafCache
from repro.serving.metrics import ServingMetrics


def _jit_cache_size(fn) -> int:
    # private jax API; if it moves we must NOT silently return 0 — the
    # zero-recompile serving gate would become vacuous
    return int(fn._cache_size())


@dataclasses.dataclass
class _BucketRuntime:
    """One warmed rung: per-segment plans + one fused jitted pipeline."""

    bucket: int  # query-row capacity of this rung
    plan: SearchPlan  # primary plan (largest segment) — observe()/reporting
    plans: tuple  # one resolved plan per segment
    q_total: int  # largest per-segment padded lookup row count
    fn: object  # jitted (segments, tree, queries, n_valid) -> (result, leaves)
    plan_rows: tuple = ()  # (plan, padded rows, n_shards) per segment
    # scan_codes rungs only: the uniform ADC candidate width the pipeline
    # emits (the caller reranks exactly), and the fused fn's signature
    # grows to (segments, codes, codebooks, tree, queries, n_valid)
    rerank: int | None = None


def make_bucket_runtime(
    mesh,
    n_leaves: int,
    segments,
    bucket: int,
    *,
    k: int,
    probes: int,
    layout: str,
    impl: str,
    ordinals=None,
    emit_slots: bool = False,
    cost_model="auto",
    calibration=None,
    slab_scale: float = 1.0,
    rerank: int | None = None,
    codes=None,
    codebooks=None,
) -> _BucketRuntime:
    """Build one warmed bucket rung over ``segments`` (masked views).

    ``cost_model``/``calibration`` select which cost model ranks an
    ``"auto"`` layout (see :mod:`repro.core.engine.costmodel`);
    ``slab_scale`` grows each segment plan's slab budget (the sharded
    session's per-shard fitted-cost headroom — never shrinks, so it is
    result-safe).

    The fused jitted pipeline runs ONE lookup build (probe routing + leaf
    sort) shared by every segment, then each segment's executor over it,
    then the cross-segment ascending-distance merge on device.

    ``ordinals`` are the segments' global append positions (default
    ``0..len-1`` — the whole-index case). With ``emit_slots=True`` the
    pipeline returns ``(result, leaves, slots)`` where ``slots[q, j] =
    segment_ordinal * k + column`` is each candidate's position in the
    global segment-ordered concatenation — the key the sharded
    scatter-gather merge (:mod:`repro.index.sharding`) fuses shard
    partials by — and the merge uses a *stable* sort so ties keep global
    slot order at any shard count.
    """
    n_shards = data_axis_size(mesh)
    if ordinals is None:
        ordinals = tuple(range(len(segments)))
    q_rows = bucket * probes
    use_codes = layout == "scan_codes"
    code_kw = {}
    if use_codes:
        if codes is None or codebooks is None:
            raise ValueError("scan_codes rungs need codes + codebooks")
        m, n_centers, dsub = codebooks.shape
        code_kw = dict(
            dim=m * dsub, rerank=rerank, code_m=int(m),
            code_bits=int(n_centers).bit_length() - 1,
        )

    def base_plan(view, rerank_override=None):
        kw = dict(code_kw)
        if rerank_override is not None:
            kw["rerank"] = rerank_override
        return make_plan(
            rows=view.rows,
            n_leaves=n_leaves,
            n_queries=bucket,
            n_shards=n_shards,
            k=k,
            probes=probes,
            layout=layout,
            impl=impl,
            model=cost_model,
            calibration=calibration,
            **kw,
        )

    base_plans = [base_plan(view) for view in segments]
    r = k
    if use_codes:
        # one uniform ADC candidate width across segments (each plan may
        # clamp rerank to its own block_rows): the min is valid everywhere
        # and keeps the merge's slot arithmetic a single stride
        r = min(p.rerank for p in base_plans)
        base_plans = [
            p if p.rerank == r else base_plan(view, rerank_override=r)
            for p, view in zip(base_plans, segments)
        ]
    plans, q_totals, execs = [], [], []
    for base_p, view in zip(base_plans, segments):
        p = scale_slab_budget(
            base_p, slab_scale, n_queries=bucket,
            shard_rows=view.rows // n_shards,
        )
        q_total = lookup_q_total(p, bucket, n_shards)
        execs.append(make_executor(
            mesh, p, n_leaves=n_leaves,
            shard_rows=view.rows // n_shards, q_total=q_total,
        ))
        plans.append(p)
        q_totals.append(q_total)
    primary = max(range(len(plans)), key=lambda i: segments[i].rows)
    # each candidate's column in the global segment-ordered concatenation
    # (scan_codes rungs stride by the candidate width r instead of k)
    width = r if use_codes else k
    slot_cols = jnp.concatenate([
        jnp.arange(g * width, g * width + width, dtype=jnp.int32)
        for g in ordinals
    ])

    def merge(outs, leaves):
        if len(outs) == 1 and not emit_slots:
            return outs[0], leaves
        all_d = jnp.concatenate([r_.dists[:bucket] for r_ in outs], axis=1)
        all_i = jnp.concatenate([r_.ids[:bucket] for r_ in outs], axis=1)
        pairs = sum(r_.pairs for r_ in outs)
        overflow = sum(r_.q_cap_overflow for r_ in outs)
        if emit_slots:
            # stable sort: ties keep concat order == ascending global slot
            sel = jnp.argsort(all_d, axis=1, stable=True)[:, :width]
            merged = SearchResult(
                ids=jnp.take_along_axis(all_i, sel, axis=1),
                dists=jnp.take_along_axis(all_d, sel, axis=1),
                pairs=pairs,
                q_cap_overflow=overflow,
            )
            return merged, leaves, slot_cols[sel]
        # cross-segment merge: same ascending-distance fold the
        # executors use across shards (ties keep segment-major order)
        neg, sel = jax.lax.top_k(-all_d, width)
        merged = SearchResult(
            ids=jnp.take_along_axis(all_i, sel, axis=1),
            dists=-neg,
            pairs=pairs,
            q_cap_overflow=overflow,
        )
        return merged, leaves

    if use_codes:
        def fused(segs, seg_codes, cbs, tree, queries, n_valid):
            lookup, leaves = build_lookup_bucketed(
                tree, queries, n_valid, probes=probes, q_total=q_rows
            )
            outs = [
                fn(seg, pad_lookup(lookup, qt), c, cbs)
                for seg, fn, qt, c in zip(segs, execs, q_totals, seg_codes)
            ]
            return merge(outs, leaves)
    else:
        def fused(segs, tree, queries, n_valid):
            # ONE lookup build (probe routing + leaf sort) shared by every
            # segment; per-segment executors only see tail padding on top
            lookup, leaves = build_lookup_bucketed(
                tree, queries, n_valid, probes=probes, q_total=q_rows
            )
            outs = [
                fn(seg, pad_lookup(lookup, qt))
                for seg, fn, qt in zip(segs, execs, q_totals)
            ]
            return merge(outs, leaves)

    return _BucketRuntime(
        bucket=bucket, plan=plans[primary], plans=tuple(plans),
        q_total=max(q_totals), fn=jax.jit(fused),
        # calibration keys on the UNSCALED plans (what a later consult
        # will derive, before any slab scaling) at each plan's own
        # n_shards (sharded rungs plan on per-shard submeshes)
        plan_rows=tuple(
            (bp, int(v.rows), n_shards)
            for bp, v in zip(base_plans, segments)
        ),
        rerank=r if use_codes else None,
    )


def attach_cache(cache: HotLeafCache, views, n_leaves: int) -> None:
    """Point a hot-leaf cache at the live rows of ``views`` (masked
    segment views) — padding and tombstoned rows are skipped, so a cached
    slab can never resurrect a deleted row."""
    if cache.capacity <= 0:
        return
    vv, ii, ll = [], [], []
    for view in views:
        ids = np.asarray(view.ids)
        live = ids >= 0  # skip padding and tombstoned rows
        vv.append(np.asarray(view.vecs)[live])
        ii.append(ids[live])
        ll.append(np.asarray(view.leaves)[live])
    cache.attach_index(
        np.concatenate(vv), np.concatenate(ii), np.concatenate(ll), n_leaves
    )


def load_or_build_index(
    index_dir: str | None,
    *,
    build_fn,
    mesh=None,
    rebuild: bool = False,
):
    """Index-once / serve-many: ``Index.open`` when ``index_dir`` holds a
    committed non-empty manifest, else ``build_fn() -> (built, tree,
    extra)`` committed there (when a directory is given).

    Returns ``(index, meta)``; ``meta["restored"]`` says which path ran.
    Shared by :meth:`SearchSession.load_or_build` and the sharded
    session's loader. ``build_fn`` may return either the historical
    ``(built, tree, extra)`` triple (committed here as one segment) or an
    already-committed :class:`~repro.index.Index` (e.g. a multi-segment
    build shaped for sharding).
    """
    import warnings

    from repro.index import Index, has_index, has_legacy_index

    mesh = mesh if mesh is not None else local_mesh()
    if index_dir and not rebuild and has_index(index_dir):
        opened = Index.open(index_dir, mesh=mesh)
        if opened.n_segments:
            return opened, dict(opened.meta, restored=True)
        # else: a crash between create and the first commit left a
        # committed-empty index — rebuild instead of serving nothing
    if index_dir and not has_index(index_dir) and has_legacy_index(index_dir):
        warnings.warn(
            f"{index_dir} holds a pre-segment-format index (index_ckpt/), "
            "which this version no longer reads; rebuilding it in the "
            "segment format",
            stacklevel=2,
        )
    out = build_fn()
    if isinstance(out, Index):
        return out, dict(out.meta, restored=False)
    built, tree, extra = out
    idx = Index.create(
        tree, index_dir or None, mesh=mesh, extra=extra, overwrite=True,
    )
    idx.append_built(built)
    idx.commit()
    return idx, dict(extra or {}, restored=False)


class SearchSession:
    """Long-lived search service over one :class:`repro.index.Index`.

    Args:
      index: a ``repro.index.Index``, or (legacy) a raw
        ``DistributedIndex`` with its ``tree`` as the second argument.
      tree/mesh: only needed for the legacy pair; an ``Index`` carries
        both.
      k/layout/probes/impl: the serving plan knobs (see
        :func:`repro.core.engine.plan`). ``layout`` also accepts
        ``"scan_codes"`` on an index with PQ codes (``enable_codes``);
        with ``"auto"`` the cost model may pick the codes tier itself.
        The decision is made once per session so every warmed rung
        serves the same tier.
      rerank: ADC candidates per query to exactly rerank on the codes
        tier (default from
        :func:`~repro.core.engine.plan.default_rerank`).
      cost_model: which cost model ranks an ``"auto"`` layout —
        ``"auto"`` (fitted > observed > heuristic, the default),
        ``"heuristic"``, ``"observed"``, or ``"fitted"`` — consulting the
        index's manifest-persisted calibration store. Post-warmup
        dispatches record measured ms/image back into that store
        (durable at the index's next ``commit``).
      max_batch_rows/n_buckets/buckets: the warmed bucket ladder —
        explicit ``buckets`` override the derived geometric ladder.
      cache_leaves/cache_admit_after: hot-leaf cache capacity (0 = off)
        and admission threshold.
      cache_eviction: ``"cost"`` (predicted ms-saved-per-resident-byte
        via the fitted cost model, the default) or ``"lru"`` — see
        :class:`~repro.serving.cache.HotLeafCache`.

    Raises:
      TypeError: a non-``Index`` first argument without its ``tree``.
      ValueError: an index with no segments (nothing to serve).
    """

    def __init__(
        self,
        index,
        tree: VocabTree | None = None,
        mesh=None,
        *,
        k: int = 10,
        layout: str = "auto",
        probes: int = 1,
        impl: str = "xla",
        rerank: int | None = None,
        max_batch_rows: int = 4096,
        n_buckets: int = 3,
        buckets: Sequence[int] | None = None,
        cache_leaves: int = 0,
        cache_admit_after: int = 2,
        cache_eviction: str = "cost",
        cost_model: str = "auto",
    ):
        from repro.index import Index

        if isinstance(index, Index):
            self.index = index
            self.mesh = mesh if mesh is not None else index.mesh
            self.tree = index.tree
        else:
            # legacy constructor: a raw DistributedIndex + its tree becomes
            # an ephemeral single-segment facade
            if not isinstance(index, DistributedIndex) or tree is None:
                raise TypeError(
                    "SearchSession takes a repro.index.Index, or the legacy "
                    "(DistributedIndex, tree) pair"
                )
            self.mesh = mesh if mesh is not None else local_mesh()
            self.index = Index.from_built(index, tree, mesh=self.mesh)
            self.tree = tree
        # pin one consistent cut of the index: every runtime, cache slab,
        # and rerank fetch resolves against this snapshot until refresh()/
        # maybe_refresh() adopts a newer one — mutations on the underlying
        # Index never perturb in-flight or queued requests
        self._pin = self.index.snapshot()
        self._segments = self._pin.views
        if not self._segments:
            raise ValueError("cannot serve an index with no segments")
        self.k = int(k)
        self.layout = layout
        self.probes = int(probes)
        self.impl = impl
        self.rerank = rerank
        self.cost_model = cost_model
        self.buckets = (
            tuple(sorted(int(b) for b in buckets))
            if buckets
            else bucket_ladder(max_batch_rows, n_buckets=n_buckets)
        )
        # codes-vs-exact resolves ONCE per session on the aggregate shape
        # (ADC and exact distances are incomparable across a merge), so
        # every rung of every ladder serves the same tier
        pq = self._pin.quantizer
        if layout == "scan_codes" and pq is None:
            raise ValueError(
                "layout='scan_codes' needs PQ codes; call "
                "index.enable_codes() first"
            )
        self._use_codes = False
        if pq is not None and layout in ("auto", "scan_codes"):
            agg = make_plan(
                rows=sum(int(v.rows) for v in self._segments),
                n_leaves=self.index.n_leaves,
                n_queries=self.buckets[-1],
                n_shards=data_axis_size(self.mesh),
                k=self.k, probes=self.probes, layout=layout, impl=impl,
                model=cost_model, calibration=self.index.calibration,
                dim=self.index.dim, rerank=rerank,
                code_m=pq.m, code_bits=pq.bits,
            )
            self._use_codes = agg.layout == "scan_codes"
        self._codes_dev = None
        self._codebooks_dev = None
        if self._use_codes:
            self._refresh_codes()
        self.metrics = ServingMetrics()
        self.cache = HotLeafCache(cache_leaves, admit_after=cache_admit_after,
                                  eviction=cache_eviction)
        self._attach_cache()
        self._build_runtimes()
        self._warmed_compiles: int | None = None
        # seed the cache's eviction score with the fitted model's view of
        # what one engine-served image costs (measured EMA refines it)
        self.cache.note_engine_cost(self.predicted_ms_per_image())

    def _attach_cache(self) -> None:
        attach_cache(self.cache, self._segments, self.index.n_leaves)

    def _refresh_codes(self) -> None:
        """Device copies of each pinned segment's PQ codes + the codebook
        table, aligned with ``self._segments`` order."""
        self._codes_dev = tuple(
            jnp.asarray(self._pin.codes[s.name])
            for s in self._pin.segments
        )
        self._codebooks_dev = jnp.asarray(self._pin.quantizer.codebooks)

    def _read_pinned_rows(self, ids) -> np.ndarray:
        """Rerank row fetches against the pinned cut — a concurrent
        delete or compaction cannot make an in-flight request's candidate
        id unreadable."""
        return self.index.read_rows(
            ids, segments=self._pin.segments, tombstones=self._pin.tombstones
        )

    @property
    def serving_layout(self) -> str:
        """The layout the warmed ladders actually execute (``layout``
        with the session's one-time codes decision applied)."""
        return "scan_codes" if self._use_codes else self.layout

    def _build_runtimes(self) -> None:
        """(Re)compile-point: one runtime per warmed bucket rung. The
        sharded session overrides this to build one rung per (shard,
        bucket) pair instead."""
        self._runtimes = {b: self._make_runtime(b) for b in self.buckets}

    # -- construction -------------------------------------------------------
    @classmethod
    def load_or_build(
        cls,
        index_dir: str | None,
        *,
        build_fn,
        mesh=None,
        rebuild: bool = False,
        **session_kw,
    ) -> tuple["SearchSession", dict]:
        """Index-once / serve-many: ``Index.open`` when ``index_dir`` holds
        a committed manifest, else call ``build_fn() -> (index, tree,
        extra)`` and commit the result there (when ``index_dir`` is given).

        Returns ``(session, meta)`` where ``meta`` is the index metadata
        (corpus geometry etc.) on restore, or ``build_fn``'s extra.
        """
        mesh = mesh if mesh is not None else local_mesh()
        idx, meta = load_or_build_index(
            index_dir, build_fn=build_fn, mesh=mesh, rebuild=rebuild,
        )
        return cls(idx, mesh=mesh, **session_kw), meta

    @property
    def pinned_version(self) -> int:
        """The index manifest version this session is currently serving
        (the snapshot pinned at construction or the last refresh)."""
        return self._pin.version

    def refresh(self) -> None:
        """Re-pin the index's current segments/tombstones (after append/
        delete/compact on the underlying Index) and rebuild the bucket
        pipelines. New shapes compile at the next :meth:`warmup` — prefer
        :meth:`maybe_refresh` on a serving loop, which warms before
        swapping."""
        self._adopt(self.index.snapshot())

    def maybe_refresh(self) -> bool:
        """Adopt the index's latest state iff it changed since the pin —
        the serve-loop's read-during-write hook (``--refresh-every``).

        O(1) when nothing changed (one stamp compare — safe to call
        between every micro-batch). On change, the new snapshot's bucket
        ladders are rebuilt AND warmed *before* this method returns, so
        the caller's next dispatch replays a compiled program: requests
        queued behind the refresh never see a half-adopted index and
        steady-state recompiles stay at zero. An index mutated down to
        zero segments keeps the old pin (there is nothing to serve).

        Returns ``True`` when a new snapshot was adopted.
        """
        if self.index.stamp == self._pin.stamp:
            return False
        snap = self.index.snapshot()
        if not snap.segments:
            return False
        self._adopt(snap)
        self.warmup()
        return True

    def _adopt(self, snap) -> None:
        """Swap the pinned snapshot: re-point views, cache slabs, device
        codes, and rebuild the bucket runtimes. Callers own warmup."""
        self._pin = snap
        self._segments = snap.views
        self._attach_cache()
        if self._use_codes:
            self._refresh_codes()
        self._build_runtimes()
        self._warmed_compiles = None

    def _make_runtime(self, bucket: int) -> _BucketRuntime:
        return make_bucket_runtime(
            self.mesh, self.index.n_leaves, self._segments, bucket,
            k=self.k, probes=self.probes, layout=self.serving_layout,
            impl=self.impl,
            cost_model=self.cost_model, calibration=self.index.calibration,
            rerank=self.rerank, codes=self._codes_dev,
            codebooks=self._codebooks_dev,
        )

    def active_cost_model(self) -> str:
        """Which model currently decides (e.g. ``"auto(fitted)"``) —
        resolved against the index's live calibration store."""
        return resolve_model(
            self.cost_model, self.index.calibration
        ).describe()

    def predicted_ms_per_image(self, bucket: int | None = None
                               ) -> float | None:
        """Modelled engine ms per image for one dispatch at ``bucket``
        (default: the largest warmed rung) — what the SLO policy derives
        its shed threshold from and the hot-leaf cache scores evictions
        with. Prefers the fitted cost model (summed over every executed
        per-segment plan, mirroring how serving attributes measurements),
        falls back to the calibration store's exact-signature means, then
        to this session's own measured ms/image; ``None`` when nothing
        can price it (callers must treat the cost as unknown)."""
        from repro.core.engine import fitted_component

        b = self.buckets[-1] if bucket is None else snap_to_bucket(
            min(int(bucket), self.max_batch_rows), self.buckets
        )
        rt = self._runtimes[b]
        fitted = fitted_component(self.cost_model, self.index.calibration)
        for model in (fitted, self.index.calibration):
            if model is None:
                continue
            preds = [
                (
                    model.predict_ms(
                        p, PlanShapes(rows=rows, n_queries=rt.bucket,
                                      n_shards=ns,
                                      n_leaves=self.index.n_leaves,
                                      dim=self._shapes_dim(p)),
                    )
                    if fitted is model
                    else model.mean_ms(p)
                )
                for p, rows, ns in rt.plan_rows
            ]
            if all(v is not None for v in preds):
                total = float(sum(preds))
                if total > 0:
                    return total
        if self.metrics.engine_images:
            return self.metrics.ms_per_image
        return None

    # -- compile accounting -------------------------------------------------
    def recompiles(self) -> int:
        """Total jitted-executor compilations so far (jit cache entries)."""
        return sum(_jit_cache_size(rt.fn) for rt in self._runtimes.values())

    def steady_state_recompiles(self) -> int:
        """Compilations after warmup — the serving invariant is 0."""
        if self._warmed_compiles is None:
            return 0
        n = self.recompiles() - self._warmed_compiles
        self.metrics.recompiles_after_warmup = n
        return n

    def warmup(self) -> float:
        """Compile every bucket rung once (dummy batch) — steady-state
        requests then only ever replay warmed programs. Returns the wall
        milliseconds spent compiling (also folded into the metrics)."""
        d = self.index.dim
        with get_tracer().span("session.warmup", buckets=len(self.buckets)):
            t0 = time.perf_counter()
            for rt in self._runtimes.values():
                dummy = jnp.zeros((rt.bucket, d), jnp.float32)
                res, leaves = self._dispatch(rt, dummy, np.int32(0))
                jax.block_until_ready((res.ids, leaves))
            dt_ms = (time.perf_counter() - t0) * 1e3
        self.metrics.warmup_ms += dt_ms
        self._warmed_compiles = self.recompiles()
        return dt_ms

    # -- serve path ---------------------------------------------------------
    @property
    def max_batch_rows(self) -> int:
        return self.buckets[-1]

    def _dispatch(self, rt: _BucketRuntime, buf, n_valid):
        """Invoke one rung's fused pipeline (codes rungs take the device
        codes + codebook table as extra leading arguments)."""
        if rt.rerank is not None:
            return rt.fn(self._segments, self._codes_dev,
                         self._codebooks_dev, self.tree, buf, n_valid)
        return rt.fn(self._segments, self.tree, buf, n_valid)

    def _execute(
        self, queries: np.ndarray, *, n_images: int | None = None
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, float]:
        """Run one micro-batch through its snapped bucket rung.

        Returns ``(ids (n,k), dists (n,k), probe_leaves (n,probes),
        seconds)``; feeds metrics, the hot-leaf cache, and the plan's
        ms/image observations.
        """
        n, d = queries.shape
        if n > self.max_batch_rows:
            raise ValueError(
                f"batch of {n} rows exceeds largest bucket "
                f"{self.max_batch_rows}; split it across dispatches"
            )
        rt = self._runtimes[snap_to_bucket(n, self.buckets)]
        buf = np.zeros((rt.bucket, d), np.float32)
        buf[:n] = queries
        t0 = time.perf_counter()
        res, leaves = self._dispatch(rt, jnp.asarray(buf), np.int32(n))
        jax.block_until_ready((res.ids, res.dists, leaves))
        dt = time.perf_counter() - t0
        ids = np.asarray(res.ids[:n])
        dists = np.asarray(res.dists[:n])
        leaves_np = np.asarray(leaves[:n])
        tr = get_tracer()
        if tr.enabled:
            t1 = tr.now()
            tr.add_span(
                "engine.execute", t1 - dt, t1, rows=n, bucket=rt.bucket,
                layout=rt.plan.layout, segments=len(rt.plans),
                plan=signature_key(plan_signature(rt.plan)),
                cost_model=self.active_cost_model(),
            )
        if self._use_codes:
            # the rung emitted rt.rerank ADC candidates per query; fetch
            # the survivors' raw rows and rerank exactly (the rerank wall
            # time is part of serving the request, so it stays in dt)
            t_r = time.perf_counter()
            with tr.span("engine.rerank", k=self.k,
                         candidates=int(ids.shape[1])):
                ids, dists = rerank_exact(
                    self.index.read_rows, queries, ids, self.k
                )
            dt += time.perf_counter() - t_r
        self.metrics.engine_batches += 1
        self.metrics.engine_ms += dt * 1e3
        self.metrics.query_rows += n
        overflow = int(res.q_cap_overflow)
        self.metrics.q_cap_overflow += overflow
        if n_images:
            self.metrics.engine_images += n_images
            self._record_calibration(rt, dt * 1e3 / n_images)
            # measured engine cost refines the cache's eviction score
            self.cache.note_engine_cost(dt * 1e3 / n_images)
        if not self._use_codes:
            # a starved dispatch must not seed the cache: a cached
            # full-slab scan would disagree with the truncated engine
            # answer. Codes sessions never seed it at all — a cache hit
            # would answer with an exact scan, diverging from the
            # ADC+rerank tier the engine serves.
            self.cache.record(queries, leaves_np, exact=overflow == 0)
        return ids, dists, leaves_np, dt

    def search(
        self, queries: np.ndarray, *, n_images: int | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """One-shot search of ``(n, d)`` query rows.

        Args:
          queries: ``(n, d)`` float rows; batches larger than the top
            bucket are split across dispatches.
          n_images: images this batch represents — feeds the ms/image
            metric and the plan's cost-model observations when given.

        Returns:
          ``(ids, dists)`` of shape ``(n, k)`` each — bit-identical to
          ``core.search.batch_search`` under the same plan budgets.
        """
        queries = np.asarray(queries, np.float32)
        if len(queries) <= self.max_batch_rows:
            ids, dists, _, _ = self._execute(queries, n_images=n_images)
            return ids, dists
        # split batches: per-chunk plan observations would mis-attribute the
        # whole request's images to one chunk's wall time, so only the
        # aggregate image/ms counters are fed (ms_per_image stays honest)
        out_i, out_d = [], []
        for s in range(0, len(queries), self.max_batch_rows):
            chunk = queries[s: s + self.max_batch_rows]
            ids, dists, _, _ = self._execute(chunk)
            out_i.append(ids)
            out_d.append(dists)
        if n_images:
            self.metrics.engine_images += n_images
        return np.concatenate(out_i), np.concatenate(out_d)

    def serve_many(self, request_batches) -> list[tuple[np.ndarray, np.ndarray]]:
        """Serve a coalesced micro-batch in one engine dispatch.

        Args:
          request_batches: per-request ``(rows, d)`` arrays whose total
            row count fits the largest warmed bucket.

        Returns:
          One ``(ids, dists)`` pair per request, in order.

        Raises:
          ValueError: the concatenated batch exceeds the largest bucket
            (the micro-batcher's coalescing contract was violated).
        """
        sizes = [len(q) for q in request_batches]
        ids, dists, _, _ = self._execute(
            np.concatenate(request_batches), n_images=len(request_batches)
        )
        out, off = [], 0
        for s in sizes:
            out.append((ids[off: off + s], dists[off: off + s]))
            off += s
        return out

    def _record_calibration(self, rt: _BucketRuntime, ms_per_image: float
                            ) -> None:
        """Measured ms/image -> the index's calibration store. A dispatch
        scans every segment (and shard) in one fused program, so the
        measured ms is attributed to each executed plan proportionally to
        its rows share — each record's shapes then match what the next
        session's per-segment ``plan()`` consult will ask about, and the
        fit gets one shape-consistent point per plan. Only after warmup:
        a compile-tainted first dispatch must not poison the fit."""
        if self._warmed_compiles is None:
            return
        total = sum(r for _, r, _ in rt.plan_rows) or 1
        for p, rows, n_shards in rt.plan_rows:
            self.index.calibration.record(
                p, ms_per_image * rows / total,
                shapes=PlanShapes(
                    rows=rows,
                    n_queries=rt.bucket,
                    n_shards=n_shards,
                    n_leaves=self.index.n_leaves,
                    dim=self._shapes_dim(p),
                ),
            )

    def _shapes_dim(self, p: SearchPlan) -> int:
        """``PlanShapes.dim`` for a recorded/consulted plan: the codes
        tier prices by dim, the dense layouts never did — keeping dense
        shapes at ``dim=0`` preserves exact-shape matches against every
        pre-codes record and the dense consults elsewhere."""
        return self.index.dim if p.layout == "scan_codes" else 0

    def plan_summary(self) -> list[dict]:
        return [
            {
                "bucket": rt.bucket,
                "cost_model": self.cost_model,
                "layout": rt.plan.layout,
                "impl": rt.plan.impl,
                "q_total": rt.q_total,
                "block_rows": rt.plan.block_rows,
                "q_cap": rt.plan.q_cap,
                "q_tile": rt.plan.q_tile,
                "p_cap": rt.plan.p_cap,
                "rerank": rt.plan.rerank,
                "segments": len(rt.plans),
            }
            for rt in self._runtimes.values()
        ]
