"""Sharded scatter-gather serving: N shard ladders behind one session.

The paper's search phase runs as a fleet of map tasks, each scanning its
partition of the index, with one merge step fusing per-partition candidate
lists (§2.4). :class:`ShardedSearchSession` is that topology as a serving
layer over a :class:`~repro.index.ShardedIndex`:

  * **scatter** — every dispatch snaps to a warmed bucket and fans the
    padded query batch out to one fused jitted pipeline *per shard*
    (each shard owns a full bucket ladder over its segments — compile
    cost is ``shards x buckets`` programs, all paid at :meth:`warmup`);
  * **gather** — per-shard partials carry global merge *slots*
    (``segment_ordinal * k + column``), so the host-side fuse
    (:func:`repro.index.sharding.gather_merge`) reproduces the unsharded
    stable ascending-distance merge bit for bit — results are identical
    to a plain :class:`~repro.serving.SearchSession` over the same index
    at any shard count, both layouts, any probe width, tombstones
    respected;
  * **above the scatter** — the hot-leaf cache keys on the *pre-scatter*
    query bytes (one cache for the whole index, consulted before any
    shard is touched) and records routing *post-gather*; the
    micro-batcher coalesces above the session exactly as in the
    unsharded case — neither knows shards exist.

On one device the shards share the mesh and run sequentially-but-isolated
(same numerics, summed wall time — this is the regime the bit-identity
tests pin down); with enough devices each shard's programs are placed on
its own device group via ``meshutil.shard_submeshes`` and the sequential
dispatch loop overlaps across shards (dispatch is async; the gather blocks
once at the end).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (
    PlanShapes,
    SearchPlan,
    fitted_component,
    plan as make_plan,
    snap_to_bucket,
)
from repro.codes import rerank_exact
from repro.distributed.meshutil import data_axis_size
from repro.core.engine.costmodel import plan_signature, signature_key
from repro.index.sharding import (
    ShardedIndex,
    ShardPlan,
    fitted_shard_scales,
    gather_merge,
)
from repro.obs import get_tracer
from repro.serving.session import (
    SearchSession,
    _jit_cache_size,
    make_bucket_runtime,
)
from repro.serving.slo import slab_scale_cap


@dataclasses.dataclass
class _ShardedRuntime:
    """One warmed bucket rung, fanned out: one fused pipeline per shard."""

    bucket: int  # query-row capacity of this rung
    parts: tuple  # (shard_index, views, _BucketRuntime) per non-empty shard
    plan: SearchPlan  # primary plan (largest shard) — observe()/reporting
    plans: tuple  # every resolved per-segment plan across shards
    q_total: int  # largest per-segment padded lookup row count
    plan_rows: tuple = ()  # (plan, padded rows, n_shards) across shards


class ShardedSearchSession(SearchSession):
    """Scatter-gather :class:`SearchSession`: same public surface (the
    micro-batcher, trace replay, and CLI drive either interchangeably),
    shard-parallel execution underneath.

    Construct from a ``repro.index.Index`` plus either ``shards=N`` (+
    ``shard_strategy``), an explicit ``shard_plan``, or an index whose
    manifest carries a persisted plan; a ``ShardedIndex`` is also
    accepted directly. ``target_p95_ms`` caps the fitted per-shard
    slab-headroom multipliers so a grown dispatch still fits the latency
    target (see :func:`repro.serving.slo.slab_scale_cap`); ``None``
    keeps the stock cap. All other keywords are :class:`SearchSession`'s.

    Raises ``ValueError`` when no shard plan can be resolved, or when an
    explicit plan no longer covers the index's segments after a
    :meth:`refresh` (derivable strategies re-derive automatically).
    """

    def __init__(
        self,
        index,
        tree=None,
        mesh=None,
        *,
        shards: int | None = None,
        shard_plan: ShardPlan | None = None,
        shard_strategy: str = "round_robin",
        target_p95_ms: float | None = None,
        **session_kw,
    ):
        if isinstance(index, ShardedIndex):
            shard_plan = shard_plan or index.plan
            index = index.index
        self._n_shards_arg = shards
        self._shard_plan_arg = shard_plan
        self._strategy_arg = shard_strategy
        self._target_p95_ms = target_p95_ms
        super().__init__(index, tree, mesh, **session_kw)

    # -- runtime construction -----------------------------------------------
    def _derive_plan(self, n_shards: int, strategy: str) -> ShardPlan:
        """Derive a plan over the *pinned* segment cut (not the index's
        live segments — a concurrent append must not leak into the plan
        this session serves). Raises for non-derivable strategies."""
        segs = self._pin.segments
        if strategy == "round_robin":
            return ShardPlan.round_robin([s.name for s in segs], n_shards)
        if strategy == "balanced":
            return ShardPlan.balanced(
                [s.name for s in segs], [s.valid_rows for s in segs], n_shards
            )
        raise ValueError(
            f"cannot derive a {strategy!r} plan; want one of "
            "('round_robin', 'balanced')"
        )

    def _resolve_plan(self) -> ShardPlan:
        plan = self._shard_plan_arg
        if plan is None and self._n_shards_arg is not None:
            return self._derive_plan(self._n_shards_arg, self._strategy_arg)
        if plan is None:
            plan = self._pin.shard_plan
        if plan is None:
            raise ValueError(
                "ShardedSearchSession needs shards=N, a shard_plan, or an "
                "index with a persisted shard plan"
            )
        if not plan.covers([s.name for s in self._pin.segments]):
            # raises for explicit plans (cannot follow a changed cut)
            plan = self._derive_plan(plan.n_shards, plan.strategy)
        return plan

    def _build_runtimes(self) -> None:
        self.sharded = ShardedIndex(
            self.index, plan=self._resolve_plan(),
            segments=self._pin.segments, views=self._pin.views,
            codes=self._pin.codes or None, tombstones=self._pin.tombstones,
        )
        shard_views = self.sharded.shard_views()
        self._shard_codes = {}
        if self._use_codes:
            # device codes aligned with global segment ordinals; each
            # shard's rung sees only its own segments' code arrays
            for si, shard in enumerate(shard_views):
                if shard:
                    self._shard_codes[si] = tuple(
                        self._codes_dev[g] for g, _ in shard
                    )
        self._runtimes = {}
        for b in self.buckets:
            scales = self._shard_scales(shard_views, b)
            rerank = self._global_rerank(shard_views, b)
            parts = []
            for si, (shard, mesh, scale) in enumerate(
                zip(shard_views, self.sharded._meshes, scales)
            ):
                if not shard:
                    continue  # more shards than segments: empty scatter leg
                rt = make_bucket_runtime(
                    mesh, self.index.n_leaves,
                    tuple(v for _, v in shard), b,
                    k=self.k, probes=self.probes,
                    layout=self.serving_layout,
                    impl=self.impl,
                    ordinals=tuple(g for g, _ in shard),
                    emit_slots=True,
                    cost_model=self.cost_model,
                    calibration=self.index.calibration,
                    slab_scale=scale,
                    rerank=rerank,
                    codes=self._shard_codes.get(si),
                    codebooks=self._codebooks_dev,
                )
                parts.append((si, tuple(v for _, v in shard), rt))
            primary = max(
                range(len(parts)),
                key=lambda i: sum(int(v.rows) for v in parts[i][1]),
            )
            self._runtimes[b] = _ShardedRuntime(
                bucket=b,
                parts=tuple(parts),
                plan=parts[primary][2].plan,
                plans=tuple(p for _, _, rt in parts for p in rt.plans),
                q_total=max(rt.q_total for _, _, rt in parts),
                # every shard scans the dispatch: the base session's
                # rows-share attribution then covers all executed plans
                plan_rows=tuple(
                    pr for _, _, rt in parts for pr in rt.plan_rows
                ),
            )

    def _global_rerank(self, shard_views, bucket: int) -> int | None:
        """One uniform ADC candidate width for EVERY shard's rung at this
        bucket: each segment's plan clamps ``rerank`` to its own
        ``block_rows``, and the gather's slot arithmetic (``ordinal *
        width + column``) only stays a global total order when every
        shard emits the same width — the min across all segments is
        valid everywhere. ``None`` on dense tiers."""
        if not self._use_codes:
            return None
        pq = self._pin.quantizer
        widths = []
        for shard, mesh in zip(shard_views, self.sharded._meshes):
            ns = data_axis_size(mesh)
            for _, view in shard:
                p = make_plan(
                    rows=view.rows, n_leaves=self.index.n_leaves,
                    n_queries=bucket, n_shards=ns, k=self.k,
                    probes=self.probes, layout="scan_codes",
                    impl=self.impl, model=self.cost_model,
                    calibration=self.index.calibration,
                    dim=self.index.dim, rerank=self.rerank,
                    code_m=pq.m, code_bits=pq.bits,
                )
                widths.append(p.rerank)
        return min(widths)

    def _shard_scales(self, shard_views, bucket: int) -> list[float]:
        """Per-shard slab-headroom multipliers for one bucket rung —
        the shared :func:`repro.index.sharding.fitted_shard_scales`
        (all ones until the index's calibration yields a usable fit, i.e.
        the uniform budget split). With ``target_p95_ms`` set, the
        multiplier ceiling shrinks so the fitted model predicts a grown
        dispatch still fits the target's dispatch budget."""
        max_scale = 2.0
        if self._target_p95_ms:
            max_scale = slab_scale_cap(
                self._target_p95_ms,
                self._predicted_dispatch_ms(shard_views, bucket),
            )
        return fitted_shard_scales(
            self.index, shard_views, self.sharded._meshes,
            cost_model=self.cost_model, n_queries=bucket, k=self.k,
            probes=self.probes,
            # codes rungs budget like the dense point-major family; the
            # probe plans only supply tile features, and grow-only scales
            # keep any mispricing result-safe
            layout="auto" if self._use_codes else self.layout,
            impl=self.impl,
            max_scale=max_scale,
        )

    def _predicted_dispatch_ms(self, shard_views, bucket: int) -> float | None:
        """Fitted prediction for one full-bucket dispatch at scale 1 —
        the sum of per-shard scan costs (on one device the shard scans
        run back to back). ``None`` when any shard cannot be planned or
        priced, which falls back to the stock headroom cap."""
        fitted = fitted_component(self.cost_model, self.index.calibration)
        if fitted is None:
            return None
        total = 0.0
        for shard, mesh in zip(shard_views, self.sharded._meshes):
            if not shard:
                continue
            rows = sum(int(v.rows) for _, v in shard)
            ns = data_axis_size(mesh)
            try:
                p = make_plan(
                    rows=rows, n_leaves=self.index.n_leaves,
                    n_queries=bucket, n_shards=ns, k=self.k,
                    probes=self.probes, layout=self.layout, impl=self.impl,
                    model=self.cost_model,
                    calibration=self.index.calibration,
                )
            except ValueError:
                return None
            pred = fitted.predict_ms(p, PlanShapes(
                rows=rows, n_queries=bucket, n_shards=ns,
                n_leaves=self.index.n_leaves,
            ))
            if pred is None:
                return None
            total += pred
        return total or None

    # -- compile accounting --------------------------------------------------
    def recompiles(self) -> int:
        """Total jitted compilations across every (shard, bucket) program."""
        return sum(
            _jit_cache_size(rt.fn)
            for rtb in self._runtimes.values()
            for _, _, rt in rtb.parts
        )

    def warmup(self) -> float:
        """Compile every shard's every bucket rung once (dummy batch);
        steady state then replays warmed programs only. Returns wall ms."""
        d = self.index.dim
        with get_tracer().span("session.warmup", buckets=len(self.buckets),
                               shards=self.n_shards):
            t0 = time.perf_counter()
            for rtb in self._runtimes.values():
                dummy = jnp.zeros((rtb.bucket, d), jnp.float32)
                outs = [
                    self._dispatch_shard(si, rt, views, dummy, np.int32(0))
                    for si, views, rt in rtb.parts
                ]
                for res, leaves, _slots in outs:
                    jax.block_until_ready((res.ids, leaves))
            dt_ms = (time.perf_counter() - t0) * 1e3
        self.metrics.warmup_ms += dt_ms
        self._warmed_compiles = self.recompiles()
        return dt_ms

    # -- serve path ----------------------------------------------------------
    def _dispatch_shard(self, si, rt, views, buf, n_valid):
        """Invoke one shard's fused pipeline (codes rungs take that
        shard's device codes + the codebook table as extra args)."""
        if rt.rerank is not None:
            return rt.fn(views, self._shard_codes[si],
                         self._codebooks_dev, self.tree, buf, n_valid)
        return rt.fn(views, self.tree, buf, n_valid)

    def _execute(
        self, queries: np.ndarray, *, n_images: int | None = None
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, float]:
        """Scatter one micro-batch to every shard, gather-merge the
        partials. Same contract as the unsharded ``_execute``: returns
        ``(ids, dists, probe_leaves, seconds)``, feeds metrics, the
        (pre-scatter) hot-leaf cache, and the plan observations."""
        n, d = queries.shape
        if n > self.max_batch_rows:
            raise ValueError(
                f"batch of {n} rows exceeds largest bucket "
                f"{self.max_batch_rows}; split it across dispatches"
            )
        rtb = self._runtimes[snap_to_bucket(n, self.buckets)]
        buf = np.zeros((rtb.bucket, d), np.float32)
        buf[:n] = queries
        jbuf = jnp.asarray(buf)
        nv = np.int32(n)
        tr = get_tracer()
        t0 = time.perf_counter()
        if tr.enabled:
            # per-shard spans need per-shard completion times, so block
            # each scatter leg in turn. The programs, inputs, and merge
            # are untouched — numerics (ids/dists) stay bit-identical to
            # the async path; only wall attribution differs.
            outs = []
            for si, views, rt in rtb.parts:
                with tr.span(
                    "shard.scan", shard=si, bucket=rtb.bucket,
                    rows=sum(int(v.rows) for v in views),
                    segments=len(views),
                ):
                    out = self._dispatch_shard(si, rt, views, jbuf, nv)
                    jax.block_until_ready(
                        (out[0].ids, out[0].dists, out[2], out[1])
                    )
                outs.append(out)
        else:
            # dispatch every shard first (async), block once for the
            # gather — on disjoint device groups the scans overlap; on one
            # device XLA runs them back to back with identical numerics
            outs = [
                self._dispatch_shard(si, rt, views, jbuf, nv)
                for si, views, rt in rtb.parts
            ]
            for res, leaves, slots in outs:
                jax.block_until_ready((res.ids, res.dists, slots, leaves))
        dt = time.perf_counter() - t0
        if tr.enabled:
            t1 = tr.now()
            tr.add_span(
                "engine.execute", t1 - dt, t1, rows=n, bucket=rtb.bucket,
                layout=rtb.plan.layout, shards=len(rtb.parts),
                plan=signature_key(plan_signature(rtb.plan)),
                cost_model=self.active_cost_model(),
            )
        # codes rungs gather CANDIDATE tables (uniform width, slot-tagged,
        # so the merged candidate set is shard-count-invariant), then one
        # global exact rerank produces the final top-k
        width = rtb.parts[0][2].rerank or self.k
        with tr.span("gather.merge", shards=len(rtb.parts), rows=n):
            ids, dists = gather_merge(
                [
                    (
                        np.asarray(res.ids[:n]),
                        np.asarray(res.dists[:n]),
                        np.asarray(slots[:n]),
                    )
                    for res, _leaves, slots in outs
                ],
                width,
            )
        if self._use_codes:
            t_r = time.perf_counter()
            with tr.span("engine.rerank", k=self.k, candidates=width):
                ids, dists = rerank_exact(
                    self._read_pinned_rows, queries, ids, self.k
                )
            dt += time.perf_counter() - t_r
        # every shard routes the same queries through the same tree; shard
        # 0's probe-leaf matrix is THE routing (the broadcast analog)
        leaves_np = np.asarray(outs[0][1][:n])
        overflow = sum(int(res.q_cap_overflow) for res, _, _ in outs)
        self.metrics.engine_batches += 1
        self.metrics.engine_ms += dt * 1e3
        self.metrics.query_rows += n
        self.metrics.q_cap_overflow += overflow
        if n_images:
            self.metrics.engine_images += n_images
            self._record_calibration(rtb, dt * 1e3 / n_images)
            # measured engine cost refines the cache's eviction score
            self.cache.note_engine_cost(dt * 1e3 / n_images)
        if not self._use_codes:
            # a starved dispatch must not seed the cache (see
            # SearchSession; codes sessions never seed it at all)
            self.cache.record(queries, leaves_np, exact=overflow == 0)
        return ids, dists, leaves_np, dt

    # -- reporting ------------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return self.sharded.n_shards

    @property
    def shard_plan(self) -> ShardPlan:
        return self.sharded.plan

    def per_shard_stats(self) -> dict:
        """The bound plan plus rows/segments per shard (CLI + benchmark
        reporting)."""
        return self.sharded.stats()

    def plan_summary(self) -> list[dict]:
        return [
            {
                "bucket": rtb.bucket,
                "cost_model": self.cost_model,
                "layout": rtb.plan.layout,
                "q_total": rtb.q_total,
                "block_rows": rtb.plan.block_rows,
                "q_cap": rtb.plan.q_cap,
                "q_tile": rtb.plan.q_tile,
                "p_cap": rtb.plan.p_cap,
                "rerank": rtb.plan.rerank,
                "segments": len(rtb.plans),
                "shards": len(rtb.parts),
            }
            for rtb in self._runtimes.values()
        ]
