"""SLO-grade scheduling policy: priority classes, deadlines, admission
control, and the closed-loop ladder tuner.

The paper's headline number (~210 ms/image over 100M images, Exp #5) is a
*sustained* figure — the system holds it under continuous load. Our serving
benchmark showed the opposite failure mode: engine cost ~15 ms/image but
p95 latency >1 s, nearly all of it queueing. This module attacks the queue
with policy rather than kernels:

  * **priority classes** — every :class:`~repro.serving.trace.Request`
    carries one of :data:`PRIORITY_CLASSES` (``interactive`` > ``standard``
    > ``batch``); the micro-batcher dispatches earliest-deadline-first
    within class, higher classes first;
  * **deadline budgets** — each class owns a latency deadline (SLO) and a
    coalescing budget (how long the batcher may hold a request to fill a
    bucket); both live in :class:`SLOPolicy`;
  * **admission control** — when queue depth crosses a fitted-cost-derived
    threshold (the depth at which queued work alone exceeds the ``batch``
    deadline), incoming ``batch`` requests are shed (or deadline-downgraded)
    instead of poisoning every class's tail;
  * **ladder tuning** — :func:`tune_ladder` uses the fitted
    :class:`~repro.core.engine.costmodel.CostModel` to pick the bucket
    ladder whose largest dispatch still fits a target p95
    (``launch/serve --target-p95-ms``).

Scheduling only ever changes *when* a request runs, never *what* it
returns: per-request results are independent of batch composition (the
lookup routes each query row independently), so ``fifo`` and ``edf``
replays of the same trace return bit-identical ids + distances — the
``--slo-smoke`` gate asserts it.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

#: scheduling classes, highest priority first
PRIORITY_CLASSES = ("interactive", "standard", "batch")

_CLASS_RANK = {name: i for i, name in enumerate(PRIORITY_CLASSES)}

#: per-class completion deadline (the SLO the benchmark reports
#: attainment against)
DEFAULT_DEADLINES_MS = {
    "interactive": 50.0,
    "standard": 250.0,
    "batch": 2000.0,
}

#: fraction of a target p95 the tuner budgets for the dispatch itself
#: (the rest absorbs queueing + coalescing wait)
DISPATCH_FRACTION = 0.5


def class_rank(priority: str) -> int:
    """Scheduling rank of a priority class (0 = most urgent).

    Raises:
      ValueError: an unknown class name.
    """
    try:
        return _CLASS_RANK[priority]
    except KeyError:
        raise ValueError(
            f"unknown priority class {priority!r}; "
            f"want one of {PRIORITY_CLASSES}"
        ) from None


def _default_max_waits(base_ms: float) -> dict[str, float]:
    """Per-class coalescing budgets from one base figure: interactive
    requests coalesce briefly (latency is the product), batch requests
    coalesce long (amortisation is the product)."""
    base = float(base_ms)
    return {
        "interactive": max(0.5, base / 4.0),
        "standard": base,
        "batch": base * 10.0,
    }


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """The scheduling contract the micro-batcher enforces.

    Args:
      deadlines_ms: per-class completion deadline (arrival -> finish).
        EDF orders within a class by ``arrival + deadline``.
      max_wait_ms: per-class coalescing budget — how long the batcher may
        hold the head request waiting for more rows.
      shed_depth: queue depth (pending requests) at which admission
        control engages for ``batch`` work; ``None`` disables shedding
        (the only cap left is the hard ``max_queue``).
      on_overload: ``"shed"`` drops incoming batch requests outright
        (completion ``source="shed"``); ``"downgrade"`` keeps them but
        pushes their deadline out by one full batch budget, so they yield
        to everything else instead of being dropped.
    """

    deadlines_ms: Mapping[str, float]
    max_wait_ms: Mapping[str, float]
    shed_depth: int | None = None
    on_overload: str = "shed"

    def __post_init__(self):
        if self.on_overload not in ("shed", "downgrade"):
            raise ValueError(
                f"on_overload={self.on_overload!r}; want shed|downgrade"
            )
        for m in (self.deadlines_ms, self.max_wait_ms):
            missing = [c for c in PRIORITY_CLASSES if c not in m]
            if missing:
                raise ValueError(f"policy missing classes {missing}")

    def deadline_s(self, priority: str) -> float:
        return self.deadlines_ms[priority] / 1e3

    def max_wait_s(self, priority: str) -> float:
        return self.max_wait_ms[priority] / 1e3

    @classmethod
    def default(cls, *, base_max_wait_ms: float = 5.0,
                deadlines_ms: Mapping[str, float] | None = None,
                shed_depth: int | None = None,
                on_overload: str = "shed") -> "SLOPolicy":
        """A policy with the stock class deadlines and derived per-class
        coalescing budgets (no admission control unless ``shed_depth``)."""
        return cls(
            deadlines_ms=dict(DEFAULT_DEADLINES_MS, **(deadlines_ms or {})),
            max_wait_ms=_default_max_waits(base_max_wait_ms),
            shed_depth=shed_depth,
            on_overload=on_overload,
        )

    @classmethod
    def for_session(cls, session, *, base_max_wait_ms: float = 5.0,
                    deadlines_ms: Mapping[str, float] | None = None,
                    shed_depth: int | None = None,
                    on_overload: str = "shed",
                    max_depth: int = 4096) -> "SLOPolicy":
        """Derive the shed threshold from the session's fitted cost.

        The queue depth at which the queued work *alone* already exceeds
        the ``batch`` deadline — ``deadline_ms / predicted ms-per-image``
        — is where admitting more batch work is pointless: it cannot meet
        its SLO and only lengthens every other class's queue. Falls back
        to no shedding (``shed_depth=None``) when the session's index has
        no usable calibration (predicted cost unknown).
        """
        policy = cls.default(
            base_max_wait_ms=base_max_wait_ms, deadlines_ms=deadlines_ms,
            shed_depth=shed_depth, on_overload=on_overload,
        )
        if shed_depth is not None:
            return policy
        ms = session.predicted_ms_per_image()
        if ms is None or ms <= 0:
            return policy
        depth = int(policy.deadlines_ms["batch"] / ms)
        return dataclasses.replace(
            policy, shed_depth=max(4, min(int(max_depth), depth))
        )


# ---------------------------------------------------------------------------
# closed-loop ladder tuning (launch/serve --target-p95-ms)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LadderDecision:
    """What :func:`tune_ladder` decided and why.

    ``decided_by`` is ``"fitted"`` when the fitted cost model priced the
    candidate ladders, ``"default"`` when no usable fit existed and the
    stock ladder was kept. ``predicted_dispatch_ms`` is the modelled wall
    time of one full top-bucket dispatch (``None`` without a fit).
    """

    buckets: tuple
    max_wait_ms: float
    predicted_dispatch_ms: float | None
    decided_by: str


def tune_ladder(
    calibration,
    *,
    target_p95_ms: float,
    rows: int,
    n_leaves: int,
    desc_per_image: int,
    max_batch_rows: int = 4096,
    n_buckets: int = 3,
    n_shards: int = 1,
    k: int = 10,
    probes: int = 1,
    layout: str = "auto",
    impl: str = "xla",
    cost_model: str = "auto",
    base_max_wait_ms: float = 5.0,
) -> LadderDecision:
    """Pick a bucket ladder whose largest dispatch fits a target p95.

    A request's p95 latency is roughly (queue wait) + (coalescing wait) +
    (one dispatch). The tuner bounds the last term: the fitted model
    prices a full dispatch at each candidate top bucket (``ms/image x
    images per bucket``) and the largest bucket whose dispatch stays
    within ``target_p95_ms x DISPATCH_FRACTION`` wins — big enough to
    amortise, small enough that a request arriving behind one dispatch
    still meets the target. The coalescing budget is then the slack
    between target and dispatch cost (capped at ``base_max_wait_ms``).

    Args:
      calibration: the index's :class:`~repro.core.engine.CalibrationStore`.
      target_p95_ms: the latency target the ladder must serve.
      rows/n_leaves/n_shards/k/probes/layout/impl: the serving plan
        shapes (see :func:`repro.core.engine.plan`).
      desc_per_image: query rows per image — converts the fit's ms/image
        into per-dispatch wall time.
      max_batch_rows/n_buckets: the ladder search space (candidates are
        the stock geometric ladder's rungs).

    Returns:
      A :class:`LadderDecision`; without a usable fit the stock ladder is
      returned unchanged (``decided_by="default"``).
    """
    from repro.core.engine import (
        PlanShapes,
        bucket_ladder,
        fitted_component,
        plan as make_plan,
    )

    default = bucket_ladder(max_batch_rows, n_buckets=n_buckets)
    fitted = fitted_component(cost_model, calibration)
    if fitted is None:
        return LadderDecision(
            buckets=default, max_wait_ms=base_max_wait_ms,
            predicted_dispatch_ms=None, decided_by="default",
        )
    budget = float(target_p95_ms) * DISPATCH_FRACTION
    # candidates: the rungs of a finer ladder, largest first
    candidates = sorted(
        set(bucket_ladder(max_batch_rows, n_buckets=max(4, n_buckets + 2))),
        reverse=True,
    )
    chosen, chosen_ms = None, None
    for b in candidates:
        try:
            p = make_plan(
                rows=rows, n_leaves=n_leaves, n_queries=b,
                n_shards=n_shards, k=k, probes=probes, layout=layout,
                impl=impl, model=cost_model, calibration=calibration,
            )
        except ValueError:
            continue  # no usable tiling at this bucket
        per_image = fitted.predict_ms(
            p, PlanShapes(rows=rows, n_queries=b, n_shards=n_shards,
                          n_leaves=n_leaves),
        )
        if per_image is None:
            continue
        dispatch_ms = max(0.0, per_image) * max(1, b // max(1, desc_per_image))
        # largest-first: the first rung whose dispatch fits wins; if none
        # fits, the loop leaves the smallest plannable rung chosen
        chosen, chosen_ms = b, dispatch_ms
        if dispatch_ms <= budget:
            break
    if chosen is None:
        return LadderDecision(
            buckets=default, max_wait_ms=base_max_wait_ms,
            predicted_dispatch_ms=None, decided_by="default",
        )
    slack = max(1.0, float(target_p95_ms) - chosen_ms)
    return LadderDecision(
        buckets=bucket_ladder(chosen, n_buckets=n_buckets),
        max_wait_ms=min(float(base_max_wait_ms), slack),
        predicted_dispatch_ms=float(chosen_ms),
        decided_by="fitted",
    )


def slab_scale_cap(target_p95_ms: float | None,
                   predicted_ms_per_image: float | None,
                   *, default: float = 2.0) -> float:
    """Cap on the sharded session's per-shard slab-headroom multipliers.

    Growing a shard's slab budget grows its scan cost roughly linearly
    (the fitted model's ``rows_scanned`` term); with a p95 target, growth
    is capped so a grown dispatch still fits the target's dispatch
    budget. Without a target or a priced cost, the stock cap applies.
    """
    if not target_p95_ms or not predicted_ms_per_image \
            or predicted_ms_per_image <= 0:
        return float(default)
    cap = (float(target_p95_ms) * DISPATCH_FRACTION
           / float(predicted_ms_per_image))
    return max(1.0, min(float(default), cap))
