"""Trace-driven load generation for the serving layer.

A *trace* is the replayable description of a workload: which image each
request queries and when it arrives (``repro.data.synth.sample_trace`` —
uniform or Zipf-skewed popularity, Poisson or all-at-once arrivals,
deterministic under a seed). This module turns a trace into concrete
:class:`Request` objects: a request is one query image, i.e. its
``desc_per_image`` descriptor rows read from the corpus store
(``read_rows`` — only the containing blocks are touched) and perturbed
with noise seeded *by image id*, so a repeated image is the same photo with
the same descriptors — exactly the repetition the hot-leaf cache exploits.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data import synth


@dataclasses.dataclass
class Request:
    """One in-flight search request: a query image's descriptor rows."""

    rid: int
    image_id: int
    arrival: float  # seconds since trace start
    queries: np.ndarray  # (desc_per_image, dim) float32

    @property
    def rows(self) -> int:
        return self.queries.shape[0]


class TraceLoadGenerator:
    """Materialise query vectors for a (image_ids, arrivals) trace.

    ``corpus`` is either a block store (anything with ``read_rows``/``dim``:
    :class:`~repro.data.store.DescriptorStore` or ``VirtualStore``) or a
    resident ``(rows, dim)`` array. Image ``i`` owns descriptor rows
    ``[i * desc_per_image, (i+1) * desc_per_image)`` — the
    ``synth.sample_images`` layout, which persisted corpora keep.
    """

    def __init__(self, corpus, desc_per_image: int, *, noise: float = 4.0,
                 seed: int = 0):
        self.corpus = corpus
        self.desc_per_image = int(desc_per_image)
        self.noise = float(noise)
        self.seed = int(seed)

    def _read_rows(self, rows: np.ndarray) -> np.ndarray:
        if isinstance(self.corpus, np.ndarray):
            return self.corpus[rows]
        return self.corpus.read_rows(rows)

    def query_image(self, image_id: int) -> np.ndarray:
        """The (deterministic) query descriptors for one image."""
        dpi = self.desc_per_image
        rows = image_id * dpi + np.arange(dpi, dtype=np.int64)
        vecs = np.asarray(self._read_rows(rows), np.float32)
        rng = np.random.default_rng((self.seed, int(image_id)))
        q = vecs + rng.standard_normal(vecs.shape).astype(np.float32) * self.noise
        return np.clip(q, 0.0, 255.0)

    def requests(
        self, image_ids: np.ndarray, arrivals: np.ndarray
    ) -> list[Request]:
        return [
            Request(rid=r, image_id=int(img), arrival=float(t),
                    queries=self.query_image(int(img)))
            for r, (img, t) in enumerate(zip(image_ids, arrivals))
        ]

    def from_trace(
        self,
        n_requests: int,
        n_images: int,
        *,
        skew: str = "uniform",
        zipf_s: float = 1.1,
        rate: float | None = None,
        seed: int | None = None,
    ) -> list[Request]:
        """Sample a trace and materialise it in one step."""
        image_ids, arrivals = synth.sample_trace(
            n_requests, n_images, skew=skew, zipf_s=zipf_s, rate=rate,
            seed=self.seed if seed is None else seed,
        )
        return self.requests(image_ids, arrivals)
