"""Trace-driven load generation for the serving layer.

A *trace* is the replayable description of a workload: which image each
request queries and when it arrives (``repro.data.synth.sample_trace`` —
uniform or Zipf-skewed popularity, Poisson or all-at-once arrivals,
deterministic under a seed). This module turns a trace into concrete
:class:`Request` objects: a request is one query image, i.e. its
``desc_per_image`` descriptor rows read from the corpus store
(``read_rows`` — only the containing blocks are touched) and perturbed
with noise seeded *by image id*, so a repeated image is the same photo with
the same descriptors — exactly the repetition the hot-leaf cache exploits.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data import synth


@dataclasses.dataclass
class Request:
    """One in-flight search request: a query image's descriptor rows.

    ``priority`` is one of :data:`repro.serving.slo.PRIORITY_CLASSES`
    (``interactive`` / ``standard`` / ``batch``) — the scheduling class
    the micro-batcher's EDF dispatch and admission control key on. It
    never affects *what* the request returns, only when it runs.
    """

    rid: int
    image_id: int
    arrival: float  # seconds since trace start
    queries: np.ndarray  # (desc_per_image, dim) float32
    priority: str = "standard"

    @property
    def rows(self) -> int:
        return self.queries.shape[0]


@dataclasses.dataclass(frozen=True)
class TenantClass:
    """One tenant population in a multi-tenant trace.

    Args:
      priority: the scheduling class its requests carry.
      n_requests: how many requests this tenant contributes.
      rate: mean arrival rate in requests/second.
      skew: ``"uniform"`` or ``"zipf"`` image popularity.
      zipf_s: per-class Zipf exponent (each tenant has its own hot set).
      burst_factor: >= 1. 1 = steady Poisson; B > 1 concentrates all
        arrivals into the first ``1/B`` of every ``burst_period_s``
        window at ``B x rate`` (an on/off modulated Poisson process), so
        the *mean* rate — the offered load — is unchanged.
      burst_period_s: length of one on/off window.
    """

    priority: str
    n_requests: int
    rate: float
    skew: str = "zipf"
    zipf_s: float = 1.1
    burst_factor: float = 1.0
    burst_period_s: float = 1.0

    def __post_init__(self):
        from repro.serving.slo import class_rank

        class_rank(self.priority)  # raises on an unknown class
        if self.burst_factor < 1.0:
            raise ValueError(f"burst_factor={self.burst_factor} must be >= 1")
        if self.rate <= 0:
            raise ValueError(f"rate={self.rate} must be > 0")

    def arrivals(self, rng: np.random.Generator) -> np.ndarray:
        """Deterministic arrival times (seconds, sorted) for this tenant."""
        gaps = rng.exponential(
            1.0 / (self.rate * self.burst_factor), size=self.n_requests
        )
        on_time = np.cumsum(gaps)
        if self.burst_factor == 1.0:
            return on_time
        # map "on-clock" time to wall time: each window of burst_period_s
        # wall seconds is active only for its first on_len seconds
        on_len = self.burst_period_s / self.burst_factor
        window = np.floor(on_time / on_len)
        return window * self.burst_period_s + (on_time - window * on_len)


class TraceLoadGenerator:
    """Materialise query vectors for a (image_ids, arrivals) trace.

    ``corpus`` is either a block store (anything with ``read_rows``/``dim``:
    :class:`~repro.data.store.DescriptorStore` or ``VirtualStore``) or a
    resident ``(rows, dim)`` array. Image ``i`` owns descriptor rows
    ``[i * desc_per_image, (i+1) * desc_per_image)`` — the
    ``synth.sample_images`` layout, which persisted corpora keep.
    """

    def __init__(self, corpus, desc_per_image: int, *, noise: float = 4.0,
                 seed: int = 0):
        self.corpus = corpus
        self.desc_per_image = int(desc_per_image)
        self.noise = float(noise)
        self.seed = int(seed)

    def _read_rows(self, rows: np.ndarray) -> np.ndarray:
        if isinstance(self.corpus, np.ndarray):
            return self.corpus[rows]
        return self.corpus.read_rows(rows)

    def query_image(self, image_id: int) -> np.ndarray:
        """The (deterministic) query descriptors for one image."""
        dpi = self.desc_per_image
        rows = image_id * dpi + np.arange(dpi, dtype=np.int64)
        vecs = np.asarray(self._read_rows(rows), np.float32)
        rng = np.random.default_rng((self.seed, int(image_id)))
        q = vecs + rng.standard_normal(vecs.shape).astype(np.float32) * self.noise
        return np.clip(q, 0.0, 255.0)

    def requests(
        self, image_ids: np.ndarray, arrivals: np.ndarray
    ) -> list[Request]:
        return [
            Request(rid=r, image_id=int(img), arrival=float(t),
                    queries=self.query_image(int(img)))
            for r, (img, t) in enumerate(zip(image_ids, arrivals))
        ]

    def from_trace(
        self,
        n_requests: int,
        n_images: int,
        *,
        skew: str = "uniform",
        zipf_s: float = 1.1,
        rate: float | None = None,
        seed: int | None = None,
    ) -> list[Request]:
        """Sample a trace and materialise it in one step."""
        image_ids, arrivals = synth.sample_trace(
            n_requests, n_images, skew=skew, zipf_s=zipf_s, rate=rate,
            seed=self.seed if seed is None else seed,
        )
        return self.requests(image_ids, arrivals)

    def multi_tenant(
        self,
        classes,
        n_images: int,
        *,
        seed: int | None = None,
    ) -> list[Request]:
        """Materialise a multi-tenant trace: several :class:`TenantClass`
        populations (each with its own rate, burstiness, and Zipf skew)
        merged into one arrival-ordered request stream.

        Deterministic under ``seed``: each class draws from its own rng
        stream (``(seed, class index)``), so adding a class never
        perturbs the others' arrivals or image picks. Request ids are
        assigned in arrival order; ties break by class rank then class
        index so the merge itself is deterministic.

        Args:
          classes: a sequence of :class:`TenantClass`.
          n_images: the corpus image count every class draws ids from.

        Returns:
          One :class:`Request` list sorted by arrival, each request
          stamped with its tenant's ``priority``.
        """
        from repro.serving.slo import class_rank

        seed = self.seed if seed is None else int(seed)
        merged = []
        for ci, tc in enumerate(classes):
            # independent, collision-free streams: one for the image ids
            # (inside sample_trace), one for the arrival process
            image_ids, _ = synth.sample_trace(
                tc.n_requests, n_images, skew=tc.skew, zipf_s=tc.zipf_s,
                rate=None, seed=np.random.SeedSequence([seed, 2 * ci]),
            )
            rng = np.random.default_rng(
                np.random.SeedSequence([seed, 2 * ci + 1])
            )
            arrivals = tc.arrivals(rng)
            for img, t in zip(image_ids, arrivals):
                merged.append(
                    (float(t), class_rank(tc.priority), ci, int(img), tc)
                )
        merged.sort(key=lambda e: e[:3])
        return [
            Request(rid=r, image_id=img, arrival=t,
                    queries=self.query_image(img), priority=tc.priority)
            for r, (t, _rank, _ci, img, tc) in enumerate(merged)
        ]


def default_tenant_mix(
    n_requests: int,
    *,
    rate: float = 100.0,
    interactive_frac: float = 0.4,
    standard_frac: float = 0.3,
    burst_factor: float = 8.0,
) -> tuple[TenantClass, ...]:
    """The stock bursty+steady multi-tenant mix the SLO benchmark replays:
    steady ``interactive`` traffic with a hot Zipf working set, steady
    ``standard`` traffic, and heavily bursty ``batch`` traffic (same mean
    offered rate per request, arrivals concentrated ``burst_factor``-fold)
    — the workload whose queueing collapses a FIFO tail."""
    n_int = int(n_requests * interactive_frac)
    n_std = int(n_requests * standard_frac)
    n_bat = n_requests - n_int - n_std
    share = float(rate) / max(1, n_requests)
    return (
        TenantClass("interactive", n_int, rate=max(1e-6, share * n_int),
                    skew="zipf", zipf_s=1.3),
        TenantClass("standard", n_std, rate=max(1e-6, share * n_std),
                    skew="zipf", zipf_s=1.1),
        TenantClass("batch", n_bat, rate=max(1e-6, share * n_bat),
                    skew="uniform", burst_factor=burst_factor,
                    burst_period_s=1.0),
    )
