"""Gradient compression for the data-parallel wire (paper analog: map-output
compression cut Hadoop's shuffle bytes 30%; bf16 halves ours, top-k cuts
more). Both carry fp32 *error feedback* so compression noise does not
accumulate (Seide et al. 2014 / Karimireddy et al. 2019 lineage).

These transforms operate on the gradient pytree *before* the cross-replica
reduction. In the explicit-DP train step (``make_train_step(dp_axis=...)``)
the psum runs on the compressed representation inside shard_map; in the
default pjit step they still bound optimizer-state bandwidth and serve as
an ablation of compression noise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def bf16_compress(grads, feedback):
    """(compressed bf16 grads, new fp32 residual)."""

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        q = acc.astype(jnp.bfloat16)
        return q, acc - q.astype(jnp.float32)

    flat = jax.tree.map(one, grads, feedback)
    comp = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return comp, resid


def topk_compress(grads, feedback, *, fraction: float = 0.01):
    """Magnitude top-k sparsification with error feedback.

    Returns (sparse grads densified — zeros off-support, new residual).
    The wire format on a real pod would be (values, indices); the dense
    zero-filled form is numerically identical and psum-compatible.
    """

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        flat = acc.reshape(-1)
        k = max(1, int(flat.shape[0] * fraction))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(flat) >= thresh).astype(jnp.float32)
        kept = flat * mask
        return kept.reshape(acc.shape), (flat - kept).reshape(acc.shape)

    pairs = jax.tree.map(one, grads, feedback)
    comp = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return comp, resid
