"""AdamW in pure JAX (the cluster image carries no optax).

fp32 moments regardless of param dtype; global-norm clipping; decoupled
weight decay; linear-warmup cosine schedule helper.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Union[float, Callable] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(math.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        mhat = m_ / bc1
        vhat = v_ / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {"grad_norm": gnorm, "lr": lr}
