"""Train-step builders: microbatch accumulation + optimizer + compression.

``make_train_step(loss_fn, opt_cfg)`` returns a jittable
``(params, opt_state, batch) -> (params, opt_state, metrics)``.

Options:
  * ``microbatches=m`` — splits the batch's leading dim into m chunks and
    accumulates grads in fp32 via ``lax.scan`` (activation memory / m,
    compute-comm overlap: each chunk's backward overlaps the next chunk's
    forward in the XLA schedule).
  * ``compress="bf16"|"topk"`` — gradient compression with fp32 error
    feedback carried inside opt_state (see grad_compress.py).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.train import grad_compress
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def init_train_state(params, *, compress: Optional[str] = None):
    state = init_opt_state(params)
    if compress:
        state["feedback"] = grad_compress.init_feedback(params)
    return state


def make_train_step(
    loss_fn: Callable,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
    compress: Optional[str] = None,
    topk_fraction: float = 0.01,
):
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, aux), grads = grad_fn(params, batch)
            return grads, aux

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

        chunks = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc(carry, chunk):
            (loss, aux), grads = grad_fn(params, chunk)
            carry = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches, carry, grads
            )
            return carry, aux

        grads, auxes = jax.lax.scan(acc, zero, chunks)
        aux = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32)), auxes)
        return grads, aux

    def train_step(params, opt_state, batch):
        grads, aux = compute_grads(params, batch)
        if compress == "bf16":
            grads, fb = grad_compress.bf16_compress(grads, opt_state["feedback"])
        elif compress == "topk":
            grads, fb = grad_compress.topk_compress(
                grads, opt_state["feedback"], fraction=topk_fraction
            )
        elif compress is not None:
            raise ValueError(f"unknown compress {compress!r}")
        feedback = fb if compress else None
        core_state = {k: v for k, v in opt_state.items() if k != "feedback"}
        params, core_state, metrics = adamw_update(params, grads, core_state, opt_cfg)
        if feedback is not None:
            core_state["feedback"] = feedback
        metrics.update(aux)
        return params, core_state, metrics

    return train_step
