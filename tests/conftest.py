# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real (1-device) CPU topology; only launch/dryrun.py forces 512 devices.
import numpy as np
import pytest

# ---------------------------------------------------------------------------
# hypothesis compat shim: the property tests import `hypothesis`
# unconditionally. When it isn't installed, degrade `@given` to a fixed
# deterministic sweep of examples (seeded per-test) instead of failing the
# whole collection with ModuleNotFoundError.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import functools
    import inspect
    import sys
    import types
    import zlib

    _MAX_EXAMPLES = 6  # fixed sweep size when degrading @given

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(0, len(items)))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", None) or getattr(
                    fn, "_max_examples", None
                ) or _MAX_EXAMPLES
                n = min(n, _MAX_EXAMPLES)
                seed0 = zlib.crc32(fn.__qualname__.encode("utf-8"))
                for ex in range(n):
                    rng = np.random.default_rng((seed0 + ex) % 2**32)
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # pytest introspects the signature to decide which fixtures to
            # inject; strategy-provided params must not look like fixtures
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items()
                    if name not in strategies
                ]
            )
            wrapper.__dict__.pop("__wrapped__", None)
            return wrapper

        return deco

    def _settings(**kw):
        def deco(fn):
            fn._max_examples = kw.get("max_examples")
            return fn

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.floats = _floats

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_repro_stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_obs():
    """Observability hygiene: every test starts with the no-op tracer and
    a fresh metrics registry, and leaves none of its spans/series behind
    for the next test (mirrors ``_isolated_calibration``)."""
    from repro import obs

    prev_tracer = obs.set_tracer(None)
    prev_registry = obs.set_registry(None)
    yield
    obs.set_tracer(prev_tracer)
    obs.set_registry(prev_registry)


@pytest.fixture(autouse=True)
def _isolated_calibration():
    """Cost-model calibration hygiene: the module-level default store is
    emptied around every test, so one test's recorded ms/image can never
    flip another test's ``plan(model="auto")`` decision. (Index-scoped
    stores are per-instance and need no guard.)"""
    from repro.core.engine import costmodel

    costmodel.reset_default_calibration()
    yield
    costmodel.reset_default_calibration()
