"""Deterministic crash/fault injection for the index's write path.

:class:`FaultFS` monkeypatches the process-wide write syscalls —
``builtins.open`` (write/append/create modes), ``os.fsync``, ``os.link``,
``os.rename``, ``os.replace``, ``os.unlink``/``os.remove`` — filtered to
one directory tree (the index root). Every filtered call is a numbered
*boundary*; arming ``fail_at=i`` raises :class:`InjectedFault` *before*
the i-th call executes, which models a crash at exactly that point: all
earlier writes are on disk, the armed one and everything after never
happened.

The enumeration protocol (see ``tests/test_durability.py``):

1. **counting pass** — run the operation under an unarmed FaultFS on a
   pristine copy; ``len(fs.boundaries)`` is the number of distinct crash
   points ``T`` (deterministic: same initial state, same op, same
   boundaries).
2. **fault pass** — for each ``i < T``, restore the pristine copy, arm
   ``fail_at=i``, run the op, catch :class:`InjectedFault`, then *reopen
   from disk* and assert the recovery invariant: the reopened index is
   exactly the pre-op or exactly the post-op published state — never a
   torn hybrid, never a resurrected orphan.
3. **retry pass** — the surviving handle retries the op with the faults
   disarmed; it must either succeed (identical-bytes manifest passthrough)
   or raise ``FileExistsError`` because the first attempt already landed.

No threads, no randomness: the boundary list is the schedule.
"""

from __future__ import annotations

import builtins
import os

_WRITE_MODE_CHARS = set("wxa+")


class InjectedFault(OSError):
    """The simulated crash raised at an armed write boundary."""


class FaultFS:
    """Context manager that intercepts write syscalls under ``root``.

    Args:
      root: directory tree to watch (the index directory). Calls whose
        target lies outside it pass through untouched and uncounted.
      fail_at: boundary ordinal to crash at, or ``None`` to only count.

    Attributes:
      boundaries: list of ``(kind, relative_path)`` recorded so far, in
        call order — ``kind`` is one of ``open``/``fsync``/``link``/
        ``rename``/``unlink``.
    """

    def __init__(self, root: str, fail_at: int | None = None):
        self.root = os.path.abspath(root)
        self.fail_at = fail_at
        self.fired = False  # the armed boundary was reached and raised
        self.boundaries: list[tuple[str, str]] = []
        self._saved: dict = {}

    # -- path filtering ------------------------------------------------------
    def _ours(self, path) -> str | None:
        if not isinstance(path, (str, bytes, os.PathLike)):
            return None
        p = os.path.abspath(os.fspath(path))
        if isinstance(p, bytes):
            p = os.fsdecode(p)
        if p == self.root or p.startswith(self.root + os.sep):
            return p
        return None

    def _hit(self, kind: str, path: str) -> None:
        i = len(self.boundaries)
        rel = os.path.relpath(path, self.root)
        self.boundaries.append((kind, rel))
        if self.fail_at is not None and i == self.fail_at:
            self.fired = True
            # NOTE: InjectedFault subclasses OSError on purpose — a
            # boundary inside a best-effort cleanup (``except OSError:
            # pass``) absorbs the crash exactly like the real filesystem
            # error it guards against; callers detect that via `fired`
            # without the op raising.
            raise InjectedFault(f"injected crash at boundary #{i}: "
                                f"{kind} {rel}")

    # -- patched syscalls ----------------------------------------------------
    def _open(self, file, mode="r", *args, **kwargs):
        p = self._ours(file)
        if p is not None and _WRITE_MODE_CHARS & set(mode):
            self._hit("open", p)
        return self._saved["open"](file, mode, *args, **kwargs)

    def _fsync(self, fd):
        # resolve the fd back to a path (Linux) so only fsyncs of files
        # under root count as boundaries
        try:
            p = self._ours(os.readlink(f"/proc/self/fd/{fd}"))
        except OSError:
            p = None
        if p is not None:
            self._hit("fsync", p)
        return self._saved["fsync"](fd)

    def _link(self, src, dst, **kwargs):
        p = self._ours(dst)
        if p is not None:
            self._hit("link", p)
        return self._saved["link"](src, dst, **kwargs)

    def _rename(self, src, dst, **kwargs):
        p = self._ours(dst) or self._ours(src)
        if p is not None:
            self._hit("rename", p)
        return self._saved["rename"](src, dst, **kwargs)

    def _replace(self, src, dst, **kwargs):
        p = self._ours(dst) or self._ours(src)
        if p is not None:
            self._hit("rename", p)
        return self._saved["replace"](src, dst, **kwargs)

    def _unlink(self, path, **kwargs):
        p = self._ours(path)
        if p is not None:
            self._hit("unlink", p)
        return self._saved["unlink"](path, **kwargs)

    # -- lifecycle -----------------------------------------------------------
    def __enter__(self) -> "FaultFS":
        self._saved = {
            "open": builtins.open,
            "fsync": os.fsync,
            "link": os.link,
            "rename": os.rename,
            "replace": os.replace,
            "unlink": os.unlink,
            "remove": os.remove,
        }
        builtins.open = self._open
        os.fsync = self._fsync
        os.link = self._link
        os.rename = self._rename
        os.replace = self._replace
        os.unlink = self._unlink
        os.remove = self._unlink
        return self

    def __exit__(self, *exc) -> None:
        builtins.open = self._saved["open"]
        os.fsync = self._saved["fsync"]
        os.link = self._saved["link"]
        os.rename = self._saved["rename"]
        os.replace = self._saved["replace"]
        os.unlink = self._saved["unlink"]
        os.remove = self._saved["remove"]
        return None
