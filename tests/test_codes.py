"""Compressed-codes tier: PQ encoder determinism, codebook manifest
round-trips, ADC kernel-vs-reference, exact-rerank bit-identity, the
batched ``read_rows`` gather, and the recall floor at shards 1-3
(docs/compressed_codes.md)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.codes import ProductQuantizer, rerank_exact
from repro.core.engine import plan as make_plan
from repro.core.tree import build_tree
from repro.data import synth
from repro.distributed.meshutil import local_mesh
from repro.index import Index
from repro.index.sharding import ShardedIndex
from repro.kernels.adcscan import adc_topk, adc_topk_ref

DIM = 32
N = 6000
SPLIT = 2600
K = 10
PROBES = 4


@pytest.fixture(scope="module")
def corpus():
    vecs_np, _ = synth.sample_descriptors(N, DIM, seed=0, n_centers=64)
    tree = build_tree(jnp.asarray(vecs_np), (8, 8),
                      key=jax.random.PRNGKey(1))
    mesh = local_mesh()
    q_np = vecs_np[:64] + np.random.default_rng(2).standard_normal(
        (64, DIM)
    ).astype(np.float32)
    return vecs_np, tree, mesh, q_np


@pytest.fixture(scope="module")
def coded_index(corpus, tmp_path_factory):
    """create -> append x2 -> enable_codes -> commit: the canonical
    codes-enabled grown index, durable so reopen tests can share it."""
    vecs_np, tree, mesh, _ = corpus
    d = str(tmp_path_factory.mktemp("codes") / "idx")
    idx = Index.create(tree, d, mesh=mesh)
    idx.append(vecs_np[:SPLIT])
    idx.append(vecs_np[SPLIT:])
    idx.enable_codes(m=8, bits=8, seed=0)
    idx.commit()
    return idx


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def test_pq_train_deterministic(corpus):
    vecs_np = corpus[0]
    a = ProductQuantizer.train(vecs_np, m=8, bits=8, seed=0)
    b = ProductQuantizer.train(vecs_np, m=8, bits=8, seed=0)
    assert a.codebooks.tobytes() == b.codebooks.tobytes()
    assert a.encode(vecs_np[:500]).tobytes() == \
        b.encode(vecs_np[:500]).tobytes()
    # a different seed trains different centroids (the sample moved)
    c = ProductQuantizer.train(vecs_np, m=8, bits=8, seed=1)
    assert a.codebooks.tobytes() != c.codebooks.tobytes()


def test_pq_json_roundtrip_bytes(corpus):
    vecs_np = corpus[0]
    pq = ProductQuantizer.train(vecs_np, m=8, bits=8, seed=0)
    back = ProductQuantizer.from_json(json.loads(json.dumps(pq.to_json())))
    assert back.codebooks.tobytes() == pq.codebooks.tobytes()
    assert back.m == pq.m and back.bits == pq.bits
    assert back.encode(vecs_np[:200]).tobytes() == \
        pq.encode(vecs_np[:200]).tobytes()


def test_pq_decode_reduces_error_and_lut_is_exact(corpus):
    vecs_np = corpus[0]
    pq = ProductQuantizer.train(vecs_np, m=8, bits=8, seed=0)
    codes = pq.encode(vecs_np)
    assert codes.dtype == np.uint8 and codes.shape == (N, 8)
    recon = pq.decode(codes)
    err = float(((recon - vecs_np) ** 2).sum(1).mean())
    baseline = float(((vecs_np - vecs_np.mean(0)) ** 2).sum(1).mean())
    assert err < 0.25 * baseline, (err, baseline)
    # lut[q, j, c] == ||q_j - codebook[j, c]||^2, and summing the coded
    # entries reproduces the decoded distance exactly
    q = vecs_np[:5]
    lut = pq.lut(q)
    dsub = DIM // 8
    for j in (0, 7):
        want = ((q[:, None, j * dsub:(j + 1) * dsub]
                 - pq.codebooks[None, j]) ** 2).sum(-1)
        np.testing.assert_allclose(lut[:, j], want, rtol=1e-5, atol=1e-3)
    adc = lut[np.arange(5)[:, None, None],
              np.arange(8)[None, None, :],
              codes[None, :50].astype(np.int64)].sum(-1)
    want = ((pq.decode(codes[:50])[None] - q[:, None]) ** 2).sum(-1)
    np.testing.assert_allclose(adc, want, rtol=1e-4, atol=1e-2)


# ---------------------------------------------------------------------------
# ADC kernel vs reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(300, 40, 4, 16), (513, 129, 8, 256)])
def test_adcscan_kernel_matches_ref(shape):
    P, Q, m, C = shape
    rng = np.random.default_rng(3)
    codes = rng.integers(0, C, (P, m)).astype(np.uint8)
    lut = rng.random((Q, m, C), dtype=np.float32)
    plf = rng.integers(0, 5, P).astype(np.int32)
    qlf = rng.integers(0, 5, Q).astype(np.int32)
    rd, ri = adc_topk_ref(jnp.asarray(codes), jnp.asarray(plf),
                          jnp.asarray(lut), jnp.asarray(qlf), 8)
    kd, ki = adc_topk(jnp.asarray(codes), jnp.asarray(plf),
                      jnp.asarray(lut), jnp.asarray(qlf),
                      k=8, impl="pallas")
    np.testing.assert_allclose(np.asarray(kd), np.asarray(rd),
                               rtol=1e-5, atol=1e-4)
    # ids must agree wherever the distance is unique (ties may reorder)
    rd, kd, ri, ki = map(np.asarray, (rd, kd, ri, ki))
    unique = np.ones_like(rd, bool)
    unique[:, 1:] &= rd[:, 1:] != rd[:, :-1]
    unique[:, :-1] &= rd[:, :-1] != rd[:, 1:]
    np.testing.assert_array_equal(ri[unique], ki[unique])


# ---------------------------------------------------------------------------
# exact rerank
# ---------------------------------------------------------------------------


def test_rerank_exact_bit_identical_to_bruteforce(corpus):
    vecs_np, _, _, q_np = corpus

    def read_rows(ids):
        return vecs_np[np.asarray(ids)]

    rng = np.random.default_rng(4)
    cand = rng.integers(0, N, (len(q_np), 24)).astype(np.int64)
    cand[:, 5] = cand[:, 3]   # duplicates must not double-count
    cand[:, -1] = -1          # empty slots must be ignored
    ids, dists = rerank_exact(read_rows, q_np, cand, K)
    for i in range(len(q_np)):
        u = np.unique(cand[i][cand[i] >= 0])
        d = ((vecs_np[u] - q_np[i]) ** 2).sum(1).astype(np.float32)
        order = np.lexsort((u, d))[:K]
        np.testing.assert_array_equal(ids[i], u[order])
        np.testing.assert_array_equal(dists[i], d[order])
    # fewer valid candidates than k: -1/inf padding, no crash
    ids, dists = rerank_exact(read_rows, q_np[:2],
                              np.array([[7, -1, -1], [-1, -1, -1]]), K)
    assert ids[0][0] == 7 and (ids[0][1:] == -1).all()
    assert (ids[1] == -1).all() and np.isinf(dists[1]).all()


def test_index_codes_search_matches_manual_rerank(coded_index, corpus):
    """The facade's codes path == ADC candidates + rerank_exact by hand:
    rerank ordering is exact (bit-identical) over the same candidates."""
    q_np = corpus[3]
    res = coded_index.search(q_np, k=K, probes=PROBES, layout="scan_codes")
    again = coded_index.search(q_np, k=K, probes=PROBES,
                               layout="scan_codes")
    np.testing.assert_array_equal(np.asarray(res.ids),
                                  np.asarray(again.ids))
    # rerank distances must be *exact* L2 against raw rows, not ADC
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    live = ids >= 0
    rows = coded_index.read_rows(ids[live].astype(np.int64))
    qexp = np.repeat(q_np, K, axis=0).reshape(len(q_np), K, DIM)[live]
    np.testing.assert_allclose(((rows - qexp) ** 2).sum(1), dists[live],
                               rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# lifecycle round-trips
# ---------------------------------------------------------------------------


def test_codebook_roundtrip_commit_open(coded_index, corpus):
    _, _, mesh, q_np = corpus
    reopened = Index.open(coded_index.directory, mesh=mesh)
    assert reopened.quantizer is not None
    assert reopened.quantizer.codebooks.tobytes() == \
        coded_index.quantizer.codebooks.tobytes()
    assert reopened.codes_stats() == coded_index.codes_stats()
    a = coded_index.search(q_np, k=K, probes=PROBES, layout="scan_codes")
    b = reopened.search(q_np, k=K, probes=PROBES, layout="scan_codes")
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))


def test_codes_survive_compact_and_delete(corpus, tmp_path):
    vecs_np, tree, mesh, q_np = corpus
    idx = Index.create(tree, str(tmp_path / "idx"), mesh=mesh)
    idx.append(vecs_np[:SPLIT])
    idx.append(vecs_np[SPLIT:])
    idx.enable_codes(m=8, bits=8, seed=0)
    idx.commit()
    before = idx.quantizer.codebooks.tobytes()
    idx.delete(np.arange(40))
    idx.compact()
    # same codebooks, survivors re-encoded, deleted ids gone
    assert idx.quantizer.codebooks.tobytes() == before
    assert idx.n_segments == 1
    res = idx.search(q_np, k=K, probes=PROBES, layout="scan_codes",
                     rerank=64)
    ids = np.asarray(res.ids)
    assert not np.isin(ids, np.arange(40)).any()
    reopened = Index.open(idx.directory, mesh=mesh)
    res2 = reopened.search(q_np, k=K, probes=PROBES, layout="scan_codes",
                           rerank=64)
    np.testing.assert_array_equal(ids, np.asarray(res2.ids))


def test_append_to_coded_index_encodes_new_segment(corpus, tmp_path):
    vecs_np, tree, mesh, q_np = corpus
    idx = Index.create(tree, str(tmp_path / "idx"), mesh=mesh)
    idx.append(vecs_np[:SPLIT])
    idx.enable_codes(m=8, bits=8, seed=0)
    idx.commit()
    idx.append(vecs_np[SPLIT:])
    idx.commit()
    reopened = Index.open(idx.directory, mesh=mesh)
    assert len(reopened._codes) == reopened.n_segments == 2
    a = idx.search(q_np, k=K, probes=PROBES, layout="scan_codes")
    b = reopened.search(q_np, k=K, probes=PROBES, layout="scan_codes")
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))


# ---------------------------------------------------------------------------
# batched read_rows
# ---------------------------------------------------------------------------


def test_read_rows_out_of_order_dup_cross_segment(coded_index, corpus):
    vecs_np = corpus[0]
    # out-of-order + duplicates + ids straddling both segments, one call
    ids = np.array([N - 1, 3, SPLIT - 1, 3, SPLIT, 0, N - 1, SPLIT + 7])
    got = coded_index.read_rows(ids)
    np.testing.assert_array_equal(got, vecs_np[ids])
    with pytest.raises(IndexError):
        coded_index.read_rows(np.array([0, N + 100]))
    with pytest.raises(IndexError):
        coded_index.read_rows(np.array([-2]))


# ---------------------------------------------------------------------------
# planning + recall floor
# ---------------------------------------------------------------------------


def test_auto_plan_prices_codes_per_shape():
    kw = dict(n_leaves=64, n_queries=64, n_shards=1, k=K, probes=PROBES,
              layout="auto", model="heuristic", dim=DIM,
              code_m=8, code_bits=8)
    assert make_plan(rows=40_000, **kw).layout == "scan_codes"
    assert make_plan(rows=1_000, **kw).layout == "point_major"
    # without a codes artifact the layout never enters the candidates
    dense = make_plan(rows=40_000, n_leaves=64, n_queries=64, n_shards=1,
                      k=K, probes=PROBES, layout="auto", model="heuristic")
    assert dense.layout != "scan_codes"


def test_scan_codes_without_quantizer_raises(corpus, tmp_path):
    vecs_np, tree, mesh, q_np = corpus
    idx = Index.create(tree, str(tmp_path / "idx"), mesh=mesh)
    idx.append(vecs_np[:SPLIT])
    idx.commit()
    with pytest.raises(ValueError, match="codes"):
        idx.search(q_np, k=K, layout="scan_codes")


@pytest.mark.parametrize("shards", [1, 2, 3])
def test_codes_recall_floor_and_shard_identity(coded_index, corpus, shards):
    """recall@k(scan_codes) >= 0.9 vs scan-exact at the same probes, and
    the sharded codes path is bit-identical to unsharded."""
    q_np = corpus[3]
    ref = coded_index.search(q_np, k=K, probes=PROBES,
                             layout="point_major")
    ref_ids = np.asarray(ref.ids)
    base = coded_index.search(q_np, k=K, probes=PROBES,
                              layout="scan_codes")
    sharded = ShardedIndex(coded_index, n_shards=shards)
    res = sharded.search(q_np, k=K, probes=PROBES, layout="scan_codes")
    np.testing.assert_array_equal(np.asarray(res.ids),
                                  np.asarray(base.ids))
    np.testing.assert_array_equal(np.asarray(res.dists),
                                  np.asarray(base.dists))
    ids = np.asarray(res.ids)
    recall = np.mean([
        len(set(ids[i][ids[i] >= 0]) & set(ref_ids[i][ref_ids[i] >= 0]))
        / K
        for i in range(len(q_np))
    ])
    assert recall >= 0.9, f"recall@{K} {recall:.3f} (shards={shards})"


def test_serving_session_codes_matches_facade(coded_index, corpus):
    from repro.serving import SearchSession

    _, _, mesh, q_np = corpus
    s = SearchSession(coded_index, mesh=mesh, k=K, probes=PROBES,
                      buckets=(64,))
    assert s.serving_layout == "scan_codes"
    s.warmup()
    ids, dists = s.search(q_np)
    assert s.steady_state_recompiles() == 0
    res = coded_index.search(q_np, k=K, probes=PROBES, layout="scan_codes")
    np.testing.assert_array_equal(ids, np.asarray(res.ids))
    np.testing.assert_array_equal(dists, np.asarray(res.dists))
